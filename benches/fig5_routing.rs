//! Fig. 5 — percentage of tokens routed to attention per layer.
//!
//! Trains DTRNet-BiLayer with the Eq. 7 penalty and reports the per-layer
//! attention fraction trajectory — the paper's headline "~10% of tokens"
//! and "remarkably uniform across DTR layers" claims — alongside MoD
//! (capacity-pinned ≈70%) and D-LLM (Ω-target) baselines.

use anyhow::Result;

use dtrnet::config::{LayerKind, TrainConfig};
use dtrnet::coordinator::ArtifactTrainer;
use dtrnet::data::{corpus, Dataset};
use dtrnet::runtime::Engine;
use dtrnet::util::bench::{print_table, write_results};
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;
use dtrnet::util::stats;

fn main() -> Result<()> {
    let steps: usize = std::env::var("DTRNET_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(80);
    let engine = Engine::new(&dtrnet::artifacts_dir())?;
    let mut results = Json::obj();
    let mut rows = Vec::new();

    for tag in ["tiny_dtr_bilayer", "tiny_mod", "tiny_dllm"] {
        let tcfg = TrainConfig {
            steps,
            peak_lr: 1e-3,
            log_every: usize::MAX,
            ..Default::default()
        };
        let mut trainer = ArtifactTrainer::new(&engine, tag, 0)?;
        let mut rng = Rng::new(7);
        let data = Dataset::new(
            corpus::markov_corpus(&mut rng, 256, 200 * trainer.seq, 12),
            trainer.seq,
        );
        let (train_data, eval_data) = data.split(0.1);
        let report = trainer.run(&tcfg, &train_data, None)?;

        // measured at inference over held-out data
        let fwd = format!("{tag}_fwd_b4s128");
        let res = dtrnet::eval::perplexity(&engine, &fwd, trainer.params(), &eval_data, 6)?;
        let fracs = res.routing.fractions();
        let cfg = &engine.manifest.get(&fwd)?.config;
        let routed_layers: Vec<usize> = cfg
            .layer_kinds()
            .iter()
            .enumerate()
            .filter(|(_, k)| !matches!(k, LayerKind::Dense))
            .map(|(i, _)| i)
            .collect();
        let mean = res.routing.mean_fraction(&routed_layers);
        let spread = {
            let v: Vec<f64> = routed_layers.iter().map(|&l| fracs[l]).collect();
            stats::stddev(&v)
        };
        println!(
            "[fig5] {tag:<18} routed-layer mean {:.1}% stddev {:.3} (train-end {:?})",
            mean * 100.0,
            spread,
            report.attn_frac
        );
        rows.push(
            std::iter::once(tag.to_string())
                .chain(fracs.iter().map(|f| format!("{:.0}%", f * 100.0)))
                .chain([format!("{:.1}%", mean * 100.0)])
                .collect::<Vec<_>>(),
        );
        results.set(
            tag,
            Json::from_pairs(vec![
                ("fractions", Json::arr_f64(&fracs)),
                ("routed_layer_mean", Json::Num(mean)),
                ("routed_layer_stddev", Json::Num(spread)),
                ("train_end_fracs", Json::arr_f64(&report.attn_frac)),
                ("steps", Json::Num(steps as f64)),
            ]),
        );
    }
    print_table(
        &format!("Fig. 5 — % tokens → attention per layer ({steps} steps)"),
        &["model", "L0", "L1", "L2", "L3", "L4", "L5", "routed-mean"],
        &rows,
    );
    write_results("fig5_routing.json", results);
    Ok(())
}
