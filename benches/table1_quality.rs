//! Tables 1–6 — quality at matched compute across the architecture matrix.
//!
//! Trains every variant the paper compares (dense, DTRNet Bi/Tri/LaterHalf,
//! DTRNet-Skip, MoD k=0.7/0.125, D-LLM Ω=0.85/0.55, expert-choice routing,
//! bypass-without-VO) under identical data/steps/schedule, then evaluates:
//!   * text ppl   — embedded-corpus held-out (the WIKI column's proxy)
//!   * lm ppl     — synthetic Markov held-out (the LMBD column's proxy)
//!   * FLOPs ratio — analytic model fed with the *measured* routing
//!     fractions (paper's "matched FLOPs" axis)
//!   * attn%      — mean attention routing over DTR layers (Fig. 5 number)
//!
//! Steps default to a smoke-scale 60 (≈8 min wall on 1 CPU core for the
//! full 11-variant matrix); the EXPERIMENTS.md reference run used
//! `DTRNET_BENCH_STEPS=300`. Quality *ordering* is the reproduction
//! target, not absolute perplexities (see DESIGN.md §Substitutions).

use anyhow::Result;

use dtrnet::config::{LayerKind, TrainConfig};
use dtrnet::coordinator::ArtifactTrainer;
use dtrnet::data::{corpus, Dataset};
use dtrnet::model::flops;
use dtrnet::runtime::Engine;
use dtrnet::tokenizer::{ByteTokenizer, Tokenizer};
use dtrnet::util::bench::{print_table, write_results};
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;

struct Row {
    tag: &'static str,
    flops_ratio: f64,
    text_ppl: f64,
    lm_ppl: f64,
    attn_pct: f64,
    final_loss: f64,
}

fn run_variant(engine: &Engine, tag: &'static str, steps: usize) -> Result<Row> {
    let tcfg = TrainConfig {
        steps,
        peak_lr: 1e-3,
        seed: 0,
        log_every: usize::MAX, // quiet
        ..Default::default()
    };
    let mut trainer = ArtifactTrainer::new(engine, tag, 0)?;
    let seq = trainer.seq;

    // identical data across variants: markov LM + embedded text mixture
    let mut rng = Rng::new(7);
    let lm = Dataset::new(corpus::markov_corpus(&mut rng, 256, 300 * seq, 12), seq);
    let text = Dataset::new(ByteTokenizer.encode(&corpus::embedded_corpus()), seq);
    let (lm_train, lm_eval) = lm.split(0.1);
    let (_, text_eval) = text.split(0.3);

    let report = trainer.run(&tcfg, &lm_train, None)?;

    let fwd = format!("{tag}_fwd_b4s128");
    let lm_res = dtrnet::eval::perplexity(engine, &fwd, trainer.params(), &lm_eval, 6)?;
    let text_res = dtrnet::eval::perplexity(engine, &fwd, trainer.params(), &text_eval, 4)?;

    // measured routing fractions → matched-FLOPs axis
    let cfg = &engine.manifest.get(&fwd)?.config;
    let fracs = lm_res.routing.fractions();
    let ratio = flops::flops_ratio_vs_dense(cfg, seq, Some(&fracs));
    let dtr_layers: Vec<usize> = cfg
        .layer_kinds()
        .iter()
        .enumerate()
        .filter(|(_, k)| !matches!(k, LayerKind::Dense))
        .map(|(i, _)| i)
        .collect();
    let attn_pct = lm_res.routing.mean_fraction(&dtr_layers) * 100.0;
    println!(
        "[table1] {tag:<24} loss {:.3} lm_ppl {:.2} text_ppl {:.2} flops {:.3} attn {:.0}%",
        report.final_loss, lm_res.ppl, text_res.ppl, ratio, attn_pct
    );
    Ok(Row {
        tag,
        flops_ratio: ratio,
        text_ppl: text_res.ppl,
        lm_ppl: lm_res.ppl,
        attn_pct,
        final_loss: report.final_loss,
    })
}

fn main() -> Result<()> {
    let steps: usize = std::env::var("DTRNET_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let engine = Engine::new(&dtrnet::artifacts_dir())?;

    // Table 1 main rows + Table 2/3/4/5/6 ablations.
    let tags: &[&'static str] = &[
        "tiny_dense",
        "tiny_dtr_bilayer",
        "tiny_dtr_trilayer",
        "tiny_dtr_laterhalf",
        "tiny_dtr_skip",       // Table 4
        "tiny_mod",            // k = 0.7
        "tiny_dllm",           // Ω = 0.85
        "tiny_dtr_bilayer_ec", // Table 2: expert-choice
        "tiny_dtr_bilayer_novo", // Table 6: bypass w/o W^V W^O
        "tiny_mod_k125",       // Table 5
        "tiny_dllm_o55",       // Table 5
    ];
    let mut rows = Vec::new();
    let mut out = Json::obj();
    for &tag in tags {
        match run_variant(&engine, tag, steps) {
            Ok(r) => {
                out.set(
                    tag,
                    Json::from_pairs(vec![
                        ("flops_ratio", Json::Num(r.flops_ratio)),
                        ("text_ppl", Json::Num(r.text_ppl)),
                        ("lm_ppl", Json::Num(r.lm_ppl)),
                        ("attn_pct", Json::Num(r.attn_pct)),
                        ("final_loss", Json::Num(r.final_loss)),
                        ("steps", Json::Num(steps as f64)),
                    ]),
                );
                rows.push(r);
            }
            Err(e) => println!("[table1] {tag} skipped: {e:#}"),
        }
    }
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.tag.to_string(),
                format!("{:.3}", r.flops_ratio),
                format!("{:.2}", r.text_ppl),
                format!("{:.2}", r.lm_ppl),
                format!("{:.0}%", r.attn_pct),
                format!("{:.3}", r.final_loss),
            ]
        })
        .collect();
    print_table(
        &format!("Table 1/2/3/4/5/6 — quality @ {steps} steps (tiny scale)"),
        &["model", "FLOPs", "TEXT ppl", "LM ppl", "attn%", "loss"],
        &table,
    );
    write_results("table1_quality.json", out);
    Ok(())
}
