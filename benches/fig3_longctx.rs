//! Fig. 3 — long-context extrapolation: ppl vs sequence length.
//!
//! Trains each variant briefly at seq 128, then evaluates answer-span
//! perplexity on needle/copy tasks at 2×–16× the training horizon through
//! the `long{S}` fwd artifacts (which bake YaRN-style RoPE scaling, as the
//! paper applies YaRN ×10 for its 20k evaluation). The reproduction
//! target is the *shape*: DTRNet stays below MoD/D-LLM as length grows.

use anyhow::Result;

use dtrnet::config::TrainConfig;
use dtrnet::coordinator::ArtifactTrainer;
use dtrnet::data::{corpus, longctx, Dataset};
use dtrnet::runtime::Engine;
use dtrnet::util::bench::{print_table, write_results};
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;

const LENGTHS: [usize; 4] = [256, 512, 1024, 2048];

fn main() -> Result<()> {
    let steps: usize = std::env::var("DTRNET_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    let n_items: usize = std::env::var("DTRNET_BENCH_ITEMS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let engine = Engine::new(&dtrnet::artifacts_dir())?;

    let mut results = Json::obj();
    results.set("lengths", Json::arr_f64(&LENGTHS.map(|n| n as f64)));
    let mut rows = Vec::new();
    for tag in ["tiny_dense", "tiny_dtr_bilayer", "tiny_mod", "tiny_dllm"] {
        // brief training at seq 128 (identical across variants)
        let tcfg = TrainConfig {
            steps,
            peak_lr: 1e-3,
            log_every: usize::MAX,
            ..Default::default()
        };
        let mut trainer = ArtifactTrainer::new(&engine, tag, 0)?;
        let mut rng = Rng::new(7);
        let data = Dataset::new(
            corpus::markov_corpus(&mut rng, 256, 200 * trainer.seq, 12),
            trainer.seq,
        );
        trainer.run(&tcfg, &data, None)?;

        let mut ppls = Vec::new();
        for &len in &LENGTHS {
            let artifact = format!("{tag}_long{len}_fwd");
            let mut task_rng = Rng::new(100 + len as u64);
            let items: Vec<_> = (0..n_items)
                .map(|i| {
                    if i % 2 == 0 {
                        longctx::needle_task(&mut task_rng, 256, len, 16)
                    } else {
                        longctx::copy_task(&mut task_rng, 256, len, 32)
                    }
                })
                .collect();
            let ppl =
                dtrnet::eval::span_perplexity(&engine, &artifact, trainer.params(), &items)?;
            ppls.push(ppl);
        }
        println!(
            "[fig3] {tag:<18} span-ppl {:?}",
            ppls.iter().map(|p| (p * 100.0).round() / 100.0).collect::<Vec<_>>()
        );
        rows.push(
            std::iter::once(tag.to_string())
                .chain(ppls.iter().map(|p| format!("{p:.1}")))
                .collect::<Vec<_>>(),
        );
        results.set(tag, Json::arr_f64(&ppls));
    }
    print_table(
        &format!("Fig. 3 — answer-span ppl vs length ({steps} train steps)"),
        &["model", "256", "512", "1024", "2048"],
        &rows,
    );
    write_results("fig3_longctx.json", results);
    Ok(())
}
