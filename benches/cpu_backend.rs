//! CPU-backend hot paths: forward tokens/s and decode steps/s for dense
//! vs DTRNet at testbed scale — the native-path counterpart of
//! `runtime_hotpath.rs` (which measures the PJRT boundary instead).
//!
//! The paper-relevant readout: DTRNet forward cost sits below dense at
//! the same shape because only the routed fraction pays quadratic
//! attention — here measured end-to-end, not analytically.

use anyhow::Result;

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::coordinator::SamplingParams;
use dtrnet::runtime::{Backend, CpuBackend, Tensor};
use dtrnet::util::bench::{bench, print_table, write_results};
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;

fn main() -> Result<()> {
    let mut results = Json::obj();
    let mut rows = Vec::new();
    let (b, s) = (2usize, 64usize);
    // Backends share the process-wide kernel pool (bit-identical at any
    // thread count; see DESIGN.md §Benchmarking).
    let threads = dtrnet::util::threadpool::global().threads();
    println!("[cpu_backend] kernel threads: {threads}");
    results.set("threads", Json::Num(threads as f64));

    for (name, variant) in [
        ("dense", Variant::Dense),
        ("dtr_bilayer", Variant::DtrBilayer),
        ("dtr_skip", Variant::DtrSkip),
    ] {
        let cfg = ModelConfig::preset("xs", variant);
        let backend = CpuBackend::init(&cfg, 0)?;
        let tokens = Tensor::i32(
            vec![b, s],
            (0..(b * s) as i32).map(|i| i * 7 % 256).collect(),
        );

        let fwd = bench(&format!("fwd_{name}"), 2, 8, || {
            backend.forward(&tokens).unwrap();
        });
        let tok_per_s = (b * s) as f64 / fwd.mean_s;

        let mut rng = Rng::new(1);
        let prompt: Vec<i32> = (0..16).map(|_| rng.below(256) as i32).collect();
        let dec = bench(&format!("decode_{name}"), 1, 4, || {
            let mut r = Rng::new(2);
            backend
                .generate(&prompt, 32, &SamplingParams::greedy(), &mut r)
                .unwrap();
        });
        let steps_per_s = 32.0 / dec.mean_s;

        rows.push(vec![
            name.to_string(),
            format!("{:.2}", fwd.mean_s * 1e3),
            format!("{:.0}", tok_per_s),
            format!("{:.0}", steps_per_s),
        ]);
        results.set(
            name,
            Json::from_pairs(vec![
                ("fwd_ms", Json::Num(fwd.mean_s * 1e3)),
                ("fwd_tokens_per_s", Json::Num(tok_per_s)),
                ("decode_steps_per_s", Json::Num(steps_per_s)),
            ]),
        );
    }

    print_table(
        &format!("CPU backend hot paths (xs, B={b} S={s})"),
        &["variant", "fwd ms", "fwd tok/s", "decode steps/s"],
        &rows,
    );
    write_results("cpu_backend.json", results);
    Ok(())
}
