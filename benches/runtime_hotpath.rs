//! Runtime hot-path microbenchmarks (the §Perf L3 profile).
//!
//! Times the building blocks the coordinator composes: literal packing,
//! artifact execution (fwd / train_step / decode), and the end-to-end
//! decode iteration — isolating coordinator overhead from XLA compute so
//! the perf pass can see which side owns each millisecond.

use anyhow::Result;

use dtrnet::runtime::{Engine, Tensor};
use dtrnet::util::bench::{bench_for, write_results, Measurement};
use dtrnet::util::json::Json;

fn main() -> Result<()> {
    let engine = Engine::new(&dtrnet::artifacts_dir())?;
    let mut ms: Vec<Measurement> = Vec::new();

    // -- literal packing overhead (pure coordinator cost)
    let big = Tensor::f32(vec![6, 4, 512, 4, 32], vec![0.0; 6 * 4 * 512 * 4 * 32]);
    ms.push(bench_for("pack_literal_12MB", 0.5, || {
        let _ = big.to_literal().unwrap();
    }));
    let lit = big.to_literal()?;
    ms.push(bench_for("unpack_literal_12MB", 0.5, || {
        let _ = Tensor::from_literal(&lit).unwrap();
    }));

    // -- xs fwd execution (B=2, S=64)
    let init = engine.load("xs_dtr_bilayer_init")?;
    let params = init.call_literals(&[Tensor::scalar_i32(0).to_literal()?])?;
    let fwd = engine.load("xs_dtr_bilayer_fwd_b2s64")?;
    let tok = Tensor::i32(vec![2, 64], vec![1; 128]).to_literal()?;
    ms.push(bench_for("xs_fwd_b2s64", 1.0, || {
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&tok);
        let _ = fwd.call_literals_ref(&inputs).unwrap();
    }));

    // -- tiny fwd (B=4, S=128): the table-1 eval path
    let init_t = engine.load("tiny_dtr_bilayer_init")?;
    let params_t = init_t.call_literals(&[Tensor::scalar_i32(0).to_literal()?])?;
    let fwd_t = engine.load("tiny_dtr_bilayer_fwd_b4s128")?;
    let tok_t = Tensor::i32(vec![4, 128], vec![1; 512]).to_literal()?;
    ms.push(bench_for("tiny_fwd_b4s128", 1.5, || {
        let mut inputs: Vec<&xla::Literal> = params_t.iter().collect();
        inputs.push(&tok_t);
        let _ = fwd_t.call_literals_ref(&inputs).unwrap();
    }));

    // -- tiny train step (fwd+bwd+AdamW, B=4 S=128)
    let tinit = engine.load("tiny_dtr_bilayer_train_init")?;
    let state = tinit.call_literals(&[Tensor::scalar_i32(0).to_literal()?])?;
    let tstep = engine.load("tiny_dtr_bilayer_train_step")?;
    let step_l = Tensor::scalar_f32(1.0).to_literal()?;
    let lr_l = Tensor::scalar_f32(1e-3).to_literal()?;
    let seed_l = Tensor::scalar_i32(0).to_literal()?;
    ms.push(bench_for("tiny_train_step_b4s128", 2.0, || {
        let mut inputs: Vec<&xla::Literal> = state.iter().collect();
        inputs.push(&tok_t);
        inputs.push(&step_l);
        inputs.push(&lr_l);
        inputs.push(&seed_l);
        let _ = tstep.call_literals_ref(&inputs).unwrap();
    }));

    // -- serving decode step (B=4, M=512) with resident cache literals
    let dec = engine.load("tiny_dtr_bilayer_serve_decode_b4m512")?;
    let spec = &dec.spec;
    let nparams = spec.nparams.unwrap();
    let cache_shape = spec.inputs[nparams].shape.clone();
    let ck = Tensor::zeros_f32(cache_shape.clone()).to_literal()?;
    let cv = Tensor::zeros_f32(cache_shape.clone()).to_literal()?;
    let lens = Tensor::zeros_i32(vec![cache_shape[0], cache_shape[1]]).to_literal()?;
    let toks = Tensor::i32(vec![4], vec![1, 2, 3, 4]).to_literal()?;
    let pos = Tensor::i32(vec![4], vec![0, 0, 0, 0]).to_literal()?;
    ms.push(bench_for("tiny_decode_step_b4m512", 1.5, || {
        let mut inputs: Vec<&xla::Literal> = params_t.iter().collect();
        inputs.push(&ck);
        inputs.push(&cv);
        inputs.push(&lens);
        inputs.push(&toks);
        inputs.push(&pos);
        let _ = dec.call_literals_ref(&inputs).unwrap();
    }));

    // -- compile cost report (one-time, amortized)
    println!("\ncompile times (one-time): fwd {:.2}s train {:.2}s decode {:.2}s",
             fwd_t.compile_s, tstep.compile_s, dec.compile_s);

    let out = Json::Obj(
        ms.iter()
            .map(|m| (m.name.clone(), m.to_json()))
            .collect(),
    );
    write_results("runtime_hotpath.json", out);
    Ok(())
}
