//! Coordinator throughput: serving engine end-to-end + host-side pieces.
//!
//! (a) serving tokens/s for dense vs DTRNet at several batch fills — the
//!     paper's "efficiency gains scale with sequence length / batching"
//!     story measured on this testbed;
//! (b) microbenches of the pure-host components (batcher, KV pool,
//!     routing stats) proving the coordinator is not the bottleneck
//!     (§Perf L3 target).

use anyhow::Result;
use std::time::Instant;

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::coordinator::{Batcher, KvPool, Request, RoutingStats, ServeEngine};
use dtrnet::runtime::{Engine, Tensor};
use dtrnet::util::bench::{bench, print_table, write_results};
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;

fn serving(engine: &Engine) -> Result<Json> {
    let mut out = Json::obj();
    let mut rows = Vec::new();
    for tag in ["tiny_dense", "tiny_dtr_bilayer"] {
        for n_req in [1usize, 4, 8] {
            let decode = format!("{tag}_serve_decode_b4m512");
            let init = engine.load(&format!("{tag}_init"))?;
            let params = init.call_literals(&[Tensor::scalar_i32(0).to_literal()?])?;
            let mut srv = ServeEngine::new(engine, &decode, params, 16)?;
            let mut rng = Rng::new(2);
            let now = Instant::now();
            for i in 0..n_req {
                srv.submit(Request {
                    id: i as u64,
                    prompt: (0..32).map(|_| rng.below(256) as i32).collect(),
                    max_new_tokens: 48,
                    temperature: 0.0,
                    arrival: now,
                });
            }
            let rep = srv.run_to_completion(1_000_000)?;
            rows.push(vec![
                tag.to_string(),
                n_req.to_string(),
                format!("{:.1}", rep.tokens_per_s),
                format!("{:.2}", rep.decode_step_ms_p50),
                format!("{:.2}", rep.ttft_ms_p50),
            ]);
            out.set(
                &format!("{tag}_r{n_req}"),
                Json::from_pairs(vec![
                    ("tokens_per_s", Json::Num(rep.tokens_per_s)),
                    ("step_ms_p50", Json::Num(rep.decode_step_ms_p50)),
                    ("ttft_ms_p50", Json::Num(rep.ttft_ms_p50)),
                ]),
            );
        }
    }
    print_table(
        "serving throughput (decode B=4 slots)",
        &["model", "reqs", "tok/s", "step ms", "ttft ms"],
        &rows,
    );
    Ok(out)
}

fn host_micro() -> Json {
    let mut out = Json::obj();
    // batcher admit/advance cycle
    let m = bench("batcher_admit_advance_1k_reqs", 2, 20, || {
        let mut b = Batcher::new(8, 2048);
        let now = Instant::now();
        for i in 0..1000u64 {
            b.submit(Request {
                id: i,
                prompt: vec![1, 2, 3, 4],
                max_new_tokens: 4,
                temperature: 0.0,
                arrival: now,
            });
        }
        while !b.idle() {
            b.admit();
            for s in 0..8 {
                if b.active[s].is_some() {
                    b.advance(s, 1, now);
                }
            }
        }
        assert_eq!(b.completed.len(), 1000);
    });
    out.set("batcher", m.to_json());

    // KV pool append/release
    let cfg = ModelConfig::preset("tiny", Variant::DtrBilayer);
    let m = bench("kv_pool_100k_appends", 2, 10, || {
        let mut p = KvPool::new(&cfg, 8, 16, usize::MAX / 2);
        let routed = [true, false, true, false, true, true];
        for i in 0..100_000 {
            p.append(i % 8, &routed);
        }
        for s in 0..8 {
            p.release(s);
        }
    });
    out.set("kv_pool", m.to_json());

    // routing stats ingestion (fwd-eval path)
    let route = vec![1.0f32; 4 * 6 * 128];
    let m = bench("routing_stats_record_4x6x128", 2, 200, || {
        let mut s = RoutingStats::new(6);
        s.record_route_tensor(&route, 4, 6, 128);
    });
    out.set("routing_stats", m.to_json());
    out
}

fn main() -> Result<()> {
    let mut results = Json::obj();
    results.set("host_micro", host_micro());
    match Engine::new(&dtrnet::artifacts_dir()) {
        Ok(engine) => results.set("serving", serving(&engine)?),
        Err(e) => println!("[coordinator_throughput] no artifacts: {e:#}"),
    }
    write_results("coordinator_throughput.json", results);
    Ok(())
}
