//! Coordinator throughput: serving engine end-to-end + host-side pieces.
//!
//! (a) the backend-generic continuous-batching engine on the native CPU
//!     backend: serving tokens/s for dense vs DTRNet across batch fills
//!     and prefill modes — the paper's "efficiency gains scale with
//!     batching" story measured with no artifacts and no XLA;
//! (b) microbenches of the pure-host components (batcher, KV pool,
//!     routing stats) proving the coordinator is not the bottleneck
//!     (§Perf L3 target);
//! (c) with the `pjrt` feature + AOT artifacts present: the artifact
//!     decode engine, for apples-to-apples backend comparison.
//!
//! Pass `--test` (e.g. `cargo bench --bench coordinator_throughput --
//! --test`) for a seconds-scale CI smoke configuration.

use anyhow::Result;
use std::time::Instant;

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::coordinator::{
    generate_workload, Batcher, KvPool, PrefillMode, Request, RoutingStats, Server,
    ServerConfig, WorkloadSpec,
};
use dtrnet::runtime::CpuBackend;
use dtrnet::util::bench::{bench, print_table, write_results};
use dtrnet::util::json::Json;

fn cpu_serving(quick: bool) -> Result<Json> {
    let mut out = Json::obj();
    let mut rows = Vec::new();
    let (preset, n_req) = if quick { ("xs", 4) } else { ("tiny", 16) };
    let slot_fills: &[usize] = if quick { &[2] } else { &[1, 4, 8] };
    for variant in [Variant::Dense, Variant::DtrBilayer] {
        let cfg = ModelConfig::preset(preset, variant);
        let backend = CpuBackend::init(&cfg, 0)?;
        for &slots in slot_fills {
            for (mode_name, prefill) in [
                ("chunked", PrefillMode::Chunked(32)),
                ("stepwise", PrefillMode::Decode),
            ] {
                let scfg = ServerConfig {
                    slots,
                    prefill,
                    ..Default::default()
                };
                let mut srv = Server::new(&backend, scfg)?;
                let spec = WorkloadSpec {
                    n_requests: n_req,
                    arrival_rate: 10_000.0,
                    prompt_len_mean: 12,
                    prompt_len_max: 32,
                    gen_len_mean: if quick { 8 } else { 24 },
                    gen_len_max: if quick { 16 } else { 48 },
                    temperature: 0.0,
                    vocab: cfg.vocab_size,
                };
                let trace = generate_workload(&spec, 2);
                let rep = srv.run_workload(&trace, 10_000_000)?;
                assert_eq!(rep.completed + rep.evicted, n_req, "requests lost");
                let key = format!("{}_{}_s{}", variant.as_str(), mode_name, slots);
                rows.push(vec![
                    variant.as_str().to_string(),
                    slots.to_string(),
                    mode_name.to_string(),
                    format!("{:.1}", rep.tokens_per_s),
                    format!("{:.3}", rep.decode_step_ms_p50),
                    format!("{:.2}", rep.ttft_ms_p50),
                    format!("{:.2}", rep.batch_occupancy),
                    format!("{}/{}", rep.pool.pages_peak, rep.dense_pages_peak),
                ]);
                out.set(
                    &key,
                    Json::from_pairs(vec![
                        ("tokens_per_s", Json::Num(rep.tokens_per_s)),
                        ("step_ms_p50", Json::Num(rep.decode_step_ms_p50)),
                        ("ttft_ms_p50", Json::Num(rep.ttft_ms_p50)),
                        ("occupancy", Json::Num(rep.batch_occupancy)),
                        ("kv_pages_peak", Json::Num(rep.pool.pages_peak as f64)),
                        ("dense_pages_peak", Json::Num(rep.dense_pages_peak as f64)),
                        ("kv_savings_ratio", Json::Num(rep.kv_savings_ratio)),
                    ]),
                );
            }
        }
    }
    print_table(
        &format!("cpu serving throughput ({preset}, {n_req} requests)"),
        &[
            "model", "slots", "prefill", "tok/s", "step ms", "ttft ms", "occup",
            "kv/dense pages",
        ],
        &rows,
    );
    Ok(out)
}

fn host_micro(quick: bool) -> Json {
    let mut out = Json::obj();
    let iters = if quick { 3 } else { 20 };
    // batcher admit/advance cycle
    let m = bench("batcher_admit_advance_1k_reqs", 2, iters, || {
        let mut b = Batcher::new(8, 2048);
        let now = Instant::now();
        for i in 0..1000u64 {
            b.submit(Request {
                id: i,
                prompt: vec![1, 2, 3, 4],
                max_new_tokens: 4,
                temperature: 0.0,
                arrival: now,
            });
        }
        while !b.idle() {
            b.admit();
            for s in 0..8 {
                if b.active[s].is_some() {
                    b.advance(s, 1, now);
                }
            }
        }
        assert_eq!(b.completed.len(), 1000);
    });
    out.set("batcher", m.to_json());

    // KV pool append/release
    let cfg = ModelConfig::preset("tiny", Variant::DtrBilayer);
    let m = bench("kv_pool_100k_appends", 2, iters.min(10), || {
        let mut p = KvPool::new(&cfg, 8, 16, usize::MAX / 2);
        let routed = [true, false, true, false, true, true];
        for i in 0..100_000 {
            p.append(i % 8, &routed);
        }
        for s in 0..8 {
            p.release(s);
        }
    });
    out.set("kv_pool", m.to_json());

    // routing stats ingestion (fwd-eval path)
    let route = vec![1.0f32; 4 * 6 * 128];
    let stats_iters = if quick { 10 } else { 200 };
    let m = bench("routing_stats_record_4x6x128", 2, stats_iters, || {
        let mut s = RoutingStats::new(6);
        s.record_route_tensor(&route, 4, 6, 128);
    });
    out.set("routing_stats", m.to_json());
    out
}

#[cfg(feature = "pjrt")]
fn artifact_serving(engine: &dtrnet::runtime::Engine) -> Result<Json> {
    use dtrnet::coordinator::ServeEngine;
    use dtrnet::runtime::Tensor;
    use dtrnet::util::rng::Rng;

    let mut out = Json::obj();
    let mut rows = Vec::new();
    for tag in ["tiny_dense", "tiny_dtr_bilayer"] {
        for n_req in [1usize, 4, 8] {
            let decode = format!("{tag}_serve_decode_b4m512");
            let init = engine.load(&format!("{tag}_init"))?;
            let params = init.call_literals(&[Tensor::scalar_i32(0).to_literal()?])?;
            let mut srv = ServeEngine::new(engine, &decode, params, 16)?;
            let mut rng = Rng::new(2);
            let now = Instant::now();
            for i in 0..n_req {
                srv.submit(Request {
                    id: i as u64,
                    prompt: (0..32).map(|_| rng.below(256) as i32).collect(),
                    max_new_tokens: 48,
                    temperature: 0.0,
                    arrival: now,
                });
            }
            let rep = srv.run_to_completion(1_000_000)?;
            rows.push(vec![
                tag.to_string(),
                n_req.to_string(),
                format!("{:.1}", rep.tokens_per_s),
                format!("{:.2}", rep.decode_step_ms_p50),
                format!("{:.2}", rep.ttft_ms_p50),
            ]);
            out.set(
                &format!("{tag}_r{n_req}"),
                Json::from_pairs(vec![
                    ("tokens_per_s", Json::Num(rep.tokens_per_s)),
                    ("step_ms_p50", Json::Num(rep.decode_step_ms_p50)),
                    ("ttft_ms_p50", Json::Num(rep.ttft_ms_p50)),
                ]),
            );
        }
    }
    print_table(
        "artifact serving throughput (decode B=4 slots)",
        &["model", "reqs", "tok/s", "step ms", "ttft ms"],
        &rows,
    );
    Ok(out)
}

fn main() -> Result<()> {
    let quick = std::env::args().skip(1).any(|a| a == "--test");
    let mut results = Json::obj();
    // Backends share the process-wide kernel pool (bit-identical at any
    // thread count); `dtrnet bench` sweeps thread counts explicitly.
    let threads = dtrnet::util::threadpool::global().threads();
    println!("[coordinator_throughput] kernel threads: {threads}");
    results.set("threads", Json::Num(threads as f64));
    results.set("host_micro", host_micro(quick));
    results.set("cpu_serving", cpu_serving(quick)?);
    #[cfg(feature = "pjrt")]
    {
        match dtrnet::runtime::Engine::new(&dtrnet::artifacts_dir()) {
            Ok(engine) => results.set("artifact_serving", artifact_serving(&engine)?),
            Err(e) => println!("[coordinator_throughput] no artifacts: {e:#}"),
        }
    }
    write_results("coordinator_throughput.json", results);
    Ok(())
}
