//! Fig. 6 — KV-cache memory vs sequence length.
//!
//! Two halves:
//!  (a) analytical curve at paper scale (what Fig. 6 plots), and
//!  (b) *measured* allocation from the routing-aware paged pool while the
//!      serving engine decodes real sequences — the "true memory savings"
//!      claim made concrete. D-LLM is charged dense bytes (the paper notes
//!      its eviction is masking, not deallocation).

use anyhow::Result;

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::coordinator::{Request, ServeEngine};
use dtrnet::model::memory;
use dtrnet::runtime::{Engine, Tensor};
use dtrnet::util::bench::{print_table, write_results};
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;

fn analytic() -> Json {
    let lengths = [1024usize, 2048, 4096, 8192, 16384, 20480];
    let variants = [
        ("dense", Variant::Dense),
        ("dtr_bilayer", Variant::DtrBilayer),
        ("mod", Variant::Mod),
        ("dllm", Variant::Dllm),
    ];
    let mut rows = Vec::new();
    let mut out = Json::obj();
    out.set("lengths", Json::arr_f64(&lengths.map(|n| n as f64)));
    for (name, v) in variants {
        let cfg = ModelConfig::preset("smollm-1b3", v);
        let mb: Vec<f64> = lengths
            .iter()
            .map(|&n| memory::kv_bytes(&cfg, n, None).allocated_bytes / 1e6)
            .collect();
        rows.push(
            std::iter::once(name.to_string())
                .chain(mb.iter().map(|m| format!("{m:.0}")))
                .collect(),
        );
        out.set(name, Json::arr_f64(&mb));
    }
    print_table(
        "Fig. 6a — analytical KV cache MB (smollm-1b3)",
        &["variant", "1k", "2k", "4k", "8k", "16k", "20k"],
        &rows,
    );
    // shape checks
    let dtr = ModelConfig::preset("smollm-1b3", Variant::DtrBilayer);
    let dense = ModelConfig::preset("smollm-1b3", Variant::Dense);
    let dllm = ModelConfig::preset("smollm-1b3", Variant::Dllm);
    assert!(memory::kv_bytes(&dtr, 8192, None).ratio() < 0.65);
    assert!((memory::kv_bytes(&dllm, 8192, None).allocated_bytes
        - memory::kv_bytes(&dense, 8192, None).allocated_bytes)
        .abs()
        < 1.0);
    out
}

fn measured(engine: &Engine) -> Result<Json> {
    let mut out = Json::obj();
    let mut rows = Vec::new();
    for tag in ["tiny_dense", "tiny_dtr_bilayer"] {
        let decode = format!("{tag}_serve_decode_b4m512");
        let init = engine.load(&format!("{tag}_init"))?;
        let params = init.call_literals(&[Tensor::scalar_i32(0).to_literal()?])?;
        let mut srv = ServeEngine::new(engine, &decode, params, 16)?;
        let mut rng = Rng::new(5);
        let now = std::time::Instant::now();
        for i in 0..4u64 {
            srv.submit(Request {
                id: i,
                prompt: (0..64).map(|_| rng.below(256) as i32).collect(),
                max_new_tokens: 64,
                temperature: 0.0,
                arrival: now,
            });
        }
        let rep = srv.run_to_completion(100_000)?;
        rows.push(vec![
            tag.to_string(),
            format!("{}", rep.pool.tokens_seen),
            format!("{}", rep.pool.tokens_cached),
            format!("{:.3}", rep.kv_savings_ratio),
            format!("{:.0}", rep.pool.bytes_peak as f64 / 1024.0),
        ]);
        out.set(
            tag,
            Json::from_pairs(vec![
                ("tokens_seen", Json::Num(rep.pool.tokens_seen as f64)),
                ("tokens_cached", Json::Num(rep.pool.tokens_cached as f64)),
                ("savings_ratio", Json::Num(rep.kv_savings_ratio)),
                ("bytes_peak", Json::Num(rep.pool.bytes_peak as f64)),
            ]),
        );
    }
    print_table(
        "Fig. 6b — measured paged-pool allocation (tiny, untrained routers)",
        &["model", "tokens", "cached", "ratio", "peak KiB"],
        &rows,
    );
    Ok(out)
}

fn main() {
    let mut results = Json::obj();
    results.set("analytic_smollm_1b3", analytic());
    match Engine::new(&dtrnet::artifacts_dir()) {
        Ok(engine) => match measured(&engine) {
            Ok(j) => results.set("measured_tiny", j),
            Err(e) => println!("[fig6] measured half skipped: {e:#}"),
        },
        Err(e) => println!("[fig6] no artifacts ({e:#}); analytic half only"),
    }
    write_results("fig6_kv_memory.json", results);
}
