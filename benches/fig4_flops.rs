//! Fig. 4 — theoretical FLOPs ratio vs sequence length.
//!
//! Regenerates the paper's figure analytically at paper scale
//! (smollm-1b3) and testbed scale (tiny): FLOPs ratio relative to the
//! dense Transformer for DTRNet / MoD / D-LLM as context grows to 20k.
//! Paper reference points: DTRNet 0.785 @20k, MoD/D-LLM ≈ 0.82 @20k.

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::model::flops;
use dtrnet::util::bench::{print_table, write_results};
use dtrnet::util::json::Json;

fn series(preset: &str) -> (Vec<Vec<String>>, Json) {
    let lengths = [2048usize, 4096, 8192, 12288, 16384, 20480];
    let variants = [
        ("dtr_bilayer", Variant::DtrBilayer),
        ("dtr_trilayer", Variant::DtrTrilayer),
        ("dtr_skip", Variant::DtrSkip),
        ("mod", Variant::Mod),
        ("dllm", Variant::Dllm),
    ];
    let mut rows = Vec::new();
    let mut out = Json::obj();
    out.set("lengths", Json::arr_f64(&lengths.map(|n| n as f64)));
    for (name, v) in variants {
        let cfg = ModelConfig::preset(preset, v);
        let vals: Vec<f64> = lengths
            .iter()
            .map(|&n| flops::flops_ratio_vs_dense(&cfg, n, None))
            .collect();
        rows.push(
            std::iter::once(name.to_string())
                .chain(vals.iter().map(|r| format!("{r:.4}")))
                .collect(),
        );
        out.set(name, Json::arr_f64(&vals));
    }
    (rows, out)
}

fn main() {
    let mut results = Json::obj();
    for preset in ["smollm-1b3", "smollm-360m", "tiny"] {
        let (rows, j) = series(preset);
        print_table(
            &format!("Fig. 4 — FLOPs ratio vs dense ({preset})"),
            &["variant", "2k", "4k", "8k", "12k", "16k", "20k"],
            &rows,
        );
        results.set(preset, j);
    }

    // Shape assertions (the paper's qualitative claims):
    let dtr = ModelConfig::preset("smollm-1b3", Variant::DtrBilayer);
    let m = ModelConfig::preset("smollm-1b3", Variant::Mod);
    let d = ModelConfig::preset("smollm-1b3", Variant::Dllm);
    let r_dtr = flops::flops_ratio_vs_dense(&dtr, 20480, None);
    let r_mod = flops::flops_ratio_vs_dense(&m, 20480, None);
    let r_dllm = flops::flops_ratio_vs_dense(&d, 20480, None);
    assert!(r_dtr < r_mod && r_dtr < r_dllm,
            "DTRNet must be cheapest at 20k: {r_dtr} vs {r_mod}/{r_dllm}");
    assert!(flops::flops_ratio_vs_dense(&dtr, 2048, None) > r_dtr,
            "ratio must decline with length");
    println!(
        "\npaper check @20k: DTRNet {r_dtr:.3} (paper 0.785), MoD {r_mod:.3} \
         (paper ~0.82), D-LLM {r_dllm:.3} (paper ~0.82)"
    );
    results.set(
        "paper_check",
        Json::from_pairs(vec![
            ("dtr_20k", Json::Num(r_dtr)),
            ("mod_20k", Json::Num(r_mod)),
            ("dllm_20k", Json::Num(r_dllm)),
        ]),
    );
    write_results("fig4_flops.json", results);
}
