//! Quickstart for the native CPU backend: initialize DTRNet, run a
//! forward pass, inspect routing, decode — no artifacts, no XLA, runs on
//! any machine. The 60-second tour of the backend-agnostic public API.
//!
//! ```bash
//! cargo run --release --example cpu_quickstart
//! ```

use anyhow::Result;

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::coordinator::{RoutingStats, SamplingParams};
use dtrnet::model::{flops, memory};
use dtrnet::runtime::{Backend, CpuBackend, Tensor};
use dtrnet::util::rng::Rng;

fn main() -> Result<()> {
    // 1. Build the DTRNet-BiLayer model on the native CPU backend
    //    (seeded, deterministic — no Python in the loop at all).
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let backend = CpuBackend::init(&cfg, 42)?;
    println!(
        "backend: {} — {} layout {} ({} params)",
        backend.name(),
        cfg.name,
        cfg.layout_string(),
        cfg.param_count()
    );

    // 2. Forward a batch of token ids and read the routing telemetry.
    let (b, s) = (2usize, 64usize);
    let tokens: Vec<i32> = (0..(b * s) as i32).map(|i| i * 7 % 256).collect();
    let out = backend.forward(&Tensor::i32(vec![b, s], tokens))?;
    println!("logits shape {:?}", out.logits.shape);

    let mut stats = RoutingStats::new(cfg.n_layers);
    stats.record_route_tensor(out.route.as_f32(), b, cfg.n_layers, s);
    println!("per-layer attention fractions: {:?}", stats.fractions());

    // 3. Greedy decode with the routing-aware KV state: per DTR layer,
    //    only routed tokens are cached (the Fig. 6 memory story).
    let mut rng = Rng::new(7);
    let prompt: Vec<i32> = (0..12).map(|_| rng.below(256) as i32).collect();
    let gen = backend.generate(&prompt, 24, &SamplingParams::greedy(), &mut rng)?;
    println!("generated {} tokens: {:?}", gen.tokens.len(), gen.tokens);
    println!("decode-time attention fractions: {:?}", gen.attn_frac);

    // 4. The analytical models (Figs. 4/6) at paper scale, for context.
    let paper = ModelConfig::preset("smollm-1b3", Variant::DtrBilayer);
    println!(
        "smollm-1b3 @20k: FLOPs ratio vs dense {:.3}, KV bytes ratio {:.3}",
        flops::flops_ratio_vs_dense(&paper, 20480, None),
        memory::kv_bytes(&paper, 20480, None).ratio()
    );
    Ok(())
}
