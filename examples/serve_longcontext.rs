//! Long-context serving demo: continuous batching + routing-aware KV pool.
//!
//! Loads the tiny DTRNet serving artifact (decode B=4, max_kv=512), submits
//! a Poisson stream of long-prompt requests, and reports throughput,
//! latency percentiles, per-layer routing and the *measured* KV savings —
//! the serving-side realization of the paper's Figs. 5/6.
//!
//! ```bash
//! cargo run --release --example serve_longcontext -- --requests 12 --prompt 96 --gen 64
//! ```

use anyhow::Result;

use dtrnet::coordinator::{Request, ServeEngine};
use dtrnet::runtime::{Engine, Tensor};
use dtrnet::util::bench::{print_table, write_results};
use dtrnet::util::cli::Args;
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;

fn run_variant(engine: &Engine, tag: &str, args: &Args) -> Result<Json> {
    let decode = format!("{tag}_serve_decode_b4m512");
    let init = engine.load(&format!("tiny_{}_init",
        tag.trim_start_matches("tiny_")))?;
    let params = init.call_literals(&[Tensor::scalar_i32(0).to_literal()?])?;
    let mut srv = ServeEngine::new(engine, &decode, params, args.get_usize("page", 16))?;

    let n_req = args.get_usize("requests", 12);
    let prompt_len = args.get_usize("prompt", 96);
    let gen = args.get_usize("gen", 64);
    let mut rng = Rng::new(11);
    let now = std::time::Instant::now();
    for i in 0..n_req {
        // long prompts from the needle generator so decode exercises recall
        let item = dtrnet::data::needle_task(&mut rng, 256, prompt_len, 8);
        srv.submit(Request {
            id: i as u64,
            prompt: item.tokens.iter().map(|&t| t as i32).collect(),
            max_new_tokens: gen,
            temperature: if i % 2 == 0 { 0.0 } else { 0.8 },
            arrival: now,
        });
    }
    let report = srv.run_to_completion(1_000_000)?;
    println!(
        "[{tag}] {} reqs, {} tokens, {:.1} tok/s, step p50 {:.2} ms, \
         KV savings ratio {:.3} (1.0 = dense)",
        report.completed,
        report.tokens_generated,
        report.tokens_per_s,
        report.decode_step_ms_p50,
        report.kv_savings_ratio
    );
    Ok(report.to_json())
}

fn main() -> Result<()> {
    let args = Args::parse();
    let engine = Engine::new(&dtrnet::artifacts_dir())?;
    let mut results = Json::obj();
    let mut rows = Vec::new();
    for tag in ["tiny_dense", "tiny_dtr_bilayer"] {
        let r = run_variant(&engine, tag, &args)?;
        rows.push(vec![
            tag.to_string(),
            format!("{:.1}", r.get("tokens_per_s").unwrap().as_f64().unwrap()),
            format!("{:.2}", r.get("decode_step_ms_p50").unwrap().as_f64().unwrap()),
            format!("{:.3}", r.get("kv_savings_ratio").unwrap().as_f64().unwrap()),
            format!("{:.0}", r.get("kv_bytes_peak").unwrap().as_f64().unwrap() / 1024.0),
        ]);
        results.set(tag, r);
    }
    print_table(
        "serving: dense vs DTRNet (measured)",
        &["model", "tok/s", "step ms p50", "kv ratio", "kv peak KiB"],
        &rows,
    );
    write_results("serve_longcontext.json", results);
    println!("serve_longcontext OK");
    Ok(())
}
