//! Quickstart: load the DTRNet artifacts, run a forward pass, inspect
//! routing — the 60-second tour of the public API.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;

use dtrnet::coordinator::RoutingStats;
use dtrnet::model::{flops, memory};
use dtrnet::runtime::{Engine, Tensor};

fn main() -> Result<()> {
    // 1. Open the artifact registry (built once by `make artifacts`;
    //    Python never runs again after that).
    let engine = Engine::new(&dtrnet::artifacts_dir())?;
    println!("platform: {}", engine.platform());

    // 2. Initialize DTRNet-BiLayer parameters on-device (the init artifact
    //    is itself an XLA computation — seeded, deterministic).
    let tag = "xs_dtr_bilayer";
    let init = engine.load(&format!("{tag}_init"))?;
    let params = init.call_literals(&[Tensor::scalar_i32(42).to_literal()?])?;
    println!("initialized {} parameter tensors", params.len());

    // 3. Forward a batch of token ids and read the routing telemetry.
    let fwd = engine.load(&format!("{tag}_fwd_b2s64"))?;
    let cfg = fwd.spec.config.clone();
    let tokens: Vec<i32> = (0..2 * 64).map(|i| (i * 7 % 256) as i32).collect();
    let tok = Tensor::i32(vec![2, 64], tokens).to_literal()?;
    let mut inputs: Vec<&xla::Literal> = params.iter().collect();
    inputs.push(&tok);
    let outs = fwd.call_literals_ref(&inputs)?;
    let logits = Tensor::from_literal(&outs[0])?;
    let route = Tensor::from_literal(&outs[1])?;
    println!("logits shape {:?}", logits.shape);

    let mut stats = RoutingStats::new(cfg.n_layers);
    stats.record_route_tensor(route.as_f32(), 2, cfg.n_layers, 64);
    println!("layout {}   attention fractions per layer:", cfg.layout_string());
    for (l, f) in stats.fractions().iter().enumerate() {
        println!("  layer {l}: {:5.1}% of tokens attended", f * 100.0);
    }

    // 4. The paper's analytical models (Figs. 4 & 6) at paper scale.
    let paper = dtrnet::config::ModelConfig::preset(
        "smollm-1b3",
        dtrnet::config::Variant::DtrBilayer,
    );
    println!(
        "\nsmollm-1b3 DTRNet-BiLayer @20k tokens: FLOPs ratio {:.3} (paper: 0.785), \
         KV memory ratio {:.3}",
        flops::flops_ratio_vs_dense(&paper, 20480, None),
        memory::kv_bytes(&paper, 20480, None).ratio()
    );
    println!("\nquickstart OK");
    Ok(())
}
