//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Trains a DTRNet-BiLayer model for a few hundred steps on the synthetic
//! Markov corpus through the full three-layer stack — Rust coordinator →
//! AOT train_step (JAX fwd/bwd + AdamW) → Pallas-validated kernels — then
//! evaluates held-out perplexity and routing fractions, and writes the
//! loss curve to `results/train_e2e_<tag>.json`.
//!
//! ```bash
//! cargo run --release --example train_e2e -- --tag tiny_dtr_bilayer --steps 300
//! # also trains the dense baseline for comparison:
//! cargo run --release --example train_e2e -- --compare --steps 300
//! ```

use anyhow::Result;

use dtrnet::config::TrainConfig;
use dtrnet::coordinator::ArtifactTrainer;
use dtrnet::data::{corpus, Dataset};
use dtrnet::metrics::JsonlWriter;
use dtrnet::runtime::Engine;
use dtrnet::util::bench::write_results;
use dtrnet::util::cli::Args;
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;

fn run_one(engine: &Engine, tag: &str, args: &Args) -> Result<Json> {
    let tcfg = TrainConfig {
        steps: args.get_usize("steps", 300),
        peak_lr: args.get_f64("lr", 1e-3),
        seed: args.get_u64("seed", 0),
        log_every: args.get_usize("log-every", 25),
        ..Default::default()
    };
    let mut trainer = ArtifactTrainer::new(engine, tag, tcfg.seed as i32)?;
    let mut rng = Rng::new(args.get_u64("data-seed", 7));
    let data = Dataset::new(
        corpus::markov_corpus(&mut rng, 256, 400 * trainer.seq, 12),
        trainer.seq,
    );
    let (train_data, eval_data) = data.split(0.1);
    let log = JsonlWriter::create(std::path::Path::new(&format!(
        "results/train_{tag}.jsonl"
    )))?;
    let report = trainer.run(&tcfg, &train_data, Some(&log))?;

    // Held-out evaluation through the fwd artifact with the trained params.
    let fwd = engine
        .manifest
        .artifacts
        .iter()
        .find(|a| a.kind == "fwd" && a.name == format!("{tag}_fwd_b4s128")
            || a.kind == "fwd" && a.name.starts_with(tag) && a.seq == Some(trainer.seq))
        .map(|a| a.name.clone())
        .ok_or_else(|| anyhow::anyhow!("no fwd artifact for {tag}"))?;
    let eval = dtrnet::eval::perplexity(engine, &fwd, trainer.params(), &eval_data, 8)?;
    // Baseline: perplexity of the untrained init (sanity anchor).
    let init = engine.load(&format!("{tag}_init"))?;
    let init_params =
        init.call_literals(&[dtrnet::runtime::Tensor::scalar_i32(99).to_literal()?])?;
    let eval0 = dtrnet::eval::perplexity(engine, &fwd, &init_params, &eval_data, 4)?;

    println!(
        "[e2e {tag}] loss {:.4} -> {:.4} | held-out ppl {:.2} (untrained {:.2}) | \
         {:.0} tok/s | routing {:?}",
        report.losses.first().unwrap_or(&f64::NAN),
        report.final_loss,
        eval.ppl,
        eval0.ppl,
        report.tokens_per_s,
        eval.routing.fractions()
    );
    let mut j = report.to_json();
    j.set("heldout_ppl", Json::Num(eval.ppl));
    j.set("untrained_ppl", Json::Num(eval0.ppl));
    j.set("eval_routing", eval.routing.to_json());
    Ok(j)
}

fn main() -> Result<()> {
    let args = Args::parse();
    let engine = Engine::new(&dtrnet::artifacts_dir())?;
    let mut results = Json::obj();
    if args.has("compare") {
        for tag in ["tiny_dense", "tiny_dtr_bilayer"] {
            let r = run_one(&engine, tag, &args)?;
            results.set(tag, r);
        }
    } else {
        let tag = args.get_or("tag", "tiny_dtr_bilayer").to_string();
        let r = run_one(&engine, &tag, &args)?;
        results.set(&tag, r);
    }
    write_results("train_e2e.json", results);
    println!("train_e2e OK");
    Ok(())
}
