//! Routing + redundancy analysis (paper Fig. 1 and Fig. 5).
//!
//! 1. Fig. 1: runs the cosine-similarity probe artifact on the dense model
//!    and prints the layerwise similarity matrix — the redundancy evidence
//!    motivating DTRNet's bypass path.
//! 2. Fig. 5: runs fwd artifacts for DTRNet / MoD / D-LLM and reports the
//!    per-layer percentage of tokens routed to attention.
//!
//! Results land in `results/fig1_cosine.json` and `results/fig5_routing.json`.
//!
//! ```bash
//! cargo run --release --example routing_analysis
//! ```

use anyhow::Result;

use dtrnet::coordinator::RoutingStats;
use dtrnet::data::{corpus, Dataset};
use dtrnet::runtime::{Engine, Tensor};
use dtrnet::tokenizer::{ByteTokenizer, Tokenizer};
use dtrnet::util::bench::{print_table, write_results};
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;

fn fig1(engine: &Engine) -> Result<Json> {
    let probe = engine.load("tiny_dense_probe_probe")?;
    let spec = probe.spec.clone();
    let (b, s) = (spec.batch.unwrap(), spec.seq.unwrap());
    let init = engine.load("tiny_dense_init")?;
    let params = init.call_literals(&[Tensor::scalar_i32(0).to_literal()?])?;
    // real-text tokens (embedded corpus — the WikiText stand-in)
    let text = corpus::embedded_corpus();
    let toks: Vec<i32> = ByteTokenizer
        .encode(&text)
        .iter()
        .take(b * s)
        .map(|&t| t as i32)
        .collect();
    let sim = dtrnet::eval::cosine_probe(engine, &probe.name, &params, &toks)?;
    let adj = dtrnet::eval::adjacent_similarity(&sim);
    println!("Fig. 1 — adjacent-layer cosine similarity (untrained tiny dense):");
    for (i, v) in adj.iter().enumerate() {
        println!("  S[{},{}] = {:.4}", i, i + 1, v);
    }
    let l = sim.shape[0];
    let mut matrix = Vec::new();
    for i in 0..l {
        let row: Vec<f64> = (0..l).map(|j| sim.at(&[i, j]) as f64).collect();
        matrix.push(Json::arr_f64(&row));
    }
    Ok(Json::from_pairs(vec![
        ("adjacent", Json::arr_f64(&adj)),
        ("matrix", Json::Arr(matrix)),
    ]))
}

fn fig5(engine: &Engine) -> Result<Json> {
    let mut out = Json::obj();
    let mut rows = Vec::new();
    for (tag, fwd) in [
        ("tiny_dtr_bilayer", "tiny_dtr_bilayer_fwd_b4s128"),
        ("tiny_mod", "tiny_mod_fwd_b4s128"),
        ("tiny_dllm", "tiny_dllm_fwd_b4s128"),
    ] {
        let exe = engine.load(fwd)?;
        let cfg = exe.spec.config.clone();
        let (b, s) = (exe.spec.batch.unwrap(), exe.spec.seq.unwrap());
        let init = engine.load(&format!("{tag}_init"))?;
        let params = init.call_literals(&[Tensor::scalar_i32(0).to_literal()?])?;
        let mut rng = Rng::new(3);
        let data = Dataset::new(corpus::markov_corpus(&mut rng, 256, 40 * s, 8), s);
        let mut stats = RoutingStats::new(cfg.n_layers);
        for tokens in data.eval_batches(b).take(4) {
            let tok = Tensor::i32(vec![b, s], tokens).to_literal()?;
            let mut inputs: Vec<&xla::Literal> = params.iter().collect();
            inputs.push(&tok);
            let outs = exe.call_literals_ref(&inputs)?;
            let route = Tensor::from_literal(&outs[1])?;
            stats.record_route_tensor(route.as_f32(), b, cfg.n_layers, s);
        }
        let fr = stats.fractions();
        rows.push(
            std::iter::once(tag.to_string())
                .chain(fr.iter().map(|f| format!("{:.0}%", f * 100.0)))
                .collect::<Vec<_>>(),
        );
        out.set(tag, stats.to_json());
    }
    print_table(
        "Fig. 5 — % tokens → attention per layer (untrained routers)",
        &["model", "L0", "L1", "L2", "L3", "L4", "L5"],
        &rows,
    );
    Ok(out)
}

fn main() -> Result<()> {
    let engine = Engine::new(&dtrnet::artifacts_dir())?;
    let f1 = fig1(&engine)?;
    write_results("fig1_cosine.json", f1);
    let f5 = fig5(&engine)?;
    write_results("fig5_routing.json", f5);
    println!("routing_analysis OK (trained-router numbers come from train_e2e + fig5 bench)");
    Ok(())
}
