//! Shared harness for the `dtrnet-fuzz` targets: corpus loading, a
//! seeded xorshift mutation engine (built on the repo's own
//! [`Rng`]), and a catch-unwind driver that saves crashing
//! inputs to `fuzz/artifacts/<target>/`.
//!
//! The targets themselves are one-liners over the differential oracles
//! in `dtrnet::coordinator::http::torture` — the same invariants the
//! tier-1 `fuzz_replay` test replays over the committed corpus, so a
//! crash found here becomes a regression seed by copying the artifact
//! into `fuzz/corpus/<target>/`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};

use dtrnet::util::rng::Rng;

/// Default mutation iterations when a target gets no CLI argument.
pub const DEFAULT_ITERS: usize = 5_000;

/// Inputs longer than this are truncated — parser limits trip far
/// earlier, so growing further only slows the loop down.
pub const MAX_LEN: usize = 8 * 1024;

/// `fuzz/corpus/<name>` resolved against this crate's manifest.
pub fn corpus_dir(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("corpus").join(name)
}

/// Load every corpus file under `dir`, sorted by file name so replay
/// order is stable.
pub fn load_corpus(dir: &Path) -> std::io::Result<Vec<(String, Vec<u8>)>> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.filter_map(|e| e.ok()).collect();
    entries.sort_by_key(|e| e.file_name());
    let mut out = Vec::new();
    for e in entries {
        let path = e.path();
        if path.is_file() {
            out.push((
                e.file_name().to_string_lossy().into_owned(),
                std::fs::read(&path)?,
            ));
        }
    }
    Ok(out)
}

/// One mutation round: 1-4 stacked edits (bit flips, inserts, deletes,
/// slice duplication, interesting-byte overwrites, truncation).
pub fn mutate(rng: &mut Rng, seed: &[u8]) -> Vec<u8> {
    const INTERESTING: &[u8] = b"\0\x7f\xff\r\n\"\\{}[]:, 0";
    let mut data = seed.to_vec();
    for _ in 0..(1 + rng.usize_below(4)) {
        match rng.below(6) {
            0 if !data.is_empty() => {
                let i = rng.usize_below(data.len());
                data[i] ^= 1 << rng.below(8);
            }
            1 => {
                let i = rng.usize_below(data.len() + 1);
                data.insert(i, rng.below(256) as u8);
            }
            2 if !data.is_empty() => {
                let i = rng.usize_below(data.len());
                data.remove(i);
            }
            3 if !data.is_empty() => {
                let start = rng.usize_below(data.len());
                let len = 1 + rng.usize_below((data.len() - start).min(16));
                let chunk: Vec<u8> = data[start..start + len].to_vec();
                let at = rng.usize_below(data.len() + 1);
                data.splice(at..at, chunk);
            }
            4 if !data.is_empty() => {
                let i = rng.usize_below(data.len());
                data[i] = INTERESTING[rng.usize_below(INTERESTING.len())];
            }
            _ => {
                data.truncate(rng.usize_below(data.len() + 1));
            }
        }
    }
    data.truncate(MAX_LEN);
    data
}

/// Replay the whole corpus, then run `iters` mutated inputs through
/// `check`. On panic the offending input is written to
/// `fuzz/artifacts/<target>/crash-<n>.bin` and the process exits
/// non-zero. Fully deterministic for a given (corpus, iters, seed).
pub fn run_target(target: &str, iters: usize, seed: u64, check: impl Fn(&[u8])) {
    let dir = corpus_dir(target);
    let corpus = load_corpus(&dir)
        .unwrap_or_else(|e| panic!("cannot load corpus {}: {e}", dir.display()));
    assert!(
        !corpus.is_empty(),
        "empty corpus at {} — commit seeds first",
        dir.display()
    );
    let mut crashes = 0usize;
    for (name, data) in &corpus {
        if !shielded(&check, data) {
            crashes += 1;
            eprintln!("[{target}] corpus seed {name} PANICKED");
        }
    }
    let mut rng = Rng::new(seed);
    for i in 0..iters {
        let base = &corpus[rng.usize_below(corpus.len())].1;
        let data = mutate(&mut rng, base);
        if !shielded(&check, &data) {
            crashes += 1;
            let art_dir = Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts")
                .join(target);
            std::fs::create_dir_all(&art_dir).expect("create artifacts dir");
            let path = art_dir.join(format!("crash-{i}.bin"));
            std::fs::write(&path, &data).expect("write crash artifact");
            eprintln!(
                "[{target}] iter {i}: PANIC on {} bytes — saved {}",
                data.len(),
                path.display()
            );
            if crashes >= 8 {
                break;
            }
        }
    }
    if crashes > 0 {
        eprintln!("[{target}] {crashes} crashing inputs (see fuzz/artifacts/{target}/)");
        std::process::exit(101);
    }
    println!(
        "[{target}] OK: {} corpus seeds + {iters} mutations, no invariant violations",
        corpus.len()
    );
}

/// Run `check` shielded from panics; false = it panicked.
fn shielded(check: &impl Fn(&[u8]), data: &[u8]) -> bool {
    catch_unwind(AssertUnwindSafe(|| check(data))).is_ok()
}

/// Shared CLI parsing for the targets: `<bin> [iters] [seed]`.
pub fn cli_args() -> (usize, u64) {
    let mut args = std::env::args().skip(1);
    let iters = args
        .next()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_ITERS);
    let seed = args.next().and_then(|s| s.parse().ok()).unwrap_or(0x5eed);
    (iters, seed)
}
