//! Fuzz the strict JSON machines: the byte-at-a-time validator must be
//! split invariant and agree with the borrowing tree parser, and
//! anything both accept must parse under the lenient `util::json`.
//!
//! Usage: `cargo run -p dtrnet-fuzz --bin json_push -- [iters] [seed]`

use dtrnet::coordinator::http::torture::check_json_bytes;

fn main() {
    let (iters, seed) = dtrnet_fuzz::cli_args();
    dtrnet_fuzz::run_target("json", iters, seed, |data| {
        check_json_bytes(data);
    });
}
