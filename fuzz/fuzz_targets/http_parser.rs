//! Fuzz the HTTP push parser's split-invariance oracle: one-shot,
//! byte-by-byte, and pseudo-random-split feeds must agree bitwise, and
//! every parsed body must satisfy the JSON oracles too.
//!
//! Usage: `cargo run -p dtrnet-fuzz --bin http_parser -- [iters] [seed]`

use dtrnet::coordinator::http::torture::check_http_bytes;

fn main() {
    let (iters, seed) = dtrnet_fuzz::cli_args();
    dtrnet_fuzz::run_target("http", iters, seed, |data| {
        check_http_bytes(data);
    });
}
