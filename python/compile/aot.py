"""AOT lowering: JAX entry points → HLO *text* artifacts + manifest.json.

This is the only place Python touches the pipeline; after ``make
artifacts`` the Rust binary is self-contained. Interchange is HLO text —
NOT ``.serialize()`` — because the image's xla_extension 0.5.1 rejects
jax≥0.5 protos with 64-bit instruction ids; the text parser reassigns ids
(see /opt/xla-example/README.md).

Artifacts per (preset, variant[, ablation]):
  init / train_init  — seeded parameter (+ Adam moment) initialization
  fwd                — batched forward: logits + routing telemetry
  train_step         — fused fwd+bwd+clip+AdamW (lr is an input)
  decode             — batched 1-token decode w/ compacted KV cache update
  prefill            — single-sequence prefill → compacted cache
  probe              — layerwise cosine-similarity matrix (paper Fig. 1)

Manifest schema (consumed by rust/src/runtime/manifest.rs):
  {"artifacts": [{name, file, kind, tag, config, batch, seq, max_kv,
                  params: [{path, shape, dtype}], inputs: [...],
                  outputs: [...]}, ...]}
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T
from . import decode as D


# --------------------------------------------------------------------------
# HLO text emission


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(x):
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


def _iospec(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return [{"shape": list(l.shape), "dtype": str(l.dtype)} for l in leaves]


# --------------------------------------------------------------------------
# Entry-point builders. Each returns (flat_fn, example_args, io_metadata).


def build_init(cfg):
    def fn(seed):
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
        return tuple(l for _, l in M.flatten_params(params))
    return fn, (jnp.int32(0),)


def build_train_init(cfg):
    def fn(seed):
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
        m, v = T.init_opt_state(params)
        leaves = lambda p: tuple(l for _, l in M.flatten_params(p))
        return leaves(params) + leaves(m) + leaves(v)
    return fn, (jnp.int32(0),)


def build_fwd(cfg, batch, seq, use_pallas=True):
    nparams = len(M.flatten_params(M.init_params(cfg, jax.random.PRNGKey(0))))

    def fn(*args):
        params = M.unflatten_params(cfg, args[:nparams])
        tokens = args[nparams]
        logits, aux = M.forward(cfg, params, tokens, train=False,
                                use_pallas=use_pallas)
        # route/g_attn: [B, L, n] → attn fraction per layer for Fig. 5
        attn_frac = aux["route"].mean(axis=(0, 2))
        return logits, aux["route"], aux["g_attn"], attn_frac
    return fn, nparams, (batch, seq)


def build_train_step(cfg, batch, seq):
    p0 = M.init_params(cfg, jax.random.PRNGKey(0))
    nparams = len(M.flatten_params(p0))

    def fn(*args):
        i = 0
        params = M.unflatten_params(cfg, args[i:i + nparams]); i += nparams
        m = M.unflatten_params(cfg, args[i:i + nparams]); i += nparams
        v = M.unflatten_params(cfg, args[i:i + nparams]); i += nparams
        tokens, step, lr, seed = args[i], args[i + 1], args[i + 2], args[i + 3]
        np_, nm, nv, metrics = T.train_step(cfg, params, m, v, tokens,
                                            step, lr, seed)
        leaves = lambda p: tuple(l for _, l in M.flatten_params(p))
        return leaves(np_) + leaves(nm) + leaves(nv) + metrics
    return fn, nparams, (batch, seq)


def build_decode(cfg, batch, max_kv):
    nparams = len(M.flatten_params(M.init_params(cfg, jax.random.PRNGKey(0))))

    def fn(*args):
        params = M.unflatten_params(cfg, args[:nparams])
        ck, cv, lens, tokens, pos = args[nparams:nparams + 5]
        return D.decode_step(cfg, params, ck, cv, lens, tokens, pos)
    return fn, nparams, (batch, max_kv)


def build_prefill(cfg, seq):
    nparams = len(M.flatten_params(M.init_params(cfg, jax.random.PRNGKey(0))))

    def fn(*args):
        params = M.unflatten_params(cfg, args[:nparams])
        tokens = args[nparams]
        return D.prefill(cfg, params, tokens)
    return fn, nparams, seq


def build_probe(cfg, batch, seq):
    """Fig. 1: mean cosine similarity between layer embeddings."""
    nparams = len(M.flatten_params(M.init_params(cfg, jax.random.PRNGKey(0))))

    def fn(*args):
        params = M.unflatten_params(cfg, args[:nparams])
        tokens = args[nparams]

        def one(t):
            _, aux = M.forward_seq(cfg, params, t, train=False,
                                   use_pallas=False, collect_hidden=True)
            return aux["hidden"]  # [L+1, n, d]
        hidden = jax.vmap(one)(tokens)  # [B, L+1, n, d]
        hn = hidden / (jnp.linalg.norm(hidden, axis=-1, keepdims=True) + 1e-9)
        B, n = tokens.shape
        sim = jnp.einsum("blnd,bmnd->lm", hn, hn) / (B * n)
        return (sim,)
    return fn, nparams, (batch, seq)


# --------------------------------------------------------------------------
# Artifact emission


def emit(out_dir, manifest, name, kind, cfg, fn, example_args, extra=None):
    # Resumable: skip artifacts that already exist with a manifest entry
    # (make artifacts is re-entrant; --force via DTRNET_AOT_FORCE=1).
    existing = {a["name"] for a in manifest["artifacts"]}
    if (name in existing
            and os.path.exists(os.path.join(out_dir, f"{name}.hlo.txt"))
            and not os.environ.get("DTRNET_AOT_FORCE")):
        print(f"  skip {name} (exists)")
        return
    # keep_unused: the manifest promises a stable positional signature, so
    # parameters that a particular variant doesn't read (e.g. the Gumbel
    # seed outside D-LLM) must still exist in the lowered module.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    p0 = M.init_params(cfg, jax.random.PRNGKey(0))
    entry = {
        "name": name,
        "file": fname,
        "kind": kind,
        "config": cfg.to_dict(),
        "params": [{"path": p, "shape": list(l.shape), "dtype": str(l.dtype)}
                   for p, l in M.flatten_params(p0)],
        "inputs": _iospec(example_args),
        "outputs": _iospec(jax.eval_shape(fn, *example_args)),
    }
    entry.update(extra or {})
    manifest["artifacts"] = [a for a in manifest["artifacts"] if a["name"] != name]
    manifest["artifacts"].append(entry)
    # Incremental manifest write: a killed/partial run stays consistent.
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB, "
          f"{len(entry['inputs'])} in / {len(entry['outputs'])} out)", flush=True)


def emit_set(out_dir, manifest, tag, cfg, *, fwd=None, train=None,
             decode=None, prefill_seq=None, probe=None, init=True,
             use_pallas_fwd=True):
    """Emit the artifact family for one model config under name prefix tag."""
    print(f"[aot] {tag}  variant={cfg.variant} layers="
          f"{''.join(M.layer_kinds(cfg))}")
    if init:
        fn, args = build_init(cfg)
        emit(out_dir, manifest, f"{tag}_init", "init", cfg, fn, args)
    if train is not None:
        b, s = train
        fn, args = build_train_init(cfg)
        emit(out_dir, manifest, f"{tag}_train_init", "train_init", cfg, fn, args)
        fn, nparams, _ = build_train_step(cfg, b, s)
        p0 = M.init_params(cfg, jax.random.PRNGKey(0))
        leaves = [l for _, l in M.flatten_params(p0)]
        ex = ([_spec(l) for l in leaves] * 3 +
              [jax.ShapeDtypeStruct((b, s), jnp.int32),
               jax.ShapeDtypeStruct((), jnp.float32),
               jax.ShapeDtypeStruct((), jnp.float32),
               jax.ShapeDtypeStruct((), jnp.int32)])
        emit(out_dir, manifest, f"{tag}_train_step", "train_step", cfg, fn, ex,
             extra={"batch": b, "seq": s, "nparams": nparams})
    if fwd is not None:
        b, s = fwd
        fn, nparams, _ = build_fwd(cfg, b, s, use_pallas=use_pallas_fwd)
        p0 = M.init_params(cfg, jax.random.PRNGKey(0))
        ex = ([_spec(l) for _, l in M.flatten_params(p0)] +
              [jax.ShapeDtypeStruct((b, s), jnp.int32)])
        emit(out_dir, manifest, f"{tag}_fwd_b{b}s{s}", "fwd", cfg, fn, ex,
             extra={"batch": b, "seq": s, "nparams": nparams})
    if decode is not None:
        b, mx = decode
        fn, nparams, _ = build_decode(cfg, b, mx)
        p0 = M.init_params(cfg, jax.random.PRNGKey(0))
        L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
        ex = ([_spec(l) for _, l in M.flatten_params(p0)] +
              [jax.ShapeDtypeStruct((L, b, mx, H, hd), jnp.float32),
               jax.ShapeDtypeStruct((L, b, mx, H, hd), jnp.float32),
               jax.ShapeDtypeStruct((L, b), jnp.int32),
               jax.ShapeDtypeStruct((b,), jnp.int32),
               jax.ShapeDtypeStruct((b,), jnp.int32)])
        emit(out_dir, manifest, f"{tag}_decode_b{b}m{mx}", "decode", cfg, fn,
             ex, extra={"batch": b, "max_kv": mx, "nparams": nparams})
    if prefill_seq is not None:
        fn, nparams, _ = build_prefill(cfg, prefill_seq)
        p0 = M.init_params(cfg, jax.random.PRNGKey(0))
        ex = ([_spec(l) for _, l in M.flatten_params(p0)] +
              [jax.ShapeDtypeStruct((prefill_seq,), jnp.int32)])
        emit(out_dir, manifest, f"{tag}_prefill_s{prefill_seq}", "prefill",
             cfg, fn, ex, extra={"seq": prefill_seq, "nparams": nparams})
    if probe is not None:
        b, s = probe
        fn, nparams, _ = build_probe(cfg, b, s)
        p0 = M.init_params(cfg, jax.random.PRNGKey(0))
        ex = ([_spec(l) for _, l in M.flatten_params(p0)] +
              [jax.ShapeDtypeStruct((b, s), jnp.int32)])
        emit(out_dir, manifest, f"{tag}_probe", "probe", cfg, fn, ex,
             extra={"batch": b, "seq": s, "nparams": nparams})


# --------------------------------------------------------------------------
# Suites


def suite_test(out_dir, manifest):
    """xs-scale artifacts for cargo/pytest integration tests (fast)."""
    for variant in ["dense", "dtr_bilayer"]:
        cfg = M.make_config("xs", variant)
        emit_set(out_dir, manifest, f"xs_{variant}", cfg,
                 fwd=(2, 64), train=(2, 64), decode=(2, 96),
                 prefill_seq=32, probe=(2, 64))


def suite_standard(out_dir, manifest):
    """tiny-scale artifacts: the Table-1/2/3/4/5/6 training matrix plus
    decode/probe for the serving + analysis paths."""
    b, s = 4, 128
    # Table 1 main rows + Table 3/4 ablation rows
    for variant in ["dense", "dtr_bilayer", "dtr_trilayer", "dtr_laterhalf",
                    "dtr_skip", "mod", "dllm"]:
        cfg = M.make_config("tiny", variant)
        emit_set(out_dir, manifest, f"tiny_{variant}", cfg,
                 fwd=(b, s), train=(b, s))
    # Table 2: expert-choice routing ablation
    cfg = M.make_config("tiny", "dtr_bilayer", routing="expert")
    emit_set(out_dir, manifest, "tiny_dtr_bilayer_ec", cfg,
             fwd=(b, s), train=(b, s))
    # Table 6: bypass without W^V W^O
    cfg = M.make_config("tiny", "dtr_bilayer", bypass_vo=False)
    emit_set(out_dir, manifest, "tiny_dtr_bilayer_novo", cfg,
             fwd=(b, s), train=(b, s))
    # Table 5: original capacity variants
    cfg = M.make_config("tiny", "mod", mod_capacity=0.125)
    emit_set(out_dir, manifest, "tiny_mod_k125", cfg, fwd=(b, s), train=(b, s))
    cfg = M.make_config("tiny", "dllm", dllm_omega=0.55)
    emit_set(out_dir, manifest, "tiny_dllm_o55", cfg, fwd=(b, s), train=(b, s))
    # Serving path: decode + prefill for the headline variant and dense
    for variant in ["dense", "dtr_bilayer"]:
        cfg = M.make_config("tiny", variant)
        emit_set(out_dir, manifest, f"tiny_{variant}_serve", cfg,
                 decode=(4, 512), prefill_seq=128, init=False)
    # Fig. 1 probe on the dense model
    cfg = M.make_config("tiny", "dense")
    emit_set(out_dir, manifest, "tiny_dense_probe", cfg, probe=(2, 128),
             init=False)


def suite_longctx(out_dir, manifest):
    """Fig. 3 artifacts: fwd at growing sequence lengths with RoPE scaling
    (YaRN-style position compression) beyond the 128-token train horizon."""
    for variant in ["dense", "dtr_bilayer", "mod", "dllm"]:
        for s in [256, 512, 1024, 2048]:
            scale = max(1.0, s / 128.0)
            cfg = M.make_config("tiny", variant, rope_scale=scale)
            tag = f"tiny_{variant}_long{s}"
            fn, nparams, _ = build_fwd(cfg, 1, s, use_pallas=True)
            p0 = M.init_params(cfg, jax.random.PRNGKey(0))
            ex = ([_spec(l) for _, l in M.flatten_params(p0)] +
                  [jax.ShapeDtypeStruct((1, s), jnp.int32)])
            emit(out_dir, manifest, f"{tag}_fwd", "fwd", cfg, fn,
                 ex, extra={"batch": 1, "seq": s, "nparams": nparams})


SUITES = {
    "test": suite_test,
    "standard": suite_standard,
    "longctx": suite_longctx,
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--suites", default="test,standard,longctx",
                    help="comma-separated subset of: " + ",".join(SUITES))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"artifacts": []}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    for s in args.suites.split(","):
        SUITES[s](args.out_dir, manifest)
    # dedupe by name, last wins
    seen = {}
    for a in manifest["artifacts"]:
        seen[a["name"]] = a
    manifest["artifacts"] = list(seen.values())
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
