"""L2: fused AdamW training step (paper §Training Setup) — AOT entry point.

One HLO module computes: forward + backward of the composite loss (CE +
Eq. 7 routing penalty / baseline aux), global-norm gradient clipping at
0.1, and the AdamW update (weight decay 0.01 on matrices only). The
learning rate is an *input* so the Rust coordinator owns the cosine/warmup
schedule without recompiling.

Hyperparameters follow the paper: AdamW, peak lr 3e-4 (driven by L3),
weight decay 0.01, grad clip 0.1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M

BETA1 = 0.9
BETA2 = 0.95
EPS = 1e-8
WEIGHT_DECAY = 0.01
GRAD_CLIP = 0.1


def init_opt_state(params):
    """Adam moments, zero-initialized, same pytree as params."""
    zeros = lambda p: jnp.zeros_like(p)
    return jax.tree_util.tree_map(zeros, params), \
        jax.tree_util.tree_map(zeros, params)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in jax.tree_util.tree_leaves(tree)))


def train_step(cfg: M.ModelConfig, params, m, v, tokens, step, lr, seed):
    """One optimizer step.

    params/m/v: pytrees; tokens: [B, n] int32; step: f32 scalar (1-based,
    for bias correction); lr: f32 scalar; seed: i32 scalar (D-LLM Gumbel
    sampling — folded with step so every step resamples).

    Returns (new_params, new_m, new_v, metrics) with metrics =
    (loss, ce, penalty, grad_norm, attn_frac [L]).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step.astype(jnp.int32))
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, tokens, key), has_aux=True)(params)

    gn = global_norm(grads)
    scale = jnp.minimum(1.0, GRAD_CLIP / (gn + 1e-12))
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    b1c = 1.0 - BETA1 ** step
    b2c = 1.0 - BETA2 ** step

    def upd(p, g, mi, vi):
        mi = BETA1 * mi + (1 - BETA1) * g
        vi = BETA2 * vi + (1 - BETA2) * g * g
        mhat = mi / b1c
        vhat = vi / b2c
        delta = mhat / (jnp.sqrt(vhat) + EPS)
        # decoupled weight decay on matrices only (norm gains exempt)
        wd = WEIGHT_DECAY if p.ndim >= 2 else 0.0
        return p - lr * (delta + wd * p), mi, vi

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    new = [upd(p, g, mi, vi) for p, g, mi, vi
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [t[0] for t in new])
    new_m = jax.tree_util.tree_unflatten(treedef, [t[1] for t in new])
    new_v = jax.tree_util.tree_unflatten(treedef, [t[2] for t in new])
    out_metrics = (loss, metrics["ce"], metrics["penalty"], gn,
                   metrics["attn_frac"])
    return new_params, new_m, new_v, out_metrics
