"""L2: the DTRNet model family in JAX (build-time only; lowered to HLO).

Implements the full architecture space of the paper (Sharma et al., 2025):

  dense          — SmolLM-style baseline (pre-norm RMSNorm, RoPE, SwiGLU)
  dtr_bilayer    — T-D-T-D-…-T   (paper's best, Table 1/3)
  dtr_trilayer   — T-D-D-T-…-T   (Table 1/3)
  dtr_laterhalf  — T…T D…D T     (Table 3)
  dtr_6t         — 2+2+2 dense anchors, DTR elsewhere (Table 3)
  dtr_skip       — BiLayer with routers forced to bypass (Table 4)
  mod            — Mixture-of-Depths baseline, expert-choice top-k,
                   alternating layers, aux inference classifier (Table 1/5)
  dllm           — D-LLM baseline, per-layer token-choice whole-block skip,
                   Gumbel-ST training, first 2 layers dense, first 2 tokens
                   always executed (Table 1/5)

Routing ablations: ``routing='expert'`` (Table 2) and ``bypass_vo=False``
(Table 6) are config switches.

Training uses the pure-jnp oracle path (differentiable); inference
artifacts use the Pallas kernels (kernels are allclose-tested against the
oracles, so the two paths are interchangeable numerics-wise).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from .kernels import ref
from . import kernels

Params = Dict[str, Any]

# --------------------------------------------------------------------------
# Config


@dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    vocab_size: int = 256
    d_model: int = 128
    n_layers: int = 6
    n_heads: int = 4
    d_ff: int = 352
    max_seq: int = 128
    variant: str = "dtr_bilayer"
    routing: str = "token"          # token | expert    (Table 2)
    bypass_vo: bool = True          # False = Table 6 ablation
    expert_capacity: float = 0.25   # DTR expert-choice capacity
    mod_capacity: float = 0.7       # MoD top-k ratio   (Table 5: 0.125/0.7)
    dllm_omega: float = 0.85        # D-LLM usage target (Table 5: 0.55/0.85)
    lambda_reg: float = 8e-4        # Eq. 7 lambda
    rope_theta: float = 10000.0
    rope_scale: float = 1.0         # >1 = YaRN-style extrapolation factor
    rmsnorm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


# Preset configs. smollm-360m / smollm-1b3 mirror the paper's training setup
# and are config-only on this testbed (see DESIGN.md §Substitutions).
PRESETS: Dict[str, dict] = {
    "xs": dict(vocab_size=256, d_model=64, n_layers=4, n_heads=4, d_ff=176,
               max_seq=64),
    "tiny": dict(vocab_size=256, d_model=128, n_layers=6, n_heads=4, d_ff=352,
                 max_seq=128),
    "small": dict(vocab_size=256, d_model=256, n_layers=8, n_heads=8, d_ff=704,
                  max_seq=256),
    "smollm-360m": dict(vocab_size=32000, d_model=960, n_layers=32, n_heads=15,
                        d_ff=2560, max_seq=2048),
    "smollm-1b3": dict(vocab_size=32000, d_model=2048, n_layers=24, n_heads=32,
                       d_ff=5632, max_seq=2048),
}


def make_config(preset: str, variant: str, **overrides) -> ModelConfig:
    kw = dict(PRESETS[preset])
    kw.update(overrides)
    return ModelConfig(name=preset, variant=variant, **kw)


# --------------------------------------------------------------------------
# Layer layout (paper §Architectural Design Choices + Appendix A2)


def layer_kinds(cfg: ModelConfig) -> List[str]:
    """Per-layer kind: 'T' dense transformer, 'D' DTR, 'M' MoD, 'L' D-LLM."""
    L = cfg.n_layers
    v = cfg.variant
    if v == "dense":
        return ["T"] * L
    if v in ("dtr_bilayer", "dtr_skip"):
        # T-D-T-D-…-T: first/last dense, alternate in between.
        kinds = ["D" if i % 2 == 1 else "T" for i in range(L)]
    elif v == "dtr_trilayer":
        # T-D-D-T-D-D-…: dense anchor every third layer.
        kinds = ["T" if i % 3 == 0 else "D" for i in range(L)]
    elif v == "dtr_laterhalf":
        kinds = ["T"] * (L // 2) + ["D"] * (L - L // 2)
    elif v == "dtr_6t":
        kinds = ["D"] * L
        anchors = [0, 1, L // 2 - 1, L // 2, L - 2, L - 1]
        for a in anchors:
            kinds[a] = "T"
    elif v == "mod":
        # MoD block after each transformer layer (paper's bi-layer config).
        kinds = ["M" if i % 2 == 1 else "T" for i in range(L)]
    elif v == "dllm":
        # First two layers dense, all subsequent layers D-LLM blocks.
        kinds = ["T", "T"] + ["L"] * (L - 2)
    else:
        raise ValueError(f"unknown variant {v!r}")
    kinds[0] = "T"
    kinds[-1] = "T"
    return kinds[:L]


# --------------------------------------------------------------------------
# Parameter init & flattening


def init_params(cfg: ModelConfig, key) -> Params:
    """LLaMA-style init: N(0, 0.02), output projections scaled by 1/sqrt(2L)."""
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    kinds = layer_kinds(cfg)
    n_keys = 3 + cfg.n_layers * 12
    ks = iter(jax.random.split(key, n_keys))
    std = 0.02
    out_std = std / (2 * cfg.n_layers) ** 0.5

    def mat(k, shape, s=std):
        return (jax.random.normal(k, shape, jnp.float32) * s)

    params: Params = {
        "tok_embed": mat(next(ks), (V, d)),
        "unembed": mat(next(ks), (d, V)),
        "out_norm": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for kind in kinds:
        lp = {
            "norm1": jnp.ones((d,), jnp.float32),
            "norm2": jnp.ones((d,), jnp.float32),
            "wq": mat(next(ks), (d, d)),
            "wk": mat(next(ks), (d, d)),
            "wv": mat(next(ks), (d, d)),
            "wo": mat(next(ks), (d, d), out_std),
            "w_gate": mat(next(ks), (d, ff)),
            "w_up": mat(next(ks), (d, ff)),
            "w_down": mat(next(ks), (ff, d), out_std),
        }
        if kind in ("D", "L"):
            lp["r_w1"] = mat(next(ks), (d, d // 2))
            lp["r_w2"] = mat(next(ks), (d // 2, 2))
        elif kind == "M":
            lp["r_w"] = mat(next(ks), (d, 1))
            lp["cls_w"] = mat(next(ks), (d, 1))
        params["layers"].append(lp)
    return params


def flatten_params(params: Params):
    """Deterministic (path, leaf) list — the layout contract with Rust.

    Order: tok_embed, unembed, out_norm, then per layer in index order with
    sorted key order inside each layer dict.
    """
    out = []
    out.append(("tok_embed", params["tok_embed"]))
    out.append(("unembed", params["unembed"]))
    out.append(("out_norm", params["out_norm"]))
    for i, lp in enumerate(params["layers"]):
        for k in sorted(lp.keys()):
            out.append((f"layers.{i}.{k}", lp[k]))
    return out


def unflatten_params(cfg: ModelConfig, leaves) -> Params:
    """Inverse of flatten_params given leaves in the same order."""
    kinds = layer_kinds(cfg)
    it = iter(leaves)
    params: Params = {
        "tok_embed": next(it), "unembed": next(it), "out_norm": next(it),
        "layers": [],
    }
    base = ["norm1", "norm2", "w_down", "w_gate", "w_up", "wk", "wo", "wq", "wv"]
    for kind in kinds:
        keys = base + (["r_w1", "r_w2"] if kind in ("D", "L")
                       else ["cls_w", "r_w"] if kind == "M" else [])
        lp = {k: next(it) for k in sorted(keys)}
        params["layers"].append(lp)
    return params


# --------------------------------------------------------------------------
# Sub-modules (single sequence [n, d]; batch handled by vmap in forward)


def _kth_largest(x, k: int):
    """k-th largest value of a 1-D vector, as sort + one-hot contraction.

    Deliberately avoids both `lax.top_k` (lowers to a `topk` HLO op whose
    `largest` attribute the image's XLA 0.5.1 text parser rejects) and
    sorted-vector indexing (lowers to a batched gather this jaxlib build
    rejects under vmap). sort + mask-multiply-sum uses only universally
    parseable ops.
    """
    n = x.shape[0]
    # stop_gradient: the threshold is a non-differentiable selection
    # boundary, and sort's VJP is itself a batched gather (same jaxlib bug).
    s = jnp.sort(jax.lax.stop_gradient(x))  # ascending
    mask = (jnp.arange(n) == n - k).astype(x.dtype)
    return (s * mask).sum()


def _rope(cfg, x, positions):
    # rope_scale implements position-interpolation extrapolation (YaRN-lite):
    # positions are compressed by 1/scale before the rotary embedding.
    pos = positions.astype(jnp.float32) / cfg.rope_scale
    return ref.rope_ref(x, pos, cfg.rope_theta)


def _attention_kv(cfg, lp, u, positions, delta, use_pallas: bool):
    """Routed/dense causal MHA on normalized stream u: [n, d].

    Returns (out [n, d], k [n, H, hd], v [n, H, hd]) — k/v are the exact
    tensors a decode-time KV cache would hold (k already RoPE'd), so the
    prefill path in decode.py shares this code instead of re-deriving it.
    """
    n, d = u.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = _rope(cfg, (u @ lp["wq"]).reshape(n, H, hd), positions)
    k = _rope(cfg, (u @ lp["wk"]).reshape(n, H, hd), positions)
    v = (u @ lp["wv"]).reshape(n, H, hd)
    if use_pallas:
        ctx = kernels.routed_attention(
            q.transpose(1, 0, 2), k.transpose(1, 0, 2), v.transpose(1, 0, 2),
            delta).transpose(1, 0, 2)
    else:
        ctx = ref.routed_attention_ref(q, k, v, delta)
    return ctx.reshape(n, d) @ lp["wo"], k, v


def _attention(cfg, lp, u, positions, delta, use_pallas: bool):
    return _attention_kv(cfg, lp, u, positions, delta, use_pallas)[0]


def _mlp(lp, x):
    return ref.swiglu_mlp_ref(x, lp["w_gate"], lp["w_up"], lp["w_down"])


def _dtr_route(cfg, lp, u, use_pallas: bool):
    """Router scores + hard decision; token-choice (Eq. 2) or expert-choice
    (Appendix A1: top expert_capacity fraction by g_attn)."""
    if use_pallas:
        g, delta_tc = kernels.router(u, lp["r_w1"], lp["r_w2"])
    else:
        g = ref.router_ref(u, lp["r_w1"], lp["r_w2"])
        delta_tc = ref.route_decision_ref(g)
    if cfg.variant == "dtr_skip":
        return g, jnp.zeros_like(delta_tc)
    if cfg.routing == "expert":
        n = u.shape[0]
        k = max(1, int(round(cfg.expert_capacity * n)))
        thresh = _kth_largest(g[:, 0], k)
        return g, (g[:, 0] >= thresh).astype(g.dtype)
    return g, delta_tc


def _layer_T(cfg, lp, x, positions, use_pallas):
    n = x.shape[0]
    ones = jnp.ones((n,), x.dtype)
    u = ref.rmsnorm_ref(x, lp["norm1"], cfg.rmsnorm_eps)
    h = x + _attention(cfg, lp, u, positions, ones, use_pallas)
    y = h + _mlp(lp, ref.rmsnorm_ref(h, lp["norm2"], cfg.rmsnorm_eps))
    return y, {"route": ones, "g_attn": ones}


def _layer_D(cfg, lp, x, positions, use_pallas):
    """DTR layer (paper Fig. 2): router → {quadratic, linear} path, shared
    W^V/W^O/MLP; soft-score output weighting (train==inference semantics)."""
    u = ref.rmsnorm_ref(x, lp["norm1"], cfg.rmsnorm_eps)
    g, delta = _dtr_route(cfg, lp, u, use_pallas)
    attn_out = _attention(cfg, lp, u, positions, delta, use_pallas)
    if cfg.bypass_vo:
        byp = (kernels.bypass(u, lp["wv"], lp["wo"]) if use_pallas
               else ref.bypass_ref(u, lp["wv"], lp["wo"]))
    else:
        byp = u
    mixed = jnp.where(delta[:, None] > 0.5,
                      g[:, 0:1] * attn_out,
                      g[:, 1:2] * byp)
    h = x + mixed
    y = h + _mlp(lp, ref.rmsnorm_ref(h, lp["norm2"], cfg.rmsnorm_eps))
    return y, {"route": delta, "g_attn": g[:, 0]}


def _layer_M(cfg, lp, x, positions, use_pallas, train: bool):
    """MoD block: expert-choice top-k during training; causal classifier
    (sigmoid(u·cls_w) > 0.5) at inference. Skipped tokens: pure residual."""
    n = x.shape[0]
    u = ref.rmsnorm_ref(x, lp["norm1"], cfg.rmsnorm_eps)
    r = (u @ lp["r_w"])[:, 0]                      # router scalar
    p_cls = jax.nn.sigmoid((u @ lp["cls_w"])[:, 0])  # inference classifier
    if train:
        k = max(1, int(round(cfg.mod_capacity * n)))
        thresh = _kth_largest(r, k)
        sel = (r >= thresh).astype(x.dtype)
    else:
        sel = (p_cls > 0.5).astype(x.dtype)
    gate = jax.nn.sigmoid(r)                       # soft weight for gradients
    h = x + sel[:, None] * gate[:, None] * _attention(
        cfg, lp, u, positions, sel, use_pallas)
    mlp_out = _mlp(lp, ref.rmsnorm_ref(h, lp["norm2"], cfg.rmsnorm_eps))
    y = h + sel[:, None] * gate[:, None] * mlp_out
    return y, {"route": sel, "g_attn": gate, "mod_r": r, "mod_p": p_cls}


def _layer_L(cfg, lp, x, positions, use_pallas, train: bool, gkey):
    """D-LLM block: 2-layer MLP gate, Gumbel-ST sample during training,
    deterministic threshold at inference; whole-block skip; first two
    tokens always executed (paper's D-LLM setup)."""
    n = x.shape[0]
    u = ref.rmsnorm_ref(x, lp["norm1"], cfg.rmsnorm_eps)
    g = ref.router_ref(u, lp["r_w1"], lp["r_w2"])
    if train:
        gumbel = -jnp.log(-jnp.log(
            jax.random.uniform(gkey, g.shape, jnp.float32, 1e-6, 1.0 - 1e-6)))
        zl = jnp.log(g + 1e-9) + gumbel
        hard = (zl[:, 0] > zl[:, 1]).astype(x.dtype)
    else:
        hard = (g[:, 0] > g[:, 1]).astype(x.dtype)
    forced = (positions - positions[0] < 2).astype(x.dtype)  # first 2 tokens
    hard = jnp.maximum(hard, forced)
    # Straight-through: hard decision forward, soft gate gradient.
    exec_w = hard + g[:, 0] - jax.lax.stop_gradient(g[:, 0])
    blk_attn = _attention(cfg, lp, u, positions, hard, use_pallas)
    h = x + exec_w[:, None] * blk_attn
    mlp_out = _mlp(lp, ref.rmsnorm_ref(h, lp["norm2"], cfg.rmsnorm_eps))
    y = h + exec_w[:, None] * mlp_out
    return y, {"route": hard, "g_attn": g[:, 0]}


# --------------------------------------------------------------------------
# Forward


def forward_seq(cfg: ModelConfig, params: Params, tokens, *, train: bool,
                use_pallas: bool, rng_key=None, collect_hidden: bool = False):
    """Single-sequence forward. tokens: [n] int32 → (logits [n, V], aux).

    aux: route [L, n], g_attn [L, n], plus mod/dllm extras and optionally
    hidden [L+1, n, d] for the Fig.-1 cosine probe.
    """
    kinds = layer_kinds(cfg)
    n = tokens.shape[0]
    positions = jnp.arange(n, dtype=jnp.int32)
    x = params["tok_embed"][tokens]
    routes, gattns, extras = [], [], {"mod_r": [], "mod_p": []}
    hidden = [x] if collect_hidden else None
    if rng_key is None:
        rng_key = jax.random.PRNGKey(0)
    lkeys = jax.random.split(rng_key, cfg.n_layers)
    for i, (kind, lp) in enumerate(zip(kinds, params["layers"])):
        if kind == "T":
            x, aux = _layer_T(cfg, lp, x, positions, use_pallas)
        elif kind == "D":
            x, aux = _layer_D(cfg, lp, x, positions, use_pallas)
        elif kind == "M":
            x, aux = _layer_M(cfg, lp, x, positions, use_pallas, train)
            extras["mod_r"].append(aux["mod_r"])
            extras["mod_p"].append(aux["mod_p"])
        else:
            x, aux = _layer_L(cfg, lp, x, positions, use_pallas, train, lkeys[i])
        routes.append(aux["route"])
        gattns.append(aux["g_attn"])
        if collect_hidden:
            hidden.append(x)
    x = ref.rmsnorm_ref(x, params["out_norm"], cfg.rmsnorm_eps)
    logits = x @ params["unembed"]
    out_aux = {
        "route": jnp.stack(routes),      # [L, n]
        "g_attn": jnp.stack(gattns),     # [L, n]
    }
    if extras["mod_r"]:
        out_aux["mod_r"] = jnp.stack(extras["mod_r"])
        out_aux["mod_p"] = jnp.stack(extras["mod_p"])
    if collect_hidden:
        out_aux["hidden"] = jnp.stack(hidden)  # [L+1, n, d]
    return logits, out_aux


def forward(cfg: ModelConfig, params: Params, tokens, *, train: bool = False,
            use_pallas: bool = False, rng_key=None):
    """Batched forward. tokens: [B, n] → (logits [B, n, V], aux batched)."""
    if rng_key is None:
        rng_key = jax.random.PRNGKey(0)
    keys = jax.random.split(rng_key, tokens.shape[0])
    return jax.vmap(
        lambda t, k: forward_seq(cfg, params, t, train=train,
                                 use_pallas=use_pallas, rng_key=k)
    )(tokens, keys)


# --------------------------------------------------------------------------
# Losses (paper Eq. 7 + baseline aux objectives)


def routing_penalty(cfg: ModelConfig, aux) -> jnp.ndarray:
    """Eq. 7 regularizer, per-token normalized (see DESIGN.md):
    ``sum_l alpha_l * mean_i g_attn_i`` with alpha_l = f_l / sum f, alpha
    treated as a constant (stop-grad) load weight. Only DTR layers count."""
    kinds = layer_kinds(cfg)
    dtr = jnp.asarray([1.0 if k == "D" else 0.0 for k in kinds])
    route = aux["route"].mean(axis=(0, 2))   # [L] mean load per layer (batch)
    g = aux["g_attn"].mean(axis=(0, 2))      # [L] mean attention mass
    f = route * dtr
    alpha = jax.lax.stop_gradient(f / (f.sum() + 1e-9))
    return (alpha * g * dtr).sum()


def dllm_aux_loss(cfg: ModelConfig, aux) -> jnp.ndarray:
    """Usage-target penalty: mean_l (usage_l - Omega)^2 over D-LLM layers."""
    kinds = layer_kinds(cfg)
    mask = jnp.asarray([1.0 if k == "L" else 0.0 for k in kinds])
    usage = aux["g_attn"].mean(axis=(0, 2))  # soft usage per layer
    per = (usage - cfg.dllm_omega) ** 2 * mask
    return per.sum() / (mask.sum() + 1e-9)


def loss_fn(cfg: ModelConfig, params: Params, tokens, rng_key,
            use_pallas: bool = False):
    """Composite training loss. tokens: [B, n] int32.

    Returns (loss, metrics dict) where metrics includes ce, aux penalty and
    per-layer attention load (paper Fig. 5 during training).
    """
    logits, aux = forward(cfg, params, tokens, train=True,
                          use_pallas=use_pallas, rng_key=rng_key)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    ce = (logz - gold).mean()

    kinds = layer_kinds(cfg)
    if cfg.variant.startswith("dtr"):
        pen = cfg.lambda_reg * routing_penalty(cfg, aux)
    elif cfg.variant == "mod":
        # classifier BCE against the expert-choice selection
        msel = jax.lax.stop_gradient(
            jnp.stack([aux["route"][:, i] for i, k in enumerate(kinds) if k == "M"],
                      axis=1))  # [B, nM, n]
        p = jnp.clip(aux["mod_p"], 1e-6, 1 - 1e-6)  # vmap'd: already [B, nM, n]
        pen = -(msel * jnp.log(p) + (1 - msel) * jnp.log(1 - p)).mean()
    elif cfg.variant == "dllm":
        pen = dllm_aux_loss(cfg, aux)
    else:
        pen = jnp.asarray(0.0)

    loss = ce + pen
    attn_frac = aux["route"].mean(axis=(0, 2))  # [L]
    return loss, {"ce": ce, "penalty": pen, "attn_frac": attn_frac}
