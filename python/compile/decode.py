"""L2: batched autoregressive decode step with routing-aware KV cache.

This is where the paper's Fig. 6 memory claim becomes real: the cache is
*compacted per layer* — a token appends k/v at layer l only when that
layer routed it to attention (dense layers always do; DTR/MoD/D-LLM layers
only for selected tokens). Each layer's cache therefore holds only the
~10% of tokens that were routed, and the Rust paged pool (L3) mirrors the
per-layer lengths to allocate pages on demand.

Shapes (all static — HLO requirement):
  cache_k, cache_v : [L, B, M, H, hd]   M = max cached entries per layer
  lens             : [L, B] i32          compacted lengths
  tokens           : [B] i32             current token ids
  positions        : [B] i32             absolute positions (RoPE)

The decode step returns updated cache/lens plus per-layer routing
decisions so L3 can account pages and Fig.-5 statistics.

Attention here is a cache matvec (one query against ≤M compacted keys) —
a VPU-bound op with no n² term; the Pallas flash kernel is for the
training/prefill shapes, so this path uses plain jnp on purpose.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M
from .kernels import ref

NEG_INF = -1e30


def _decode_attn(cfg, lp, u, q_pos, ck, cv, lens, delta):
    """One-query attention over the (already updated) compacted cache.

    u: [B, d]; ck/cv: [B, Mx, H, hd]; lens: [B] (entries valid AFTER this
    token's append, i.e. includes self when routed); delta: [B].
    Returns attn_out [B, d] (zeros where delta=0 — callers select).
    """
    B, d = u.shape
    H, hd = cfg.n_heads, cfg.head_dim
    Mx = ck.shape[1]
    q = jax.vmap(lambda uu, pp: M._rope(cfg, (uu[None, :] @ lp["wq"])
                                        .reshape(1, H, hd), pp[None]))(
        u, q_pos)[:, 0]                                   # [B, H, hd]
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bhd,bmhd->bhm", q, ck) * scale        # [B, H, Mx]
    valid = (jnp.arange(Mx)[None, :] < lens[:, None])     # [B, Mx]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    s = s - s.max(axis=-1, keepdims=True)
    w = jnp.exp(s)
    w = w / (w.sum(axis=-1, keepdims=True) + 1e-30)
    ctx = jnp.einsum("bhm,bmhd->bhd", w, cv)              # [B, H, hd]
    return ctx.reshape(B, d) @ lp["wo"]


def decode_step(cfg: M.ModelConfig, params, cache_k, cache_v, lens,
                tokens, positions):
    """One decode step for a batch of B independent sequences.

    Returns (logits [B, V], new_cache_k, new_cache_v, new_lens,
    routed [L, B], g_attn [L, B]).
    """
    kinds = M.layer_kinds(cfg)
    L = cfg.n_layers
    B = tokens.shape[0]
    H, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    Mx = cache_k.shape[2]

    x = params["tok_embed"][tokens]                       # [B, d]
    new_ck, new_cv, new_lens = [], [], []
    routed_all, gattn_all = [], []

    for l, (kind, lp) in enumerate(zip(kinds, params["layers"])):
        u = ref.rmsnorm_ref(x, lp["norm1"], cfg.rmsnorm_eps)

        # --- routing decision (token-choice: decode is causal by nature)
        if kind == "T":
            delta = jnp.ones((B,), jnp.float32)
            g0 = jnp.ones((B,), jnp.float32)
            gate = None
        elif kind == "D":
            g = ref.router_ref(u, lp["r_w1"], lp["r_w2"])
            g0 = g[:, 0]
            if cfg.variant == "dtr_skip":
                delta = jnp.zeros((B,), jnp.float32)
            else:
                delta = (g[:, 0] > g[:, 1]).astype(jnp.float32)
            gate = None
        elif kind == "M":
            p_cls = jax.nn.sigmoid((u @ lp["cls_w"])[:, 0])
            r = (u @ lp["r_w"])[:, 0]
            delta = (p_cls > 0.5).astype(jnp.float32)
            gate = jax.nn.sigmoid(r)
            g0 = p_cls
        else:  # D-LLM
            g = ref.router_ref(u, lp["r_w1"], lp["r_w2"])
            delta = (g[:, 0] > g[:, 1]).astype(jnp.float32)
            delta = jnp.maximum(delta, (positions < 2).astype(jnp.float32))
            gate = None
            g0 = g[:, 0]

        # --- KV append (only committed where routed)
        k_new = jax.vmap(lambda uu, pp: M._rope(
            cfg, (uu[None, :] @ lp["wk"]).reshape(1, H, hd), pp[None]))(
            u, positions)[:, 0]                           # [B, H, hd]
        v_new = (u @ lp["wv"]).reshape(B, H, hd)
        write_idx = jnp.minimum(lens[l], Mx - 1)          # L3 guards overflow

        # Scatter-free masked write (§Perf L2): vmapped dynamic_update_slice
        # lowers to an XLA scatter, which the CPU backend executes as a
        # scalar loop (measured 2.9× slower end-to-end). A one-hot
        # multiply-add is fully vectorized, and folding the routing
        # decision into the mask removes the full-cache select as well.
        onehot = (jnp.arange(Mx)[None, :] == write_idx[:, None]).astype(
            jnp.float32) * delta[:, None]                 # [B, Mx]
        m4 = onehot[:, :, None, None]
        ck_l = cache_k[l] * (1.0 - m4) + k_new[:, None] * m4
        cv_l = cache_v[l] * (1.0 - m4) + v_new[:, None] * m4
        lens_l = lens[l] + delta.astype(jnp.int32)
        att_len = jnp.where(delta > 0.5, lens_l, lens[l])

        # --- layer update
        attn_out = _decode_attn(cfg, lp, u, positions, ck_l, cv_l,
                                att_len, delta)
        if kind == "T":
            h = x + attn_out
            y = h + M._mlp(lp, ref.rmsnorm_ref(h, lp["norm2"], cfg.rmsnorm_eps))
        elif kind == "D":
            g = ref.router_ref(u, lp["r_w1"], lp["r_w2"])
            byp = ref.bypass_ref(u, lp["wv"], lp["wo"]) if cfg.bypass_vo else u
            mixed = jnp.where(delta[:, None] > 0.5,
                              g[:, 0:1] * attn_out,
                              g[:, 1:2] * byp)
            h = x + mixed
            y = h + M._mlp(lp, ref.rmsnorm_ref(h, lp["norm2"], cfg.rmsnorm_eps))
        elif kind == "M":
            w_ = (delta * gate)[:, None]
            h = x + w_ * attn_out
            y = h + w_ * M._mlp(lp, ref.rmsnorm_ref(h, lp["norm2"],
                                                    cfg.rmsnorm_eps))
        else:  # D-LLM whole-block gate
            w_ = delta[:, None]
            h = x + w_ * attn_out
            y = h + w_ * M._mlp(lp, ref.rmsnorm_ref(h, lp["norm2"],
                                                    cfg.rmsnorm_eps))
        x = y
        new_ck.append(ck_l)
        new_cv.append(cv_l)
        new_lens.append(lens_l)
        routed_all.append(delta)
        gattn_all.append(g0)

    x = ref.rmsnorm_ref(x, params["out_norm"], cfg.rmsnorm_eps)
    logits = x @ params["unembed"]
    return (logits, jnp.stack(new_ck), jnp.stack(new_cv),
            jnp.stack(new_lens), jnp.stack(routed_all), jnp.stack(gattn_all))


def prefill(cfg: M.ModelConfig, params, tokens):
    """Single-sequence prefill: run the training-shape forward and compact
    each layer's routed k/v to the cache layout.

    tokens: [S] int32 → (cache_k [L, S, H, hd], cache_v, lens [L],
    last_logits [V], routed [L, S]).  The cache is sized S here; L3 copies
    into its paged pool (only `lens[l]` entries are meaningful).
    """
    kinds = M.layer_kinds(cfg)
    S = tokens.shape[0]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = params["tok_embed"][tokens]
    cks, cvs, lens, routes = [], [], [], []
    for l, (kind, lp) in enumerate(zip(kinds, params["layers"])):
        u = ref.rmsnorm_ref(x, lp["norm1"], cfg.rmsnorm_eps)
        if kind == "T":
            delta = jnp.ones((S,), jnp.float32)
            attn_out, k, v = M._attention_kv(cfg, lp, u, positions, delta, False)
            h = x + attn_out
        elif kind == "D":
            g, delta = M._dtr_route(cfg, lp, u, False)
            attn_out, k, v = M._attention_kv(cfg, lp, u, positions, delta, False)
            byp = ref.bypass_ref(u, lp["wv"], lp["wo"]) if cfg.bypass_vo else u
            mixed = jnp.where(delta[:, None] > 0.5,
                              g[:, 0:1] * attn_out, g[:, 1:2] * byp)
            h = x + mixed
        elif kind == "M":
            p_cls = jax.nn.sigmoid((u @ lp["cls_w"])[:, 0])
            r = (u @ lp["r_w"])[:, 0]
            delta = (p_cls > 0.5).astype(jnp.float32)
            gate = jax.nn.sigmoid(r)
            attn_out, k, v = M._attention_kv(cfg, lp, u, positions, delta, False)
            h = x + (delta * gate)[:, None] * attn_out
        else:
            g = ref.router_ref(u, lp["r_w1"], lp["r_w2"])
            delta = (g[:, 0] > g[:, 1]).astype(jnp.float32)
            delta = jnp.maximum(delta, (positions < 2).astype(jnp.float32))
            attn_out, k, v = M._attention_kv(cfg, lp, u, positions, delta, False)
            h = x + delta[:, None] * attn_out

        if kind in ("T", "D"):
            y = h + M._mlp(lp, ref.rmsnorm_ref(h, lp["norm2"], cfg.rmsnorm_eps))
        else:
            w_ = delta[:, None] * (gate[:, None] if kind == "M" else 1.0)
            y = h + w_ * M._mlp(lp, ref.rmsnorm_ref(h, lp["norm2"],
                                                    cfg.rmsnorm_eps))
        x = y

        # Compact routed tokens to the front, preserving order (stable sort
        # on 1-delta). Non-routed slots beyond lens[l] are junk by contract.
        order = jnp.argsort(1.0 - delta, stable=True)
        cks.append(k[order])
        cvs.append(v[order])
        lens.append(delta.sum().astype(jnp.int32))
        routes.append(delta)

    x = ref.rmsnorm_ref(x, params["out_norm"], cfg.rmsnorm_eps)
    logits = x @ params["unembed"]
    return (jnp.stack(cks), jnp.stack(cvs), jnp.stack(lens),
            logits[-1], jnp.stack(routes))
