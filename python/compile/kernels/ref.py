"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness spec).

Every Pallas kernel in this package has a reference implementation here,
written in straightforward jnp with no fusion/tiling tricks. pytest
(``python/tests/test_kernels.py``) asserts allclose between each kernel and
its oracle across a hypothesis-driven sweep of shapes/dtypes/seeds.

Shapes use the conventions of the paper (DTRNet, Sharma et al. 2025):
  n   — sequence length            d  — model dim
  h   — number of heads            hd — head dim (d = h * hd)
All reference functions are batch-free ([n, d] inputs); the L2 model vmaps.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def router_ref(x, w1, w2):
    """DTRNet token router (paper Eq. 1).

    ``G_i = softmax(SiLU(x_i W1) W2)`` with W1: [d, d/2], W2: [d/2, 2].
    Returns soft scores g: [n, 2] — column 0 = attention path, 1 = bypass.
    """
    hidden = silu(x @ w1)
    logits = hidden @ w2
    logits = logits - logits.max(axis=-1, keepdims=True)
    e = jnp.exp(logits)
    return e / e.sum(axis=-1, keepdims=True)


def route_decision_ref(g):
    """Hard token-choice routing (paper Eq. 2): delta_i = 1[g_attn > g_bypass]."""
    return (g[:, 0] > g[:, 1]).astype(jnp.float32)


def bypass_ref(x, wv, wo):
    """Linear-path update (paper Eq. 5 core): ``x W^V W^O`` — self-attention
    without interaction (a token attends only to itself)."""
    return (x @ wv) @ wo


def rope_ref(x, positions, theta: float = 10000.0):
    """Rotary position embedding over the last dim of [n, h, hd]."""
    n, h, hd = x.shape
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [n, half]
    cos = jnp.cos(angles)[:, None, :]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def routed_attention_ref(q, k, v, delta, scale=None):
    """Routed multi-head attention (paper Eq. 4 + sparse-equivalence Eq. 6).

    q, k, v: [n, h, hd] (already RoPE'd); delta: [n] in {0,1}.
    Attention is causal AND restricted to the routed-token submask
    ``M = delta · deltaᵀ``; the diagonal is always allowed so that softmax
    rows of non-routed queries stay finite (their output is discarded by
    the caller's path select).
    Returns [n, h, hd] — the pre-W^O context vectors.
    """
    n, h, hd = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    logits = jnp.einsum("qhd,khd->hqk", q, k) * scale  # [h, n, n]
    causal = jnp.tril(jnp.ones((n, n), dtype=bool))
    routed = (delta[:, None] > 0.5) & (delta[None, :] > 0.5)
    allowed = causal & (routed | jnp.eye(n, dtype=bool))
    logits = jnp.where(allowed[None, :, :], logits, NEG_INF)
    logits = logits - logits.max(axis=-1, keepdims=True)
    w = jnp.exp(logits)
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", w, v)


def dense_attention_ref(q, k, v, scale=None):
    """Plain causal MHA — the dense-baseline path (delta = all-ones)."""
    n = q.shape[0]
    return routed_attention_ref(q, k, v, jnp.ones((n,), jnp.float32), scale)


def swiglu_mlp_ref(x, w_gate, w_up, w_down):
    """SwiGLU MLP (SmolLM/LLaMA family): ``(SiLU(xWg) * xWu) Wd``."""
    return (silu(x @ w_gate) * (x @ w_up)) @ w_down


def rmsnorm_ref(x, weight, eps: float = 1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * weight


def dtr_token_update_ref(x, w1, w2, wq, wk, wv, wo, positions, n_heads,
                         theta: float = 10000.0, bypass_vo: bool = True):
    """Full DTR layer token-mixing sublayer (router + both paths + select).

    Input x is the *normalized* residual stream ([n, d]); returns
    (update [n, d], g [n, 2], delta [n]).  Mirrors paper Eqs. 1–5: routed
    tokens get ``g_attn · Attn(x)``, bypassed get ``g_bypass · x W^V W^O``.
    """
    n, d = x.shape
    hd = d // n_heads
    g = router_ref(x, w1, w2)
    delta = route_decision_ref(g)

    q = rope_ref((x @ wq).reshape(n, n_heads, hd), positions, theta)
    k = rope_ref((x @ wk).reshape(n, n_heads, hd), positions, theta)
    v = (x @ wv).reshape(n, n_heads, hd)
    ctx = routed_attention_ref(q, k, v, delta).reshape(n, d)
    attn_out = ctx @ wo

    byp = bypass_ref(x, wv, wo) if bypass_vo else x
    out = jnp.where(delta[:, None] > 0.5,
                    g[:, 0:1] * attn_out,
                    g[:, 1:2] * byp)
    return out, g, delta
