"""Pallas kernel: DTRNet linear-path (bypass) update — ``x W^V W^O``.

Paper Eq. 5: bypassed tokens receive a token-local update through the
*shared* value and output projections ("self-attention without
interaction"). This is the kernel that makes 90% of tokens linear-cost.

TPU mapping: the token axis is tiled in BLOCK_N rows; W^V and W^O are
[d, d] and are streamed tile-by-tile along the contraction axis so the
VMEM working set stays at 2·BLOCK_N·d + 2·BLOCK_D·d floats. Both matmuls
hit the MXU; the intermediate ``x W^V`` tile never leaves VMEM (this
fusion — not materializing xW^V to HBM — is the point of the kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bypass_kernel(x_ref, wv_ref, wo_ref, o_ref):
    x = x_ref[...]  # [bn, d]
    t = x @ wv_ref[...]  # [bn, d]  — stays in VMEM
    o_ref[...] = t @ wo_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n",))
def bypass(x, wv, wo, *, block_n: int = 128):
    """Fused ``(x @ wv) @ wo`` over token tiles. x: [n, d] → [n, d]."""
    n, d = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0
    return pl.pallas_call(
        _bypass_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, wv, wo)
