"""L1 Pallas kernels for DTRNet (interpret-mode; see DESIGN.md).

Public surface:
  router(x, w1, w2)                 -> (g [n,2], delta [n])      Eq. 1-2
  bypass(x, wv, wo)                 -> [n, d]                    Eq. 5
  routed_attention(q, k, v, delta)  -> [h, n, hd]                Eq. 4+6
  dense_attention(q, k, v)          -> [h, n, hd]
plus `ref` — the pure-jnp oracles every kernel is tested against.
"""

from .router import router
from .bypass import bypass
from .routed_attention import routed_attention, dense_attention
from . import ref

__all__ = ["router", "bypass", "routed_attention", "dense_attention", "ref"]
