"""Pallas kernel: routed causal flash-attention (paper Eq. 4 + Eq. 6).

FlashAttention-style online-softmax attention restricted to the routed
token submask ``M = delta · deltaᵀ`` (plus causal mask, plus the diagonal
so non-routed rows stay finite — their output is discarded by the layer's
path select).

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper packs selected
tokens with FlashAttention-2's ``flash_attn_varlen_func`` on GPU. The TPU
analogue implemented here is *block-sparse masking*: the grid iterates
(head, q-block) and the kernel streams k/v-blocks HBM→VMEM, skipping the
entire MXU matmul for k-blocks that (a) lie strictly above the causal
diagonal or (b) contain no routed token when the q-block also has no
routed token. Online softmax keeps the working set at
O(BLOCK_Q·BLOCK_K + BLOCK_Q·hd) VMEM per step.

interpret=True: CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _routed_attn_kernel(q_ref, k_ref, v_ref, delta_ref, o_ref, *,
                        block_q: int, block_k: int, scale: float):
    qi = pl.program_id(1)  # q-block index within this head
    q = q_ref[0]  # [bq, hd]
    dq = delta_ref[...]  # [n] routing decisions (whole sequence, small)
    n = dq.shape[0]
    hd = q.shape[-1]

    q_start = qi * block_q
    q_pos = q_start + jax.lax.iota(jnp.int32, block_q)  # absolute q rows
    dq_tile = jax.lax.dynamic_slice(dq, (q_start,), (block_q,))  # [bq]

    num_kb = pl.cdiv(n, block_k)

    def body(j, carry):
        acc, m_i, l_i = carry
        k_start = j * block_k
        k = jax.lax.dynamic_slice(k_ref[0], (k_start, 0), (block_k, hd))
        v = jax.lax.dynamic_slice(v_ref[0], (k_start, 0), (block_k, hd))
        dk_tile = jax.lax.dynamic_slice(dq, (k_start,), (block_k,))
        k_pos = k_start + jax.lax.iota(jnp.int32, block_k)

        s = (q @ k.T) * scale  # [bq, bk] — MXU matmul
        causal = q_pos[:, None] >= k_pos[None, :]
        routed = (dq_tile[:, None] > 0.5) & (dk_tile[None, :] > 0.5)
        diag = q_pos[:, None] == k_pos[None, :]
        allowed = causal & (routed | diag)
        s = jnp.where(allowed, s, NEG_INF)

        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))  # [bq]
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    # Causal: k-blocks strictly above this q-block's last row contribute
    # nothing; loop only over j <= last needed block (block-level skipping,
    # the TPU analogue of FA2's threadblock early-exit).
    last_kb = (q_start + block_q - 1) // block_k + 1
    acc, m_i, l_i = jax.lax.fori_loop(0, last_kb, body, (acc0, m0, l0))
    o_ref[0] = (acc / l_i[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def routed_attention(q, k, v, delta, *, block_q: int = 128, block_k: int = 128):
    """Routed causal attention. q/k/v: [h, n, hd] (RoPE applied by caller);
    delta: [n] in {0,1}. Returns [h, n, hd] context (pre-W^O)."""
    h, n, hd = q.shape
    block_q = min(block_q, n)
    block_k = min(block_k, n)
    assert n % block_q == 0 and n % block_k == 0
    scale = 1.0 / (hd ** 0.5)
    grid = (h, n // block_q)
    kernel = functools.partial(
        _routed_attn_kernel, block_q=block_q, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((1, n, hd), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((1, n, hd), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((n,), lambda hh, qq: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda hh, qq: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, hd), q.dtype),
        interpret=True,
    )(q, k, v, delta)


def dense_attention(q, k, v, **kw):
    """Dense causal attention = routed attention with all tokens routed."""
    n = q.shape[1]
    return routed_attention(q, k, v, jnp.ones((n,), q.dtype), **kw)
