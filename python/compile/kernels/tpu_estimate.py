"""TPU performance estimation for the Pallas kernels (L1 §Perf).

interpret=True gives CPU-numpy timings only — not a TPU proxy — so kernel
"profiling" here is structural: given a BlockSpec schedule we compute

  * VMEM working set per grid step (must fit ~16 MiB/core on TPUv4),
  * MXU utilization estimate: fraction of matmul dims aligned to the
    128×128 systolic array,
  * HBM traffic and arithmetic intensity (FLOPs/byte) → roofline regime.

These numbers drive the block-size choices in the kernels and are recorded
in EXPERIMENTS.md §Perf (L1). The same analysis reproduces the paper's
efficiency argument: the routed kernel's HBM traffic scales with the
routed fraction f while the bypass path stays matmul-bound.
"""

from __future__ import annotations

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM, TPUv4-ish
MXU_DIM = 128
HBM_GBPS = 1200e9  # TPUv4 HBM bandwidth
MXU_FLOPS = 275e12  # TPUv4 bf16 peak


@dataclass
class KernelEstimate:
    name: str
    vmem_bytes: int
    fits_vmem: bool
    mxu_utilization: float      # dim-alignment proxy in [0, 1]
    hbm_bytes: float            # per full kernel invocation
    flops: float                # per full kernel invocation
    arithmetic_intensity: float # flops / hbm byte
    bound: str                  # "memory" | "compute"

    def roofline_tflops(self) -> float:
        """Achievable TFLOP/s under the simple roofline."""
        return min(MXU_FLOPS, self.arithmetic_intensity * HBM_GBPS) / 1e12


def _align(d: int) -> float:
    """Fraction of an MXU tile a dimension of size d fills (≤1)."""
    return min(1.0, d / MXU_DIM)


def estimate_router(n: int, d: int, block_n: int, dtype_bytes: int = 4) -> KernelEstimate:
    """Router kernel: per grid step holds x-tile + W1 + W2 + activations."""
    dh = d // 2
    vmem = dtype_bytes * (block_n * d + d * dh + dh * 2 + block_n * dh + block_n * 2)
    flops = 2.0 * n * d * dh + 2.0 * n * dh * 2
    hbm = dtype_bytes * (n * d + d * dh + dh * 2 + n * 2 + n)
    ai = flops / hbm
    return KernelEstimate(
        name=f"router(n={n},d={d},bn={block_n})",
        vmem_bytes=vmem,
        fits_vmem=vmem <= VMEM_BYTES,
        mxu_utilization=_align(block_n) * _align(dh),
        hbm_bytes=hbm,
        flops=flops,
        arithmetic_intensity=ai,
        bound="compute" if ai * HBM_GBPS > MXU_FLOPS else "memory",
    )


def estimate_bypass(n: int, d: int, block_n: int, block_d: int = 512,
                    dtype_bytes: int = 4) -> KernelEstimate:
    """Bypass kernel. At small d (the interpret-mode kernels) both [d, d]
    weights sit in VMEM; at paper scale the schedule streams weight column
    tiles of width `block_d` HBM→VMEM (the BlockSpec analogue of a K-sliced
    matmul), keeping the working set at x-tile + 2 weight tiles +
    intermediate."""
    resident = 2 * d * d  # whole weights resident (small-d fast path)
    streamed = 2 * d * block_d + block_n * block_d  # streamed schedule
    vmem = dtype_bytes * (block_n * d + min(resident, streamed) + 2 * block_n * d)
    flops = 4.0 * n * d * d
    # fusion saves writing/rereading the intermediate x·W^V (2·n·d elements)
    hbm = dtype_bytes * (n * d + 2 * d * d + n * d)
    ai = flops / hbm
    return KernelEstimate(
        name=f"bypass(n={n},d={d},bn={block_n})",
        vmem_bytes=vmem,
        fits_vmem=vmem <= VMEM_BYTES,
        mxu_utilization=_align(block_n) * _align(d),
        hbm_bytes=hbm,
        flops=flops,
        arithmetic_intensity=ai,
        bound="compute" if ai * HBM_GBPS > MXU_FLOPS else "memory",
    )


def estimate_routed_attention(n: int, h: int, hd: int, block_q: int, block_k: int,
                              routed_frac: float = 1.0,
                              dtype_bytes: int = 4) -> KernelEstimate:
    """Flash-style routed attention: per grid step one q-tile + streamed
    k/v-tiles + online-softmax accumulators. Routing reduces both the
    effective FLOPs and (with block-level skipping) the streamed k/v bytes
    by ~f² for score/AV work — the TPU analogue of varlen packing."""
    vmem = dtype_bytes * (
        block_q * hd          # q tile
        + 2 * block_k * hd    # k, v tiles
        + block_q * block_k   # scores tile
        + block_q * hd        # accumulator
        + 3 * block_q         # m, l, delta slices
        + n                   # routing vector (whole sequence, tiny)
    )
    f = max(routed_frac, 1e-6)
    causal = 0.5
    flops = h * (4.0 * n * n * hd) * causal * f * f
    # k/v streamed once per q-block → n/block_q passes; block-skipping
    # cuts the k-stream to the routed fraction
    kv_stream = h * (n / block_q) * n * hd * 2 * f
    hbm = dtype_bytes * (h * 2 * n * hd + kv_stream + n)
    ai = flops / hbm
    return KernelEstimate(
        name=f"routed_attn(n={n},h={h},hd={hd},bq={block_q},bk={block_k},f={routed_frac})",
        vmem_bytes=vmem,
        fits_vmem=vmem <= VMEM_BYTES,
        mxu_utilization=_align(block_q) * _align(block_k) * _align(hd),
        hbm_bytes=hbm,
        flops=flops,
        arithmetic_intensity=ai,
        bound="compute" if ai * HBM_GBPS > MXU_FLOPS else "memory",
    )


def sweep_block_sizes(n: int = 2048, h: int = 16, hd: int = 128,
                      routed_frac: float = 0.1):
    """The §Perf L1 table: candidate (block_q, block_k) schedules ranked by
    roofline throughput among those that fit VMEM."""
    rows = []
    for bq in (64, 128, 256, 512):
        for bk in (64, 128, 256, 512):
            e = estimate_routed_attention(n, h, hd, bq, bk, routed_frac)
            rows.append((bq, bk, e))
    rows.sort(key=lambda r: (not r[2].fits_vmem, -r[2].roofline_tflops(),
                             -r[2].mxu_utilization))
    return rows


if __name__ == "__main__":
    print(f"{'bq':>5} {'bk':>5} {'VMEM MiB':>9} {'fits':>5} {'MXU':>5} "
          f"{'AI':>7} {'roof TF/s':>10}")
    for bq, bk, e in sweep_block_sizes():
        print(f"{bq:>5} {bk:>5} {e.vmem_bytes / 2**20:>9.2f} "
              f"{str(e.fits_vmem):>5} {e.mxu_utilization:>5.2f} "
              f"{e.arithmetic_intensity:>7.1f} {e.roofline_tflops():>10.1f}")
