"""Pallas kernel: DTRNet token router (paper Eq. 1-2).

Computes, for a tile of tokens, the two-way routing distribution
``G = softmax(SiLU(x W1) W2)`` and the hard decision ``delta``.

TPU mapping (see DESIGN.md §Hardware-Adaptation): tokens are tiled along
the sequence axis in BLOCK_N chunks; W1 ([d, d/2]) and W2 ([d/2, 2]) are
small enough to live in VMEM for every realistic d (d=2048 → 2 MiB + 8 KiB
in f32), so each grid step does two MXU matmuls over the resident weights.
``interpret=True`` everywhere in this repo: the CPU PJRT plugin cannot run
Mosaic custom-calls; interpret mode lowers to plain HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(x_ref, w1_ref, w2_ref, g_ref, delta_ref):
    x = x_ref[...]  # [bn, d]
    h = x @ w1_ref[...]
    h = h * (1.0 / (1.0 + jnp.exp(-h)))  # SiLU on the VPU
    logits = h @ w2_ref[...]  # [bn, 2]
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    g = e / jnp.sum(e, axis=-1, keepdims=True)
    g_ref[...] = g
    delta_ref[...] = (g[:, 0] > g[:, 1]).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n",))
def router(x, w1, w2, *, block_n: int = 128):
    """Routing scores + hard decisions for all tokens.

    x: [n, d]; w1: [d, d/2]; w2: [d/2, 2]  →  (g [n, 2], delta [n]).
    n must be a multiple of block_n (callers pad; the L2 model always
    runs power-of-two sequence lengths).
    """
    n, d = x.shape
    block_n = min(block_n, n)
    assert n % block_n == 0, f"n={n} not a multiple of block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _router_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, w1.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((w1.shape[1], 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 2), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=True,
    )(x, w1, w2)
