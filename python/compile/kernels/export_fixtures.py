"""Export golden vectors from the ref.py oracles for the Rust CPU backend.

Writes ``rust/tests/fixtures/ref_vectors.json``: for each kernel in
``ref.py``, a seeded set of inputs and the oracle's outputs. The Rust
side (``rust/tests/golden_ref.rs``) replays the inputs through the
native kernels in ``rust/src/runtime/cpu/kernels.rs`` and asserts
allclose to 1e-4 — the cross-language correctness contract for the CPU
backend.

Run from the repo root (requires jax, build-time only):

    python3 python/compile/kernels/export_fixtures.py
"""

from __future__ import annotations

import importlib.util
import json
import os
import sys

import numpy as np


def _load_ref():
    here = os.path.dirname(os.path.abspath(__file__))
    spec = importlib.util.spec_from_file_location("ref", os.path.join(here, "ref.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _t(arr) -> dict:
    a = np.asarray(arr, dtype=np.float32)
    return {"shape": list(a.shape), "data": [float(x) for x in a.reshape(-1)]}


def main() -> None:
    ref = _load_ref()
    rng = np.random.default_rng(20250731)

    def randn(*shape, scale=1.0):
        return (rng.standard_normal(shape) * scale).astype(np.float32)

    cases = {}

    # rmsnorm
    x = randn(6, 8)
    w = (1.0 + 0.1 * rng.standard_normal(8)).astype(np.float32)
    cases["rmsnorm"] = {
        "x": _t(x),
        "weight": _t(w),
        "eps": 1e-5,
        "out": _t(ref.rmsnorm_ref(x, w)),
    }

    # router (+ hard decision)
    x = randn(5, 8)
    w1 = randn(8, 4, scale=0.5)
    w2 = randn(4, 2, scale=0.5)
    g = ref.router_ref(x, w1, w2)
    cases["router"] = {
        "x": _t(x),
        "w1": _t(w1),
        "w2": _t(w2),
        "g": _t(g),
        "delta": _t(ref.route_decision_ref(g)),
    }

    # bypass (linear path)
    x = randn(4, 8)
    wv = randn(8, 8, scale=0.4)
    wo = randn(8, 8, scale=0.4)
    cases["bypass"] = {
        "x": _t(x),
        "wv": _t(wv),
        "wo": _t(wo),
        "out": _t(ref.bypass_ref(x, wv, wo)),
    }

    # rope
    x = randn(5, 2, 4)
    pos = np.arange(5, dtype=np.float32)
    cases["rope"] = {
        "x": _t(x),
        "positions": _t(pos),
        "theta": 10000.0,
        "out": _t(ref.rope_ref(x, pos)),
    }

    # routed attention (mixed routing) + dense attention (all routed)
    q = randn(6, 2, 4)
    k = randn(6, 2, 4)
    v = randn(6, 2, 4)
    delta = np.array([1, 0, 1, 1, 0, 1], dtype=np.float32)
    cases["routed_attention"] = {
        "q": _t(q),
        "k": _t(k),
        "v": _t(v),
        "delta": _t(delta),
        "out": _t(ref.routed_attention_ref(q, k, v, delta)),
    }
    cases["dense_attention"] = {
        "q": _t(q),
        "k": _t(k),
        "v": _t(v),
        "out": _t(ref.dense_attention_ref(q, k, v)),
    }

    # swiglu mlp
    x = randn(4, 8)
    wg = randn(8, 12, scale=0.5)
    wu = randn(8, 12, scale=0.5)
    wd = randn(12, 8, scale=0.5)
    cases["swiglu_mlp"] = {
        "x": _t(x),
        "w_gate": _t(wg),
        "w_up": _t(wu),
        "w_down": _t(wd),
        "out": _t(ref.swiglu_mlp_ref(x, wg, wu, wd)),
    }

    # full DTR token-mixing sublayer, both bypass modes. Resample until the
    # router decision is mixed (some routed, some bypassed) so the fixture
    # exercises both paths and the routed-submask attention.
    n, d, heads = 8, 16, 4
    while True:
        x = randn(n, d, scale=0.8)
        w1 = randn(d, d // 2, scale=0.4)
        w2 = randn(d // 2, 2, scale=0.4)
        dec = np.asarray(ref.route_decision_ref(ref.router_ref(x, w1, w2)))
        if 0 < dec.sum() < n:
            break
    wq = randn(d, d, scale=0.3)
    wk = randn(d, d, scale=0.3)
    wv = randn(d, d, scale=0.3)
    wo = randn(d, d, scale=0.3)
    pos = np.arange(n, dtype=np.float32)
    for key, vo in (("dtr_token_update", True), ("dtr_token_update_novo", False)):
        out, g, delta = ref.dtr_token_update_ref(
            x, w1, w2, wq, wk, wv, wo, pos, heads, bypass_vo=vo
        )
        cases[key] = {
            "x": _t(x),
            "w1": _t(w1),
            "w2": _t(w2),
            "wq": _t(wq),
            "wk": _t(wk),
            "wv": _t(wv),
            "wo": _t(wo),
            "positions": _t(pos),
            "n_heads": heads,
            "bypass_vo": vo,
            "update": _t(out),
            "g": _t(g),
            "delta": _t(delta),
        }

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    out_path = os.path.join(root, "rust", "tests", "fixtures", "ref_vectors.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    payload = {"seed": 20250731, "tolerance": 1e-4, "cases": cases}
    with open(out_path, "w") as f:
        json.dump(payload, f)
    n_vals = sum(
        len(t["data"])
        for case in cases.values()
        for t in case.values()
        if isinstance(t, dict) and "data" in t
    )
    print(f"wrote {out_path}: {len(cases)} cases, {n_vals} scalars", file=sys.stderr)


if __name__ == "__main__":
    main()
