"""AOT layer tests: HLO text emission, manifest schema, IO consistency."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_roundtrips_numerics():
    # lower a small fn, re-load through xla_client, execute, compare
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "parameter" in text.lower()


def test_emit_writes_file_and_manifest(tmp_path):
    cfg = M.make_config("xs", "dense")
    manifest = {"artifacts": []}
    fn, args = aot.build_init(cfg)
    aot.emit(str(tmp_path), manifest, "t_init", "init", cfg, fn, args)
    assert (tmp_path / "t_init.hlo.txt").exists()
    assert (tmp_path / "manifest.json").exists()
    m = json.loads((tmp_path / "manifest.json").read_text())
    [e] = m["artifacts"]
    assert e["name"] == "t_init"
    assert e["kind"] == "init"
    # params layout recorded with shapes
    p0 = M.init_params(cfg, jax.random.PRNGKey(0))
    assert len(e["params"]) == len(M.flatten_params(p0))
    assert e["params"][0]["path"] == "tok_embed"


def test_emit_skips_existing(tmp_path, capsys):
    cfg = M.make_config("xs", "dense")
    manifest = {"artifacts": []}
    fn, args = aot.build_init(cfg)
    aot.emit(str(tmp_path), manifest, "t_init", "init", cfg, fn, args)
    aot.emit(str(tmp_path), manifest, "t_init", "init", cfg, fn, args)
    out = capsys.readouterr().out
    assert "skip t_init" in out
    assert len(manifest["artifacts"]) == 1


def test_build_fwd_io_counts():
    cfg = M.make_config("xs", "dtr_bilayer")
    fn, nparams, _ = aot.build_fwd(cfg, 2, 64, use_pallas=False)
    p0 = M.init_params(cfg, jax.random.PRNGKey(0))
    assert nparams == len(M.flatten_params(p0))
    leaves = [l for _, l in M.flatten_params(p0)]
    toks = jnp.zeros((2, 64), jnp.int32)
    outs = fn(*leaves, toks)
    logits, route, g_attn, frac = outs
    assert logits.shape == (2, 64, cfg.vocab_size)
    assert route.shape == (2, cfg.n_layers, 64)
    assert frac.shape == (cfg.n_layers,)


def test_manifest_real_artifacts_parse():
    # the repo's generated manifest (if present) has consistent entries
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    m = json.load(open(path))
    assert len(m["artifacts"]) >= 1
    names = [a["name"] for a in m["artifacts"]]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for a in m["artifacts"]:
        assert a["kind"] in {"init", "train_init", "train_step", "fwd",
                             "decode", "prefill", "probe"}
        assert os.path.exists(os.path.join(os.path.dirname(path), a["file"])), a["file"]
        if a["kind"] == "train_step":
            # inputs = 3*nparams + tokens + step + lr + seed
            assert len(a["inputs"]) == 3 * a["nparams"] + 4
            # outputs = 3*nparams + loss, ce, pen, gnorm, attn_frac
            assert len(a["outputs"]) == 3 * a["nparams"] + 5
        if a["kind"] == "fwd":
            assert len(a["inputs"]) == a["nparams"] + 1
            assert len(a["outputs"]) == 4
        if a["kind"] == "decode":
            assert len(a["inputs"]) == a["nparams"] + 5
            assert len(a["outputs"]) == 6


def test_probe_matrix_properties():
    cfg = M.make_config("xs", "dense")
    fn, nparams, _ = aot.build_probe(cfg, 2, 32)
    p0 = M.init_params(cfg, jax.random.PRNGKey(0))
    leaves = [l for _, l in M.flatten_params(p0)]
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 32), 0, 256)
    (sim,) = fn(*leaves, toks)
    L = cfg.n_layers
    assert sim.shape == (L + 1, L + 1)
    d = np.diag(np.asarray(sim))
    np.testing.assert_allclose(d, 1.0, rtol=1e-4)  # self-similarity
    np.testing.assert_allclose(np.asarray(sim), np.asarray(sim).T, rtol=1e-4)
