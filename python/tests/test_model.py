"""L2 semantics tests: variants, routing behavior, losses, gradients."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

ALL_VARIANTS = ["dense", "dtr_bilayer", "dtr_trilayer", "dtr_laterhalf",
                "dtr_skip", "mod", "dllm"]


def cfg_of(variant, **kw):
    return M.make_config("xs", variant, **kw)


def toks(cfg, batch=2, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, cfg.max_seq),
                              0, cfg.vocab_size)


# ---------------------------------------------------------------------------
# layouts


def test_layer_kinds_anchors():
    # paper: first and last layers are always standard Transformer layers
    for v in ALL_VARIANTS:
        kinds = M.layer_kinds(cfg_of(v))
        assert kinds[0] == "T" and kinds[-1] == "T", (v, kinds)


def test_layer_kinds_patterns():
    assert "".join(M.layer_kinds(cfg_of("dtr_bilayer"))) == "TDTT"
    assert "".join(M.layer_kinds(cfg_of("mod"))) == "TMTT"
    assert "".join(M.layer_kinds(cfg_of("dllm"))) == "TTLT"
    c6 = M.make_config("tiny", "dtr_trilayer")
    assert "".join(M.layer_kinds(c6)) == "TDDTDT"


def test_unknown_variant_raises():
    with pytest.raises(ValueError):
        M.layer_kinds(cfg_of("dense").__class__(variant="nope"))


# ---------------------------------------------------------------------------
# forward semantics


@pytest.mark.parametrize("variant", ALL_VARIANTS)
def test_forward_shapes_and_finite(variant):
    cfg = cfg_of(variant)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    t = toks(cfg)
    logits, aux = M.forward(cfg, p, t, train=False)
    assert logits.shape == (2, cfg.max_seq, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert aux["route"].shape == (2, cfg.n_layers, cfg.max_seq)


def test_dense_layers_route_everything():
    cfg = cfg_of("dtr_bilayer")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    _, aux = M.forward(cfg, p, toks(cfg), train=False)
    kinds = M.layer_kinds(cfg)
    for i, k in enumerate(kinds):
        frac = float(aux["route"][:, i].mean())
        if k == "T":
            assert frac == 1.0
        else:
            assert frac < 1.0


def test_dtr_skip_routes_nothing_to_attention():
    cfg = cfg_of("dtr_skip")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    _, aux = M.forward(cfg, p, toks(cfg), train=False)
    kinds = M.layer_kinds(cfg)
    for i, k in enumerate(kinds):
        if k == "D":
            assert float(aux["route"][:, i].sum()) == 0.0


def test_expert_choice_hits_capacity_exactly():
    cfg = cfg_of("dtr_bilayer", routing="expert", expert_capacity=0.25)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    _, aux = M.forward(cfg, p, toks(cfg), train=False)
    i = M.layer_kinds(cfg).index("D")
    frac = float(aux["route"][:, i].mean())
    assert abs(frac - 0.25) < 0.02


def test_mod_training_capacity():
    cfg = cfg_of("mod", mod_capacity=0.5)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    _, aux = M.forward(cfg, p, toks(cfg), train=True)
    i = M.layer_kinds(cfg).index("M")
    frac = float(aux["route"][:, i].mean())
    assert abs(frac - 0.5) < 0.02


def test_dllm_forces_first_two_tokens():
    cfg = cfg_of("dllm")
    p = M.init_params(cfg, jax.random.PRNGKey(3))
    _, aux = M.forward(cfg, p, toks(cfg), train=False)
    i = M.layer_kinds(cfg).index("L")
    assert float(aux["route"][:, i, :2].min()) == 1.0


def test_bypassed_tokens_still_updated():
    # The paper's core claim: every token gets an explicit update even when
    # skipping attention (unlike MoD/D-LLM). With dtr_skip, outputs at DTR
    # layers must differ from the residual input (bypass path + MLP apply).
    cfg = cfg_of("dtr_skip")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    t = toks(cfg, batch=1)
    logits_skip, _ = M.forward(cfg, p, t, train=False)
    # remove layer-1 (a DTR layer) entirely by zeroing its contribution:
    # if bypass did nothing, logits would be identical
    p2 = jax.tree_util.tree_map(lambda x: x, p)
    p2["layers"][1]["wv"] = jnp.zeros_like(p2["layers"][1]["wv"])
    logits_zero, _ = M.forward(cfg, p2, t, train=False)
    assert not np.allclose(np.asarray(logits_skip), np.asarray(logits_zero))


def test_routing_mask_blocks_cross_token_flow():
    # Sparse-attention equivalence (Eq. 6): with dtr_skip, a perturbation at
    # token j must not influence token i<j through the DTR layer's attention
    # ... but dense layers still mix. So instead check a 1-layer-only model:
    cfg = M.ModelConfig(name="probe", vocab_size=64, d_model=32, n_layers=3,
                        n_heads=2, d_ff=64, max_seq=16, variant="dtr_skip")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    kinds = M.layer_kinds(cfg)
    assert kinds == ["T", "D", "T"]
    t = jnp.zeros((1, 16), jnp.int32)
    t2 = t.at[0, 8].set(5)
    l1, _ = M.forward(cfg, p, t, train=False)
    l2, _ = M.forward(cfg, p, t2, train=False)
    # causal: positions before 8 unaffected by the change at 8
    np.testing.assert_allclose(np.asarray(l1[0, :8]), np.asarray(l2[0, :8]),
                               rtol=1e-5, atol=1e-6)
    # positions after 8 affected (through the dense layers)
    assert not np.allclose(np.asarray(l1[0, 9:]), np.asarray(l2[0, 9:]))


def test_bypass_vo_ablation_changes_output():
    cfg1 = cfg_of("dtr_bilayer")
    cfg2 = cfg_of("dtr_bilayer", bypass_vo=False)
    p = M.init_params(cfg1, jax.random.PRNGKey(0))
    t = toks(cfg1)
    l1, _ = M.forward(cfg1, p, t, train=False)
    l2, _ = M.forward(cfg2, p, t, train=False)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


# ---------------------------------------------------------------------------
# losses


@pytest.mark.parametrize("variant", ALL_VARIANTS)
def test_loss_finite_and_grads_flow(variant):
    cfg = cfg_of(variant)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    t = toks(cfg)
    loss, metrics = M.loss_fn(cfg, p, t, jax.random.PRNGKey(2))
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda pp: M.loss_fn(cfg, pp, t, jax.random.PRNGKey(2))[0])(p)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves)
    gn = float(sum(jnp.sum(l * l) for l in leaves))
    assert gn > 0.0


def test_router_gets_gradient():
    cfg = cfg_of("dtr_bilayer")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    t = toks(cfg)
    g = jax.grad(lambda pp: M.loss_fn(cfg, pp, t, jax.random.PRNGKey(2))[0])(p)
    i = M.layer_kinds(cfg).index("D")
    r1 = float(jnp.abs(g["layers"][i]["r_w1"]).sum())
    assert r1 > 0.0, "router weights must receive gradient via soft scores"


def test_penalty_increases_with_lambda():
    t = toks(cfg_of("dtr_bilayer"))
    p = M.init_params(cfg_of("dtr_bilayer"), jax.random.PRNGKey(0))
    _, m1 = M.loss_fn(cfg_of("dtr_bilayer", lambda_reg=1e-4), p, t, jax.random.PRNGKey(2))
    _, m2 = M.loss_fn(cfg_of("dtr_bilayer", lambda_reg=1e-2), p, t, jax.random.PRNGKey(2))
    assert float(m2["penalty"]) > float(m1["penalty"])


def test_dense_has_zero_penalty():
    cfg = cfg_of("dense")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    _, m = M.loss_fn(cfg, p, toks(cfg), jax.random.PRNGKey(2))
    assert float(m["penalty"]) == 0.0


def test_eq7_penalty_targets_attention_mass():
    # pushing router strongly toward attention must raise the Eq.7 penalty
    cfg = cfg_of("dtr_bilayer")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    i = M.layer_kinds(cfg).index("D")
    p_hi = jax.tree_util.tree_map(lambda x: x, p)
    # bias w2 column 0 (attention) up via weights: add large constant row
    p_hi["layers"][i]["r_w2"] = p_hi["layers"][i]["r_w2"].at[:, 0].add(10.0)
    t = toks(cfg)
    _, m_lo = M.loss_fn(cfg, p, t, jax.random.PRNGKey(2))
    _, m_hi = M.loss_fn(cfg, p_hi, t, jax.random.PRNGKey(2))
    assert float(m_hi["penalty"]) > float(m_lo["penalty"])


# ---------------------------------------------------------------------------
# params & flattening


@pytest.mark.parametrize("variant", ALL_VARIANTS)
def test_flatten_unflatten_roundtrip(variant):
    cfg = cfg_of(variant)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    flat = M.flatten_params(p)
    p2 = M.unflatten_params(cfg, [l for _, l in flat])
    for (path1, l1), (path2, l2) in zip(flat, M.flatten_params(p2)):
        assert path1 == path2
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_flat_order_is_deterministic():
    cfg = cfg_of("dtr_bilayer")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    names = [n for n, _ in M.flatten_params(p)]
    assert names[:3] == ["tok_embed", "unembed", "out_norm"]
    assert names == sorted(names, key=lambda n: (n.split(".")[0] != "tok_embed",)) or True
    # per-layer keys sorted
    layer0 = [n for n in names if n.startswith("layers.0.")]
    assert layer0 == sorted(layer0)


def test_param_count_matches_rust_model():
    # mirrors config::ModelConfig::param_count in rust
    cfg = cfg_of("dtr_bilayer")
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    total = sum(int(np.prod(l.shape)) for _, l in M.flatten_params(p))
    d, ff, V = cfg.d_model, cfg.d_ff, cfg.vocab_size
    expect = V * d * 2 + d
    for k in M.layer_kinds(cfg):
        expect += 2 * d + 4 * d * d + 3 * d * ff
        if k in ("D", "L"):
            expect += d * (d // 2) + (d // 2) * 2
        elif k == "M":
            expect += 2 * d
    assert total == expect
