"""Tests for the L1 TPU performance-estimation model (§Perf analytics)."""

import pytest

from compile.kernels import tpu_estimate as te


def test_vmem_fits_for_shipped_block_sizes():
    # the defaults shipped in the kernels (block 128) must fit VMEM at
    # paper scale (d=2048, n=2048, hd=128)
    assert te.estimate_router(2048, 2048, 128).fits_vmem
    assert te.estimate_bypass(2048, 2048, 128).fits_vmem
    assert te.estimate_routed_attention(2048, 16, 128, 128, 128).fits_vmem


def test_vmem_grows_with_block():
    a = te.estimate_routed_attention(2048, 16, 128, 64, 64)
    b = te.estimate_routed_attention(2048, 16, 128, 512, 512)
    assert b.vmem_bytes > a.vmem_bytes


def test_bypass_streams_weights_at_scale():
    # at paper scale the schedule must stream weight tiles, not hold the
    # 2×[2048,2048] matrices resident (32 MiB > VMEM)
    e = te.estimate_bypass(4096, 2048, 128)
    resident = 2 * 2048 * 2048 * 4
    assert e.vmem_bytes < resident
    assert e.fits_vmem
    # d=2048 aligned to MXU → full utilization proxy
    assert e.mxu_utilization == 1.0
    # at tiny scale the resident path is cheaper and is what ships
    tiny = te.estimate_bypass(128, 128, 128)
    assert tiny.vmem_bytes <= 4 * (128 * 128 + 2 * 128 * 128 + 2 * 128 * 128)


def test_routing_reduces_attention_flops_quadratically():
    dense = te.estimate_routed_attention(4096, 16, 128, 128, 128, routed_frac=1.0)
    routed = te.estimate_routed_attention(4096, 16, 128, 128, 128, routed_frac=0.1)
    assert routed.flops == pytest.approx(dense.flops * 0.01, rel=1e-6)
    assert routed.hbm_bytes < dense.hbm_bytes


def test_misaligned_dims_lower_mxu():
    good = te.estimate_routed_attention(2048, 16, 128, 128, 128)
    bad = te.estimate_routed_attention(2048, 16, 64, 128, 128)  # hd=64
    assert bad.mxu_utilization < good.mxu_utilization


def test_roofline_bounded_by_peak():
    for bq in (64, 128, 256):
        e = te.estimate_routed_attention(8192, 16, 128, bq, 128)
        assert e.roofline_tflops() <= te.MXU_FLOPS / 1e12 + 1e-9


def test_sweep_prefers_fitting_schedules():
    rows = te.sweep_block_sizes()
    fits = [e.fits_vmem for _, _, e in rows]
    # all fitting schedules rank before non-fitting ones
    first_nonfit = fits.index(False) if False in fits else len(fits)
    assert all(fits[:first_nonfit])
    assert not any(fits[first_nonfit:])


def test_bypass_is_compute_bound_at_scale():
    # the point of fusing x·W^V·W^O: stays in the MXU-bound regime
    e = te.estimate_bypass(4096, 2048, 256)
    assert e.arithmetic_intensity > 100
