"""Decode/prefill consistency: the serving path must match the training
forward exactly (the paper's token-choice routing makes this possible —
Appendix A1's argument for token-choice over expert-choice)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M, decode as D

jax.config.update("jax_platform_name", "cpu")

VARIANTS = ["dense", "dtr_bilayer", "dtr_skip", "mod", "dllm"]


def setup(variant, seed=0):
    cfg = M.make_config("xs", variant)
    p = M.init_params(cfg, jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (32,), 0,
                              cfg.vocab_size)
    return cfg, p, toks


@pytest.mark.parametrize("variant", VARIANTS)
def test_prefill_matches_forward_logits(variant):
    cfg, p, toks = setup(variant)
    logits_full, _ = M.forward_seq(cfg, p, toks, train=False, use_pallas=False)
    _, _, _, last_lg, _ = D.prefill(cfg, p, toks)
    np.testing.assert_allclose(np.asarray(last_lg),
                               np.asarray(logits_full[-1]), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("variant", VARIANTS)
def test_decode_continues_prefill(variant):
    cfg, p, toks = setup(variant)
    S = toks.shape[0]
    half = S // 2
    logits_full, aux = M.forward_seq(cfg, p, toks, train=False, use_pallas=False)
    ck, cv, lens, _, _ = D.prefill(cfg, p, toks[:half])
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
    Mx = S + 4
    CK = jnp.zeros((L, 1, Mx, H, hd)).at[:, 0, :half].set(ck)
    CV = jnp.zeros((L, 1, Mx, H, hd)).at[:, 0, :half].set(cv)
    LENS = lens[:, None]
    outs = []
    for t in range(half, S):
        lg, CK, CV, LENS, routed, g0 = D.decode_step(
            cfg, p, CK, CV, LENS, toks[t:t + 1], jnp.array([t], jnp.int32))
        outs.append(lg[0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs)),
                               np.asarray(logits_full[half:]),
                               rtol=2e-3, atol=2e-3)


def test_decode_routing_matches_forward_routing():
    cfg, p, toks = setup("dtr_bilayer")
    S = toks.shape[0]
    _, aux = M.forward_seq(cfg, p, toks, train=False, use_pallas=False)
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
    Mx = S + 4
    CK = jnp.zeros((L, 1, Mx, H, hd))
    CV = jnp.zeros((L, 1, Mx, H, hd))
    LENS = jnp.zeros((L, 1), jnp.int32)
    for t in range(S):
        lg, CK, CV, LENS, routed, _ = D.decode_step(
            cfg, p, CK, CV, LENS, toks[t:t + 1], jnp.array([t], jnp.int32))
        np.testing.assert_array_equal(np.asarray(routed[:, 0]),
                                      np.asarray(aux["route"][:, t]))


def test_kv_lens_track_routing():
    # paper Fig. 6 mechanism: per-layer cache length == #routed tokens
    cfg, p, toks = setup("dtr_bilayer")
    S = toks.shape[0]
    _, aux = M.forward_seq(cfg, p, toks, train=False, use_pallas=False)
    ck, cv, lens, _, routed = D.prefill(cfg, p, toks)
    expect = np.asarray(aux["route"]).sum(axis=1).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(lens), expect)
    # DTR layers cache strictly fewer tokens than dense layers at init
    kinds = M.layer_kinds(cfg)
    for i, k in enumerate(kinds):
        if k == "T":
            assert int(lens[i]) == S


def test_batched_decode_isolates_sequences():
    # two identical sequences in different slots must produce identical
    # logits, regardless of what the other slot does
    cfg, p, toks = setup("dtr_bilayer")
    L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
    Mx = 16
    B = 2
    CK = jnp.zeros((L, B, Mx, H, hd))
    CV = jnp.zeros((L, B, Mx, H, hd))
    LENS = jnp.zeros((L, B), jnp.int32)
    seq_a = toks[:8]
    seq_b = toks[8:16]
    # feed slot0=a, slot1=b
    lgs = []
    for t in range(8):
        lg, CK, CV, LENS, _, _ = D.decode_step(
            cfg, p, CK, CV, LENS,
            jnp.array([seq_a[t], seq_b[t]], jnp.int32),
            jnp.array([t, t], jnp.int32))
        lgs.append(lg)
    # now replay with slot1=a as well; slot0 logits must be unchanged
    CK2 = jnp.zeros((L, B, Mx, H, hd))
    CV2 = jnp.zeros((L, B, Mx, H, hd))
    LENS2 = jnp.zeros((L, B), jnp.int32)
    for t in range(8):
        lg2, CK2, CV2, LENS2, _, _ = D.decode_step(
            cfg, p, CK2, CV2, LENS2,
            jnp.array([seq_a[t], seq_a[t]], jnp.int32),
            jnp.array([t, t], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg2[0]), np.asarray(lgs[t][0]),
                                   rtol=1e-5, atol=1e-5)
        # and both slots see identical inputs → identical outputs
        np.testing.assert_allclose(np.asarray(lg2[0]), np.asarray(lg2[1]),
                                   rtol=1e-5, atol=1e-5)


def test_train_step_decreases_loss():
    from compile import train as T
    cfg = M.make_config("xs", "dtr_bilayer")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    m, v = T.init_opt_state(params)
    # learnable data: repeated pattern
    base = jnp.tile(jnp.arange(16, dtype=jnp.int32), 8)[:64]
    toks = jnp.stack([base, base])
    losses = []
    for s in range(1, 21):
        params, m, v, (loss, ce, pen, gn, frac) = T.train_step(
            cfg, params, m, v, toks, jnp.float32(s), jnp.float32(3e-3),
            jnp.int32(0))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, losses


def test_grad_clip_bounds_update():
    from compile import train as T
    cfg = M.make_config("xs", "dense")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    m, v = T.init_opt_state(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 256)
    _, _, _, (_, _, _, gn, _) = T.train_step(
        cfg, params, m, v, toks, jnp.float32(1), jnp.float32(3e-4), jnp.int32(0))
    assert float(gn) > 0.0
