"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

hypothesis sweeps shapes/dtypes/seeds; every kernel must match its ref
within float32 tolerances across the whole sweep.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# router


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_router_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, n, d)
    w1 = rand(rng, d, d // 2, scale=0.1)
    w2 = rand(rng, d // 2, 2, scale=0.1)
    g, delta = kernels.router(x, w1, w2, block_n=32)
    gr = ref.router_ref(x, w1, w2)
    dr = ref.route_decision_ref(gr)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(delta), np.asarray(dr))


def test_router_scores_are_distribution():
    rng = np.random.default_rng(0)
    x, w1, w2 = rand(rng, 64, 32), rand(rng, 32, 16), rand(rng, 16, 2)
    g, _ = kernels.router(x, w1, w2)
    np.testing.assert_allclose(np.asarray(g).sum(-1), 1.0, rtol=1e-5)
    assert (np.asarray(g) >= 0).all()


def test_router_block_size_invariance():
    rng = np.random.default_rng(1)
    x, w1, w2 = rand(rng, 128, 32), rand(rng, 32, 16), rand(rng, 16, 2)
    g32, _ = kernels.router(x, w1, w2, block_n=32)
    g128, _ = kernels.router(x, w1, w2, block_n=128)
    np.testing.assert_allclose(g32, g128, rtol=1e-6)


# ---------------------------------------------------------------------------
# bypass


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bypass_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    x, wv, wo = rand(rng, n, d), rand(rng, d, d, scale=0.1), rand(rng, d, d, scale=0.1)
    out = kernels.bypass(x, wv, wo, block_n=32)
    np.testing.assert_allclose(out, ref.bypass_ref(x, wv, wo), rtol=1e-4, atol=1e-5)


def test_bypass_is_tokenwise():
    # bypass must not mix tokens: changing token j leaves token i unchanged
    rng = np.random.default_rng(2)
    x, wv, wo = rand(rng, 64, 32), rand(rng, 32, 32), rand(rng, 32, 32)
    out1 = np.asarray(kernels.bypass(x, wv, wo))
    x2 = x.at[10].set(0.0)
    out2 = np.asarray(kernels.bypass(x2, wv, wo))
    np.testing.assert_allclose(out1[:10], out2[:10], rtol=1e-6)
    np.testing.assert_allclose(out1[11:], out2[11:], rtol=1e-6)
    assert not np.allclose(out1[10], out2[10])


# ---------------------------------------------------------------------------
# routed attention


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([64, 128]),
    h=st.sampled_from([1, 4]),
    hd=st.sampled_from([8, 16]),
    p_route=st.floats(0.05, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
def test_routed_attention_matches_ref(n, h, hd, p_route, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (rand(rng, h, n, hd) for _ in range(3))
    delta = jnp.asarray(rng.random(n) < p_route, jnp.float32)
    out = kernels.routed_attention(q, k, v, delta, block_q=32, block_k=32)
    outr = ref.routed_attention_ref(
        q.transpose(1, 0, 2), k.transpose(1, 0, 2), v.transpose(1, 0, 2), delta
    ).transpose(1, 0, 2)
    np.testing.assert_allclose(out, outr, rtol=2e-4, atol=2e-5)


def test_dense_attention_equals_all_routed():
    rng = np.random.default_rng(3)
    q, k, v = (rand(rng, 2, 64, 16) for _ in range(3))
    a = kernels.dense_attention(q, k, v, block_q=32, block_k=32)
    b = kernels.routed_attention(q, k, v, jnp.ones((64,), jnp.float32),
                                 block_q=32, block_k=32)
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_routed_attention_is_causal():
    # future keys must not influence a routed query
    rng = np.random.default_rng(4)
    q, k, v = (rand(rng, 1, 64, 8) for _ in range(3))
    delta = jnp.ones((64,), jnp.float32)
    out1 = np.asarray(kernels.routed_attention(q, k, v, delta, block_q=32, block_k=32))
    k2 = k.at[:, 40:].set(0.0)
    v2 = v.at[:, 40:].set(0.0)
    out2 = np.asarray(kernels.routed_attention(q, k2, v2, delta, block_q=32, block_k=32))
    np.testing.assert_allclose(out1[:, :40], out2[:, :40], rtol=1e-5, atol=1e-6)


def test_routed_attention_masks_bypassed_keys():
    # a bypassed token's K/V must not affect routed queries (Eq. 6)
    rng = np.random.default_rng(5)
    q, k, v = (rand(rng, 1, 64, 8) for _ in range(3))
    delta = jnp.ones((64,), jnp.float32).at[7].set(0.0)
    out1 = np.asarray(kernels.routed_attention(q, k, v, delta, block_q=32, block_k=32))
    k2 = k.at[:, 7].set(99.0)
    v2 = v.at[:, 7].set(99.0)
    out2 = np.asarray(kernels.routed_attention(q, k2, v2, delta, block_q=32, block_k=32))
    rows = [i for i in range(64) if i != 7]
    np.testing.assert_allclose(out1[:, rows], out2[:, rows], rtol=1e-5, atol=1e-6)


def test_block_shape_invariance():
    rng = np.random.default_rng(6)
    q, k, v = (rand(rng, 2, 128, 16) for _ in range(3))
    delta = jnp.asarray(rng.integers(0, 2, 128), jnp.float32)
    a = kernels.routed_attention(q, k, v, delta, block_q=32, block_k=64)
    b = kernels.routed_attention(q, k, v, delta, block_q=128, block_k=32)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# rope / norms (oracle self-consistency)


def test_rope_preserves_norm():
    rng = np.random.default_rng(7)
    x = rand(rng, 32, 2, 16)
    pos = jnp.arange(32)
    y = ref.rope_ref(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )


def test_rope_position_zero_is_identity():
    rng = np.random.default_rng(8)
    x = rand(rng, 4, 2, 16)
    y = ref.rope_ref(x, jnp.zeros(4, jnp.int32))
    np.testing.assert_allclose(x, y, rtol=1e-6)


def test_rope_relative_shift_invariance():
    # RoPE inner products depend only on relative offsets
    rng = np.random.default_rng(9)
    q = rand(rng, 8, 1, 16)
    k = rand(rng, 8, 1, 16)
    p1 = jnp.arange(8)
    p2 = jnp.arange(8) + 100
    q1, k1 = ref.rope_ref(q, p1), ref.rope_ref(k, p1)
    q2, k2 = ref.rope_ref(q, p2), ref.rope_ref(k, p2)
    s1 = np.einsum("qhd,khd->qk", np.asarray(q1), np.asarray(k1))
    s2 = np.einsum("qhd,khd->qk", np.asarray(q2), np.asarray(k2))
    np.testing.assert_allclose(s1, s2, rtol=1e-3, atol=1e-4)


def test_rmsnorm_unit_rms():
    rng = np.random.default_rng(10)
    x = rand(rng, 16, 32, scale=5.0)
    y = np.asarray(ref.rmsnorm_ref(x, jnp.ones(32)))
    rms = np.sqrt((y**2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
