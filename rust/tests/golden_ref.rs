//! Golden-vector parity: native CPU kernels vs the Python oracles.
//!
//! `rust/tests/fixtures/ref_vectors.json` is exported from
//! `python/compile/kernels/ref.py` by
//! `python/compile/kernels/export_fixtures.py` (build-time only; the
//! fixture is checked in so this suite runs fully offline). Every kernel
//! in `runtime::cpu::kernels` must match its oracle to 1e-4.

use dtrnet::runtime::cpu::kernels;
use dtrnet::testing::assert_allclose;
use dtrnet::util::json::Json;

const RTOL: f32 = 1e-4;
const ATOL: f32 = 1e-4;

fn fixture() -> Json {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/fixtures/ref_vectors.json"
    );
    Json::parse_file(std::path::Path::new(path)).expect("parse ref_vectors.json")
}

fn case(fix: &Json, name: &str) -> Json {
    fix.get("cases")
        .and_then(|c| c.get(name))
        .unwrap_or_else(|| panic!("fixture case {name} missing"))
        .clone()
}

fn tensor(c: &Json, key: &str) -> (Vec<usize>, Vec<f32>) {
    let t = c
        .get(key)
        .unwrap_or_else(|| panic!("fixture field {key} missing"));
    let shape: Vec<usize> = t
        .get("shape")
        .and_then(|s| s.as_arr())
        .unwrap()
        .iter()
        .map(|v| v.as_usize().unwrap())
        .collect();
    let data: Vec<f32> = t
        .get("data")
        .and_then(|d| d.as_arr())
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    assert_eq!(shape.iter().product::<usize>(), data.len());
    (shape, data)
}

#[test]
fn golden_rmsnorm() {
    let c = case(&fixture(), "rmsnorm");
    let (_, x) = tensor(&c, "x");
    let (_, w) = tensor(&c, "weight");
    let (_, want) = tensor(&c, "out");
    let eps = c.get("eps").unwrap().as_f64().unwrap() as f32;
    assert_allclose(&kernels::rmsnorm(&x, &w, eps), &want, RTOL, ATOL);
}

#[test]
fn golden_router_and_decision() {
    let c = case(&fixture(), "router");
    let (xs, x) = tensor(&c, "x");
    let (w1s, w1) = tensor(&c, "w1");
    let (_, w2) = tensor(&c, "w2");
    let (_, want_g) = tensor(&c, "g");
    let (_, want_delta) = tensor(&c, "delta");
    let (n, d, dh) = (xs[0], xs[1], w1s[1]);
    let g = kernels::router(&x, &w1, &w2, n, d, dh);
    assert_allclose(&g, &want_g, RTOL, ATOL);
    assert_allclose(&kernels::route_decision(&g), &want_delta, 0.0, 1e-6);
}

#[test]
fn golden_bypass() {
    let c = case(&fixture(), "bypass");
    let (xs, x) = tensor(&c, "x");
    let (_, wv) = tensor(&c, "wv");
    let (_, wo) = tensor(&c, "wo");
    let (_, want) = tensor(&c, "out");
    assert_allclose(&kernels::bypass(&x, &wv, &wo, xs[0], xs[1]), &want, RTOL, ATOL);
}

#[test]
fn golden_rope() {
    let c = case(&fixture(), "rope");
    let (xs, x) = tensor(&c, "x");
    let (_, pos) = tensor(&c, "positions");
    let (_, want) = tensor(&c, "out");
    let theta = c.get("theta").unwrap().as_f64().unwrap() as f32;
    let out = kernels::rope(&x, &pos, xs[0], xs[1], xs[2], theta);
    assert_allclose(&out, &want, RTOL, ATOL);
}

#[test]
fn golden_routed_attention() {
    let c = case(&fixture(), "routed_attention");
    let (qs, q) = tensor(&c, "q");
    let (_, k) = tensor(&c, "k");
    let (_, v) = tensor(&c, "v");
    let (_, delta) = tensor(&c, "delta");
    let (_, want) = tensor(&c, "out");
    let out = kernels::routed_attention(&q, &k, &v, &delta, qs[0], qs[1], qs[2]);
    assert_allclose(&out, &want, RTOL, ATOL);
}

#[test]
fn golden_dense_attention() {
    let c = case(&fixture(), "dense_attention");
    let (qs, q) = tensor(&c, "q");
    let (_, k) = tensor(&c, "k");
    let (_, v) = tensor(&c, "v");
    let (_, want) = tensor(&c, "out");
    let out = kernels::dense_attention(&q, &k, &v, qs[0], qs[1], qs[2]);
    assert_allclose(&out, &want, RTOL, ATOL);
}

#[test]
fn golden_swiglu_mlp() {
    let c = case(&fixture(), "swiglu_mlp");
    let (xs, x) = tensor(&c, "x");
    let (ws, wg) = tensor(&c, "w_gate");
    let (_, wu) = tensor(&c, "w_up");
    let (_, wd) = tensor(&c, "w_down");
    let (_, want) = tensor(&c, "out");
    let out = kernels::swiglu_mlp(&x, &wg, &wu, &wd, xs[0], xs[1], ws[1]);
    assert_allclose(&out, &want, RTOL, ATOL);
}

fn check_dtr_update(case_name: &str) {
    let c = case(&fixture(), case_name);
    let (xs, x) = tensor(&c, "x");
    let (_, w1) = tensor(&c, "w1");
    let (_, w2) = tensor(&c, "w2");
    let (_, wq) = tensor(&c, "wq");
    let (_, wk) = tensor(&c, "wk");
    let (_, wv) = tensor(&c, "wv");
    let (_, wo) = tensor(&c, "wo");
    let (_, pos) = tensor(&c, "positions");
    let (_, want_update) = tensor(&c, "update");
    let (_, want_g) = tensor(&c, "g");
    let (_, want_delta) = tensor(&c, "delta");
    let heads = c.get("n_heads").unwrap().as_usize().unwrap();
    let bypass_vo = c.get("bypass_vo").unwrap().as_bool().unwrap();
    let (n, d) = (xs[0], xs[1]);
    let out = kernels::dtr_token_update(
        &x, &w1, &w2, &wq, &wk, &wv, &wo, &pos, n, d, heads, 10000.0, bypass_vo, None,
    );
    // the fixture's routing mixes both paths — make sure it stays a real test
    let routed: f32 = want_delta.iter().sum();
    assert!(routed > 0.0 && routed < n as f32, "fixture routing not mixed");
    assert_allclose(&out.delta, &want_delta, 0.0, 1e-6);
    assert_allclose(&out.g, &want_g, RTOL, ATOL);
    assert_allclose(&out.update, &want_update, RTOL, ATOL);
}

#[test]
fn golden_dtr_token_update() {
    check_dtr_update("dtr_token_update");
}

#[test]
fn golden_dtr_token_update_without_vo_bypass() {
    check_dtr_update("dtr_token_update_novo");
}
