//! Property-based tests over coordinator invariants (in-repo harness —
//! proptest is unavailable offline; see DESIGN.md §Substitutions).

use std::time::Instant;

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::coordinator::{Batcher, KvPool, Request, RoutingStats};
use dtrnet::data::Dataset;
use dtrnet::model::{flops, memory};
use dtrnet::testing::{property, Gen};
use dtrnet::tokenizer::{BpeTokenizer, ByteTokenizer, Tokenizer};
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;

const VARIANTS: [Variant; 8] = [
    Variant::Dense,
    Variant::DtrBilayer,
    Variant::DtrTrilayer,
    Variant::DtrLaterhalf,
    Variant::Dtr6T,
    Variant::DtrSkip,
    Variant::Mod,
    Variant::Dllm,
];

fn arb_cfg(g: &mut Gen) -> ModelConfig {
    let variant = VARIANTS[g.usize(0..VARIANTS.len())];
    let mut cfg = ModelConfig::preset("tiny", variant);
    cfg.n_layers = g.usize(7..33); // ≥7 so dtr_6t anchors are distinct
    cfg
}

#[test]
fn prop_layout_anchors_dense() {
    // paper invariant: first and last layers are always dense transformers
    property("layout anchors", 200, |g| {
        let cfg = arb_cfg(g);
        let kinds = cfg.layer_kinds();
        assert_eq!(kinds.len(), cfg.n_layers);
        assert_eq!(kinds[0], dtrnet::config::LayerKind::Dense);
        assert_eq!(kinds[cfg.n_layers - 1], dtrnet::config::LayerKind::Dense);
    });
}

#[test]
fn prop_flops_ratio_bounds() {
    // any routed variant costs between the skip floor and dense ceiling,
    // and the ratio is monotonically non-increasing in sequence length
    property("flops ratio bounds", 100, |g| {
        let cfg = arb_cfg(g);
        let n1 = g.usize(256..4096);
        let n2 = n1 * 2;
        let r1 = flops::flops_ratio_vs_dense(&cfg, n1, None);
        let r2 = flops::flops_ratio_vs_dense(&cfg, n2, None);
        assert!(r1 > 0.0 && r1 <= 1.0 + 1e-9, "r1={r1}");
        assert!(r2 <= r1 + 1e-9, "ratio must not grow with n: {r1} -> {r2}");
    });
}

#[test]
fn prop_kv_memory_linear_and_bounded() {
    property("kv memory", 100, |g| {
        let cfg = arb_cfg(g);
        let n = g.usize(128..8192);
        let m = memory::kv_bytes(&cfg, n, None);
        assert!(m.allocated_bytes <= m.dense_bytes + 1e-6);
        // doubling n doubles bytes exactly (linear allocator)
        let m2 = memory::kv_bytes(&cfg, n * 2, None);
        let ratio = m2.allocated_bytes / m.allocated_bytes;
        assert!((ratio - 2.0).abs() < 1e-9, "ratio={ratio}");
    });
}

#[test]
fn prop_kv_pool_conservation() {
    // pages_allocated == sum over slots/layers of ceil(len/page);
    // release always returns to zero for that slot
    property("kv pool conservation", 100, |g| {
        let cfg = ModelConfig::preset("tiny", Variant::DtrBilayer);
        let slots = g.usize(1..5);
        let page = g.usize(1..33);
        let mut pool = KvPool::new(&cfg, slots, page, usize::MAX / 2);
        let mut lens = vec![vec![0usize; cfg.n_layers]; slots];
        for _ in 0..g.usize(1..300) {
            let slot = g.usize(0..slots);
            if g.bool() {
                let routed: Vec<bool> = (0..cfg.n_layers).map(|_| g.bool()).collect();
                assert!(pool.append(slot, &routed));
                for (l, &r) in routed.iter().enumerate() {
                    if r {
                        lens[slot][l] += 1;
                    }
                }
            } else {
                pool.release(slot);
                lens[slot] = vec![0; cfg.n_layers];
            }
            // invariants
            let expect_pages: usize = lens
                .iter()
                .flat_map(|sl| sl.iter().map(|&l| l.div_ceil(page)))
                .sum();
            assert_eq!(pool.stats().pages_allocated, expect_pages);
            for s in 0..slots {
                assert_eq!(pool.lens(s), lens[s]);
            }
        }
    });
}

#[test]
fn prop_batcher_conservation() {
    // every submitted request is eventually exactly-once completed; token
    // counts match max_new_tokens
    property("batcher conservation", 60, |g| {
        let slots = g.usize(1..6);
        let n_req = g.usize(1..30);
        let mut b = Batcher::new(slots, 1024);
        let now = Instant::now();
        let mut want_tokens = 0usize;
        for i in 0..n_req {
            let gen = g.usize(1..8);
            want_tokens += gen;
            assert!(b.submit(Request {
                id: i as u64,
                prompt: (0..g.usize(1..10)).map(|x| x as i32).collect(),
                max_new_tokens: gen,
                temperature: 0.0,
                arrival: now,
            }));
        }
        let mut guard = 0;
        while !b.idle() {
            b.admit();
            for s in 0..slots {
                if b.active[s].is_some() {
                    b.advance(s, g.u32(0..256) as i32, now);
                }
            }
            guard += 1;
            assert!(guard < 100_000, "batcher wedged");
        }
        assert_eq!(b.completed.len(), n_req);
        let got: usize = b.completed.iter().map(|c| c.generated.len()).sum();
        assert_eq!(got, want_tokens);
        let mut ids: Vec<u64> = b.completed.iter().map(|c| c.req.id).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), n_req, "exactly-once completion");
    });
}

#[test]
fn prop_bpe_roundtrip() {
    property("bpe roundtrip", 40, |g| {
        // train on random ascii corpus, encode/decode arbitrary strings
        let corpus: String = (0..g.usize(200..2000))
            .map(|_| (b'a' + g.u32(0..6) as u8) as char)
            .collect();
        let tok = BpeTokenizer::train(&corpus, 256 + g.usize(0..64));
        let probe: String = (0..g.usize(0..100))
            .map(|_| (b'a' + g.u32(0..26) as u8) as char)
            .collect();
        assert_eq!(tok.decode(&tok.encode(&probe)), probe);
        // encoding never produces out-of-vocab ids
        assert!(tok.encode(&probe).iter().all(|&id| (id as usize) < tok.vocab_size()));
    });
}

#[test]
fn prop_byte_tokenizer_total() {
    property("byte tokenizer roundtrip", 40, |g| {
        let s: String = (0..g.usize(0..200))
            .map(|_| char::from_u32(g.u32(1..0x250)).unwrap_or('x'))
            .collect();
        let t = ByteTokenizer;
        assert_eq!(t.decode(&t.encode(&s)), s);
    });
}

#[test]
fn prop_json_roundtrip() {
    fn arb_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize(0..4) } else { g.usize(0..6) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64(-1e9, 1e9) * 1000.0).round() / 1000.0),
            3 => Json::Str((0..g.usize(0..12))
                .map(|_| char::from_u32(g.u32(0x20..0x7f)).unwrap())
                .collect()),
            4 => Json::Arr((0..g.usize(0..5)).map(|_| arb_json(g, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for _ in 0..g.usize(0..5) {
                    let k: String = (0..g.usize(1..8))
                        .map(|_| char::from_u32(g.u32(0x61..0x7b)).unwrap())
                        .collect();
                    m.insert(k, arb_json(g, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    property("json roundtrip", 200, |g| {
        let j = arb_json(g, 3);
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
        let re2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, re2);
    });
}

#[test]
fn prop_dataset_windows_cover() {
    property("dataset windows", 60, |g| {
        let seq = g.usize(4..64);
        let n = seq * g.usize(2..20) + g.usize(0..seq);
        let tokens: Vec<u32> = (0..n as u32).collect();
        let d = Dataset::new(tokens, seq);
        let mut rng = Rng::new(g.case as u64);
        let b = d.sample_batch(&mut rng, 3);
        assert_eq!(b.len(), 3 * seq);
        // each window is a contiguous run
        for w in 0..3 {
            let s = &b[w * seq..(w + 1) * seq];
            for i in 1..seq {
                assert_eq!(s[i], s[i - 1] + 1);
            }
        }
    });
}

#[test]
fn prop_routing_stats_fractions_bounded() {
    property("routing stats", 60, |g| {
        let layers = g.usize(1..8);
        let mut st = RoutingStats::new(layers);
        for _ in 0..g.usize(1..20) {
            for l in 0..layers {
                let total = g.usize(1..100) as u64;
                let att = g.usize(0..(total as usize + 1)) as u64;
                st.record_layer(l, att, total);
            }
        }
        for f in st.fractions() {
            assert!((0.0..=1.0).contains(&f));
        }
    });
}
