//! Observability contracts: the Chrome trace export format, ring-buffer
//! wraparound semantics, the tracing-is-read-only determinism property,
//! and measured-vs-analytic FLOP reconciliation (`model/flops.rs` as
//! the oracle for `telemetry::FlopCounters`).
//!
//! Span tracing is process-global state, so every test that flips it
//! holds `telemetry::state_guard()` — cargo's parallel test threads
//! would otherwise race on `set_enabled`/`clear`. The FLOP counters are
//! per-backend-instance and need no serialization.

use std::collections::{HashMap, HashSet};

use dtrnet::config::{LayerKind, ModelConfig, Variant};
use dtrnet::coordinator::{
    generate_workload, PrefillMode, SamplingParams, Server, ServerConfig, WorkloadSpec,
};
use dtrnet::model::flops;
use dtrnet::runtime::{Backend, CpuBackend, QuantizedCpuBackend, Tensor};
use dtrnet::telemetry::{self, ArgValue};
use dtrnet::util::json::Json;
use dtrnet::util::rng::Rng;

/// Small mixed-length workload sized for the xs preset (max_seq 64).
fn spec(n: usize, temperature: f32) -> WorkloadSpec {
    WorkloadSpec {
        n_requests: n,
        arrival_rate: 2000.0,
        prompt_len_mean: 6,
        prompt_len_max: 16,
        gen_len_mean: 8,
        gen_len_max: 20,
        temperature,
        vocab: 256,
    }
}

fn serve_streams(be: &CpuBackend, workload_seed: u64) -> Vec<(u64, Vec<i32>)> {
    let cfg = ServerConfig {
        slots: 2,
        seed: 5,
        prefill: PrefillMode::Chunked(8),
        ..Default::default()
    };
    let mut srv = Server::new(be, cfg).unwrap();
    let trace = generate_workload(&spec(6, 0.0), workload_seed);
    let mut rep = srv.run_workload(&trace, 1_000_000).unwrap();
    rep.requests.sort_by_key(|r| r.id);
    rep.requests.into_iter().map(|r| (r.id, r.tokens)).collect()
}

#[test]
fn serve_trace_round_trips_chrome_json() {
    let _guard = telemetry::state_guard();
    telemetry::set_enabled(true);
    telemetry::clear();
    let be = CpuBackend::init(&ModelConfig::preset("xs", Variant::DtrBilayer), 3).unwrap();
    serve_streams(&be, 13);
    telemetry::set_enabled(false);
    assert_eq!(telemetry::dropped_events(), 0, "small run must not wrap the ring");

    let doc = telemetry::export_chrome_trace();
    let parsed = Json::parse(&doc.to_string()).expect("exported trace must be valid JSON");
    telemetry::clear();
    let events = match parsed.path("traceEvents") {
        Some(Json::Arr(evs)) => evs,
        other => panic!("traceEvents missing or not an array: {other:?}"),
    };
    assert!(!events.is_empty(), "serve run recorded no trace events");

    // Structural invariants of the Chrome trace-event stream: per-thread
    // timestamps never regress (rings preserve recording order), duration
    // B/E events nest and balance per thread, async b/e events balance
    // per (name, id), instants carry a scope.
    let mut depth: HashMap<i64, i64> = HashMap::new();
    let mut last_ts: HashMap<i64, f64> = HashMap::new();
    let mut async_open: HashMap<(String, i64), i64> = HashMap::new();
    let mut names: HashSet<String> = HashSet::new();
    for ev in events {
        let name = ev.path("name").and_then(Json::as_str).expect("event name").to_string();
        let ph = ev.path("ph").and_then(Json::as_str).expect("event phase");
        let tid = ev.path("tid").and_then(Json::as_f64).expect("event tid") as i64;
        let ts = ev.path("ts").and_then(Json::as_f64).expect("event ts");
        let last = last_ts.entry(tid).or_insert(f64::NEG_INFINITY);
        assert!(ts >= *last, "ts regressed on tid {tid}: {ts} after {last}");
        *last = ts;
        match ph {
            "B" => *depth.entry(tid).or_insert(0) += 1,
            "E" => {
                let d = depth.entry(tid).or_insert(0);
                *d -= 1;
                assert!(*d >= 0, "E without matching B on tid {tid} ({name})");
            }
            "b" | "e" => {
                let id = ev.path("id").and_then(Json::as_f64).expect("async id") as i64;
                let open = async_open.entry((name.clone(), id)).or_insert(0);
                *open += if ph == "b" { 1 } else { -1 };
                assert!(*open >= 0, "async e without b for {name}/{id}");
            }
            "i" => {
                assert_eq!(ev.path("s").and_then(Json::as_str), Some("t"), "instant scope");
            }
            other => panic!("unexpected trace phase {other:?}"),
        }
        names.insert(name);
    }
    for (tid, d) in &depth {
        assert_eq!(*d, 0, "unbalanced B/E spans on tid {tid}");
    }
    for ((name, id), d) in &async_open {
        assert_eq!(*d, 0, "unbalanced async span {name}/{id}");
    }
    // The serve engine's instrumentation points must all be present.
    for want in ["engine_step", "prefill", "request"] {
        assert!(names.contains(want), "span {want:?} missing from trace ({names:?})");
    }
}

#[test]
fn ring_wraparound_drops_oldest_not_newest() {
    let _guard = telemetry::state_guard();
    telemetry::set_enabled(true);
    telemetry::clear();
    telemetry::set_ring_capacity(8);
    for i in 0..20u64 {
        telemetry::instant("wrap", vec![("i", ArgValue::from(i))]);
    }
    let kept: Vec<f64> = telemetry::snapshot_events()
        .into_iter()
        .filter(|e| e.name == "wrap")
        .map(|e| match &e.args[0].1 {
            ArgValue::Num(v) => *v,
            other => panic!("numeric arg expected, got {other:?}"),
        })
        .collect();
    let dropped = telemetry::dropped_events();
    telemetry::set_ring_capacity(telemetry::DEFAULT_RING_CAPACITY);
    telemetry::set_enabled(false);
    telemetry::clear();

    assert_eq!(kept.len(), 8, "ring must hold exactly its capacity");
    let want: Vec<f64> = (12..20).map(|v| v as f64).collect();
    assert_eq!(kept, want, "wraparound must keep the newest events");
    assert_eq!(dropped, 12, "dropped-event counter must tally the overwritten oldest");
}

#[test]
fn tracing_on_vs_off_is_bitwise_identical() {
    let _guard = telemetry::state_guard();
    let be = CpuBackend::init(&ModelConfig::preset("xs", Variant::DtrBilayer), 17).unwrap();
    let tokens = Tensor::i32(vec![2, 24], (0..48).map(|i| i * 7 % 256).collect());
    let prompt: Vec<i32> = (0..9).map(|i| i * 23 % 256).collect();

    telemetry::set_enabled(false);
    let logits_off = be.forward(&tokens).unwrap().logits;
    let mut rng = Rng::new(2);
    let gen_off = be.generate(&prompt, 10, &SamplingParams::greedy(), &mut rng).unwrap().tokens;
    let streams_off = serve_streams(&be, 29);

    telemetry::set_enabled(true);
    telemetry::clear();
    let logits_on = be.forward(&tokens).unwrap().logits;
    let mut rng = Rng::new(2);
    let gen_on = be.generate(&prompt, 10, &SamplingParams::greedy(), &mut rng).unwrap().tokens;
    let streams_on = serve_streams(&be, 29);
    telemetry::set_enabled(false);
    telemetry::clear();

    assert_eq!(logits_off.as_f32(), logits_on.as_f32(), "forward logits bits changed");
    assert_eq!(gen_off, gen_on, "generate token stream changed");
    assert_eq!(streams_off, streams_on, "serve token streams changed");
}

#[test]
fn measured_flops_reconcile_exactly_on_dense() {
    // Every section of the dense forward has an exact closed form, so
    // measured-vs-analytic agreement is equality, not a tolerance: the
    // per-row accounting sums Σ(p+1) = n(n+1)/2 back to the averaged
    // analytic model, and the dense-equivalent denominator is the same
    // sum — the per-layer ratio is exactly 1.0.
    let cfg = ModelConfig::preset("xs", Variant::Dense);
    let be = CpuBackend::init(&cfg, 0).unwrap();
    let (b, s) = (2usize, 48usize);
    let tokens = Tensor::i32(vec![b, s], (0..(b * s) as i32).map(|i| i * 11 % 256).collect());
    let fc = be.flop_counters().unwrap();
    fc.reset();
    be.forward(&tokens).unwrap();
    let measured = fc.to_json();

    let rows = match measured.path("layers") {
        Some(Json::Arr(rows)) => rows,
        other => panic!("per-layer rows missing: {other:?}"),
    };
    assert_eq!(rows.len(), cfg.n_layers);
    for (li, row) in rows.iter().enumerate() {
        let total = row.path("total").and_then(Json::as_f64).unwrap();
        let analytic = flops::flops_per_layer(&cfg, li, s, 1.0).total() * (b * s) as f64;
        assert!(
            (total - analytic).abs() <= 1e-9 * analytic,
            "layer {li}: measured {total} vs analytic {analytic}"
        );
        let ratio = row.path("ratio_vs_dense").and_then(Json::as_f64).unwrap();
        assert!((ratio - 1.0).abs() < 1e-12, "dense layer {li} ratio {ratio}");
    }
    let total = measured.path("total").and_then(Json::as_f64).unwrap();
    let analytic_total = flops::flops_forward(&cfg, s, None) * (b * s) as f64;
    assert!(
        (total - analytic_total).abs() <= 1e-9 * analytic_total,
        "whole-model measured {total} vs analytic {analytic_total}"
    );
}

#[test]
fn measured_flops_reconcile_with_routing_on_dtr() {
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let be = CpuBackend::init(&cfg, 0).unwrap();
    let n = 48usize;
    let tokens = Tensor::i32(vec![1, n], (0..n as i32).map(|i| i * 13 % 256).collect());
    let fc = be.flop_counters().unwrap();
    fc.reset();
    let out = be.forward(&tokens).unwrap();
    let measured = fc.to_json();

    let (d, ff) = (cfg.d_model as f64, cfg.d_ff as f64);
    let nn = n as f64;
    let route = out.route.as_f32(); // [1, L, n]
    let rows = match measured.path("layers") {
        Some(Json::Arr(rows)) => rows,
        other => panic!("per-layer rows missing: {other:?}"),
    };
    let dense_eq: f64 = (0..n).map(|p| flops::dense_flops_per_token(&cfg, p + 1)).sum();
    for (li, kind) in cfg.layer_kinds().iter().enumerate() {
        let row = &rows[li];
        let get = |k: &str| row.path(k).and_then(Json::as_f64).unwrap();
        // Exact attention context from the actual routing decisions:
        // routed row j attends over the j routed tokens up to and
        // including itself (only routed tokens hold KV).
        let layer_route = &route[li * n..(li + 1) * n];
        let att = layer_route.iter().filter(|&&v| v > 0.5).count() as f64;
        let (mut seen, mut ctx_total) = (0.0f64, 0.0f64);
        for &v in layer_route {
            if v > 0.5 {
                seen += 1.0;
                ctx_total += seen;
            }
        }
        match kind {
            LayerKind::Dense => {
                assert_eq!(att, nn, "dense layer {li} must route everything");
                assert!(get("router").abs() < 0.5);
                assert!((get("qkvo_proj") - nn * 8.0 * d * d).abs() < 0.5);
                assert!((get("attn_mix") - 4.0 * d * nn * (nn + 1.0) / 2.0).abs() < 0.5);
                assert!(get("bypass").abs() < 0.5);
                assert!((get("ratio_vs_dense") - 1.0).abs() < 1e-12, "layer {li}");
            }
            LayerKind::Dtr => {
                assert!((get("router") - nn * (d * d + 2.0 * d)).abs() < 0.5);
                assert!((get("qkvo_proj") - att * 8.0 * d * d).abs() < 0.5, "layer {li}");
                assert!((get("attn_mix") - 4.0 * d * ctx_total).abs() < 0.5, "layer {li}");
                assert!((get("bypass") - (nn - att) * 4.0 * d * d).abs() < 0.5, "layer {li}");
                // The analytic model with the measured routing fraction
                // agrees within tolerance: it idealizes the attention
                // context as f·(n+1)/2 per routed query; every other
                // section is exact, and attn_mix is a minority term.
                let analytic = flops::flops_per_layer(&cfg, li, n, att / nn).total() * nn;
                let total = get("total");
                assert!(
                    (total - analytic).abs() / analytic < 0.15,
                    "layer {li}: measured {total} vs analytic {analytic}"
                );
            }
            other => panic!("unexpected layer kind {other:?}"),
        }
        assert!((get("mlp") - nn * 6.0 * d * ff).abs() < 0.5);
        assert!((get("dense_equiv") - dense_eq).abs() < 0.5, "layer {li}");
    }
    let vocab = cfg.vocab_size as f64;
    let unembed = measured.path("unembed").and_then(Json::as_f64).unwrap();
    assert!((unembed - nn * 2.0 * d * vocab).abs() < 0.5);
}

#[test]
fn quant_backend_counts_flops_too() {
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let be = QuantizedCpuBackend::init(&cfg, 0).unwrap();
    let n = 32usize;
    let tokens = Tensor::i32(vec![1, n], (0..n as i32).map(|i| i * 5 % 256).collect());
    let fc = be.flop_counters().unwrap();
    fc.reset();
    be.forward(&tokens).unwrap();
    let measured = fc.to_json();
    assert!(measured.path("total").and_then(Json::as_f64).unwrap() > 0.0);
    let rows = match measured.path("layers") {
        Some(Json::Arr(rows)) => rows,
        other => panic!("per-layer rows missing: {other:?}"),
    };
    // Int8 dense layers execute exactly dense-equivalent work, so the
    // measured ratio is exactly 1.0 there too; DTR layers record a
    // positive ratio (the training-shape int8 path runs both branches
    // before the select, so it is not gated below 1.0 here).
    for (li, kind) in cfg.layer_kinds().iter().enumerate() {
        let ratio = rows[li].path("ratio_vs_dense").and_then(Json::as_f64).unwrap();
        match kind {
            LayerKind::Dense => {
                assert!((ratio - 1.0).abs() < 1e-12, "int8 dense layer {li} ratio {ratio}")
            }
            _ => assert!(ratio > 0.0, "int8 DTR layer {li} ratio {ratio}"),
        }
    }
}
