//! Int8 quantization subsystem tests (DESIGN.md §Quantization):
//!
//! * round-trip property: per-output-row quantization error of a matmul
//!   is bounded by `scale_j/2 · Σ|x_row|` — the analytical worst case of
//!   symmetric rounding;
//! * bitwise thread invariance of the quantized forward / chunked
//!   prefill / batched decode paths, including every KV-cache byte
//!   (thread count is a throughput knob on the int8 path too);
//! * batching invariance: `decode_batch` ≡ per-sequence `decode_step`,
//!   `prefill_chunked` ≡ the sequential decode loop, bitwise;
//! * routing-decision equality vs the f32 backend on a pinned seeded
//!   scenario (exact — the margins were verified decisive), plus the
//!   margin-aware equivalence gate across seeds;
//! * quantized decode agrees with quantized forward on the same prefix.

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::coordinator::SamplingParams;
use dtrnet::runtime::cpu::kernels;
use dtrnet::runtime::quant::{check_routing_equivalence, compare_routing};
use dtrnet::runtime::{Backend, CpuBackend, DecodeState, QuantizedCpuBackend, Tensor};
use dtrnet::testing::{assert_allclose, property, Gen};
use dtrnet::util::rng::Rng;
use dtrnet::util::threadpool::Pool;

fn randn_vec(g: &mut Gen, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| g.rng.normal() as f32 * scale).collect()
}

#[test]
fn prop_quantized_matmul_error_bounded_by_row_scale() {
    property("|x@W - x@Wq| <= scale_j/2 * sum|x|", 40, |g| {
        let n = g.usize(1..5);
        let k = g.usize(1..80);
        let m = g.usize(1..40);
        let w = randn_vec(g, k * m, 0.5);
        let x = randn_vec(g, n * k, 1.0);
        let (q, scales) = kernels::quantize_rows(&w, k, m);
        let exact = kernels::matmul(&x, &w, n, k, m);
        let quant = kernels::matmul_q8(&x, &q, &scales, n, k, m);
        for i in 0..n {
            let l1: f32 = x[i * k..(i + 1) * k].iter().map(|v| v.abs()).sum();
            for j in 0..m {
                let err = (exact[i * m + j] - quant[i * m + j]).abs();
                // each weight is off by at most scale/2 (round-to-nearest),
                // plus f32 accumulation slack on both sides
                let bound = 0.5 * scales[j] * l1 + 1e-4 * (1.0 + l1);
                assert!(
                    err <= bound,
                    "row {i} col {j}: err {err} > bound {bound} (k={k})"
                );
            }
        }
    });
}

#[test]
fn prop_matmul_q8_par_bit_identical_to_serial() {
    property("matmul_q8 pooled == serial (bitwise)", 30, |g| {
        let pool = Pool::with_threads(g.usize(2..5));
        let n = if g.bool() { 1 } else { g.usize(2..9) };
        let k = g.usize(1..200);
        let m = g.usize(1..200);
        let w = randn_vec(g, k * m, 0.4);
        let x = randn_vec(g, n * k, 1.0);
        let (q, scales) = kernels::quantize_rows(&w, k, m);
        assert_eq!(
            kernels::matmul_q8(&x, &q, &scales, n, k, m),
            kernels::matmul_q8_par(&pool, &x, &q, &scales, n, k, m),
            "n={n} k={k} m={m}"
        );
    });
}

#[test]
fn prop_quant_backend_threaded_bit_identical_to_single_thread() {
    property(
        "int8 threads=N ≡ threads=1 bitwise: forward/prefill/decode_batch + caches",
        5,
        |g| {
            let variants = [Variant::Dense, Variant::DtrBilayer, Variant::DtrTrilayer];
            let variant = variants[g.usize(0..variants.len())];
            let cfg = ModelConfig::preset("xs", variant);
            let seed = 6000 + g.case as u64;
            let mut serial = QuantizedCpuBackend::init(&cfg, seed).unwrap();
            serial.set_threads(1);
            let mut threaded = QuantizedCpuBackend::init(&cfg, seed).unwrap();
            threaded.set_threads(g.usize(2..5));

            let s = g.usize(2..32);
            let tokens: Vec<i32> = (0..s).map(|_| g.rng.below(256) as i32).collect();
            let a = serial
                .forward(&Tensor::i32(vec![1, s], tokens.clone()))
                .unwrap();
            let b = threaded
                .forward(&Tensor::i32(vec![1, s], tokens.clone()))
                .unwrap();
            assert_eq!(a.logits, b.logits, "int8 forward logits bits diverged");
            assert_eq!(a.route, b.route, "int8 forward routing diverged");

            let chunk = g.usize(1..12);
            let mut st_s = serial.begin_decode();
            let out_s = serial.prefill_chunked(&mut st_s, &tokens, chunk).unwrap();
            let mut st_t = threaded.begin_decode();
            let out_t = threaded.prefill_chunked(&mut st_t, &tokens, chunk).unwrap();
            assert_eq!(out_s.logits, out_t.logits, "int8 prefill logits diverged");
            assert_eq!(out_s.routed, out_t.routed);
            assert_eq!(
                st_s.snapshot_kv(),
                st_t.snapshot_kv(),
                "int8 prefill cache diverged"
            );

            let bsz = g.usize(1..4);
            let mut states_s: Vec<DecodeState> = Vec::new();
            let mut states_t: Vec<DecodeState> = Vec::new();
            for bi in 0..bsz {
                let plen = g.usize(1..6);
                let prompt: Vec<i32> =
                    (0..plen).map(|i| ((bi * 31 + i * 7) % 256) as i32).collect();
                let mut ss = serial.begin_decode();
                serial.prefill(&mut ss, &prompt).unwrap();
                let mut st = threaded.begin_decode();
                threaded.prefill(&mut st, &prompt).unwrap();
                states_s.push(ss);
                states_t.push(st);
            }
            for step in 0..3 {
                let toks: Vec<i32> = (0..bsz)
                    .map(|i| ((step * 53 + i * 17) % 256) as i32)
                    .collect();
                let mut refs_s: Vec<&mut DecodeState> = states_s.iter_mut().collect();
                let outs_s = serial.decode_batch(&mut refs_s, &toks).unwrap();
                let mut refs_t: Vec<&mut DecodeState> = states_t.iter_mut().collect();
                let outs_t = threaded.decode_batch(&mut refs_t, &toks).unwrap();
                for i in 0..bsz {
                    assert_eq!(
                        outs_s[i].logits, outs_t[i].logits,
                        "int8 decode_batch seq {i} step {step} diverged"
                    );
                    assert_eq!(outs_s[i].routed, outs_t[i].routed);
                }
            }
            for (i, (ss, st)) in states_s.iter().zip(&states_t).enumerate() {
                assert_eq!(
                    ss.snapshot_kv(),
                    st.snapshot_kv(),
                    "int8 seq {i} cache diverged"
                );
            }
        },
    );
}

#[test]
fn prop_quant_decode_batch_bit_identical_to_decode_step() {
    property("int8 decode_batch == per-sequence decode_step (bitwise)", 5, |g| {
        let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
        let backend = QuantizedCpuBackend::init(&cfg, 7000 + g.case as u64).unwrap();
        let b = g.usize(1..5);
        let mut seq_states: Vec<DecodeState> = (0..b).map(|_| backend.begin_decode()).collect();
        for st in seq_states.iter_mut() {
            for _ in 0..g.usize(1..6) {
                backend.decode_step(st, g.rng.below(256) as i32).unwrap();
            }
        }
        let mut bat_states = seq_states.clone();
        for step in 0..3 {
            let toks: Vec<i32> = (0..b).map(|i| ((step * 31 + i * 17) % 256) as i32).collect();
            let seq_outs: Vec<_> = seq_states
                .iter_mut()
                .zip(&toks)
                .map(|(s, &t)| backend.decode_step(s, t).unwrap())
                .collect();
            let mut refs: Vec<&mut DecodeState> = bat_states.iter_mut().collect();
            let bat_outs = backend.decode_batch(&mut refs, &toks).unwrap();
            for i in 0..b {
                assert_eq!(seq_outs[i].logits, bat_outs[i].logits, "seq {i} step {step}");
                assert_eq!(seq_outs[i].routed, bat_outs[i].routed);
            }
        }
        for (i, (a, c)) in seq_states.iter().zip(&bat_states).enumerate() {
            assert_eq!(a.snapshot_kv(), c.snapshot_kv(), "seq {i} cache diverged");
        }
    });
}

#[test]
fn prop_quant_prefill_chunked_bit_identical_to_sequential() {
    property("int8 prefill_chunked(c) == sequential decode loop", 6, |g| {
        let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
        let backend = QuantizedCpuBackend::init(&cfg, 8000 + g.case as u64).unwrap();
        let n = g.usize(2..20);
        let tokens: Vec<i32> = (0..n).map(|_| g.rng.below(256) as i32).collect();
        let chunk = g.usize(1..24);

        let mut s_ref = backend.begin_decode();
        let mut last = None;
        for &t in &tokens {
            last = Some(backend.decode_step(&mut s_ref, t).unwrap());
        }
        let last = last.unwrap();

        let mut s_chk = backend.begin_decode();
        let out = backend.prefill_chunked(&mut s_chk, &tokens, chunk).unwrap();
        assert_eq!(last.logits, out.logits, "chunk={chunk} n={n}");
        assert_eq!(last.routed, out.routed);
        assert_eq!(
            s_ref.snapshot_kv(),
            s_chk.snapshot_kv(),
            "chunk={chunk}: cache diverged"
        );
    });
}

/// Pinned scenario whose routing margins were verified decisive (min f32
/// margin ~6e-4 against a quantization perturbation ~1e-4): int8 must
/// reproduce the f32 hard routing decisions *exactly* here.
#[test]
fn routing_decisions_match_f32_exactly_on_pinned_scenario() {
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let f32_be = CpuBackend::init(&cfg, 0).unwrap();
    let int8_be = f32_be.quantized().unwrap();
    let tokens: Vec<i32> = (0..24).map(|i| (i * 13) % 256).collect();
    let t = Tensor::i32(vec![1, 24], tokens);
    let a = f32_be.forward(&t).unwrap();
    let b = int8_be.forward(&t).unwrap();
    assert_eq!(a.route, b.route, "int8 flipped a routing decision on the pinned scenario");
    let eq = compare_routing(&a, &b);
    assert_eq!(eq.flips, 0);
    assert!(eq.min_f32_margin > 1e-4, "margin {:.2e}", eq.min_f32_margin);
}

/// The margin-aware gate across several seeds and both incremental and
/// batched evaluation orders: no decisive flips anywhere, near-tie flips
/// (if any) inside the budget.
#[test]
fn routing_equivalence_gate_holds_across_seeds() {
    for seed in [1u64, 2, 3] {
        let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
        let f32_be = CpuBackend::init(&cfg, seed).unwrap();
        let int8_be = f32_be.quantized().unwrap();
        let tokens: Vec<i32> = (0..24).map(|i| ((i * 13 + seed as usize) % 256) as i32).collect();
        let t = Tensor::i32(vec![1, 24], tokens);
        let a = f32_be.forward(&t).unwrap();
        let b = int8_be.forward(&t).unwrap();
        let eq = check_routing_equivalence(&a, &b)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(eq.decisions > 0);
    }
}

#[test]
fn quant_decode_matches_quant_forward_prefix() {
    // The incremental int8 path must agree with the batched int8 forward
    // (same tolerance as the f32 backend's decode/forward property).
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let backend = QuantizedCpuBackend::init(&cfg, 5).unwrap();
    let s = 12usize;
    let tokens: Vec<i32> = (0..s).map(|i| ((i * 29) % 256) as i32).collect();
    let fwd = backend
        .forward(&Tensor::i32(vec![1, s], tokens.clone()))
        .unwrap();
    let mut state = backend.begin_decode();
    let step = backend.prefill(&mut state, &tokens).unwrap();
    let v = cfg.vocab_size;
    let last = &fwd.logits.as_f32()[(s - 1) * v..s * v];
    assert_allclose(step.logits.as_f32(), last, 1e-3, 1e-3);
    // cache lens equal the forward pass's routed counts
    let lens = state.lens(cfg.d_model);
    for l in 0..cfg.n_layers {
        let routed: usize = fwd.route.as_f32()[l * s..(l + 1) * s]
            .iter()
            .filter(|&&r| r > 0.5)
            .count();
        assert_eq!(lens[l], routed, "layer {l} cache len != routed count");
    }
}

#[test]
fn quant_greedy_generation_is_deterministic() {
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let backend = QuantizedCpuBackend::init(&cfg, 9).unwrap();
    let prompt: Vec<i32> = (0..6).map(|i| (i * 11) % 256).collect();
    let run = |seed: u64| {
        let mut rng = Rng::new(seed);
        backend
            .generate(&prompt, 8, &SamplingParams::greedy(), &mut rng)
            .unwrap()
            .tokens
    };
    let a = run(0);
    assert_eq!(a.len(), 8);
    assert_eq!(a, run(1), "greedy int8 decode must not depend on the rng");
}
