//! Cross-layer integration: native CPU backend — the offline mirror of
//! `integration_runtime.rs` / `integration_serve.rs`.
//!
//! Drives the same shape and route-semantics assertions through
//! `CpuBackend` instead of PJRT artifacts, so `cargo test -q` exercises
//! the full DTRNet block (router → routed attention / bypass → MLP →
//! decode) with no AOT artifacts and no xla crate present.

use dtrnet::config::{ModelConfig, TrainConfig, Variant};
use dtrnet::coordinator::{SamplingParams, Trainer};
use dtrnet::data::corpus;
use dtrnet::data::Dataset;
use dtrnet::runtime::{Backend, CpuBackend, CpuTrainer, RouterMode, Tensor, TrainBackend};
use dtrnet::util::rng::Rng;

fn backend(variant: Variant, seed: u64) -> CpuBackend {
    CpuBackend::init(&ModelConfig::preset("xs", variant), seed).unwrap()
}

#[test]
fn init_is_seed_deterministic() {
    let tokens = Tensor::i32(vec![1, 16], (0..16).map(|i| i * 3 % 256).collect());
    let a = backend(Variant::DtrBilayer, 7).forward(&tokens).unwrap();
    let b = backend(Variant::DtrBilayer, 7).forward(&tokens).unwrap();
    let c = backend(Variant::DtrBilayer, 8).forward(&tokens).unwrap();
    assert_eq!(a.logits, b.logits);
    assert_ne!(a.logits, c.logits);
}

#[test]
fn fwd_shapes_and_route_semantics() {
    let be = backend(Variant::DtrBilayer, 0);
    let tok = Tensor::i32(vec![2, 64], (0..128).map(|i| i % 256).collect());
    let out = be.forward(&tok).unwrap();
    assert_eq!(out.logits.shape, vec![2, 64, 256]);
    assert!(out.logits.as_f32().iter().all(|x| x.is_finite()));
    // route: dense layers (0, 2, 3 in TDTT) must be all-ones
    assert_eq!(out.route.shape, vec![2, 4, 64]);
    let layout = be.config().layout_string();
    assert_eq!(layout, "TDTT");
    for b in 0..2 {
        for (l, k) in layout.chars().enumerate() {
            let off = (b * 4 + l) * 64;
            let frac: f32 = out.route.as_f32()[off..off + 64].iter().sum::<f32>() / 64.0;
            if k == 'T' {
                assert_eq!(frac, 1.0, "dense layer {l} must attend all");
            } else {
                assert!(frac < 1.0, "DTR layer {l} should bypass some tokens");
            }
        }
    }
    // g_attn on dense layers is pinned to 1.0; on DTR layers it is a
    // softmax column, strictly inside (0, 1)
    for (l, k) in layout.chars().enumerate() {
        let row = &out.g_attn.as_f32()[l * 64..(l + 1) * 64];
        if k == 'T' {
            assert!(row.iter().all(|&g| g == 1.0));
        } else {
            assert!(row.iter().all(|&g| g > 0.0 && g < 1.0));
        }
    }
}

#[test]
fn fwd_is_deterministic() {
    let be = backend(Variant::Dense, 3);
    let tok = Tensor::i32(vec![2, 64], vec![42; 128]);
    let a = be.forward(&tok).unwrap();
    let b = be.forward(&tok).unwrap();
    assert_eq!(a.logits, b.logits);
}

#[test]
fn prefill_matches_fwd_prefix() {
    // the decode path must agree with the training-shape forward
    let be = backend(Variant::DtrBilayer, 1);
    let toks: Vec<i32> = (0..32).map(|i| (i * 13 % 256) as i32).collect();
    let fwd = be.forward(&Tensor::i32(vec![1, 32], toks.clone())).unwrap();

    let mut state = be.begin_decode();
    let last = be.prefill(&mut state, &toks).unwrap();
    assert_eq!(last.logits.shape, vec![256]);

    // fwd logits at position 31 — causal prefix equality
    let v = 256;
    let fwd_row = &fwd.logits.as_f32()[31 * v..32 * v];
    dtrnet::testing::assert_allclose(last.logits.as_f32(), fwd_row, 1e-3, 1e-3);

    // lens: dense layers cached all 32 tokens; DTR layer fewer
    let lens = state.lens(be.config().d_model);
    let layout = be.config().layout_string();
    for (l, k) in layout.chars().enumerate() {
        if k == 'T' {
            assert_eq!(lens[l], 32);
        } else {
            assert!(lens[l] < 32, "DTR layer should cache fewer (got {})", lens[l]);
        }
    }
}

#[test]
fn decode_step_appends_kv_only_when_routed() {
    let be = backend(Variant::DtrBilayer, 2);
    let d = be.config().d_model;
    let mut state = be.begin_decode();
    let mut prev = state.lens(d);
    for t in 0..10 {
        let step = be.decode_step(&mut state, (t * 31 % 256) as i32).unwrap();
        let lens = state.lens(d);
        // invariant: lens increase exactly by the routing decision
        for l in 0..be.config().n_layers {
            let expect = prev[l] + step.routed[l] as usize;
            assert_eq!(lens[l], expect, "layer {l} at step {t}");
        }
        prev = lens;
    }
    // dense layers cached all 10; DTR layer ≤ 10
    let layout = be.config().layout_string();
    for (l, k) in layout.chars().enumerate() {
        if k == 'T' {
            assert_eq!(prev[l], 10);
        } else {
            assert!(prev[l] <= 10);
        }
    }
}

#[test]
fn greedy_decoding_is_deterministic() {
    let be = backend(Variant::DtrBilayer, 5);
    let prompt: Vec<i32> = (0..6).map(|i| i * 11 % 256).collect();
    let run = |seed: u64| {
        let mut rng = Rng::new(seed);
        be.generate(&prompt, 8, &SamplingParams::greedy(), &mut rng)
            .unwrap()
            .tokens
    };
    let a = run(0);
    assert_eq!(a.len(), 8);
    assert_eq!(a, run(1), "greedy decode must not depend on the rng");
}

#[test]
fn temperature_sampling_differs_from_greedy() {
    let be = backend(Variant::DtrBilayer, 5);
    let prompt: Vec<i32> = (0..8).map(|i| i * 7 % 256).collect();
    let mut rng = Rng::new(9);
    let greedy = be
        .generate(&prompt, 12, &SamplingParams::greedy(), &mut rng)
        .unwrap();
    let hot = be
        .generate(&prompt, 12, &SamplingParams::temperature(1.5), &mut rng)
        .unwrap();
    // untrained logits are near-uniform → hot sampling almost surely differs
    assert_ne!(greedy.tokens, hot.tokens);
}

#[test]
fn generate_reports_routing_fractions() {
    let be = backend(Variant::DtrBilayer, 4);
    let prompt: Vec<i32> = (0..10).map(|i| i * 3 % 256).collect();
    let mut rng = Rng::new(2);
    let out = be
        .generate(&prompt, 6, &SamplingParams::greedy(), &mut rng)
        .unwrap();
    let layout = be.config().layout_string();
    for (l, k) in layout.chars().enumerate() {
        let f = out.attn_frac[l];
        assert!((0.0..=1.0).contains(&f));
        if k == 'T' {
            assert_eq!(f, 1.0, "dense layer {l} attends every token");
        }
    }
}

#[test]
fn topk_router_selects_exact_capacity() {
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let mut be = CpuBackend::init(&cfg, 0).unwrap();
    be.set_router_mode(RouterMode::ExpertChoice { capacity: 0.1 });
    let s = 30;
    let tok = Tensor::i32(vec![1, s], (0..s as i32).collect());
    let out = be.forward(&tok).unwrap();
    let k = (0.1f64 * s as f64).ceil() as usize; // = 3
    for (l, kind) in cfg.layout_string().chars().enumerate() {
        let row = &out.route.as_f32()[l * s..(l + 1) * s];
        let routed = row.iter().filter(|&&r| r > 0.5).count();
        if kind == 'D' {
            assert_eq!(routed, k, "layer {l}: capacity 0.1 of {s} must route {k}");
        } else {
            assert_eq!(routed, s);
        }
    }
}

#[test]
fn checkpoint_file_handoff_preserves_outputs() {
    let be = backend(Variant::DtrBilayer, 11);
    let dir = std::env::temp_dir().join("dtrnet_cpu_ck_test");
    let path = dir.join("cpu.dtck");
    be.to_checkpoint().save(&path).unwrap();
    let ck = dtrnet::runtime::Checkpoint::load(&path).unwrap();
    let re = CpuBackend::from_checkpoint(be.config(), &ck).unwrap();
    let tok = Tensor::i32(vec![1, 20], (0..20).map(|i| i * 9 % 256).collect());
    assert_eq!(
        be.forward(&tok).unwrap().logits,
        re.forward(&tok).unwrap().logits
    );
}

#[test]
fn train_checkpoint_serve_eval_roundtrip() {
    // The offline train→serve loop end to end: orchestrated training on
    // the CPU trainer, DTCK checkpoint to disk, reload into the serving
    // backend, then eval + generate on the trained weights.
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let hp = TrainConfig {
        steps: 6,
        batch: 2,
        seq: 24,
        log_every: 100,
        ..Default::default()
    };
    let mut rng = Rng::new(41);
    let data = Dataset::new(corpus::markov_corpus(&mut rng, 256, 60 * hp.seq, 12), hp.seq);
    let mut tb = CpuTrainer::new(&cfg, &hp).unwrap();
    let dir = std::env::temp_dir().join("dtrnet_train_roundtrip");
    let path = dir.join("trained.dtck");
    let report = {
        let mut trainer = Trainer::new(&mut tb, "xs_dtr_bilayer");
        let report = trainer.run(&hp, &data, None).unwrap();
        trainer.save_checkpoint(&path).unwrap();
        report
    };
    assert_eq!(report.steps, hp.steps);
    assert_eq!(report.losses.len(), hp.steps);
    assert!(report.final_loss.is_finite());
    assert_eq!(report.attn_frac.len(), cfg.n_layers);
    assert!(report.tokens_per_s > 0.0);

    // serve path: the saved checkpoint must load and match the trainer's
    // in-memory weights bit for bit.
    let ck = dtrnet::runtime::Checkpoint::load(&path).unwrap();
    let served = CpuBackend::from_checkpoint(&cfg, &ck).unwrap();
    let probe = Tensor::i32(vec![1, 10], (0..10).map(|i| i * 11 % 256).collect());
    assert_eq!(
        served.forward(&probe).unwrap().logits,
        tb.to_backend().unwrap().forward(&probe).unwrap().logits,
        "served weights differ from trained weights"
    );

    // eval + generate run on the trained checkpoint
    let r = dtrnet::eval::perplexity_backend(&served, &data, 2, 2).unwrap();
    assert!(r.ppl.is_finite() && r.ppl > 1.0);
    let mut grng = Rng::new(9);
    let gen = served
        .generate(&[5, 6, 7], 8, &SamplingParams::greedy(), &mut grng)
        .unwrap();
    assert_eq!(gen.tokens.len(), 8);
}

#[test]
fn train_checkpoint_quantized_serve_roundtrip() {
    // The train→quantized-serve handoff: train briefly, save a DTCK
    // checkpoint into a directory that does not exist yet (the --save
    // parent-dir contract), reload it int8-quantized (`--quant int8`
    // semantics), then eval + decode on the quantized trained weights.
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let hp = TrainConfig {
        steps: 5,
        batch: 2,
        seq: 24,
        log_every: 100,
        ..Default::default()
    };
    let mut rng = Rng::new(43);
    let data = Dataset::new(corpus::markov_corpus(&mut rng, 256, 60 * hp.seq, 12), hp.seq);
    let mut tb = CpuTrainer::new(&cfg, &hp).unwrap();
    let root = std::env::temp_dir().join("dtrnet_train_quant_roundtrip");
    let _ = std::fs::remove_dir_all(&root);
    let path = root.join("nested").join("trained.dtck");
    {
        let mut trainer = Trainer::new(&mut tb, "xs_dtr_bilayer_q8");
        trainer.run(&hp, &data, None).unwrap();
        trainer.save_checkpoint(&path).unwrap(); // must create parent dirs
    }
    assert!(path.exists(), "--save must create missing parent directories");

    let ck = dtrnet::runtime::Checkpoint::load(&path).unwrap();
    let f32_be = CpuBackend::from_checkpoint(&cfg, &ck).unwrap();
    let int8_be = dtrnet::runtime::QuantizedCpuBackend::from_checkpoint(&cfg, &ck).unwrap();
    assert!(int8_be.weight_bytes().compression() >= 3.5);

    // int8 serving of the trained weights: finite perplexity, within 1%
    // of the f32 backend on the same corpus.
    let rf = dtrnet::eval::perplexity_backend(&f32_be, &data, 2, 2).unwrap();
    let rq = dtrnet::eval::perplexity_backend(&int8_be, &data, 2, 2).unwrap();
    assert!(rq.ppl.is_finite() && rq.ppl > 1.0);
    assert!(
        (rq.ppl - rf.ppl).abs() / rf.ppl < 0.01,
        "trained int8 ppl drifted from f32: {} vs {}",
        rq.ppl,
        rf.ppl
    );
    // No decisive routing flips on the trained weights (near-ties may
    // move — see DESIGN.md §Quantization — but a confident router must
    // survive quantization).
    let toks = Tensor::i32(vec![1, hp.seq], data.window(0));
    let eq = dtrnet::runtime::quant::compare_routing(
        &f32_be.forward(&toks).unwrap(),
        &int8_be.forward(&toks).unwrap(),
    );
    assert_eq!(eq.decisive_flips, 0, "flips {} of {}", eq.flips, eq.decisions);

    // decode runs end to end on the quantized trained model
    let mut grng = Rng::new(3);
    let gen = int8_be
        .generate(&[5, 6, 7], 8, &SamplingParams::greedy(), &mut grng)
        .unwrap();
    assert_eq!(gen.tokens.len(), 8);
}

#[test]
fn trained_loss_beats_init_on_fixed_batch() {
    // Keep stepping one batch: the trained model must fit it better than
    // the init did (the offline mirror of the CI train-smoke gate).
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let hp = TrainConfig {
        steps: 10,
        batch: 2,
        seq: 20,
        ..Default::default()
    };
    let mut tb = CpuTrainer::new(&cfg, &hp).unwrap();
    let mut rng = Rng::new(17);
    let tokens: Vec<i32> = (0..hp.batch * hp.seq)
        .map(|_| rng.below(64) as i32)
        .collect();
    let first = tb.train_step(&tokens, 1, 3e-3, 0).unwrap().loss;
    let mut last = first;
    for s in 2..=hp.steps {
        last = tb.train_step(&tokens, s, 3e-3, 0).unwrap().loss;
    }
    assert!(last < first, "training did not reduce loss: {first:.4} -> {last:.4}");
}

#[test]
fn eval_harness_runs_against_cpu_backend() {
    let be = backend(Variant::DtrBilayer, 0);
    let mut rng = Rng::new(7);
    let seq = 32;
    let data = Dataset::new(corpus::markov_corpus(&mut rng, 256, 40 * seq, 12), seq);
    let r = dtrnet::eval::perplexity_backend(&be, &data, 2, 3).unwrap();
    assert!(r.ppl.is_finite() && r.ppl > 1.0);
    assert!(r.n_tokens > 0);
    let fr = r.routing.fractions();
    // TDTT layout: dense layers attend 100%
    assert_eq!(fr[0], 1.0);
    assert_eq!(fr[2], 1.0);
    assert_eq!(fr[3], 1.0);
    assert!(fr[1] <= 1.0);
}
