//! Deterministic replay of the committed fuzz corpus — tier-1 CI
//! exercises every seed (plus a cheap bit-flip sweep around each one)
//! through the differential oracles without any fuzzer toolchain.
//!
//! The mutational fuzzers live in the `dtrnet-fuzz` workspace member;
//! when one finds a crash it writes the input to `fuzz/artifacts/` and
//! the fix lands with the input promoted into `fuzz/corpus/`, where
//! this test keeps it pinned forever.

use std::path::PathBuf;

use dtrnet::coordinator::http::torture::{check_http_bytes, check_json_bytes};

/// Load `fuzz/corpus/<name>` sorted by file name (root manifest dir —
/// the corpus is shared with the `dtrnet-fuzz` member).
fn corpus(name: &str) -> Vec<(String, Vec<u8>)> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("fuzz")
        .join("corpus")
        .join(name);
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("missing corpus {}: {e}", dir.display()))
        .filter_map(|e| e.ok())
        .collect();
    entries.sort_by_key(|e| e.file_name());
    entries
        .into_iter()
        .filter(|e| e.path().is_file())
        .map(|e| {
            (
                e.file_name().to_string_lossy().into_owned(),
                std::fs::read(e.path()).expect("read corpus file"),
            )
        })
        .collect()
}

/// Run `check` on every seed and on a deterministic single-bit-flip
/// sweep of it (stride keeps the sweep bounded for longer seeds).
fn replay(seeds: &[(String, Vec<u8>)], check: impl Fn(&[u8])) {
    for (name, data) in seeds {
        check(data);
        let stride = (data.len() / 64).max(1);
        for i in (0..data.len()).step_by(stride) {
            for bit in [0u8, 2, 5, 7] {
                let mut m = data.clone();
                m[i] ^= 1 << bit;
                // A panic here names the seed via the unwind payload of
                // the oracle; the outer assert message adds the file.
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| check(&m)));
                assert!(r.is_ok(), "oracle panicked on {name} with byte {i} bit {bit} flipped");
            }
        }
    }
}

#[test]
fn http_corpus_replays_clean() {
    let seeds = corpus("http");
    assert!(seeds.len() >= 12, "http corpus shrank to {} seeds", seeds.len());
    replay(&seeds, |d| {
        check_http_bytes(d);
    });
}

#[test]
fn json_corpus_replays_clean() {
    let seeds = corpus("json");
    assert!(seeds.len() >= 8, "json corpus shrank to {} seeds", seeds.len());
    replay(&seeds, |d| {
        check_json_bytes(d);
    });
}

#[test]
fn corpus_has_both_verdicts() {
    // The corpus must keep exercising both sides of each oracle:
    // at least one JSON seed each machine accepts and one it rejects,
    // and at least one HTTP seed that parses a request cleanly and one
    // that trips a protocol error.
    let json = corpus("json");
    let accepted = json.iter().filter(|(_, d)| check_json_bytes(d)).count();
    assert!(accepted >= 1, "no accepted JSON seeds left");
    assert!(accepted < json.len(), "no rejected JSON seeds left");

    let http = corpus("http");
    let mut ok_requests = 0usize;
    let mut errors = 0usize;
    for (_, d) in &http {
        let out = check_http_bytes(d);
        if !out.requests.is_empty() {
            ok_requests += 1;
        }
        if out.error.is_some() {
            errors += 1;
        }
    }
    assert!(ok_requests >= 3, "corpus lost its well-formed HTTP seeds");
    assert!(errors >= 3, "corpus lost its malformed HTTP seeds");
}
