//! Socket-level integration tests for the HTTP/1.1 front end: real TCP
//! connections against a live engine, covering streamed and buffered
//! generation, bitwise response stability under arbitrary request
//! chunking, the malformed-input status matrix, premature closes, read
//! deadlines, keep-alive/pipelining, and overload backpressure — with
//! the final [`HttpReport`] reconciled against what the clients saw
//! (and KV slot accounting back at idle) after every scenario.

use std::io::Write;
use std::net::SocketAddr;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::coordinator::http::frontend::StopHandle;
use dtrnet::coordinator::http::{generate_request, get_request, HttpClient};
use dtrnet::coordinator::{HttpReport, ListenConfig, NetFrontend, PrefillMode, ServerConfig};
use dtrnet::runtime::CpuBackend;

const TIMEOUT: Duration = Duration::from_secs(30);

struct TestServer {
    addr: SocketAddr,
    stop: StopHandle,
    handle: thread::JoinHandle<anyhow::Result<HttpReport>>,
}

/// Bind on an ephemeral loopback port and serve from a background
/// thread that owns the backend (the engine runs on that thread; the
/// front end spawns its own accept/connection threads).
fn start(scfg: ServerConfig, lcfg: ListenConfig) -> TestServer {
    let (tx, rx) = mpsc::channel();
    let handle = thread::spawn(move || {
        let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
        let be = CpuBackend::init(&cfg, 42)?;
        let fe = NetFrontend::bind("127.0.0.1:0", lcfg)?;
        let _ = tx.send((fe.local_addr()?, fe.stop_handle()));
        fe.run(&be, scfg, None)
    });
    match rx.recv() {
        Ok((addr, stop)) => TestServer { addr, stop, handle },
        Err(_) => {
            let err = handle.join().expect("server thread panicked");
            panic!("server failed to start: {:?}", err.err());
        }
    }
}

impl TestServer {
    fn client(&self) -> HttpClient {
        HttpClient::connect(self.addr, TIMEOUT).expect("connect")
    }

    fn finish(self) -> HttpReport {
        self.stop.stop();
        self.handle
            .join()
            .expect("server thread panicked")
            .expect("server errored")
    }
}

fn scfg() -> ServerConfig {
    ServerConfig {
        slots: 2,
        prefill: PrefillMode::Chunked(16),
        ..Default::default()
    }
}

/// NDJSON rows of a streamed response body.
fn rows(body: &[u8]) -> Vec<String> {
    std::str::from_utf8(body)
        .expect("stream body must be utf-8")
        .lines()
        .map(|l| l.to_string())
        .collect()
}

#[test]
fn streamed_generation_roundtrips_over_tcp() {
    let srv = start(scfg(), ListenConfig::default());
    let mut c = srv.client();

    let body = "{\"prompt\":[7,11,13],\"max_new_tokens\":6,\"stream\":true}";
    let resp = c.roundtrip(&generate_request(body, false)).expect("stream roundtrip");
    assert_eq!(resp.status, 200);
    assert!(resp.chunked, "stream=true must use chunked transfer encoding");
    assert_eq!(resp.header("content-type"), Some("application/x-ndjson"));
    let rows = rows(&resp.body);
    assert_eq!(rows.len(), 7, "6 token rows + 1 done row: {rows:?}");
    for row in &rows[..6] {
        assert!(row.starts_with("{\"token\":"), "bad token row {row}");
    }
    let done = &rows[6];
    assert!(done.contains("\"done\":true"), "bad done row {done}");
    assert!(done.contains("\"n_tokens\":6"), "bad done row {done}");
    assert!(done.contains("\"finish\":"), "bad done row {done}");
    assert!(resp.chunk_ms.len() >= 2, "tokens must arrive as separate chunks");

    // Keep-alive: same connection serves a buffered generate and a
    // health probe afterwards.
    let resp = c
        .roundtrip(&generate_request("{\"text\":\"hi\",\"max_new_tokens\":3}", false))
        .expect("buffered roundtrip");
    assert_eq!(resp.status, 200);
    assert!(!resp.chunked);
    let text = String::from_utf8(resp.body).unwrap();
    assert!(text.contains("\"tokens\":["), "buffered body must inline tokens: {text}");
    assert!(text.contains("\"n_tokens\":3"), "{text}");

    let resp = c.roundtrip(&get_request("/health", true)).expect("health");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body, b"{\"ok\":true}");

    drop(c);
    let rep = srv.finish();
    assert_eq!(rep.net.status(200), 3);
    assert_eq!(rep.net.requests, 3);
    assert_eq!(rep.net.connections, 1);
    assert_eq!(rep.engine.completed, 2);
    assert_eq!(rep.engine.rejected, 0);
    assert_eq!(rep.engine.pool.pages_allocated, 0, "KV pages must drain to idle");
}

#[test]
fn response_bytes_are_identical_under_request_chunking() {
    // No Date header, greedy decoding, one request at a time: the exact
    // response bytes must not depend on how the request bytes arrive.
    let srv = start(scfg(), ListenConfig::default());
    let streamed =
        generate_request("{\"prompt\":[3,5,8],\"max_new_tokens\":5,\"stream\":true}", true);
    let buffered = generate_request("{\"prompt\":[3,5,8],\"max_new_tokens\":5}", true);

    for req in [&streamed, &buffered] {
        let mut raws: Vec<Vec<u8>> = Vec::new();
        // One-shot, 16-byte dribble, and 1-byte dribble of the head
        // with the body split in two.
        let plans: Vec<Vec<&[u8]>> = vec![
            vec![&req[..]],
            req.chunks(16).collect(),
            {
                let head_end = req.len() - 8;
                let mut plan: Vec<&[u8]> = req[..head_end].chunks(1).collect();
                plan.push(&req[head_end..]);
                plan
            },
        ];
        for plan in plans {
            let mut c = srv.client();
            for (i, seg) in plan.iter().enumerate() {
                c.stream().write_all(seg).expect("dribble write");
                if i % 8 == 0 {
                    thread::sleep(Duration::from_millis(1));
                }
            }
            let resp = c.read_response().expect("read after dribble");
            assert_eq!(resp.status, 200);
            raws.push(resp.raw);
        }
        assert_eq!(raws[0], raws[1], "response changed under 16-byte chunking");
        assert_eq!(raws[0], raws[2], "response changed under byte dribble");
    }

    let rep = srv.finish();
    assert_eq!(rep.net.status(200), 6);
    assert_eq!(rep.engine.completed, 6);
    assert_eq!(rep.engine.pool.pages_allocated, 0);
}

#[test]
fn pipelined_requests_on_one_connection() {
    let srv = start(scfg(), ListenConfig::default());
    let mut c = srv.client();

    // Two generates and a health probe written back-to-back in a single
    // write; responses must come back in order on the same connection.
    let mut batch = Vec::new();
    batch.extend_from_slice(&generate_request("{\"prompt\":[1],\"max_new_tokens\":2}", false));
    batch.extend_from_slice(&generate_request("{\"prompt\":[2],\"max_new_tokens\":2}", false));
    batch.extend_from_slice(&get_request("/health", true));
    c.send(&batch).expect("pipelined send");

    let first = c.read_response().expect("first pipelined");
    let second = c.read_response().expect("second pipelined");
    let third = c.read_response().expect("third pipelined");
    assert_eq!(first.status, 200);
    assert_eq!(second.status, 200);
    assert_eq!(third.body, b"{\"ok\":true}");
    assert!(String::from_utf8(first.body).unwrap().contains("\"n_tokens\":2"));

    drop(c);
    let rep = srv.finish();
    assert_eq!(rep.net.status(200), 3);
    assert_eq!(rep.net.connections, 1);
    assert_eq!(rep.engine.completed, 2);
    assert_eq!(rep.engine.pool.pages_allocated, 0);
}

#[test]
fn malformed_requests_map_to_specific_statuses() {
    let srv = start(scfg(), ListenConfig::default());

    fn post(body_bytes: &[u8]) -> Vec<u8> {
        let mut req = format!(
            "POST /generate HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n",
            body_bytes.len()
        )
        .into_bytes();
        req.extend_from_slice(body_bytes);
        req
    }

    let cases: Vec<(&str, Vec<u8>, u16)> = vec![
        ("garbage request line", b"NOT HTTP AT ALL\r\n\r\n".to_vec(), 400),
        ("http/2.0", b"GET /health HTTP/2.0\r\nHost: t\r\n\r\n".to_vec(), 505),
        (
            "transfer-encoding request",
            b"POST /generate HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n".to_vec(),
            501,
        ),
        (
            "post without content-length",
            b"POST /generate HTTP/1.1\r\nHost: t\r\n\r\n".to_vec(),
            411,
        ),
        (
            "oversized content-length",
            b"POST /generate HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n".to_vec(),
            413,
        ),
        ("header bomb", {
            let mut b = b"GET /health HTTP/1.1\r\n".to_vec();
            for i in 0..100 {
                b.extend_from_slice(format!("X-Bomb-{i}: x\r\n").as_bytes());
            }
            b.extend_from_slice(b"\r\n");
            b
        }, 431),
        ("truncated json body", post(b"{\"prompt\":[1"), 400),
        ("invalid utf-8 body", post(b"{\"text\":\"\xff\xfe\"}"), 400),
        ("unknown field", post(b"{\"prompt\":[1],\"bogus\":1}"), 400),
        ("prompt and text together", post(b"{\"prompt\":[1],\"text\":\"x\"}"), 400),
        ("neither prompt nor text", post(b"{\"max_new_tokens\":2}"), 400),
        ("out-of-vocab prompt", post(b"{\"prompt\":[999999]}"), 400),
        ("empty prompt", post(b"{\"prompt\":[]}"), 400),
        ("method not allowed", get_request("/generate", true), 405),
        ("unknown target", get_request("/nowhere", true), 404),
    ];

    for (name, req, want) in &cases {
        let mut c = srv.client();
        let resp = c.roundtrip(req).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(resp.status, *want, "{name}");
        let text = String::from_utf8_lossy(&resp.body).into_owned();
        assert!(text.contains("\"error\":"), "{name}: body must carry an error: {text}");
        if *want == 405 {
            assert_eq!(resp.header("allow"), Some("POST, OPTIONS"), "{name}");
        }
    }

    // The server must still be fully alive afterwards.
    let mut c = srv.client();
    let resp = c
        .roundtrip(&generate_request("{\"prompt\":[1,2],\"max_new_tokens\":2}", true))
        .expect("post-matrix generate");
    assert_eq!(resp.status, 200);

    let rep = srv.finish();
    // Stream-level rejections: garbage, 2.0, TE, no-CL, big-CL, bomb,
    // plus the invalid-UTF-8 body caught by the incremental JSON check.
    assert_eq!(rep.net.parse_errors, 7);
    assert_eq!(rep.net.status(400), 8);
    for code in [404, 405, 411, 413, 431, 501, 505] {
        assert_eq!(rep.net.status(code), 1, "status {code} count");
    }
    // `requests` counts fully parsed requests only: the 7 stream-level
    // rejections above never complete one.
    assert_eq!(rep.net.requests, cases.len() as u64 - 7 + 1);
    assert_eq!(rep.engine.completed, 1);
    assert_eq!(rep.engine.pool.pages_allocated, 0, "no malformed request may leak pages");
}

#[test]
fn premature_close_and_read_deadline_are_handled() {
    // The mid-request read deadline (150 ms) is deliberately much
    // shorter than the idle keep-alive window (1500 ms): a stalled
    // half-request must 408 fast, while a quiet keep-alive connection
    // outlives the read deadline and only closes (silently) at the
    // idle timeout.
    let lcfg = ListenConfig {
        read_timeout_ms: 150,
        idle_timeout_ms: 1_500,
        ..Default::default()
    };
    let srv = start(scfg(), lcfg);

    // Half a request, then the client vanishes: clean early-close drop.
    {
        let mut c = srv.client();
        c.stream()
            .write_all(b"POST /generate HTTP/1.1\r\nContent-Le")
            .expect("partial write");
    }
    thread::sleep(Duration::from_millis(50));

    // Half a request, then the client stalls: 408 within the deadline.
    let mut c = srv.client();
    c.send(b"POST /generate HTTP/1.1\r\nContent-Le").expect("partial send");
    let resp = c.read_response().expect("deadline response");
    assert_eq!(resp.status, 408);

    // An idle keep-alive connection survives silence well past the
    // mid-request read deadline...
    let mut idle = srv.client();
    idle.send(&get_request("/health", false)).expect("health send");
    assert_eq!(idle.read_response().expect("health").status, 200);
    thread::sleep(Duration::from_millis(500));
    idle.send(&get_request("/health", false)).expect("post-idle send");
    assert_eq!(
        idle.read_response().expect("idle connection must outlive the read deadline").status,
        200
    );
    // ...and then timing out idle is NOT an error: no response, just a
    // quiet close (the read_response fails cleanly, no 408 recorded).
    assert!(idle.read_response().is_err(), "idle close must not carry a response");

    // And the server still serves.
    let mut c = srv.client();
    assert_eq!(c.roundtrip(&get_request("/health", true)).expect("alive").status, 200);

    let rep = srv.finish();
    assert!(rep.net.early_closes >= 1, "early close must be counted");
    assert_eq!(rep.net.status(408), 1, "the idle close must not add a 408");
    assert_eq!(rep.net.status(200), 3);
    assert_eq!(rep.engine.pool.pages_allocated, 0);
}

#[test]
fn head_and_options_are_answered() {
    use std::io::Read;

    let srv = start(scfg(), ListenConfig::default());

    // HEAD /health: the GET response's status line and headers
    // (Content-Length included), no body bytes on the wire. Read the
    // raw head manually — the client helper would wait for a body.
    let mut c = srv.client();
    c.send(b"HEAD /health HTTP/1.1\r\nHost: t\r\n\r\n").expect("head send");
    let mut raw = Vec::new();
    let mut byte = [0u8; 1];
    while !raw.ends_with(b"\r\n\r\n") {
        c.stream().read_exact(&mut byte).expect("head response bytes");
        raw.push(byte[0]);
        assert!(raw.len() < 4096, "unterminated HEAD response head");
    }
    let head = String::from_utf8(raw).expect("ascii head");
    assert!(head.starts_with("HTTP/1.1 200 OK\r\n"), "{head}");
    assert!(
        head.contains(&format!("Content-Length: {}\r\n", "{\"ok\":true}".len())),
        "HEAD must carry the GET body's Content-Length: {head}"
    );
    // Framing stays intact: the same connection serves a normal GET.
    let resp = c.roundtrip(&get_request("/health", false)).expect("get after head");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body, b"{\"ok\":true}");

    // OPTIONS: 204 + the target's Allow set, empty body.
    let resp = c
        .roundtrip(b"OPTIONS /generate HTTP/1.1\r\nHost: t\r\n\r\n")
        .expect("options generate");
    assert_eq!(resp.status, 204);
    assert_eq!(resp.header("allow"), Some("POST, OPTIONS"));
    assert!(resp.body.is_empty());
    let resp = c
        .roundtrip(b"OPTIONS /health HTTP/1.1\r\nHost: t\r\n\r\n")
        .expect("options health");
    assert_eq!(resp.status, 204);
    assert_eq!(resp.header("allow"), Some("GET, HEAD, OPTIONS"));

    // HEAD of an unknown target: 404 headers only, connection reusable.
    c.send(b"HEAD /nowhere HTTP/1.1\r\nHost: t\r\n\r\n").expect("head 404 send");
    let mut raw = Vec::new();
    while !raw.ends_with(b"\r\n\r\n") {
        c.stream().read_exact(&mut byte).expect("head 404 bytes");
        raw.push(byte[0]);
        assert!(raw.len() < 4096, "unterminated HEAD response head");
    }
    assert!(
        String::from_utf8(raw).expect("ascii head").starts_with("HTTP/1.1 404"),
        "HEAD on an unknown target must 404"
    );
    let resp = c.roundtrip(&get_request("/health", true)).expect("get after 404 head");
    assert_eq!(resp.status, 200);

    drop(c);
    let rep = srv.finish();
    assert_eq!(rep.net.status(200), 3);
    assert_eq!(rep.net.status(204), 2);
    assert_eq!(rep.net.status(404), 1);
    assert_eq!(rep.net.requests, 6);
    assert_eq!(rep.net.connections, 1);
    assert_eq!(rep.net.parse_errors, 0);
}

#[test]
fn overload_sheds_load_with_429_and_recovers() {
    // One slot, one queue entry: a concurrent burst must see a mix of
    // 200s and prompt 429s, and the engine accounting must close.
    let scfg = ServerConfig {
        slots: 1,
        max_queue: 1,
        prefill: PrefillMode::Chunked(16),
        ..Default::default()
    };
    let srv = start(scfg, ListenConfig::default());
    let addr = srv.addr;

    let burst = 6;
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(burst));
    let mut workers = Vec::new();
    for i in 0..burst {
        let barrier = std::sync::Arc::clone(&barrier);
        workers.push(thread::spawn(move || {
            let mut c = HttpClient::connect(addr, TIMEOUT).expect("connect");
            let body = format!("{{\"prompt\":[{}],\"max_new_tokens\":12}}", i + 1);
            barrier.wait();
            c.roundtrip(&generate_request(&body, true)).expect("burst roundtrip")
        }));
    }
    let mut ok = 0u64;
    let mut rejected = 0u64;
    for w in workers {
        let resp = w.join().expect("client thread panicked");
        match resp.status {
            200 => {
                ok += 1;
                assert!(String::from_utf8(resp.body).unwrap().contains("\"n_tokens\":12"));
            }
            429 => {
                rejected += 1;
                assert!(resp.header("retry-after").is_some(), "429 must carry Retry-After");
            }
            other => panic!("unexpected status {other} under overload"),
        }
    }
    assert!(ok >= 1, "some of the burst must be served");
    assert!(rejected >= 1, "a 1-deep queue must shed load");

    let rep = srv.finish();
    assert_eq!(rep.net.status(200), ok);
    assert_eq!(rep.net.status(429), rejected);
    assert_eq!(rep.engine.rejected as u64, rejected, "engine and edge must agree on rejects");
    assert_eq!((rep.engine.completed + rep.engine.evicted) as u64, ok);
    assert_eq!(rep.engine.pool.pages_allocated, 0, "overload must not leak KV pages");
}

#[test]
fn metrics_endpoint_reports_live_counters() {
    use dtrnet::util::json::Json;

    let srv = start(scfg(), ListenConfig::default());
    let mut c = srv.client();
    let resp = c
        .roundtrip(&generate_request("{\"prompt\":[1,2],\"max_new_tokens\":3}", false))
        .expect("generate");
    assert_eq!(resp.status, 200);

    let resp = c.roundtrip(&get_request("/metrics", true)).expect("metrics");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("content-type"), Some("application/json"));
    let text = String::from_utf8(resp.body).expect("utf-8 metrics body");
    let js = Json::parse(&text).expect("metrics body must parse as json");

    // Socket-edge block: both requests on this connection are counted
    // (the /metrics request itself included), bytes flowed both ways.
    assert!(js.path("net.requests").unwrap().as_f64().unwrap() >= 2.0, "{text}");
    assert!(js.path("net.bytes_in").unwrap().as_f64().unwrap() > 0.0);
    assert!(js.path("net.bytes_out").unwrap().as_f64().unwrap() > 0.0);
    assert!(js.path("net.by_status").is_some(), "{text}");

    // Engine block: the finished generate is visible, pages drained.
    assert_eq!(js.path("engine.completed").unwrap().as_f64().unwrap(), 1.0, "{text}");
    assert_eq!(js.path("engine.tokens_generated").unwrap().as_f64().unwrap(), 3.0);
    assert_eq!(js.path("engine.kv_pages_allocated").unwrap().as_f64().unwrap(), 0.0);
    assert!(js.path("engine.kv_pages_peak").unwrap().as_f64().unwrap() > 0.0);
    assert!(js.path("engine.queue_depth").is_some());
    assert!(js.path("engine.kv_resident_pages_peak").is_some());

    drop(c);
    let rep = srv.finish();
    assert_eq!(rep.net.requests, 2);
    assert_eq!(rep.net.status(200), 2);
    assert_eq!(rep.engine.completed, 1);
}

#[test]
fn client_disconnect_cancels_generation_and_drains_kv() {
    use dtrnet::coordinator::FinishReason;
    use dtrnet::util::json::Json;
    use std::io::Read;

    let scfg = ServerConfig {
        slots: 1,
        prefill: PrefillMode::Chunked(16),
        ..Default::default()
    };
    let srv = start(scfg, ListenConfig::default());

    // Up to a few attempts: the disconnect must land while the slot is
    // still generating for the cancel to beat natural retirement.
    let read_metrics = |srv: &TestServer| {
        let mut c = srv.client();
        let resp = c.roundtrip(&get_request("/metrics", true)).expect("metrics");
        let text = String::from_utf8(resp.body).expect("utf-8");
        Json::parse(&text).expect("metrics json")
    };
    let num = |js: &Json, p: &str| js.path(p).unwrap().as_f64().unwrap();

    let mut cancelled = false;
    'attempts: for _ in 0..3 {
        let finished_before = num(&read_metrics(&srv), "engine.requests_finished");
        {
            let mut c = srv.client();
            c.send(&generate_request(
                "{\"prompt\":[5,6,7],\"max_new_tokens\":10000,\"stream\":true}",
                false,
            ))
            .expect("stream send");
            // Wait for generation to actually start, then vanish.
            let mut byte = [0u8; 1];
            c.stream().read_exact(&mut byte).expect("first stream byte");
        } // socket drops here, mid-stream

        // The engine must notice the dead sink, cancel the request, and
        // drain its slot and pages — observable through /metrics.
        for _ in 0..250 {
            let js = read_metrics(&srv);
            if num(&js, "engine.cancelled") >= 1.0 {
                assert_eq!(num(&js, "engine.active_slots"), 0.0);
                assert_eq!(
                    num(&js, "engine.kv_pages_allocated"),
                    0.0,
                    "cancel must drain pages"
                );
                cancelled = true;
                break 'attempts;
            }
            if num(&js, "engine.requests_finished") > finished_before {
                // Lost the race: the request retired before the dead
                // sink was noticed. Try again.
                break;
            }
            thread::sleep(Duration::from_millis(10));
        }
    }
    assert!(cancelled, "disconnect must cancel the in-flight generation");

    let rep = srv.finish();
    assert!(
        rep.engine.requests.iter().any(|r| r.finish == FinishReason::Cancelled),
        "report must record the cancellation: {:?}",
        rep.engine.requests.iter().map(|r| r.finish).collect::<Vec<_>>()
    );
    assert_eq!(rep.engine.pool.pages_allocated, 0, "KV pages must drain to idle");
}

#[test]
fn max_requests_drains_and_exits_on_its_own() {
    let lcfg = ListenConfig {
        max_requests: 2,
        ..Default::default()
    };
    let srv = start(scfg(), lcfg);
    let mut c = srv.client();
    assert_eq!(c.roundtrip(&get_request("/health", false)).expect("one").status, 200);
    let mut c2 = srv.client();
    assert_eq!(c2.roundtrip(&get_request("/health", true)).expect("two").status, 200);
    drop(c);
    drop(c2);

    // No stop() — the front end must wind down by itself.
    let rep = srv
        .handle
        .join()
        .expect("server thread panicked")
        .expect("server errored");
    assert_eq!(rep.net.requests, 2);
    assert_eq!(rep.net.status(200), 2);
}
