//! End-to-end contracts of bypass-path self-speculative decoding
//! (DESIGN.md §Speculative decoding):
//!
//! * determinism — the emitted greedy stream is bitwise identical to
//!   plain decode on both CPU backends and at every thread count;
//! * KV hygiene — a rejected draft window of any length leaves the
//!   paged pool (and the dense shadow pool) bitwise where it started,
//!   and a speculative serve still retires with zero pages held;
//! * telemetry — the serving engine reports per-request and engine-wide
//!   acceptance counters consistent with each other.

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::coordinator::{
    generate_workload, KvPool, PrefillMode, SamplingParams, Server, ServerConfig,
    SpeculativeDecoder, WorkloadSpec,
};
use dtrnet::runtime::{Backend, CpuBackend, QuantizedCpuBackend};
use dtrnet::testing::{property, Gen};
use dtrnet::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 4];

fn xs_cfg() -> ModelConfig {
    ModelConfig::preset("xs", Variant::DtrBilayer)
}

fn prompt(seed: i32, len: usize) -> Vec<i32> {
    (0..len as i32).map(|i| (i * 13 + seed * 7) % 256).collect()
}

/// Spec-vs-plain and cross-thread identity for one backend constructor.
fn assert_greedy_identity<B, F>(make: F, tag: &str)
where
    B: Backend,
    F: Fn(usize) -> B,
{
    let params = SamplingParams::greedy();
    let mut reference: Option<Vec<Vec<i32>>> = None;
    for threads in THREADS {
        let be = make(threads);
        let mut streams = Vec::new();
        for (p, k) in [(0, 1), (1, 3), (2, 4), (3, 7)] {
            let pr = prompt(p, 7 + p as usize);
            let base = be.generate(&pr, 18, &params, &mut Rng::new(5)).unwrap();
            let mut dec = SpeculativeDecoder::new(&be, k).unwrap();
            let spec = dec.generate(&pr, 18, &params, &mut Rng::new(5)).unwrap();
            assert_eq!(
                spec.tokens, base.tokens,
                "{tag}: spec stream diverged (threads={threads} k={k} prompt={p})"
            );
            assert_eq!(spec.attn_frac, base.attn_frac, "{tag}: attn_frac diverged");
            streams.push(spec.tokens);
        }
        match &reference {
            None => reference = Some(streams),
            Some(r) => assert_eq!(
                &streams, r,
                "{tag}: streams not thread-invariant at threads={threads}"
            ),
        }
    }
}

#[test]
fn greedy_identity_across_threads_f32() {
    assert_greedy_identity(
        |t| {
            let mut be = CpuBackend::init(&xs_cfg(), 17).unwrap();
            be.set_threads(t);
            be
        },
        "f32",
    );
}

#[test]
fn greedy_identity_across_threads_int8() {
    assert_greedy_identity(
        |t| {
            let mut be = QuantizedCpuBackend::init(&xs_cfg(), 17).unwrap();
            be.set_threads(t);
            be
        },
        "int8",
    );
}

/// Serve-level contract: `--speculate k` changes throughput mechanics
/// only — every greedy request's token stream matches the plain engine,
/// acceptance counters are consistent, and no pages outlive the run.
fn assert_serve_identity(be: &dyn Backend) {
    let trace = generate_workload(
        &WorkloadSpec {
            n_requests: 8,
            arrival_rate: 10_000.0,
            prompt_len_mean: 8,
            prompt_len_max: 16,
            gen_len_mean: 10,
            gen_len_max: 20,
            temperature: 0.0,
            vocab: 256,
        },
        23,
    );
    let run = |speculate: usize| {
        let cfg = ServerConfig {
            slots: 2,
            prefill: PrefillMode::Chunked(16),
            speculate,
            ..Default::default()
        };
        let mut server = Server::new(be, cfg).unwrap();
        server.run_workload(&trace, 200_000).unwrap()
    };
    let base = run(0);
    let spec = run(4);
    assert_eq!(base.completed + base.evicted, 8);
    assert_eq!(spec.completed + spec.evicted, 8);

    let streams = |rep: &dtrnet::coordinator::ServeReport| {
        let mut s: Vec<(u64, Vec<i32>)> =
            rep.requests.iter().map(|r| (r.id, r.tokens.clone())).collect();
        s.sort_by_key(|(id, _)| *id);
        s
    };
    assert_eq!(streams(&spec), streams(&base), "speculation changed a stream");

    // Plain engine never speculates; the speculative one must have, and
    // per-request counters must sum to the engine-wide totals.
    assert_eq!(base.spec.drafted, 0);
    assert!(spec.spec.drafted > 0, "no drafts despite --speculate 4");
    assert!(spec.spec.accepted <= spec.spec.drafted);
    assert!((0.0..=1.0).contains(&spec.spec.acceptance_rate()));
    let (d, a) = spec
        .requests
        .iter()
        .fold((0u64, 0u64), |(d, a), r| (d + r.spec_drafted, a + r.spec_accepted));
    assert_eq!(d, spec.spec.drafted, "per-request drafted != engine total");
    assert_eq!(a, spec.spec.accepted, "per-request accepted != engine total");

    // Pages-to-zero shutdown invariant survives speculation.
    assert_eq!(spec.pool.pages_allocated, 0, "leaked KV pages");
    assert_eq!(base.pool.pages_allocated, 0);
}

#[test]
fn serve_speculative_matches_plain_f32() {
    assert_serve_identity(&CpuBackend::init(&xs_cfg(), 29).unwrap());
}

#[test]
fn serve_speculative_matches_plain_int8() {
    assert_serve_identity(&QuantizedCpuBackend::init(&xs_cfg(), 29).unwrap());
}

/// Satellite property: a rejected draft window of *any* length — random
/// routing patterns, random page geometry, capacity-limited pools where
/// some appends are refused — rolls the routed pool and the dense shadow
/// pool back bitwise to their pre-draft accounting.
#[test]
fn prop_rejected_draft_restores_pool_accounting() {
    let cfg = ModelConfig::preset("tiny", Variant::DtrBilayer);
    property("rejected draft pool rollback", 120, |g: &mut Gen| {
        let page = g.usize(1..24);
        let max_pages = g.usize(12..400);
        let mut pool = KvPool::new(&cfg, 2, page, max_pages);
        let mut shadow = KvPool::new(&cfg, 2, page, usize::MAX / 2);
        let dense = vec![true; cfg.n_layers];

        // Random committed history on both slots (capacity refusals are
        // atomic, so ignoring the result keeps the pool consistent).
        for _ in 0..g.usize(0..48) {
            let slot = g.usize(0..2);
            let routed: Vec<bool> = (0..cfg.n_layers).map(|_| g.bool()).collect();
            let _ = pool.append(slot, &routed);
            assert!(shadow.append(slot, &dense));
        }
        let slot = g.usize(0..2);
        let before = (pool.stats(), pool.lens(0), pool.lens(1));
        let shadow_before = (shadow.stats(), shadow.lens(0), shadow.lens(1));

        // A draft window of arbitrary length, then full rejection.
        let mark = pool.spec_begin(slot);
        let smark = shadow.spec_begin(slot);
        for _ in 0..g.usize(0..24) {
            let routed: Vec<bool> = (0..cfg.n_layers).map(|_| g.bool()).collect();
            let _ = pool.append(slot, &routed);
            assert!(shadow.append(slot, &dense));
        }
        pool.spec_rollback(&mark);
        shadow.spec_rollback(&smark);

        let after = (pool.stats(), pool.lens(0), pool.lens(1));
        let shadow_after = (shadow.stats(), shadow.lens(0), shadow.lens(1));
        let sides = [
            ("pool", &before, &after),
            ("shadow", &shadow_before, &shadow_after),
        ];
        for (which, b, a) in sides {
            assert_eq!(b.1, a.1, "{which}: slot 0 lens changed");
            assert_eq!(b.2, a.2, "{which}: slot 1 lens changed");
            assert_eq!(b.0.pages_allocated, a.0.pages_allocated, "{which}");
            assert_eq!(b.0.pages_peak, a.0.pages_peak, "{which}: peak must rewind");
            assert_eq!(b.0.bytes_allocated, a.0.bytes_allocated, "{which}");
            assert_eq!(b.0.bytes_peak, a.0.bytes_peak, "{which}");
            assert_eq!(b.0.tokens_cached, a.0.tokens_cached, "{which}");
            assert_eq!(b.0.tokens_seen, a.0.tokens_seen, "{which}");
        }

        // The pool stays live after a rollback: release everything and
        // the shutdown invariant holds.
        pool.release(0);
        pool.release(1);
        shadow.release(0);
        shadow.release(1);
        assert_eq!(pool.stats().pages_allocated, 0);
        assert_eq!(shadow.stats().pages_allocated, 0);
    });
}

/// The thread-count leg of the satellite property: drive a *real* draft
/// window (backend spec iteration) at several thread counts, mirror its
/// routed rows into a pool + dense shadow the way the serving engine
/// does, and require (a) bitwise pool restoration after rejection and
/// (b) thread-invariant routing decisions.
#[test]
fn rejected_real_draft_windows_are_thread_invariant() {
    let cfg = xs_cfg();
    let pr = prompt(4, 10);
    let params = SamplingParams::greedy();
    let mut reference: Option<(Vec<i32>, Vec<Vec<bool>>, Vec<Vec<bool>>)> = None;
    for threads in THREADS {
        let mut be = CpuBackend::init(&cfg, 31).unwrap();
        be.set_threads(threads);
        let mut state = be.begin_decode();
        be.prefill(&mut state, &pr).unwrap();

        // Charge the pools for the prefill, then run one draft/verify
        // iteration and mirror both transient windows.
        let mut pool = KvPool::new(&cfg, 1, 8, 10_000);
        let mut shadow = KvPool::new(&cfg, 1, 8, usize::MAX / 2);
        let lens = state.lens(cfg.d_model);
        assert!(pool.append_prefill(0, &lens, pr.len()));
        assert!(shadow.append_prefill(0, &vec![pr.len(); cfg.n_layers], pr.len()));

        let mut dec = SpeculativeDecoder::new(&be, 4).unwrap();
        let it = dec
            .step(&mut state, 3, 16, &params, &[3], &mut Rng::new(0))
            .unwrap();
        assert!(it.drafted > 0, "window must have drafted");

        let before = (pool.stats(), pool.lens(0), shadow.stats(), shadow.lens(0));
        for window in [&it.draft_routed, &it.verify_routed] {
            let mark = pool.spec_begin(0);
            let smark = shadow.spec_begin(0);
            for routed in window.iter() {
                assert!(pool.append(0, routed));
                assert!(shadow.append(0, &vec![true; cfg.n_layers]));
            }
            pool.spec_rollback(&mark);
            shadow.spec_rollback(&smark);
        }
        let after = (pool.stats(), pool.lens(0), shadow.stats(), shadow.lens(0));
        assert_eq!(before.1, after.1, "threads={threads}: pool lens changed");
        assert_eq!(before.3, after.3, "threads={threads}: shadow lens changed");
        for (b, a) in [(&before.0, &after.0), (&before.2, &after.2)] {
            assert_eq!(b.pages_allocated, a.pages_allocated, "threads={threads}");
            assert_eq!(b.pages_peak, a.pages_peak, "threads={threads}");
            assert_eq!(b.tokens_cached, a.tokens_cached, "threads={threads}");
            assert_eq!(b.tokens_seen, a.tokens_seen, "threads={threads}");
        }

        // Routing (and therefore page traffic) must not depend on the
        // thread count.
        let got = (it.emitted.clone(), it.draft_routed.clone(), it.verify_routed.clone());
        match &reference {
            None => reference = Some(got),
            Some(r) => assert_eq!(&got, r, "threads={threads}: window not invariant"),
        }
    }
}
