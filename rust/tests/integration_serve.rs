//! Integration: serving engine over real decode artifacts.
//!
//! Requires the `pjrt` feature + AOT artifacts (see Cargo.toml
//! `required-features`).
#![cfg(feature = "pjrt")]

use std::time::Instant;

use dtrnet::coordinator::{Request, ServeEngine};
use dtrnet::runtime::{Engine, Tensor};
use dtrnet::util::rng::Rng;

fn engine() -> Engine {
    Engine::new(&dtrnet::artifacts_dir()).expect("run `make artifacts` first")
}

fn serve(tag: &str, e: &Engine) -> ServeEngine {
    let init = e.load(&format!("{tag}_init")).unwrap();
    let params = init
        .call_literals(&[Tensor::scalar_i32(0).to_literal().unwrap()])
        .unwrap();
    ServeEngine::new(e, &format!("{tag}_decode_b2m96"), params, 8).unwrap()
}

fn reqs(n: usize, prompt: usize, gen: usize, temp: f32) -> Vec<Request> {
    let mut rng = Rng::new(9);
    let now = Instant::now();
    (0..n)
        .map(|i| Request {
            id: i as u64,
            prompt: (0..prompt).map(|_| rng.below(256) as i32).collect(),
            max_new_tokens: gen,
            temperature: temp,
            arrival: now,
        })
        .collect()
}

#[test]
fn completes_all_requests() {
    let e = engine();
    let mut srv = serve("xs_dtr_bilayer", &e);
    for r in reqs(5, 8, 6, 0.0) {
        assert!(srv.submit(r));
    }
    let rep = srv.run_to_completion(10_000).unwrap();
    assert_eq!(rep.completed, 5);
    assert_eq!(rep.tokens_generated, 5 * 6);
    assert!(rep.tokens_per_s > 0.0);
    // pool must end empty (all slots released)
    assert_eq!(rep.pool.pages_allocated, 0);
    assert!(rep.pool.pages_peak > 0);
}

#[test]
fn greedy_decoding_is_deterministic() {
    let e = engine();
    let gen = |_: u32| {
        let mut srv = serve("xs_dtr_bilayer", &e);
        for r in reqs(2, 6, 8, 0.0) {
            srv.submit(r);
        }
        srv.run_to_completion(10_000).unwrap();
        srv.batcher
            .completed
            .iter()
            .map(|c| c.generated.clone())
            .collect::<Vec<_>>()
    };
    assert_eq!(gen(0), gen(1));
}

#[test]
fn dtr_caches_fewer_tokens_than_dense() {
    let e = engine();
    let run = |tag: &str| {
        let mut srv = serve(tag, &e);
        for r in reqs(3, 12, 10, 0.0) {
            srv.submit(r);
        }
        srv.run_to_completion(10_000).unwrap()
    };
    let dense = run("xs_dense");
    let dtr = run("xs_dtr_bilayer");
    assert!((dense.kv_savings_ratio - 1.0).abs() < 1e-9, "dense caches everything");
    assert!(
        dtr.kv_savings_ratio < 0.95,
        "DTRNet must cache fewer: {}",
        dtr.kv_savings_ratio
    );
    assert!(dtr.pool.bytes_peak < dense.pool.bytes_peak);
}

#[test]
fn routing_stats_match_layout() {
    let e = engine();
    let mut srv = serve("xs_dtr_bilayer", &e);
    for r in reqs(2, 8, 8, 0.0) {
        srv.submit(r);
    }
    let rep = srv.run_to_completion(10_000).unwrap();
    let fr = rep.routing.fractions();
    // TDTT layout: dense layers attend 100%
    assert_eq!(fr[0], 1.0);
    assert_eq!(fr[2], 1.0);
    assert_eq!(fr[3], 1.0);
    assert!(fr[1] <= 1.0);
}

#[test]
fn temperature_sampling_differs_from_greedy() {
    let e = engine();
    let run = |temp: f32| {
        let mut srv = serve("xs_dtr_bilayer", &e);
        for r in reqs(2, 8, 12, temp) {
            srv.submit(r);
        }
        srv.run_to_completion(10_000).unwrap();
        srv.batcher
            .completed
            .iter()
            .map(|c| c.generated.clone())
            .collect::<Vec<_>>()
    };
    // untrained logits are near-uniform → hot sampling almost surely differs
    assert_ne!(run(0.0), run(1.5));
}

#[test]
fn continuous_batching_recycles_slots() {
    // more requests than slots (B=2): requires slot recycling to finish
    let e = engine();
    let mut srv = serve("xs_dtr_bilayer", &e);
    for r in reqs(7, 6, 4, 0.0) {
        srv.submit(r);
    }
    let rep = srv.run_to_completion(50_000).unwrap();
    assert_eq!(rep.completed, 7);
}
