//! Property tests over the CPU backend & kernels — the paper's central
//! routing invariants (in-repo harness; proptest unavailable offline):
//!
//! * full selection: routed attention with every token selected equals
//!   plain causal attention (paper Eq. 6 sparse-equivalence boundary);
//! * zero selection: every token still changes, via the linear bypass
//!   update `g_bypass · x W^V W^O` (paper Eq. 5);
//! * expert-choice top-k: the router selects exactly `ceil(c·n)` tokens;
//! * decode/forward consistency: sequential decode with the routing-aware
//!   KV cache reproduces the batched forward logits;
//! * thread invariance: multi-threaded kernel execution is bit-identical
//!   to `--threads 1` for forward, decode_batch, prefill_chunked AND
//!   `train_step` (weights, Adam moments, metrics), including every
//!   KV-cache byte — thread count is a throughput knob, never a
//!   semantics knob (DESIGN.md §Benchmarking).

use dtrnet::config::{ModelConfig, TrainConfig, Variant};
use dtrnet::runtime::cpu::kernels;
use dtrnet::runtime::{
    Backend, CpuBackend, CpuTrainer, DecodeState, RouterMode, Tensor, TrainBackend,
};
use dtrnet::testing::{assert_allclose, property, Gen};

fn randn_vec(g: &mut Gen, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| g.rng.normal() as f32 * scale).collect()
}

/// Independent plain causal MHA (f64 softmax accumulation, no masking
/// machinery) — the oracle for the full-selection property.
fn naive_causal_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    h: usize,
    hd: usize,
) -> Vec<f32> {
    let scale = 1.0 / (hd as f64).sqrt();
    let mut out = vec![0.0f32; n * h * hd];
    for head in 0..h {
        for i in 0..n {
            let qi = &q[(i * h + head) * hd..(i * h + head + 1) * hd];
            let logits: Vec<f64> = (0..=i)
                .map(|j| {
                    let kj = &k[(j * h + head) * hd..(j * h + head + 1) * hd];
                    qi.iter()
                        .zip(kj)
                        .map(|(&a, &b)| a as f64 * b as f64)
                        .sum::<f64>()
                        * scale
                })
                .collect();
            let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let exps: Vec<f64> = logits.iter().map(|&l| (l - m).exp()).collect();
            let z: f64 = exps.iter().sum();
            for (j, &e) in exps.iter().enumerate() {
                let w = e / z;
                let vj = &v[(j * h + head) * hd..(j * h + head + 1) * hd];
                for t in 0..hd {
                    out[(i * h + head) * hd + t] += (w * vj[t] as f64) as f32;
                }
            }
        }
    }
    out
}

#[test]
fn prop_full_selection_equals_dense_attention() {
    property("routed(all ones) == causal attention", 50, |g| {
        let n = g.usize(1..10);
        let h = g.usize(1..4);
        let hd = 2 * g.usize(1..4);
        let q = randn_vec(g, n * h * hd, 1.0);
        let k = randn_vec(g, n * h * hd, 1.0);
        let v = randn_vec(g, n * h * hd, 1.0);
        let ones = vec![1.0f32; n];
        let routed = kernels::routed_attention(&q, &k, &v, &ones, n, h, hd);
        let dense = kernels::dense_attention(&q, &k, &v, n, h, hd);
        let naive = naive_causal_attention(&q, &k, &v, n, h, hd);
        assert_allclose(&routed, &dense, 1e-6, 1e-6);
        assert_allclose(&routed, &naive, 1e-4, 1e-4);
    });
}

#[test]
fn prop_zero_selection_still_updates_every_token() {
    property("zero routed -> bypass updates every token", 40, |g| {
        let n = g.usize(1..8);
        let heads = [1usize, 2, 4][g.usize(0..3)];
        let d = heads * 2 * g.usize(1..4);
        let x = randn_vec(g, n * d, 1.0);
        let w1 = randn_vec(g, d * (d / 2), 0.5);
        let w2 = randn_vec(g, (d / 2) * 2, 0.5);
        let wq = randn_vec(g, d * d, 0.4);
        let wk = randn_vec(g, d * d, 0.4);
        let wv = randn_vec(g, d * d, 0.4);
        let wo = randn_vec(g, d * d, 0.4);
        let pos: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let zeros = vec![0.0f32; n];
        let out = kernels::dtr_token_update(
            &x, &w1, &w2, &wq, &wk, &wv, &wo, &pos, n, d, heads, 10000.0, true,
            Some(&zeros),
        );
        // every token's update is the soft-weighted linear bypass …
        let byp = kernels::bypass(&x, &wv, &wo, n, d);
        let want: Vec<f32> = (0..n * d).map(|i| out.g[(i / d) * 2 + 1] * byp[i]).collect();
        assert_allclose(&out.update, &want, 1e-5, 1e-5);
        // … and it is a real update: nonzero for every token (a.s.)
        for i in 0..n {
            let norm: f64 = out.update[i * d..(i + 1) * d]
                .iter()
                .map(|&u| (u as f64).powi(2))
                .sum();
            assert!(norm > 0.0, "token {i} got no bypass update");
        }
    });
}

#[test]
fn prop_topk_selects_exact_capacity() {
    property("top-k mask count == ceil(0.1 n) incl. ties", 100, |g| {
        let n = g.usize(1..64);
        // quantized scores force ties
        let scores: Vec<f32> = (0..n)
            .map(|_| (g.f64(0.0, 1.0) * 10.0).round() as f32 / 10.0)
            .collect();
        let k = ((0.1 * n as f64).ceil() as usize).max(1);
        let mask = kernels::topk_mask(&scores, k);
        let got = mask.iter().filter(|&&m| m > 0.5).count();
        assert_eq!(got, k.min(n), "scores={scores:?}");
        // selected scores dominate unselected ones
        let min_sel = mask
            .iter()
            .zip(&scores)
            .filter(|(&m, _)| m > 0.5)
            .map(|(_, &s)| s)
            .fold(f32::INFINITY, f32::min);
        for (m, &s) in mask.iter().zip(&scores) {
            if *m < 0.5 {
                assert!(s <= min_sel, "unselected {s} beats selected {min_sel}");
            }
        }
    });
}

#[test]
fn prop_expert_choice_forward_matches_capacity_exactly() {
    property("expert-choice routed fraction == ceil(0.1 s)/s", 10, |g| {
        let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
        let mut backend = CpuBackend::init(&cfg, g.case as u64).unwrap();
        backend.set_router_mode(RouterMode::ExpertChoice { capacity: 0.1 });
        let s = g.usize(10..40);
        let tokens: Vec<i32> = (0..s).map(|_| g.rng.below(256) as i32).collect();
        let out = backend
            .forward(&Tensor::i32(vec![1, s], tokens))
            .unwrap();
        let k = ((0.1 * s as f64).ceil() as usize).max(1);
        let layout = cfg.layout_string();
        for (l, kind) in layout.chars().enumerate() {
            let row = &out.route.as_f32()[l * s..(l + 1) * s];
            let routed = row.iter().filter(|&&r| r > 0.5).count();
            if kind == 'D' {
                assert_eq!(routed, k, "layer {l}: expected exactly {k} routed of {s}");
                assert!((out.attn_frac[l] - k as f64 / s as f64).abs() < 1e-12);
            } else {
                assert_eq!(routed, s, "dense layer {l} must attend all tokens");
            }
        }
    });
}

#[test]
fn prop_dense_layers_always_route_all() {
    property("dense layers route every token", 8, |g| {
        let variants = [
            Variant::Dense,
            Variant::DtrBilayer,
            Variant::DtrTrilayer,
            Variant::DtrLaterhalf,
            Variant::DtrSkip,
        ];
        let variant = variants[g.usize(0..variants.len())];
        let cfg = ModelConfig::preset("xs", variant);
        let backend = CpuBackend::init(&cfg, g.case as u64).unwrap();
        let s = g.usize(4..24);
        let tokens: Vec<i32> = (0..s).map(|_| g.rng.below(256) as i32).collect();
        let out = backend.forward(&Tensor::i32(vec![1, s], tokens)).unwrap();
        for (l, kind) in cfg.layout_string().chars().enumerate() {
            let row = &out.route.as_f32()[l * s..(l + 1) * s];
            if kind == 'T' {
                assert!(row.iter().all(|&r| r > 0.5), "dense layer {l} skipped a token");
            }
            if variant == Variant::DtrSkip && kind == 'D' {
                assert!(row.iter().all(|&r| r < 0.5), "dtr_skip layer {l} routed a token");
            }
        }
    });
}

#[test]
fn prop_decode_batch_bit_identical_to_decode_step() {
    property("decode_batch == per-sequence decode_step (bitwise)", 6, |g| {
        let variants = [Variant::Dense, Variant::DtrBilayer, Variant::DtrTrilayer];
        let variant = variants[g.usize(0..variants.len())];
        let cfg = ModelConfig::preset("xs", variant);
        let backend = CpuBackend::init(&cfg, 2000 + g.case as u64).unwrap();
        let b = g.usize(1..5);
        let n_steps = g.usize(2..7);
        // Stagger the sequences: different prompts AND different lengths,
        // so batched decode mixes positions and cache depths.
        let mut seq_states: Vec<DecodeState> = (0..b).map(|_| backend.begin_decode()).collect();
        for st in seq_states.iter_mut() {
            let plen = g.usize(1..6);
            for _ in 0..plen {
                let t = g.rng.below(256) as i32;
                backend.decode_step(st, t).unwrap();
            }
        }
        let mut bat_states = seq_states.clone();

        for step in 0..n_steps {
            let toks: Vec<i32> = (0..b).map(|i| ((step * 31 + i * 17) % 256) as i32).collect();
            let seq_outs: Vec<_> = seq_states
                .iter_mut()
                .zip(&toks)
                .map(|(s, &t)| backend.decode_step(s, t).unwrap())
                .collect();
            let mut refs: Vec<&mut DecodeState> = bat_states.iter_mut().collect();
            let bat_outs = backend.decode_batch(&mut refs, &toks).unwrap();
            assert_eq!(bat_outs.len(), b);
            for i in 0..b {
                assert_eq!(seq_outs[i].logits, bat_outs[i].logits, "seq {i} step {step}");
                assert_eq!(seq_outs[i].routed, bat_outs[i].routed, "seq {i} step {step}");
                assert_eq!(seq_outs[i].g_attn, bat_outs[i].g_attn, "seq {i} step {step}");
            }
        }
        for (i, (a, c)) in seq_states.iter().zip(&bat_states).enumerate() {
            assert_eq!(a.position, c.position, "seq {i} position");
            assert_eq!(a.snapshot_kv(), c.snapshot_kv(), "seq {i} cache diverged");
        }
    });
}

#[test]
fn prop_chunked_prefill_bit_identical_to_sequential() {
    property("prefill_chunked(c) == sequential decode loop (bitwise)", 8, |g| {
        let variants = [Variant::Dense, Variant::DtrBilayer, Variant::DtrSkip];
        let variant = variants[g.usize(0..variants.len())];
        let cfg = ModelConfig::preset("xs", variant);
        let backend = CpuBackend::init(&cfg, 3000 + g.case as u64).unwrap();
        let n = g.usize(2..20);
        let tokens: Vec<i32> = (0..n).map(|_| g.rng.below(256) as i32).collect();
        // chunk sizes spanning 1 (degenerate), mid, and > n (single chunk)
        let chunk = g.usize(1..24);

        let mut s_ref = backend.begin_decode();
        let mut last = None;
        for &t in &tokens {
            last = Some(backend.decode_step(&mut s_ref, t).unwrap());
        }
        let last = last.unwrap();

        let mut s_chk = backend.begin_decode();
        let out = backend.prefill_chunked(&mut s_chk, &tokens, chunk).unwrap();

        assert_eq!(last.logits, out.logits, "chunk={chunk} n={n}");
        assert_eq!(last.routed, out.routed);
        assert_eq!(last.g_attn, out.g_attn);
        assert_eq!(s_ref.position, s_chk.position);
        assert_eq!(
            s_ref.snapshot_kv(),
            s_chk.snapshot_kv(),
            "chunk={chunk}: cache diverged"
        );
    });
}

#[test]
fn prop_threaded_bit_identical_to_single_thread() {
    property(
        "threads=N ≡ threads=1 bitwise: forward/prefill_chunked/decode_batch + caches",
        6,
        |g| {
            let variants = [Variant::Dense, Variant::DtrBilayer, Variant::DtrTrilayer];
            let variant = variants[g.usize(0..variants.len())];
            let cfg = ModelConfig::preset("xs", variant);
            let seed = 4000 + g.case as u64;
            let mut serial = CpuBackend::init(&cfg, seed).unwrap();
            serial.set_threads(1);
            let mut threaded = CpuBackend::init(&cfg, seed).unwrap();
            threaded.set_threads(g.usize(2..5)); // 2..=4 threads

            // forward: logits, routing decisions, soft scores
            let s = g.usize(2..32);
            let tokens: Vec<i32> = (0..s).map(|_| g.rng.below(256) as i32).collect();
            let a = serial
                .forward(&Tensor::i32(vec![1, s], tokens.clone()))
                .unwrap();
            let b = threaded
                .forward(&Tensor::i32(vec![1, s], tokens.clone()))
                .unwrap();
            assert_eq!(a.logits, b.logits, "forward logits bits diverged");
            assert_eq!(a.route, b.route, "forward routing diverged");
            assert_eq!(a.g_attn, b.g_attn, "forward router scores diverged");

            // prefill_chunked: final step AND every cached KV byte
            let chunk = g.usize(1..12);
            let mut st_s = serial.begin_decode();
            let out_s = serial.prefill_chunked(&mut st_s, &tokens, chunk).unwrap();
            let mut st_t = threaded.begin_decode();
            let out_t = threaded.prefill_chunked(&mut st_t, &tokens, chunk).unwrap();
            assert_eq!(out_s.logits, out_t.logits, "prefill logits diverged");
            assert_eq!(out_s.routed, out_t.routed);
            assert_eq!(out_s.g_attn, out_t.g_attn);
            assert_eq!(st_s.position, st_t.position);
            assert_eq!(
                st_s.snapshot_kv(),
                st_t.snapshot_kv(),
                "prefill cache diverged"
            );

            // decode_batch over staggered sequences: outputs + cache bits
            let bsz = g.usize(1..4);
            let mut states_s: Vec<DecodeState> = Vec::new();
            let mut states_t: Vec<DecodeState> = Vec::new();
            for bi in 0..bsz {
                let plen = g.usize(1..6);
                let prompt: Vec<i32> =
                    (0..plen).map(|i| ((bi * 31 + i * 7) % 256) as i32).collect();
                let mut ss = serial.begin_decode();
                serial.prefill(&mut ss, &prompt).unwrap();
                let mut st = threaded.begin_decode();
                threaded.prefill(&mut st, &prompt).unwrap();
                states_s.push(ss);
                states_t.push(st);
            }
            for step in 0..3 {
                let toks: Vec<i32> = (0..bsz)
                    .map(|i| ((step * 53 + i * 17) % 256) as i32)
                    .collect();
                let mut refs_s: Vec<&mut DecodeState> = states_s.iter_mut().collect();
                let outs_s = serial.decode_batch(&mut refs_s, &toks).unwrap();
                let mut refs_t: Vec<&mut DecodeState> = states_t.iter_mut().collect();
                let outs_t = threaded.decode_batch(&mut refs_t, &toks).unwrap();
                for i in 0..bsz {
                    assert_eq!(
                        outs_s[i].logits, outs_t[i].logits,
                        "decode_batch seq {i} step {step} logits diverged"
                    );
                    assert_eq!(outs_s[i].routed, outs_t[i].routed);
                    assert_eq!(outs_s[i].g_attn, outs_t[i].g_attn);
                }
            }
            for (i, (ss, st)) in states_s.iter().zip(&states_t).enumerate() {
                assert_eq!(
                    ss.snapshot_kv(),
                    st.snapshot_kv(),
                    "seq {i} cache diverged"
                );
            }
        },
    );
}

#[test]
fn prop_train_step_bit_identical_across_threads() {
    property(
        "train_step threads=N ≡ threads=1 bitwise: weights, moments, metrics",
        4,
        |g| {
            let variants = [Variant::Dense, Variant::DtrBilayer, Variant::DtrTrilayer];
            let variant = variants[g.usize(0..variants.len())];
            let cfg = ModelConfig::preset("xs", variant);
            let hp = TrainConfig {
                batch: 2,
                seq: 8 + g.usize(0..8),
                seed: 5000 + g.case as u64,
                ..Default::default()
            };
            let mut serial = CpuTrainer::new(&cfg, &hp).unwrap();
            serial.set_threads(1);
            let mut threaded = CpuTrainer::new(&cfg, &hp).unwrap();
            threaded.set_threads(g.usize(2..5));
            for s in 1..=2usize {
                let tokens: Vec<i32> = (0..hp.batch * hp.seq)
                    .map(|_| g.rng.below(256) as i32)
                    .collect();
                let ma = serial.train_step(&tokens, s, 3e-4, 0).unwrap();
                let mb = threaded.train_step(&tokens, s, 3e-4, 0).unwrap();
                assert_eq!(ma.loss.to_bits(), mb.loss.to_bits(), "loss bits step {s}");
                assert_eq!(ma.ce.to_bits(), mb.ce.to_bits(), "ce bits step {s}");
                assert_eq!(
                    ma.penalty.to_bits(),
                    mb.penalty.to_bits(),
                    "penalty bits step {s}"
                );
                assert_eq!(
                    ma.grad_norm.to_bits(),
                    mb.grad_norm.to_bits(),
                    "grad_norm bits step {s}"
                );
                assert_eq!(ma.attn_frac, mb.attn_frac, "attn_frac step {s}");
            }
            for (ti, ((ta, _), (tb, _))) in serial
                .weights()
                .tensors()
                .into_iter()
                .zip(threaded.weights().tensors())
                .enumerate()
            {
                assert_eq!(ta, tb, "weight tensor {ti} bits diverged across threads");
            }
        },
    );
}

#[test]
fn prop_decode_matches_forward_prefix() {
    property("sequential decode == batched forward", 6, |g| {
        let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
        let backend = CpuBackend::init(&cfg, 1000 + g.case as u64).unwrap();
        let s = g.usize(2..12);
        let tokens: Vec<i32> = (0..s).map(|_| g.rng.below(256) as i32).collect();
        let fwd = backend
            .forward(&Tensor::i32(vec![1, s], tokens.clone()))
            .unwrap();
        let mut state = backend.begin_decode();
        let step = backend.prefill(&mut state, &tokens).unwrap();
        let v = cfg.vocab_size;
        let last = &fwd.logits.as_f32()[(s - 1) * v..s * v];
        assert_allclose(step.logits.as_f32(), last, 1e-3, 1e-3);
        // cache lens must equal the forward pass's routed counts
        let lens = state.lens(cfg.d_model);
        for l in 0..cfg.n_layers {
            let routed: usize = fwd.route.as_f32()[l * s..(l + 1) * s]
                .iter()
                .filter(|&&r| r > 0.5)
                .count();
            assert_eq!(lens[l], routed, "layer {l} cache len != routed count");
        }
    });
}
