//! Scalar-vs-SIMD differential harness (in-repo property harness —
//! proptest is unavailable offline; see DESIGN.md §Substitutions).
//!
//! Every vectorized kernel runs against its scalar twin over a seeded
//! randomized input stream plus a hostile shape matrix: lengths that are
//! not multiples of the 8-float lane width, n=1 decode rows, empty
//! caches/pending selections, subnormal and large-magnitude values, and
//! ±0.0. The determinism contract (DESIGN.md §SIMD dispatch) splits the
//! assertions in two:
//!
//! * **Bit-exact paths** — `axpy` (element-wise: each lane rounds
//!   independently, mul-then-add, never FMA) and `dot_q8` (the i8 path:
//!   both twins implement the same fixed 8-lane striped accumulation) —
//!   compared by `to_bits()`, so even a `-0.0` vs `+0.0` swap fails.
//!   At *fixed* precision the fast f32 reductions are also bit-exact
//!   across tiers (the striped scalar twin pins the summation order).
//! * **Tolerance-gated paths** — `--precision fast` reductions vs the
//!   exact sequential order. Reassociating a length-`n` sum moves the
//!   result by at most ~`n · ε · Σ|termᵢ|`; the tests pin that analytic
//!   bound with a 4× slack (see `reassociation_tol`).

use dtrnet::runtime::cpu::kernels::{self, simd};
use dtrnet::testing::{property, Gen};
use dtrnet::util::rng::Rng;
use dtrnet::util::simd::{detect, KernelCtx, Precision, SimdTier};
use dtrnet::util::threadpool::Pool;

/// Lengths chosen to straddle the 8-lane width: empty, sub-lane, exact
/// multiples, off-by-one on both sides, and a long tail.
const SIZES: [usize; 12] = [0, 1, 3, 7, 8, 9, 15, 16, 17, 31, 64, 257];

/// The tiers under test: scalar always, plus the detected tier when it
/// differs (on a plain host this degenerates to scalar-vs-scalar, which
/// keeps the harness green rather than vacuously skipped).
fn tiers() -> Vec<SimdTier> {
    let mut t = vec![SimdTier::Scalar];
    if detect() != SimdTier::Scalar {
        t.push(detect());
    }
    t
}

/// A value stream that keeps hitting the hostile corners: ±0.0,
/// subnormals, large magnitudes, and ordinary noise.
fn hostile_f32(rng: &mut Rng) -> f32 {
    match rng.below(8) {
        0 => 0.0,
        1 => -0.0,
        2 => 1.0e-41,  // subnormal
        3 => -1.0e-41, // subnormal
        4 => 1.0e30,
        5 => -1.0e30,
        _ => (rng.f32() - 0.5) * 4.0,
    }
}

fn hostile_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| hostile_f32(rng)).collect()
}

/// Analytic bound for reassociating a length-`n` f32 sum: the striped
/// order can drift from the sequential order by at most about
/// `n · ε · Σ|termᵢ|`; we allow 4× slack on top.
fn reassociation_tol(abs_term_sum: f32, n: usize) -> f32 {
    4.0 * n.max(1) as f32 * f32::EPSILON * abs_term_sum
}

#[test]
fn axpy_bitwise_across_tiers_on_hostile_inputs() {
    for tier in tiers() {
        let mut rng = Rng::new(0xA11);
        for &len in &SIZES {
            for case in 0..8u64 {
                let b = hostile_vec(&mut rng, len);
                let base = hostile_vec(&mut rng, len);
                let s = hostile_f32(&mut rng);
                let mut want = base.clone();
                simd::axpy_scalar(&mut want, s, &b);
                let mut got = base.clone();
                simd::axpy(tier, &mut got, s, &b);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    wb,
                    gb,
                    "axpy len={len} case={case} tier={} diverged from scalar",
                    tier.name()
                );
            }
        }
    }
}

#[test]
fn dot_q8_bitwise_across_tiers_on_hostile_inputs() {
    for tier in tiers() {
        let mut rng = Rng::new(0xD07);
        for &len in &SIZES {
            for case in 0..8u64 {
                let a = hostile_vec(&mut rng, len);
                let q: Vec<i8> =
                    (0..len).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
                let want = simd::dot_q8_scalar(&a, &q);
                let got = simd::dot_q8(tier, &a, &q);
                assert_eq!(
                    want.to_bits(),
                    got.to_bits(),
                    "dot_q8 len={len} case={case} tier={} diverged from striped scalar \
                     ({want} vs {got})",
                    tier.name()
                );
            }
        }
    }
}

#[test]
fn fast_reductions_bitwise_across_tiers_tolerance_vs_exact() {
    // Cross-tier: the fast dot/sum_sq must reproduce the striped scalar
    // twin bit-for-bit (the pinned reduction tree IS the contract).
    // Cross-precision: the striped result may differ from the exact
    // sequential order only within the reassociation bound.
    for tier in tiers() {
        let fast = KernelCtx {
            tier,
            precision: Precision::Fast,
        };
        let exact = KernelCtx {
            tier,
            precision: Precision::Exact,
        };
        let mut rng = Rng::new(0xFA57);
        for &len in &SIZES {
            // Plain noise here: a single 1e30 term legitimately swamps
            // the sum, which makes the *relative* drift unbounded.
            let a: Vec<f32> = (0..len).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            let b: Vec<f32> = (0..len).map(|_| (rng.f32() - 0.5) * 2.0).collect();

            let striped = simd::dot_f32_striped(&a, &b);
            let got = simd::dot_f32(fast, &a, &b);
            assert_eq!(
                striped.to_bits(),
                got.to_bits(),
                "fast dot_f32 len={len} tier={} diverged from striped scalar",
                tier.name()
            );
            let seq = simd::dot_f32(exact, &a, &b);
            assert_eq!(
                seq.to_bits(),
                simd::dot_seq(&a, &b).to_bits(),
                "exact dot_f32 must be the sequential order on every tier"
            );
            let abs_sum: f32 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            assert!(
                (striped - seq).abs() <= reassociation_tol(abs_sum, len),
                "fast dot_f32 len={len}: |{striped} - {seq}| exceeds the \
                 reassociation bound"
            );

            let striped = simd::sum_sq_striped(&a);
            let got = simd::sum_sq(fast, &a);
            assert_eq!(
                striped.to_bits(),
                got.to_bits(),
                "fast sum_sq len={len} tier={} diverged from striped scalar",
                tier.name()
            );
            let seq = simd::sum_sq(exact, &a);
            let abs_sum: f32 = a.iter().map(|x| x * x).sum();
            assert!(
                (striped - seq).abs() <= reassociation_tol(abs_sum, len),
                "fast sum_sq len={len}: |{striped} - {seq}| exceeds the \
                 reassociation bound"
            );
        }
    }
}

/// A scalar-pinned and a tier-pinned pool for side-by-side kernel runs
/// (per-pool ctx: no process-global state touched, test-parallel safe).
fn pool_pair(tier: SimdTier, precision: Precision) -> (Pool, Pool) {
    let scalar = Pool::serial().with_ctx(KernelCtx {
        tier: SimdTier::Scalar,
        precision,
    });
    let vector = Pool::serial().with_ctx(KernelCtx { tier, precision });
    (scalar, vector)
}

fn assert_bits_eq(want: &[f32], got: &[f32], what: &str) {
    let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
    let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
    assert_eq!(wb, gb, "{what} diverged between scalar and SIMD tiers");
}

#[test]
fn matmul_differential_hostile_shapes() {
    // n=1 is the decode hot path (column-chunked); k values straddle
    // both the lane width and the K_BLOCK tiling.
    let shapes = [
        (1usize, 1usize, 1usize),
        (1, 7, 5),
        (1, 33, 64),
        (2, 8, 8),
        (3, 17, 9),
        (4, 64, 24),
        (5, 129, 7),
    ];
    for tier in tiers() {
        let (ps, pv) = pool_pair(tier, Precision::Exact);
        let mut rng = Rng::new(0x3A7);
        for &(n, k, m) in &shapes {
            let a = hostile_vec(&mut rng, n * k);
            let b = hostile_vec(&mut rng, k * m);
            assert_bits_eq(
                &kernels::matmul_par(&ps, &a, &b, n, k, m),
                &kernels::matmul_par(&pv, &a, &b, n, k, m),
                &format!("matmul {n}x{k}x{m} tier={}", tier.name()),
            );
            // quantize_rows runs on finite weights in practice; keep the
            // magnitudes sane so dot_q8's scale product stays finite.
            let wq: Vec<f32> = (0..k * m).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            let (q, scales) = kernels::quantize_rows(&wq, k, m);
            let aq: Vec<f32> = (0..n * k).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            assert_bits_eq(
                &kernels::matmul_q8_par(&ps, &aq, &q, &scales, n, k, m),
                &kernels::matmul_q8_par(&pv, &aq, &q, &scales, n, k, m),
                &format!("matmul_q8 {n}x{k}x{m} tier={}", tier.name()),
            );
        }
    }
}

#[test]
fn rmsnorm_and_attention_differential_both_precisions() {
    for tier in tiers() {
        for precision in [Precision::Exact, Precision::Fast] {
            let (ps, pv) = pool_pair(tier, precision);
            let mut rng = Rng::new(0xA77);
            for &(n, h, hd) in &[(1usize, 1usize, 3usize), (2, 2, 8), (5, 2, 17), (4, 3, 7)] {
                let d = h * hd;
                let x: Vec<f32> = (0..n * d).map(|_| (rng.f32() - 0.5) * 4.0).collect();
                let w: Vec<f32> = (0..d).map(|_| 0.5 + rng.f32()).collect();
                assert_bits_eq(
                    &kernels::rmsnorm_par(&ps, &x, &w, 1e-5),
                    &kernels::rmsnorm_par(&pv, &x, &w, 1e-5),
                    &format!("rmsnorm n={n} d={d} tier={} {precision:?}", tier.name()),
                );
                let q: Vec<f32> = (0..n * d).map(|_| (rng.f32() - 0.5) * 2.0).collect();
                let k: Vec<f32> = (0..n * d).map(|_| (rng.f32() - 0.5) * 2.0).collect();
                let v: Vec<f32> = (0..n * d).map(|_| (rng.f32() - 0.5) * 2.0).collect();
                // delta rows include hard zeros — tokens routed fully
                // around attention (the "empty selection" corner).
                let delta: Vec<f32> =
                    (0..n).map(|i| if i % 2 == 0 { 0.0 } else { rng.f32() }).collect();
                assert_bits_eq(
                    &kernels::routed_attention_par(&ps, &q, &k, &v, &delta, n, h, hd),
                    &kernels::routed_attention_par(&pv, &q, &k, &v, &delta, n, h, hd),
                    &format!("routed_attention n={n} h={h} hd={hd} tier={}", tier.name()),
                );
                assert_bits_eq(
                    &kernels::dense_attention_par(&ps, &q, &k, &v, n, h, hd),
                    &kernels::dense_attention_par(&pv, &q, &k, &v, n, h, hd),
                    &format!("dense_attention n={n} h={h} hd={hd} tier={}", tier.name()),
                );
            }
        }
    }
}

#[test]
fn decode_attention_pending_differential_empty_and_tiny_caches() {
    for tier in tiers() {
        for precision in [Precision::Exact, Precision::Fast] {
            let scalar = KernelCtx {
                tier: SimdTier::Scalar,
                precision,
            };
            let vector = KernelCtx { tier, precision };
            let mut rng = Rng::new(0xDECD);
            for &(len, chunk, h, hd) in &[
                (0usize, 0usize, 1usize, 3usize), // empty cache, no pending
                (0, 2, 2, 8),                     // cold start mid-chunk
                (1, 0, 2, 17),                    // single cached row
                (5, 3, 2, 7),
            ] {
                let d = h * hd;
                let q: Vec<f32> = (0..d).map(|_| (rng.f32() - 0.5) * 2.0).collect();
                let cache_k: Vec<f32> = (0..len * d).map(|_| (rng.f32() - 0.5) * 2.0).collect();
                let cache_v: Vec<f32> = (0..len * d).map(|_| (rng.f32() - 0.5) * 2.0).collect();
                let pend_k: Vec<f32> = (0..chunk * d).map(|_| (rng.f32() - 0.5) * 2.0).collect();
                let pend_v: Vec<f32> = (0..chunk * d).map(|_| (rng.f32() - 0.5) * 2.0).collect();
                let pending: Vec<usize> = (0..chunk).collect();
                let k_self: Vec<f32> = (0..d).map(|_| (rng.f32() - 0.5) * 2.0).collect();
                let v_self: Vec<f32> = (0..d).map(|_| (rng.f32() - 0.5) * 2.0).collect();
                let mut want = vec![0.0f32; d];
                kernels::decode_attention_pending(
                    scalar, &q, &cache_k, &cache_v, &pend_k, &pend_v, &pending, &k_self,
                    &v_self, h, hd, &mut want,
                );
                let mut got = vec![0.0f32; d];
                kernels::decode_attention_pending(
                    vector, &q, &cache_k, &cache_v, &pend_k, &pend_v, &pending, &k_self,
                    &v_self, h, hd, &mut got,
                );
                assert_bits_eq(
                    &want,
                    &got,
                    &format!(
                        "decode_attention_pending len={len} chunk={chunk} h={h} hd={hd} \
                         tier={} {precision:?}",
                        tier.name()
                    ),
                );
            }
        }
    }
}

#[test]
fn prop_randomized_matmul_stays_tier_invariant() {
    // Randomized shape/content sweep on top of the fixed hostile matrix.
    let tier = detect();
    property("matmul tier invariance", 60, |g: &mut Gen| {
        let n = g.usize(1..6);
        let k = g.usize(1..70);
        let m = g.usize(1..40);
        let a = g.f32_vec(n * k..n * k + 1, -3.0, 3.0);
        let b = g.f32_vec(k * m..k * m + 1, -3.0, 3.0);
        let (ps, pv) = pool_pair(tier, Precision::Exact);
        assert_bits_eq(
            &kernels::matmul_par(&ps, &a, &b, n, k, m),
            &kernels::matmul_par(&pv, &a, &b, n, k, m),
            &format!("random matmul {n}x{k}x{m}"),
        );
    });
}

#[test]
fn quantize_rows_degenerate_then_dot_q8_differential() {
    // The zero/subnormal-amax fix must hold on every tier: no NaN/inf
    // out of dot_q8/matmul_q8 regardless of dispatch.
    let (k, m) = (5usize, 4usize);
    let mut w = vec![0.0f32; k * m];
    for kk in 0..k {
        w[kk * m] = 1.0e-41; // subnormal column
        w[kk * m + 1] = 0.0; // all-zero column
        w[kk * m + 2] = -0.0; // negative-zero column
        w[kk * m + 3] = 1.0e30; // large-magnitude column
    }
    let (q, scales) = kernels::quantize_rows(&w, k, m);
    assert!(scales.iter().all(|s| s.is_finite() && *s > 0.0 && s.is_normal()));
    for tier in tiers() {
        let a = vec![1.0f32; k];
        for j in 0..m {
            let dot = simd::dot_q8(tier, &a, &q[j * k..(j + 1) * k]) * scales[j];
            assert!(
                dot.is_finite(),
                "dot_q8 column {j} produced {dot} on tier {}",
                tier.name()
            );
        }
    }
}
