//! Finite-difference gradient checks for the native training path.
//!
//! Two tiers:
//!
//! * **Per-kernel** — every backward kernel in `runtime::cpu::grads`
//!   (matmul both operands, RMSNorm, RoPE, routed/dense attention,
//!   SwiGLU, router, cross-entropy head) is held to a central-difference
//!   estimate of `d⟨W, f(x)⟩/dx` on small shapes, under a multi-threaded
//!   pool (so the checks also exercise the parallel code paths).
//! * **Full model** — `CpuTrainer::loss_grads` (CE + Eq. 7 penalty,
//!   straight-through routing) is probed parameter-by-parameter for
//!   dense, dtr (mixed routed/bypassed tokens), and dtr_skip
//!   (all-bypass) models. Token-choice routing is a step function, so a
//!   probe that straddles a routing-decision boundary is detected by
//!   comparing two FD step sizes and skipped (the STE gradient is
//!   intentionally blind to the flip itself).

use dtrnet::config::{ModelConfig, TrainConfig, Variant};
use dtrnet::runtime::cpu::{grads, kernels};
use dtrnet::runtime::CpuTrainer;
use dtrnet::util::rng::Rng;
use dtrnet::util::threadpool::Pool;

fn randn(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

/// Assert an analytic derivative against its FD estimate: absolute
/// floor + 2% relative band (f32 kernels, central differences).
fn check(fd: f64, an: f32, what: &str) {
    let an = an as f64;
    let err = (fd - an).abs();
    let tol = 5e-3 + 0.02 * fd.abs().max(an.abs());
    assert!(
        err <= tol,
        "{what}: fd={fd:.6e} analytic={an:.6e} err={err:.2e} tol={tol:.2e}"
    );
}

const EPS: f32 = 1e-2;

#[test]
fn fd_matmul_both_operands() {
    let pool = Pool::with_threads(3);
    let mut rng = Rng::new(10);
    let (n, k, m) = (3usize, 5usize, 4usize);
    let mut a = randn(&mut rng, n * k, 0.8);
    let mut b = randn(&mut rng, k * m, 0.8);
    let wy = randn(&mut rng, n * m, 1.0);
    let loss = |a: &[f32], b: &[f32]| -> f64 {
        kernels::matmul(a, b, n, k, m)
            .iter()
            .zip(&wy)
            .map(|(&y, &w)| y as f64 * w as f64)
            .sum()
    };
    let da = grads::matmul_bwd_a(&pool, &wy, &b, n, k, m);
    let db = grads::matmul_bwd_b(&pool, &a, &wy, n, k, m);
    for i in 0..n * k {
        let old = a[i];
        a[i] = old + EPS;
        let lp = loss(&a, &b);
        a[i] = old - EPS;
        let lm = loss(&a, &b);
        a[i] = old;
        check((lp - lm) as f64 / (2.0 * EPS as f64), da[i], &format!("dA[{i}]"));
    }
    for i in 0..k * m {
        let old = b[i];
        b[i] = old + EPS;
        let lp = loss(&a, &b);
        b[i] = old - EPS;
        let lm = loss(&a, &b);
        b[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), db[i], &format!("dB[{i}]"));
    }
}

#[test]
fn fd_rmsnorm() {
    let pool = Pool::with_threads(2);
    let mut rng = Rng::new(11);
    let (n, d) = (4usize, 6usize);
    let mut x = randn(&mut rng, n * d, 1.0);
    let mut w = randn(&mut rng, d, 0.5);
    for v in w.iter_mut() {
        *v += 1.0; // gains near one, like real norms
    }
    let wy = randn(&mut rng, n * d, 1.0);
    let eps_n = 1e-5f32;
    let loss = |x: &[f32], w: &[f32]| -> f64 {
        kernels::rmsnorm(x, w, eps_n)
            .iter()
            .zip(&wy)
            .map(|(&y, &wv)| y as f64 * wv as f64)
            .sum()
    };
    let (dx, dw) = grads::rmsnorm_bwd(&pool, &x, &w, &wy, eps_n);
    for i in 0..n * d {
        let old = x[i];
        x[i] = old + EPS;
        let lp = loss(&x, &w);
        x[i] = old - EPS;
        let lm = loss(&x, &w);
        x[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), dx[i], &format!("rmsnorm dx[{i}]"));
    }
    for j in 0..d {
        let old = w[j];
        w[j] = old + EPS;
        let lp = loss(&x, &w);
        w[j] = old - EPS;
        let lm = loss(&x, &w);
        w[j] = old;
        check((lp - lm) / (2.0 * EPS as f64), dw[j], &format!("rmsnorm dw[{j}]"));
    }
}

#[test]
fn fd_rope() {
    let pool = Pool::with_threads(2);
    let mut rng = Rng::new(12);
    let (n, h, hd) = (5usize, 2usize, 4usize);
    let mut x = randn(&mut rng, n * h * hd, 1.0);
    let wy = randn(&mut rng, n * h * hd, 1.0);
    let pos: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let loss = |x: &[f32]| -> f64 {
        kernels::rope(x, &pos, n, h, hd, 10000.0)
            .iter()
            .zip(&wy)
            .map(|(&y, &w)| y as f64 * w as f64)
            .sum()
    };
    let dx = grads::rope_bwd(&pool, &wy, &pos, n, h, hd, 10000.0);
    for i in 0..n * h * hd {
        let old = x[i];
        x[i] = old + EPS;
        let lp = loss(&x);
        x[i] = old - EPS;
        let lm = loss(&x);
        x[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), dx[i], &format!("rope dx[{i}]"));
    }
}

#[test]
fn fd_attention_routed_and_dense() {
    let pool = Pool::with_threads(3);
    let mut rng = Rng::new(13);
    let (n, h, hd) = (6usize, 2usize, 4usize);
    // mixed routing and the dense (all-ones) boundary case
    let deltas: Vec<Vec<f32>> = vec![
        (0..n).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect(),
        vec![1.0; n],
    ];
    for delta in &deltas {
        let mut q = randn(&mut rng, n * h * hd, 0.8);
        let mut k = randn(&mut rng, n * h * hd, 0.8);
        let mut v = randn(&mut rng, n * h * hd, 0.8);
        let wy = randn(&mut rng, n * h * hd, 1.0);
        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f64 {
            kernels::routed_attention(q, k, v, delta, n, h, hd)
                .iter()
                .zip(&wy)
                .map(|(&y, &w)| y as f64 * w as f64)
                .sum()
        };
        // the training forward must agree with the inference kernel
        let (out, probs) = grads::routed_attention_probs(&pool, &q, &k, &v, delta, n, h, hd);
        assert_eq!(out, kernels::routed_attention(&q, &k, &v, delta, n, h, hd));
        let (dq, dk, dv) = grads::routed_attention_bwd(&pool, &q, &k, &v, &probs, &wy, n, h, hd);
        for i in (0..n * h * hd).step_by(3) {
            let old = q[i];
            q[i] = old + EPS;
            let lp = loss(&q, &k, &v);
            q[i] = old - EPS;
            let lm = loss(&q, &k, &v);
            q[i] = old;
            check((lp - lm) / (2.0 * EPS as f64), dq[i], &format!("attn dq[{i}]"));
        }
        for i in (0..n * h * hd).step_by(3) {
            let old = k[i];
            k[i] = old + EPS;
            let lp = loss(&q, &k, &v);
            k[i] = old - EPS;
            let lm = loss(&q, &k, &v);
            k[i] = old;
            check((lp - lm) / (2.0 * EPS as f64), dk[i], &format!("attn dk[{i}]"));
        }
        for i in (0..n * h * hd).step_by(3) {
            let old = v[i];
            v[i] = old + EPS;
            let lp = loss(&q, &k, &v);
            v[i] = old - EPS;
            let lm = loss(&q, &k, &v);
            v[i] = old;
            check((lp - lm) / (2.0 * EPS as f64), dv[i], &format!("attn dv[{i}]"));
        }
    }
}

#[test]
fn fd_swiglu() {
    let pool = Pool::with_threads(3);
    let mut rng = Rng::new(14);
    let (n, d, ff) = (3usize, 4usize, 6usize);
    let mut x = randn(&mut rng, n * d, 0.8);
    let mut wg = randn(&mut rng, d * ff, 0.5);
    let mut wu = randn(&mut rng, d * ff, 0.5);
    let mut wd = randn(&mut rng, ff * d, 0.5);
    let wy = randn(&mut rng, n * d, 1.0);
    let loss = |x: &[f32], wg: &[f32], wu: &[f32], wd: &[f32]| -> f64 {
        kernels::swiglu_mlp(x, wg, wu, wd, n, d, ff)
            .iter()
            .zip(&wy)
            .map(|(&y, &w)| y as f64 * w as f64)
            .sum()
    };
    let gate_pre = kernels::matmul(&x, &wg, n, d, ff);
    let up = kernels::matmul(&x, &wu, n, d, ff);
    let hmid: Vec<f32> = gate_pre
        .iter()
        .zip(&up)
        .map(|(&g, &u)| kernels::silu(g) * u)
        .collect();
    let (dx, dwg, dwu, dwd) = grads::swiglu_bwd(
        &pool, &x, &wg, &wu, &wd, &gate_pre, &up, &hmid, &wy, n, d, ff,
    );
    for i in 0..n * d {
        let old = x[i];
        x[i] = old + EPS;
        let lp = loss(&x, &wg, &wu, &wd);
        x[i] = old - EPS;
        let lm = loss(&x, &wg, &wu, &wd);
        x[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), dx[i], &format!("swiglu dx[{i}]"));
    }
    for i in (0..d * ff).step_by(2) {
        let old = wg[i];
        wg[i] = old + EPS;
        let lp = loss(&x, &wg, &wu, &wd);
        wg[i] = old - EPS;
        let lm = loss(&x, &wg, &wu, &wd);
        wg[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), dwg[i], &format!("swiglu dwg[{i}]"));
    }
    for i in (0..d * ff).step_by(2) {
        let old = wu[i];
        wu[i] = old + EPS;
        let lp = loss(&x, &wg, &wu, &wd);
        wu[i] = old - EPS;
        let lm = loss(&x, &wg, &wu, &wd);
        wu[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), dwu[i], &format!("swiglu dwu[{i}]"));
    }
    for i in (0..ff * d).step_by(2) {
        let old = wd[i];
        wd[i] = old + EPS;
        let lp = loss(&x, &wg, &wu, &wd);
        wd[i] = old - EPS;
        let lm = loss(&x, &wg, &wu, &wd);
        wd[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), dwd[i], &format!("swiglu dwd[{i}]"));
    }
}

#[test]
fn fd_router() {
    let pool = Pool::with_threads(2);
    let mut rng = Rng::new(15);
    let (n, d) = (5usize, 8usize);
    let dh = d / 2;
    let mut u = randn(&mut rng, n * d, 0.8);
    let mut w1 = randn(&mut rng, d * dh, 0.5);
    let mut w2 = randn(&mut rng, dh * 2, 0.5);
    let wg = randn(&mut rng, n * 2, 1.0);
    let loss = |u: &[f32], w1: &[f32], w2: &[f32]| -> f64 {
        kernels::router(u, w1, w2, n, d, dh)
            .iter()
            .zip(&wg)
            .map(|(&y, &w)| y as f64 * w as f64)
            .sum()
    };
    let g = kernels::router(&u, &w1, &w2, n, d, dh);
    let (du, dw1, dw2) = grads::router_bwd(&pool, &u, &w1, &w2, &g, &wg, n, d, dh);
    for i in 0..n * d {
        let old = u[i];
        u[i] = old + EPS;
        let lp = loss(&u, &w1, &w2);
        u[i] = old - EPS;
        let lm = loss(&u, &w1, &w2);
        u[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), du[i], &format!("router du[{i}]"));
    }
    for i in 0..d * dh {
        let old = w1[i];
        w1[i] = old + EPS;
        let lp = loss(&u, &w1, &w2);
        w1[i] = old - EPS;
        let lm = loss(&u, &w1, &w2);
        w1[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), dw1[i], &format!("router dw1[{i}]"));
    }
    for i in 0..dh * 2 {
        let old = w2[i];
        w2[i] = old + EPS;
        let lp = loss(&u, &w1, &w2);
        w2[i] = old - EPS;
        let lm = loss(&u, &w1, &w2);
        w2[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), dw2[i], &format!("router dw2[{i}]"));
    }
}

#[test]
fn fd_cross_entropy_head() {
    let pool = Pool::with_threads(2);
    let mut rng = Rng::new(16);
    let (n, v) = (5usize, 7usize);
    let mut logits = randn(&mut rng, n * v, 1.0);
    let toks: Vec<i32> = (0..n).map(|_| rng.below(v as u64) as i32).collect();
    let count = n - 1;
    let loss =
        |lg: &[f32]| -> f64 { grads::xent_loss_sum(lg, &toks, n, v) / count as f64 };
    let dl = grads::xent_bwd(&pool, &logits, &toks, count, n, v);
    for i in 0..n * v {
        let old = logits[i];
        logits[i] = old + EPS;
        let lp = loss(&logits);
        logits[i] = old - EPS;
        let lm = loss(&logits);
        logits[i] = old;
        check((lp - lm) / (2.0 * EPS as f64), dl[i], &format!("xent dlogits[{i}]"));
    }
    // the last row predicts nothing — its gradient is exactly zero
    assert!(dl[(n - 1) * v..].iter().all(|&x| x == 0.0));
}

#[test]
fn embedding_bwd_scatter_adds_repeated_tokens() {
    let d = 3;
    let mut de = vec![0.0f32; 4 * d];
    let dx: Vec<f32> = (0..3 * d).map(|i| i as f32).collect();
    grads::embedding_bwd(&mut de, &[2, 0, 2], &dx, d);
    assert_eq!(&de[0..3], &[3.0, 4.0, 5.0]); // token 0 row
    assert_eq!(&de[6..9], &[0.0 + 6.0, 1.0 + 7.0, 2.0 + 8.0]); // token 2 twice
    assert!(de[3..6].iter().all(|&x| x == 0.0));
}

// ---------------------------------------------------------------------------
// Full-model checks: CpuTrainer::loss_grads vs finite differences.

fn fd_cfg(variant: Variant, n_layers: usize) -> ModelConfig {
    let mut cfg = ModelConfig::preset("xs", variant);
    cfg.name = "fd".into();
    cfg.vocab_size = 31;
    cfg.d_model = 16;
    cfg.n_layers = n_layers;
    cfg.n_heads = 2;
    cfg.d_ff = 24;
    cfg.max_seq = 16;
    cfg
}

/// Probe three weights per tensor against central differences.
///
/// Token-choice routing makes the loss piecewise-smooth: a probe whose
/// ±eps evaluations land on different sides of a routing decision sees a
/// jump the STE gradient deliberately ignores. Such probes are detected
/// by disagreement between two FD step sizes and skipped — and the
/// detection threshold is strictly tighter than the assert tolerance, so
/// a jump small enough to evade detection also fits inside the assert
/// budget.
fn fd_full_model(variant: Variant, n_layers: usize, seed: u64) {
    let cfg = fd_cfg(variant, n_layers);
    let hp = TrainConfig {
        batch: 2,
        seq: 8,
        seed,
        ..Default::default()
    };
    let mut tr = CpuTrainer::new(&cfg, &hp).unwrap();
    tr.set_threads(3); // exercise the parallel paths under the check
    let mut rng = Rng::new(seed ^ 0x9E37);
    let tokens: Vec<i32> = (0..hp.batch * hp.seq)
        .map(|_| rng.below(cfg.vocab_size as u64) as i32)
        .collect();
    let (_, gr) = tr.loss_grads(&tokens).unwrap();
    let ganalytic: Vec<(Vec<f32>, bool)> = gr
        .tensors()
        .into_iter()
        .map(|(t, m)| (t.clone(), m))
        .collect();
    let n_tensors = ganalytic.len();
    let eps = 1e-2f32;
    let mut checked = 0usize;
    let mut skipped = 0usize;
    for ti in 0..n_tensors {
        let len = ganalytic[ti].0.len();
        if len == 0 {
            continue;
        }
        for s in 0..3usize {
            let idx = (s * 7919 + ti * 131) % len;
            let an = ganalytic[ti].0[idx] as f64;
            let mut eval_at = |delta: f32| -> f64 {
                {
                    let mut ts = tr.weights_mut().tensors_mut();
                    ts[ti].0[idx] += delta;
                }
                let (l, _) = tr.loss_grads(&tokens).unwrap();
                {
                    let mut ts = tr.weights_mut().tensors_mut();
                    ts[ti].0[idx] -= delta;
                }
                l
            };
            let fd1 = (eval_at(eps) - eval_at(-eps)) / (2.0 * eps as f64);
            let fd2 = (eval_at(eps / 2.0) - eval_at(-eps / 2.0)) / (eps as f64);
            // Two step sizes disagreeing = a routing flip inside the
            // probe interval; the STE gradient is blind to it. This
            // threshold is tighter than the assert tolerance below.
            let agree = (fd1 - fd2).abs() <= 1.5e-3 + 0.05 * fd1.abs().max(fd2.abs());
            if !agree {
                skipped += 1;
                continue;
            }
            let err = (fd1 - an).abs();
            let tol = 3e-3 + 0.07 * fd1.abs().max(an.abs());
            assert!(
                err <= tol,
                "{variant:?} tensor {ti} idx {idx}: fd={fd1:.6e} analytic={an:.6e} \
                 (err {err:.2e} > tol {tol:.2e})"
            );
            checked += 1;
        }
    }
    assert!(
        checked > 3 * skipped + 10,
        "{variant:?}: too few clean probes (checked {checked}, skipped {skipped})"
    );
}

#[test]
fn fd_full_model_dense() {
    fd_full_model(Variant::Dense, 3, 21);
}

#[test]
fn fd_full_model_dtr_mixed_routing() {
    // TDDT: two DTR layers, mixed routed/bypassed tokens — exercises the
    // straight-through select, both path gradients, and the Eq. 7
    // penalty with two alpha-weighted layers.
    fd_full_model(Variant::DtrTrilayer, 4, 22);
}

#[test]
fn fd_full_model_dtr_skip() {
    // All tokens bypass: the Table 6 ablation — pure linear-path
    // gradients, no attention contribution on DTR layers.
    fd_full_model(Variant::DtrSkip, 4, 23);
}
