//! Cross-layer integration: Rust runtime ↔ AOT artifacts.
//!
//! These tests exercise the real PJRT path over the xs artifact set (built
//! by `make artifacts`); they are the Rust-side counterpart of the python
//! decode/fwd consistency suite. They require the `pjrt` feature (see
//! Cargo.toml `required-features`); the offline mirror driving the same
//! assertions through the CPU backend lives in `integration_cpu.rs`.
#![cfg(feature = "pjrt")]

use dtrnet::runtime::{Engine, Tensor};

fn engine() -> Engine {
    Engine::new(&dtrnet::artifacts_dir()).expect("artifacts built? run `make artifacts`")
}

fn init_params(e: &Engine, tag: &str, seed: i32) -> Vec<xla::Literal> {
    let init = e.load(&format!("{tag}_init")).unwrap();
    init.call_literals(&[Tensor::scalar_i32(seed).to_literal().unwrap()])
        .unwrap()
}

#[test]
fn manifest_loads_and_indexes() {
    let e = engine();
    assert!(e.manifest.artifacts.len() >= 14);
    let spec = e.manifest.get("xs_dtr_bilayer_fwd_b2s64").unwrap();
    assert_eq!(spec.kind, "fwd");
    assert_eq!(spec.batch, Some(2));
    assert_eq!(spec.seq, Some(64));
    assert!(e.manifest.get("nope").is_err());
}

#[test]
fn init_is_seed_deterministic() {
    let e = engine();
    let a = init_params(&e, "xs_dtr_bilayer", 7);
    let b = init_params(&e, "xs_dtr_bilayer", 7);
    let c = init_params(&e, "xs_dtr_bilayer", 8);
    let ta = Tensor::from_literal(&a[0]).unwrap();
    let tb = Tensor::from_literal(&b[0]).unwrap();
    let tc = Tensor::from_literal(&c[0]).unwrap();
    assert_eq!(ta, tb);
    assert_ne!(ta, tc);
}

#[test]
fn fwd_shapes_and_route_semantics() {
    let e = engine();
    let params = init_params(&e, "xs_dtr_bilayer", 0);
    let fwd = e.load("xs_dtr_bilayer_fwd_b2s64").unwrap();
    let tok = Tensor::i32(vec![2, 64], (0..128).map(|i| i % 256).collect())
        .to_literal()
        .unwrap();
    let mut inputs: Vec<&xla::Literal> = params.iter().collect();
    inputs.push(&tok);
    let outs = fwd.call_literals_ref(&inputs).unwrap();
    assert_eq!(outs.len(), 4);
    let logits = Tensor::from_literal(&outs[0]).unwrap();
    assert_eq!(logits.shape, vec![2, 64, 256]);
    assert!(logits.as_f32().iter().all(|x| x.is_finite()));
    // route: dense layers (0, 2, 3 in TDTT) must be all-ones
    let route = Tensor::from_literal(&outs[1]).unwrap();
    assert_eq!(route.shape, vec![2, 4, 64]);
    let layout = fwd.spec.config.layout_string();
    assert_eq!(layout, "TDTT");
    for b in 0..2 {
        for (l, k) in layout.chars().enumerate() {
            let off = (b * 4 + l) * 64;
            let frac: f32 =
                route.as_f32()[off..off + 64].iter().sum::<f32>() / 64.0;
            if k == 'T' {
                assert_eq!(frac, 1.0, "dense layer {l} must attend all");
            } else {
                assert!(frac < 1.0, "DTR layer {l} should bypass some tokens");
            }
        }
    }
}

#[test]
fn fwd_is_deterministic() {
    let e = engine();
    let params = init_params(&e, "xs_dense", 3);
    let fwd = e.load("xs_dense_fwd_b2s64").unwrap();
    let tok = Tensor::i32(vec![2, 64], vec![42; 128]).to_literal().unwrap();
    let run = || {
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&tok);
        let outs = fwd.call_literals_ref(&inputs).unwrap();
        Tensor::from_literal(&outs[0]).unwrap()
    };
    assert_eq!(run(), run());
}

#[test]
fn prefill_matches_fwd_prefix() {
    // the serving path must agree with the training-shape forward
    let e = engine();
    let params = init_params(&e, "xs_dtr_bilayer", 1);
    let toks64: Vec<i32> = (0..64).map(|i| (i * 13 % 256) as i32).collect();

    let fwd = e.load("xs_dtr_bilayer_fwd_b2s64").unwrap();
    let mut both = toks64.clone();
    both.extend(&toks64);
    let tok = Tensor::i32(vec![2, 64], both).to_literal().unwrap();
    let mut inputs: Vec<&xla::Literal> = params.iter().collect();
    inputs.push(&tok);
    let outs = fwd.call_literals_ref(&inputs).unwrap();
    let logits = Tensor::from_literal(&outs[0]).unwrap();

    let prefill = e.load("xs_dtr_bilayer_prefill_s32").unwrap();
    let tok32 = Tensor::i32(vec![32], toks64[..32].to_vec())
        .to_literal()
        .unwrap();
    let mut inputs: Vec<&xla::Literal> = params.iter().collect();
    inputs.push(&tok32);
    let pouts = prefill.call_literals_ref(&inputs).unwrap();
    // outputs: ck, cv, lens, last_logits, routed
    let last_logits = Tensor::from_literal(&pouts[3]).unwrap();
    assert_eq!(last_logits.shape, vec![256]);

    // fwd logits at position 31 (batch 0) — causal prefix equality
    let v = 256;
    let fwd_row = &logits.as_f32()[31 * v..32 * v];
    dtrnet::testing::assert_allclose(last_logits.as_f32(), fwd_row, 1e-3, 1e-3);

    // lens: dense layers cached all 32 tokens; DTR layer fewer
    let lens = Tensor::from_literal(&pouts[2]).unwrap();
    let layout = prefill.spec.config.layout_string();
    for (l, k) in layout.chars().enumerate() {
        let len = lens.as_i32()[l];
        if k == 'T' {
            assert_eq!(len, 32);
        } else {
            assert!(len < 32, "DTR layer should cache fewer (got {len})");
        }
    }
}

#[test]
fn train_step_reduces_loss_on_learnable_data() {
    let e = engine();
    let tinit = e.load("xs_dtr_bilayer_train_init").unwrap();
    let mut state = tinit
        .call_literals(&[Tensor::scalar_i32(0).to_literal().unwrap()])
        .unwrap();
    let tstep = e.load("xs_dtr_bilayer_train_step").unwrap();
    let nparams = tstep.spec.nparams.unwrap();
    // learnable pattern: ramp repeated
    let base: Vec<i32> = (0..64).map(|i| (i % 16) as i32).collect();
    let mut both = base.clone();
    both.extend(&base);
    let tok = Tensor::i32(vec![2, 64], both).to_literal().unwrap();
    let lr = Tensor::scalar_f32(3e-3).to_literal().unwrap();
    let seed = Tensor::scalar_i32(0).to_literal().unwrap();
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for s in 1..=15 {
        let step = Tensor::scalar_f32(s as f32).to_literal().unwrap();
        let mut inputs: Vec<&xla::Literal> = state.iter().collect();
        inputs.push(&tok);
        inputs.push(&step);
        inputs.push(&lr);
        inputs.push(&seed);
        let mut outs = tstep.call_literals_ref(&inputs).unwrap();
        let metrics = outs.split_off(3 * nparams);
        state = outs;
        let loss = Tensor::from_literal(&metrics[0]).unwrap().scalar();
        if s == 1 {
            first = loss;
        }
        last = loss;
        assert!(loss.is_finite());
    }
    assert!(
        last < first - 0.2,
        "loss should fall on learnable data: {first} -> {last}"
    );
}

#[test]
fn decode_step_appends_kv_only_when_routed() {
    let e = engine();
    let params = init_params(&e, "xs_dtr_bilayer", 2);
    let dec = e.load("xs_dtr_bilayer_decode_b2m96").unwrap();
    let spec = &dec.spec;
    let nparams = spec.nparams.unwrap();
    let cs = spec.inputs[nparams].shape.clone(); // [L,B,M,H,hd]
    let (l_n, b_n) = (cs[0], cs[1]);
    let mut ck = Tensor::zeros_f32(cs.clone()).to_literal().unwrap();
    let mut cv = Tensor::zeros_f32(cs.clone()).to_literal().unwrap();
    let mut lens_t = Tensor::zeros_i32(vec![l_n, b_n]);
    for t in 0..10 {
        let tok = Tensor::i32(vec![b_n], vec![(t * 31 % 256) as i32; b_n])
            .to_literal()
            .unwrap();
        let pos = Tensor::i32(vec![b_n], vec![t as i32; b_n]).to_literal().unwrap();
        let lens = lens_t.to_literal().unwrap();
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&ck);
        inputs.push(&cv);
        inputs.push(&lens);
        inputs.push(&tok);
        inputs.push(&pos);
        let mut outs = dec.call_literals_ref(&inputs).unwrap();
        let _g = outs.pop().unwrap();
        let routed = Tensor::from_literal(&outs.pop().unwrap()).unwrap();
        let new_lens = Tensor::from_literal(&outs.pop().unwrap()).unwrap();
        cv = outs.pop().unwrap();
        ck = outs.pop().unwrap();
        // invariant: lens increase exactly by the routing decision
        for i in 0..l_n * b_n {
            let expect = lens_t.as_i32()[i] + (routed.as_f32()[i] > 0.5) as i32;
            assert_eq!(new_lens.as_i32()[i], expect);
        }
        lens_t = new_lens;
    }
    // dense layers cached all 10; DTR layer ≤ 10
    let layout = spec.config.layout_string();
    for (l, k) in layout.chars().enumerate() {
        let len = lens_t.as_i32()[l * b_n];
        if k == 'T' {
            assert_eq!(len, 10);
        } else {
            assert!(len <= 10);
        }
    }
}
