//! Split-read torture tests for the HTTP/1.1 push parser and the
//! strict JSON push validator: every fixture is replayed one-shot,
//! byte by byte, and split at *every* single boundary, and the parsed
//! requests must come out bitwise identical each time. Malformed
//! inputs get the same treatment and must map to the same specific
//! protocol error at every split.

use dtrnet::coordinator::http::torture::{check_http_bytes, check_json_bytes, http_outcome};
use dtrnet::coordinator::http::{HttpError, Limits, PushParser};

fn limits() -> Limits {
    Limits {
        max_head_bytes: 2048,
        max_body_bytes: 4096,
        max_headers: 32,
    }
}

/// Feed `data` with a single split at every possible boundary and
/// demand the outcome matches the one-shot parse exactly (the oracle
/// already covers byte-by-byte and pseudo-random splits).
fn every_single_split(data: &[u8]) {
    let oneshot = check_http_bytes(data);
    for cut in 0..=data.len() {
        let split = http_outcome(data, &[cut]);
        assert_eq!(oneshot, split, "outcome changed when split at byte {cut}");
    }
    // Every pair of splits in a sliding window around the head/body
    // boundary region — two partial reads are the common socket case.
    for a in 0..data.len() {
        let b = (a + 7).min(data.len());
        let split = http_outcome(data, &[a, b]);
        assert_eq!(oneshot, split, "outcome changed when split at {a},{b}");
    }
}

fn post_generate(body: &str) -> Vec<u8> {
    format!(
        "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .into_bytes()
}

#[test]
fn valid_fixtures_are_split_invariant() {
    let fixtures: Vec<Vec<u8>> = vec![
        b"GET /health HTTP/1.1\r\nHost: t\r\n\r\n".to_vec(),
        b"GET / HTTP/1.0\r\n\r\n".to_vec(),
        post_generate("{\"prompt\":[72,105],\"max_new_tokens\":4}"),
        post_generate("{\"text\":\"caf\\u00e9 \\ud83d\\ude00\",\"stream\":true}"),
        post_generate("{}"),
        b"POST /generate HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\n{}".to_vec(),
        b"GET /health HTTP/1.1\r\nConnection: close\r\nX-Pad:   spaced   \r\n\r\n".to_vec(),
    ];
    for data in &fixtures {
        every_single_split(data);
        let out = check_http_bytes(data);
        assert_eq!(out.requests.len(), 1, "fixture must parse as one request");
        assert_eq!(out.error, None);
        assert_eq!(out.buffered, 0);
    }
}

#[test]
fn parsed_head_fields_survive_any_chunking() {
    let data = post_generate("{\"prompt\":[1,2,3]}");
    let oneshot = check_http_bytes(&data);
    let (head, body) = &oneshot.requests[0];
    assert_eq!(head.method, "POST");
    assert_eq!(head.target, "/generate");
    assert!(head.http11);
    assert!(!head.close);
    assert_eq!(head.content_length, body.len());
    assert_eq!(head.header("content-type"), Some("application/json"));
    assert_eq!(body.as_slice(), b"{\"prompt\":[1,2,3]}");
    // check_http_bytes already compared byte-by-byte and random splits
    // against this exact (head, body) pair bitwise.
}

#[test]
fn pipelined_requests_share_one_read() {
    let one = post_generate("{\"prompt\":[1]}");
    let two = b"GET /health HTTP/1.1\r\n\r\n".to_vec();
    let three = post_generate("{\"text\":\"x\"}");
    let mut data = one.clone();
    data.extend_from_slice(&two);
    data.extend_from_slice(&three);

    every_single_split(&data);
    let out = check_http_bytes(&data);
    assert_eq!(out.requests.len(), 3);
    assert_eq!(out.requests[0].0.method, "POST");
    assert_eq!(out.requests[1].0.method, "GET");
    assert_eq!(out.requests[1].1, b"");
    assert_eq!(out.requests[2].1, b"{\"text\":\"x\"}");
    assert_eq!(out.error, None);
    assert_eq!(out.buffered, 0);
}

#[test]
fn malformed_inputs_fail_identically_at_every_split() {
    // (input, expected status) — each must produce the same sticky
    // error no matter how the bytes arrive.
    let cases: Vec<(Vec<u8>, u16)> = vec![
        (b"BOGUS\r\n\r\n".to_vec(), 400),
        (b"GET / HTTP/2.0\r\n\r\n".to_vec(), 505),
        (b"GET / HTTP/1.1\nHost: a\n\n".to_vec(), 400),
        (b"POST / HTTP/1.1\r\nHost: a\r\n\r\n".to_vec(), 411),
        (b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n".to_vec(), 400),
        (b"POST / HTTP/1.1\r\nContent-Length: -1\r\n\r\n".to_vec(), 400),
        (
            b"POST / HTTP/1.1\r\nContent-Length: 1\r\nContent-Length: 2\r\n\r\n".to_vec(),
            400,
        ),
        (b"POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n".to_vec(), 413),
        (
            b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n".to_vec(),
            501,
        ),
        (b"GET / HTTP/1.1\r\nBad Header: x\r\n\r\n".to_vec(), 400),
        (b"GET / HTTP/1.1\r\n: novalue\r\n\r\n".to_vec(), 400),
    ];
    for (data, status) in &cases {
        let oneshot = check_http_bytes(data);
        let err = oneshot
            .error
            .unwrap_or_else(|| panic!("{data:?} must fail"));
        assert_eq!(err.status(), *status, "wrong status for {data:?}");
        assert_eq!(oneshot.requests.len(), 0);
        every_single_split(data);
    }
}

#[test]
fn limits_trip_deterministically() {
    // Header bomb: more headers than the cap.
    let mut bomb = b"GET / HTTP/1.1\r\n".to_vec();
    for i in 0..40 {
        bomb.extend_from_slice(format!("X-H{i}: v\r\n").as_bytes());
    }
    bomb.extend_from_slice(b"\r\n");
    let out = check_http_bytes(&bomb);
    assert_eq!(out.error.map(|e| e.status()), Some(431));
    every_single_split(&bomb);

    // Head larger than max_head_bytes without ever finishing.
    let huge = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(4000)).into_bytes();
    let out = check_http_bytes(&huge);
    assert_eq!(out.error.map(|e| e.status()), Some(431));

    // An error is sticky: pushes after it keep failing with the same error.
    let mut p = PushParser::new(limits());
    let first = p.push(b"GET / HTTP/9.9\r\n\r\n").unwrap_err();
    assert_eq!(first, HttpError::UnsupportedVersion);
    assert_eq!(p.push(b"GET / HTTP/1.1\r\n\r\n").unwrap_err(), first);
    assert_eq!(p.failure(), Some(first));
    assert!(p.take().is_none());
}

#[test]
fn incremental_body_bytes_reassemble_exactly() {
    // body_new_bytes() must hand out each body byte exactly once, in
    // order, regardless of how pushes line up with the head/body split.
    let body = b"{\"prompt\":[10,20,30],\"max_new_tokens\":7}";
    let data = post_generate(std::str::from_utf8(body).unwrap());
    for cut in 0..=data.len() {
        let mut p = PushParser::new(limits());
        let mut seen: Vec<u8> = Vec::new();
        for seg in [&data[..cut], &data[cut..]] {
            p.push(seg).unwrap();
            seen.extend_from_slice(p.body_new_bytes());
        }
        assert!(p.ready());
        assert_eq!(seen, body, "body bytes diverged when split at {cut}");
        let req = p.take().unwrap();
        assert_eq!(req.body(), body);
    }
}

#[test]
fn json_push_is_split_invariant_everywhere() {
    let docs: Vec<&[u8]> = vec![
        b"{\"prompt\":[1,2,3],\"max_new_tokens\":16,\"stream\":false}",
        b"{\"text\":\"caf\\u00e9 \\ud83d\\ude00 \\\" \\\\ \\n\",\"temperature\":0.5}",
        b"[1,-2.5e-3,0.125,true,false,null,[],{}]",
        b"\"\\ud800\"",
        b"{\"a\":{\"b\":{\"c\":[{\"d\":null}]}}}",
        b"01",
        b"{\"a\":1,}",
        b"{\"a\"",
        b"\xff\xfe",
        b"{\"utf8\":\"caf\xc3\xa9 \xf0\x9f\x98\x80\"}",
    ];
    for doc in &docs {
        // The oracle covers one-shot vs byte-by-byte vs pseudo-random.
        let verdict = check_json_bytes(doc);
        // Additionally: the verdict must be identical for every single
        // split position (feed [..i] then [i..]).
        for i in 0..=doc.len() {
            use dtrnet::coordinator::http::bjson::JsonPush;
            let mut p = JsonPush::new();
            let ok = p.feed(&doc[..i]).is_ok()
                && p.feed(&doc[i..]).is_ok()
                && p.finish().is_ok();
            assert_eq!(ok, verdict, "JsonPush verdict changed at split {i} for {doc:?}");
        }
    }
}
