//! Smoke coverage for the long-context probe generators (`data::longctx`).
//!
//! The in-module tests check item layout; these tests exercise the
//! generators the way the eval path does: produce a *stream* of documents
//! at several lengths, verify the stream is seed-deterministic, and feed
//! it through the CPU runtime end to end (per-item answer-span scoring
//! and whole-stream perplexity via `Dataset`).

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::data::longctx::LongCtxItem;
use dtrnet::data::{copy_task, needle_task, Dataset};
use dtrnet::eval::{cross_entropy, perplexity_backend};
use dtrnet::runtime::{Backend, CpuBackend, Tensor};
use dtrnet::util::rng::Rng;

/// An interleaved needle/copy document stream at growing lengths — the
/// shape the ppl-vs-length benchmark consumes.
fn document_stream(seed: u64, vocab: usize, lengths: &[usize]) -> Vec<LongCtxItem> {
    let mut rng = Rng::new(seed);
    let mut items = Vec::with_capacity(lengths.len() * 2);
    for &len in lengths {
        let span = (len / 8).max(4);
        items.push(needle_task(&mut rng, vocab, len, span));
        items.push(copy_task(&mut rng, vocab, len, span));
    }
    items
}

#[test]
fn stream_is_deterministic_and_wellformed() {
    let vocab = 256;
    let lengths = [64, 128, 256, 512, 1024];
    let a = document_stream(7, vocab, &lengths);
    let b = document_stream(7, vocab, &lengths);
    assert_eq!(a.len(), lengths.len() * 2);
    for (x, y) in a.iter().zip(&b) {
        // same seed -> bitwise-identical documents and spans
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.answer_start, y.answer_start);
        assert_eq!(x.answer_end, y.answer_end);
    }
    for (i, item) in a.iter().enumerate() {
        let len = lengths[i / 2];
        assert_eq!(item.tokens.len(), len);
        assert!(item.tokens.iter().all(|&t| (t as usize) < vocab));
        // answer span is the trailing repetition of the prefix
        assert!(item.answer_start < item.answer_end);
        assert_eq!(item.answer_end, len);
        let span = item.answer_end - item.answer_start;
        assert_eq!(item.tokens[..span], item.tokens[item.answer_start..]);
    }
    let c = document_stream(8, vocab, &lengths);
    assert!(
        a.iter().zip(&c).any(|(x, y)| x.tokens != y.tokens),
        "different seeds must produce different streams"
    );
}

#[test]
fn stream_scores_through_cpu_backend() {
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let be = CpuBackend::init(&cfg, 11).unwrap();
    let (seq, vocab) = (cfg.max_seq, cfg.vocab_size);

    // Per-item answer-span scoring: every document in the stream must be
    // consumable by `forward` and yield a finite span cross-entropy.
    let items = document_stream(3, vocab, &[seq, seq, seq]);
    for item in &items {
        let tokens: Vec<i32> = item.tokens.iter().map(|&t| t as i32).collect();
        let out = be.forward(&Tensor::i32(vec![1, seq], tokens.clone())).unwrap();
        let ce = cross_entropy(
            out.logits.as_f32(),
            &tokens,
            1,
            seq,
            vocab,
            Some((item.answer_start, item.answer_end)),
        );
        assert!(ce.is_finite() && ce > 0.0, "span CE must be finite, got {ce}");
    }

    // Whole-stream perplexity: flatten the stream into a Dataset and run
    // the standard eval loop over it (batched iteration, routing stats).
    let flat: Vec<u32> = document_stream(5, vocab, &[seq, seq, seq, seq])
        .into_iter()
        .flat_map(|it| it.tokens)
        .collect();
    assert_eq!(flat.len(), 8 * seq);
    let data = Dataset::new(flat, seq);
    let res = perplexity_backend(&be, &data, 2, 4).unwrap();
    assert!(res.ppl.is_finite() && res.ppl > 1.0);
    assert!(res.n_tokens > 0);
}
