//! Smoke coverage for the long-context probe generators (`data::longctx`).
//!
//! The in-module tests check item layout; these tests exercise the
//! generators the way the eval path does: produce a *stream* of documents
//! at several lengths, verify the stream is seed-deterministic, and feed
//! it through the CPU runtime end to end (per-item answer-span scoring
//! and whole-stream perplexity via `Dataset`).
//!
//! The `bounded_*`/`needle_retrieval_*` tests additionally drive the
//! long-document path through the bounded/paged KV cache (LRU eviction
//! with spill-to-disk) and pin its determinism contract: everything —
//! token streams, logits bits, cache snapshots, answer-span retrieval —
//! must be bitwise identical to the unbounded resident slab.

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::data::longctx::LongCtxItem;
use dtrnet::data::{copy_task, needle_task, Dataset};
use dtrnet::eval::{cross_entropy, perplexity_backend};
use dtrnet::runtime::{Backend, CpuBackend, DecodeState, Tensor};
use dtrnet::util::rng::Rng;

/// Greedy argmax (first maximum), shared by both cache paths so stream
/// comparisons isolate the KV storage implementation.
fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// An interleaved needle/copy document stream at growing lengths — the
/// shape the ppl-vs-length benchmark consumes.
fn document_stream(seed: u64, vocab: usize, lengths: &[usize]) -> Vec<LongCtxItem> {
    let mut rng = Rng::new(seed);
    let mut items = Vec::with_capacity(lengths.len() * 2);
    for &len in lengths {
        let span = (len / 8).max(4);
        items.push(needle_task(&mut rng, vocab, len, span));
        items.push(copy_task(&mut rng, vocab, len, span));
    }
    items
}

#[test]
fn stream_is_deterministic_and_wellformed() {
    let vocab = 256;
    let lengths = [64, 128, 256, 512, 1024];
    let a = document_stream(7, vocab, &lengths);
    let b = document_stream(7, vocab, &lengths);
    assert_eq!(a.len(), lengths.len() * 2);
    for (x, y) in a.iter().zip(&b) {
        // same seed -> bitwise-identical documents and spans
        assert_eq!(x.tokens, y.tokens);
        assert_eq!(x.answer_start, y.answer_start);
        assert_eq!(x.answer_end, y.answer_end);
    }
    for (i, item) in a.iter().enumerate() {
        let len = lengths[i / 2];
        assert_eq!(item.tokens.len(), len);
        assert!(item.tokens.iter().all(|&t| (t as usize) < vocab));
        // answer span is the trailing repetition of the prefix
        assert!(item.answer_start < item.answer_end);
        assert_eq!(item.answer_end, len);
        let span = item.answer_end - item.answer_start;
        assert_eq!(item.tokens[..span], item.tokens[item.answer_start..]);
    }
    let c = document_stream(8, vocab, &lengths);
    assert!(
        a.iter().zip(&c).any(|(x, y)| x.tokens != y.tokens),
        "different seeds must produce different streams"
    );
}

#[test]
fn stream_scores_through_cpu_backend() {
    let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    let be = CpuBackend::init(&cfg, 11).unwrap();
    let (seq, vocab) = (cfg.max_seq, cfg.vocab_size);

    // Per-item answer-span scoring: every document in the stream must be
    // consumable by `forward` and yield a finite span cross-entropy.
    let items = document_stream(3, vocab, &[seq, seq, seq]);
    for item in &items {
        let tokens: Vec<i32> = item.tokens.iter().map(|&t| t as i32).collect();
        let out = be.forward(&Tensor::i32(vec![1, seq], tokens.clone())).unwrap();
        let ce = cross_entropy(
            out.logits.as_f32(),
            &tokens,
            1,
            seq,
            vocab,
            Some((item.answer_start, item.answer_end)),
        );
        assert!(ce.is_finite() && ce > 0.0, "span CE must be finite, got {ce}");
    }

    // Whole-stream perplexity: flatten the stream into a Dataset and run
    // the standard eval loop over it (batched iteration, routing stats).
    let flat: Vec<u32> = document_stream(5, vocab, &[seq, seq, seq, seq])
        .into_iter()
        .flat_map(|it| it.tokens)
        .collect();
    assert_eq!(flat.len(), 8 * seq);
    let data = Dataset::new(flat, seq);
    let res = perplexity_backend(&be, &data, 2, 4).unwrap();
    assert!(res.ppl.is_finite() && res.ppl > 1.0);
    assert!(res.n_tokens > 0);
}

#[test]
fn bounded_kv_eviction_is_bitwise_identical_to_resident() {
    // Context length well past the xs preset cap: RoPE works from
    // absolute positions, so only max_seq needs raising.
    let mut cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    cfg.max_seq = 1024;
    let be = CpuBackend::init(&cfg, 11).unwrap();
    let d = cfg.d_model;
    let (page_rows, gen) = (16usize, 12usize);
    let item = needle_task(&mut Rng::new(21), cfg.vocab_size, 768, 32);
    let prompt: Vec<i32> = item.tokens.iter().map(|&t| t as i32).collect();
    // Enough for one layer's full working set (a pinned layer must fit
    // resident) but far below the all-layers total, so LRU eviction and
    // spill-reload genuinely run.
    let budget = (prompt.len() + gen).div_ceil(page_rows) + 1;

    let run = |mut state: DecodeState| -> (Vec<i32>, Vec<f32>, DecodeState) {
        let mut logits = be.prefill(&mut state, &prompt).unwrap().logits;
        let mut toks = Vec::with_capacity(gen);
        for _ in 0..gen {
            let next = argmax(logits.as_f32());
            toks.push(next);
            logits = be.decode_step(&mut state, next).unwrap().logits;
        }
        (toks, logits.as_f32().to_vec(), state)
    };
    let (toks_r, logits_r, st_r) = run(be.begin_decode());
    let (toks_b, logits_b, st_b) =
        run(DecodeState::bounded(cfg.n_layers, d, page_rows, budget, None));

    assert_eq!(toks_r, toks_b, "token streams diverged under eviction");
    assert_eq!(logits_r, logits_b, "final logits bits diverged under eviction");
    assert_eq!(st_r.snapshot_kv(), st_b.snapshot_kv(), "cache contents diverged");
    // The resident slab never pages; the bounded cache stayed within its
    // budget while caching multiples of it in total.
    assert_eq!(st_r.kv.resident_pages_peak(), 0);
    let peak = st_b.kv.resident_pages_peak();
    assert!(peak > 0 && peak <= budget, "peak {peak} vs budget {budget}");
    let total: usize = st_b.lens(d).iter().map(|&l| l.div_ceil(page_rows)).sum();
    assert!(total > budget, "eviction never engaged ({total} <= {budget})");
}

#[test]
fn needle_retrieval_accuracy_is_identical_through_paged_path() {
    let mut cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
    cfg.max_seq = 1024;
    let be = CpuBackend::init(&cfg, 11).unwrap();
    let d = cfg.d_model;
    let page_rows = 16usize;
    let item = needle_task(&mut Rng::new(33), cfg.vocab_size, 640, 24);
    let span = item.answer_end - item.answer_start;
    let budget = item.tokens.len().div_ceil(page_rows) + 1;

    // Teacher-forced answer-span retrieval: prefill the document up to
    // the trailing needle, then compare each greedy prediction against
    // the true needle token before feeding the truth. With seed-init
    // weights this is a plumbing gate, not a capability claim — the
    // point is that the paged path scores the span exactly like the
    // resident slab.
    let accuracy = |mut state: DecodeState| -> (f64, Vec<i32>) {
        let prefix: Vec<i32> = item.tokens[..item.answer_start]
            .iter()
            .map(|&t| t as i32)
            .collect();
        let mut logits = be.prefill(&mut state, &prefix).unwrap().logits;
        let mut preds = Vec::with_capacity(span);
        let mut hits = 0usize;
        for pos in item.answer_start..item.answer_end {
            let pred = argmax(logits.as_f32());
            preds.push(pred);
            let truth = item.tokens[pos] as i32;
            hits += usize::from(pred == truth);
            logits = be.decode_step(&mut state, truth).unwrap().logits;
        }
        assert!(
            state.kv.resident_pages_peak() <= budget,
            "paged run exceeded its budget"
        );
        (hits as f64 / span as f64, preds)
    };
    let (acc_r, preds_r) = accuracy(be.begin_decode());
    let (acc_b, preds_b) =
        accuracy(DecodeState::bounded(cfg.n_layers, d, page_rows, budget, None));

    assert_eq!(preds_r, preds_b, "paged-path predictions diverged from resident");
    assert_eq!(acc_r.to_bits(), acc_b.to_bits(), "span accuracy diverged");
    assert!((0.0..=1.0).contains(&acc_r), "accuracy {acc_r} out of range");
}
