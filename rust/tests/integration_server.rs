//! Cross-layer integration: the backend-generic continuous-batching
//! serving engine (`coordinator::server`) on the native CPU backend.
//!
//! Pins the three subsystem contracts:
//! * scheduling — admission/recycling under mixed-length workloads;
//! * KV paging — the pool's per-(slot, layer) lens mirror the backend's
//!   routing-aware decode caches at every step (pages are allocated for
//!   exactly the routed tokens — the Fig. 6 mechanism);
//! * determinism — same seed + workload → identical per-request token
//!   streams, independent of prefill mode, batch packing, and timing.

use std::time::Instant;

use dtrnet::config::{ModelConfig, Variant};
use dtrnet::coordinator::{
    generate_workload, Batcher, FinishReason, PrefillMode, Request, Server, ServerConfig,
    WorkloadSpec,
};
use dtrnet::runtime::{Backend, CpuBackend};

fn backend(variant: Variant, seed: u64) -> CpuBackend {
    CpuBackend::init(&ModelConfig::preset("xs", variant), seed).unwrap()
}

/// Small mixed-length workload sized for the xs preset (max_seq 64).
fn spec(n: usize, temperature: f32) -> WorkloadSpec {
    WorkloadSpec {
        n_requests: n,
        arrival_rate: 2000.0,
        prompt_len_mean: 6,
        prompt_len_max: 16,
        gen_len_mean: 8,
        gen_len_max: 20,
        temperature,
        vocab: 256,
    }
}

#[test]
fn batcher_recycles_slots_under_mixed_lengths() {
    let trace = generate_workload(
        &WorkloadSpec {
            n_requests: 24,
            prompt_len_mean: 5,
            prompt_len_max: 40,
            gen_len_mean: 6,
            gen_len_max: 30,
            ..Default::default()
        },
        11,
    );
    let mut b = Batcher::new(3, 64);
    for t in &trace {
        assert!(b.submit(t.request.clone()));
    }
    let now = Instant::now();
    let mut max_active = 0;
    let mut guard = 0;
    while !b.idle() {
        b.admit();
        max_active = max_active.max(b.n_active());
        assert!(b.n_active() <= 3, "slot count exceeded");
        for s in 0..3 {
            if b.active[s].is_some() {
                b.advance(s, 1, now);
            }
        }
        guard += 1;
        assert!(guard < 100_000, "batcher failed to drain");
    }
    assert_eq!(b.completed.len(), 24, "every request must complete");
    assert_eq!(max_active, 3, "slots must saturate under backlog");
    for c in &b.completed {
        assert_eq!(c.generated.len(), c.req.max_new_tokens, "req {}", c.req.id);
        assert_eq!(c.position, c.req.prompt.len() + c.req.max_new_tokens - 1);
    }
}

#[test]
fn kv_pool_mirrors_backend_caches_every_step() {
    for prefill in [PrefillMode::Decode, PrefillMode::Chunked(5)] {
        let be = backend(Variant::DtrBilayer, 9);
        let cfg = ServerConfig {
            slots: 3,
            prefill,
            ..Default::default()
        };
        let mut srv = Server::new(&be, cfg).unwrap();
        let trace = generate_workload(&spec(8, 0.0), 3);
        for t in &trace {
            let mut req = t.request.clone();
            req.arrival = Instant::now();
            assert!(srv.submit(req));
        }
        let mut guard = 0;
        while !srv.batcher.idle() {
            srv.step().unwrap();
            // THE invariant: pool pages cover exactly the tokens the
            // backend routed into each live slot's cache.
            srv.check_kv_invariant()
                .unwrap_or_else(|e| panic!("{prefill:?}: {e:#}"));
            guard += 1;
            assert!(guard < 100_000, "engine failed to drain");
        }
        assert_eq!(
            srv.pool.stats().pages_allocated,
            0,
            "{prefill:?}: completion must recycle every page"
        );
    }
}

#[test]
fn serve_end_to_end_reports_routing_aware_savings() {
    // Two DTR layers (xs trilayer: TDDT), fine-grained 2-token pages, and
    // sequences long enough that routed page counts drop below dense ones
    // at the pool's peak with overwhelming margin.
    let be = backend(Variant::DtrTrilayer, 4);
    let cfg = ServerConfig {
        kv_page_size: 2,
        ..Default::default()
    };
    let mut srv = Server::new(&be, cfg).unwrap();
    let trace = generate_workload(
        &WorkloadSpec {
            n_requests: 10,
            arrival_rate: 2000.0,
            prompt_len_mean: 8,
            prompt_len_max: 16,
            gen_len_mean: 20,
            gen_len_max: 40,
            temperature: 0.0,
            vocab: 256,
        },
        7,
    );
    let rep = srv.run_workload(&trace, 1_000_000).unwrap();

    assert_eq!(rep.completed, 10);
    assert_eq!(rep.evicted, 0);
    assert_eq!(rep.rejected, 0);
    assert!(rep.tokens_generated > 0);
    assert!(rep.tokens_per_s > 0.0);
    assert!(rep.latency_ms_p99 >= rep.latency_ms_p50);
    assert_eq!(rep.backend, "cpu");

    // Routing telemetry: dense layers (TDDT layout: 0, 3) attend all
    // tokens; the DTR layers bypass some, which is exactly what the
    // paged pool converts into memory savings.
    let layout = be.config().layout_string();
    assert_eq!(layout, "TDDT");
    for (l, kind) in layout.chars().enumerate() {
        if kind == 'T' {
            assert_eq!(rep.attn_fracs[l], 1.0, "dense layer {l}");
        } else {
            assert!(rep.attn_fracs[l] < 1.0, "DTR layer {l} routed everything");
        }
    }
    assert!(
        rep.pool.pages_peak < rep.dense_pages_peak,
        "routed paging must beat dense: {} vs {}",
        rep.pool.pages_peak,
        rep.dense_pages_peak
    );
    assert!(rep.kv_savings_ratio < 1.0);
    // report accounting is self-consistent
    let toks: usize = rep.requests.iter().map(|r| r.tokens.len()).sum();
    assert_eq!(toks, rep.tokens_generated);
    assert!(rep.requests.iter().all(|r| r.finish == FinishReason::Completed));
}

#[test]
fn serve_determinism_same_seed_identical_token_streams() {
    // Temperature > 0 exercises the per-request RNG path: streams must be
    // a function of (weights, prompt, params, seed) only.
    let run = || {
        let be = backend(Variant::DtrBilayer, 21);
        let cfg = ServerConfig {
            slots: 3,
            seed: 99,
            ..Default::default()
        };
        let mut srv = Server::new(&be, cfg).unwrap();
        let trace = generate_workload(&spec(8, 0.8), 5);
        let mut rep = srv.run_workload(&trace, 1_000_000).unwrap();
        rep.requests.sort_by_key(|r| r.id);
        rep
    };
    let a = run();
    let b = run();
    assert_eq!(a.requests.len(), b.requests.len());
    for (x, y) in a.requests.iter().zip(&b.requests) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens, y.tokens, "request {} stream diverged", x.id);
        assert_eq!(x.finish, y.finish);
    }
    assert_eq!(a.tokens_generated, b.tokens_generated);
    assert_eq!(a.pool.tokens_cached, b.pool.tokens_cached);
}

#[test]
fn prefill_mode_does_not_change_token_streams() {
    // Even with temperature sampling: the engine draws from the RNG once
    // per generated token in both modes, and batched/chunked execution is
    // bit-identical to sequential, so the streams agree exactly.
    let be = backend(Variant::DtrBilayer, 13);
    let run = |prefill| {
        let cfg = ServerConfig {
            slots: 2,
            seed: 7,
            prefill,
            ..Default::default()
        };
        let mut srv = Server::new(&be, cfg).unwrap();
        let trace = generate_workload(&spec(6, 0.9), 17);
        let mut rep = srv.run_workload(&trace, 1_000_000).unwrap();
        rep.requests.sort_by_key(|r| r.id);
        rep.requests
            .into_iter()
            .map(|r| (r.id, r.tokens))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(PrefillMode::Decode), run(PrefillMode::Chunked(4)));
    assert_eq!(run(PrefillMode::Chunked(1)), run(PrefillMode::Chunked(64)));
}

#[test]
fn queue_backpressure_is_reported_not_fatal() {
    let be = backend(Variant::DtrBilayer, 2);
    let cfg = ServerConfig {
        slots: 1,
        max_queue: 2,
        ..Default::default()
    };
    let mut srv = Server::new(&be, cfg).unwrap();
    // Effectively-simultaneous arrivals into a 1-slot engine with a
    // 2-deep queue: the whole burst lands before the first step, so the
    // queue must overflow regardless of how fast the engine drains.
    let burst = WorkloadSpec {
        arrival_rate: 1e9,
        ..spec(12, 0.0)
    };
    let trace = generate_workload(&burst, 23);
    let rep = srv.run_workload(&trace, 1_000_000).unwrap();
    assert!(rep.rejected > 0, "tiny queue must shed load");
    assert_eq!(rep.completed + rep.evicted + rep.rejected, 12);
    assert_eq!(rep.requests.len(), rep.completed + rep.evicted);
}

#[test]
fn decode_batch_validates_lengths() {
    let be = backend(Variant::DtrBilayer, 0);
    let mut s1 = be.begin_decode();
    let mut s2 = be.begin_decode();
    let mut refs = vec![&mut s1, &mut s2];
    assert!(be.decode_batch(&mut refs, &[1]).is_err());
    assert!(be.decode_batch(&mut refs, &[1, 999]).is_err());
    let empty: &mut [&mut dtrnet::runtime::DecodeState] = &mut [];
    assert_eq!(be.decode_batch(empty, &[]).unwrap().len(), 0);
}

#[test]
fn single_request_matches_backend_generate() {
    // The engine is a scheduler around the backend: a lone greedy request
    // must reproduce Backend::generate's token stream exactly.
    use dtrnet::coordinator::SamplingParams;
    use dtrnet::util::rng::Rng;

    let be = backend(Variant::DtrBilayer, 31);
    let prompt: Vec<i32> = (0..9).map(|i| i * 23 % 256).collect();
    let mut rng = Rng::new(0);
    let direct = be
        .generate(&prompt, 12, &SamplingParams::greedy(), &mut rng)
        .unwrap();

    let mut srv = Server::new(&be, ServerConfig::default()).unwrap();
    assert!(srv.submit(Request {
        id: 0,
        prompt,
        max_new_tokens: 12,
        temperature: 0.0,
        arrival: Instant::now(),
    }));
    let rep = srv.run_to_completion(100_000).unwrap();
    assert_eq!(rep.requests.len(), 1);
    assert_eq!(rep.requests[0].tokens, direct.tokens);
}
