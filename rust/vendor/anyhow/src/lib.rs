//! In-repo substitute for the `anyhow` crate (see DESIGN.md §Substitutions).
//!
//! The build must work with no network and no registry cache, so this
//! vendored crate provides the (small) subset of anyhow's API the
//! codebase uses: [`Error`], [`Result`], the [`Context`] extension trait
//! for `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Like the real anyhow, [`Error`] deliberately does NOT implement
//! `std::error::Error` — that is what makes the blanket
//! `impl From<E: std::error::Error> for Error` coherent and lets `?`
//! convert any standard error while still propagating `Error` itself
//! (via the reflexive `From<T> for T`).
//!
//! Differences from the real crate (acceptable for this codebase):
//! the source chain is flattened into one message string at conversion
//! time instead of being kept as a trait-object chain, and there is no
//! backtrace capture.

use std::fmt;

/// A flattened, context-annotated error message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer (what `Context::context` uses).
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error {
            msg: format!("{ctx}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — plain `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-annotation extension for `Result` and `Option`.
pub trait Context<T> {
    /// Annotate an error/`None` with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    /// Annotate lazily (context built only on the error path).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error {
            msg: format!("{ctx}: {e}"),
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error {
            msg: format!("{}: {e}", f()),
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or a value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::format!(
                "condition failed: `{}`",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_literal() -> Result<()> {
        bail!("plain message")
    }

    fn fails_fmt(x: usize) -> Result<()> {
        bail!("bad value {x} ({})", x * 2)
    }

    fn guarded(n: usize) -> Result<usize> {
        ensure!(n < 10, "n too big: {n}");
        ensure!(n != 7);
        Ok(n)
    }

    #[test]
    fn macros_build_messages() {
        assert_eq!(fails_literal().unwrap_err().to_string(), "plain message");
        assert_eq!(fails_fmt(3).unwrap_err().to_string(), "bad value 3 (6)");
        assert!(guarded(3).is_ok());
        assert_eq!(guarded(12).unwrap_err().to_string(), "n too big: 12");
        assert!(guarded(7).unwrap_err().to_string().contains("n != 7"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("inner".to_string());
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
        let some: Option<u32> = Some(5);
        assert_eq!(some.context("unused").unwrap(), 5);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn io_fail() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(io_fail().is_err());
    }

    #[test]
    fn error_propagates_through_question_mark() {
        fn inner() -> Result<()> {
            bail!("deep")
        }
        fn outer() -> Result<()> {
            inner()?;
            Ok(())
        }
        assert_eq!(outer().unwrap_err().to_string(), "deep");
    }
}
