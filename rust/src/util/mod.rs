//! Offline-environment substrates.
//!
//! The build environment vendors only the `xla` crate's dependency closure,
//! so the usual ecosystem crates (serde/serde_json, clap, rand, criterion,
//! proptest) are unavailable. This module provides the minimal, well-tested
//! replacements the rest of the library builds on — including
//! [`threadpool`], the scoped work-chunking pool under every parallel CPU
//! kernel (DESIGN.md §Parallel CPU execution), and [`simd`], the
//! instruction-set tier + precision selector behind the `--simd` /
//! `--precision` flags (DESIGN.md §SIMD dispatch).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod threadpool;

pub use json::Json;
pub use rng::Rng;
