//! Tiny CLI argument parser (replaces clap, unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
/// Parsed command line: positional words plus `--key value` flags.
pub struct Args {
    /// Arguments that are not flags, in order (the subcommand first).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` / bare `--flag` (value "true").
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit arg list (excluding argv[0]).
    pub fn parse_from(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse_from(&argv)
    }

    /// Raw flag value, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Flag value with a default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Flag parsed as usize, or `default` when absent/unparseable.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Flag parsed as u64, or `default` when absent/unparseable.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Flag parsed as f64, or `default` when absent/unparseable.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Whether the flag was given at all (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse_from(&sv(&["train", "--steps", "100", "--fast", "--lr=3e-4"]));
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.has("fast"));
        assert_eq!(a.get_f64("lr", 0.0), 3e-4);
    }

    #[test]
    fn defaults() {
        let a = Args::parse_from(&sv(&[]));
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
    }
}
