//! SIMD tier selection — which vector instruction set the CPU kernels
//! dispatch to, and at which precision contract.
//!
//! The *implementations* live in `runtime::cpu::kernels::simd`; this
//! module owns the policy: runtime feature detection, the process-wide
//! selector behind the `--simd {auto,avx2,scalar}` CLI flag (and the
//! `DTRNET_SIMD` env var CI uses to force the fallback path on AVX2
//! runners), and the `--precision {exact,fast}` knob that gates the
//! f32 reductions whose vector form cannot match scalar bitwise.
//!
//! # Determinism contract (DESIGN.md §SIMD dispatch)
//!
//! * [`Precision::Exact`] (default): every kernel produces the **same
//!   bits on every tier**. Element-wise vector ops (`axpy`-style rows)
//!   round identically to the scalar loop, and the int8 dot walks a
//!   fixed 8-lane striped accumulation order that the scalar fallback
//!   reproduces exactly. Switching `--simd` is a pure throughput knob.
//! * [`Precision::Fast`]: f32 dot/sum-of-squares reductions also
//!   vectorize (8 partial accumulators instead of one), which changes
//!   rounding. Results stay deterministic for a fixed (tier, precision)
//!   pair, and the bench harness gates the drift with the margin-aware
//!   routing-equivalence and perplexity-delta checks from the
//!   quantization work (`runtime::quant`).
//!
//! Like [`threadpool::set_global_threads`](crate::util::threadpool::set_global_threads),
//! the globals here are meant to be pinned once at CLI startup; kernels
//! snapshot them into a [`KernelCtx`] carried by the
//! [`Pool`](crate::util::threadpool::Pool), so tests and the bench
//! harness can pin a tier per pool without racing on process state.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which vector instruction set the CPU kernels use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdTier {
    /// Portable scalar loops — the always-available fallback and the
    /// reference semantics every other tier is held to.
    Scalar,
    /// x86-64 AVX2 (+FMA present, though exact-precision kernels avoid
    /// fused ops so their rounding matches scalar).
    Avx2,
    /// AArch64 NEON.
    Neon,
}

impl SimdTier {
    /// Stable lowercase name (CLI/env/JSON spelling).
    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
            SimdTier::Neon => "neon",
        }
    }

    /// Whether this host can execute the tier.
    pub fn supported(self) -> bool {
        match self {
            SimdTier::Scalar => true,
            SimdTier::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("avx2")
                        && std::arch::is_x86_feature_detected!("fma")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            SimdTier::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    std::arch::is_aarch64_feature_detected!("neon")
                }
                #[cfg(not(target_arch = "aarch64"))]
                {
                    false
                }
            }
        }
    }
}

/// Best tier this host supports (what `--simd auto` resolves to).
pub fn detect() -> SimdTier {
    if SimdTier::Avx2.supported() {
        SimdTier::Avx2
    } else if SimdTier::Neon.supported() {
        SimdTier::Neon
    } else {
        SimdTier::Scalar
    }
}

/// Floating-point precision contract for the vector kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// Every kernel is bit-identical across tiers (default).
    Exact,
    /// f32 reductions (attention logits dot, rmsnorm sum-of-squares)
    /// vectorize with striped partial accumulators — faster, not
    /// bitwise vs [`Precision::Exact`], tolerance-gated in the bench
    /// harness (DESIGN.md §SIMD dispatch).
    Fast,
}

impl Precision {
    /// Stable lowercase name (CLI/env/JSON spelling).
    pub fn name(self) -> &'static str {
        match self {
            Precision::Exact => "exact",
            Precision::Fast => "fast",
        }
    }
}

/// Snapshot of the (tier, precision) pair a kernel call should use.
///
/// Carried by [`Pool`](crate::util::threadpool::Pool) so every `_par`
/// kernel — and the serial wrappers that run through `Pool::serial()` —
/// dispatches consistently without re-reading process globals, and so
/// tests can compare tiers side by side without mutating them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelCtx {
    /// Active instruction-set tier.
    pub tier: SimdTier,
    /// Active precision contract.
    pub precision: Precision,
}

impl KernelCtx {
    /// The process-wide selection (globals below, env-seeded).
    pub fn current() -> KernelCtx {
        KernelCtx {
            tier: tier(),
            precision: precision(),
        }
    }

    /// Scalar/exact — the reference semantics.
    pub fn scalar() -> KernelCtx {
        KernelCtx {
            tier: SimdTier::Scalar,
            precision: Precision::Exact,
        }
    }

    /// This context with a different tier.
    pub fn with_tier(self, tier: SimdTier) -> KernelCtx {
        KernelCtx { tier, ..self }
    }

    /// This context with a different precision.
    pub fn with_precision(self, precision: Precision) -> KernelCtx {
        KernelCtx { precision, ..self }
    }
}

// Process-wide selection. 0 = unset; otherwise value + 1 of the enum's
// discriminant-order index (Scalar=1, Avx2=2, Neon=3 / Exact=1, Fast=2).
static TIER: AtomicU8 = AtomicU8::new(0);
static PRECISION: AtomicU8 = AtomicU8::new(0);

fn tier_to_u8(t: SimdTier) -> u8 {
    match t {
        SimdTier::Scalar => 1,
        SimdTier::Avx2 => 2,
        SimdTier::Neon => 3,
    }
}

fn tier_from_u8(v: u8) -> Option<SimdTier> {
    match v {
        1 => Some(SimdTier::Scalar),
        2 => Some(SimdTier::Avx2),
        3 => Some(SimdTier::Neon),
        _ => None,
    }
}

/// Parse a `--simd` / `DTRNET_SIMD` spelling. `auto` resolves to
/// [`detect`]; a named tier must be supported on this host.
pub fn parse_tier(s: &str) -> Result<SimdTier, String> {
    let t = match s {
        "auto" => return Ok(detect()),
        "scalar" => SimdTier::Scalar,
        "avx2" => SimdTier::Avx2,
        "neon" => SimdTier::Neon,
        _ => return Err(format!("unknown simd tier '{s}' (auto|avx2|neon|scalar)")),
    };
    if !t.supported() {
        return Err(format!("simd tier '{s}' is not supported on this host"));
    }
    Ok(t)
}

/// Parse a `--precision` / `DTRNET_PRECISION` spelling.
pub fn parse_precision(s: &str) -> Result<Precision, String> {
    match s {
        "exact" => Ok(Precision::Exact),
        "fast" => Ok(Precision::Fast),
        _ => Err(format!("unknown precision '{s}' (exact|fast)")),
    }
}

/// Pin the process-wide tier (the CLI `--simd` knob). Pools constructed
/// afterwards inherit it; pools already built keep their snapshot.
pub fn set_tier(t: SimdTier) {
    TIER.store(tier_to_u8(t), Ordering::Relaxed);
}

/// Pin the process-wide precision (the CLI `--precision` knob).
pub fn set_precision(p: Precision) {
    PRECISION.store(
        match p {
            Precision::Exact => 1,
            Precision::Fast => 2,
        },
        Ordering::Relaxed,
    );
}

/// The process-wide tier. First use seeds it from `DTRNET_SIMD`
/// (`auto|avx2|neon|scalar`; invalid or unsupported values warn and
/// fall back) or [`detect`].
pub fn tier() -> SimdTier {
    if let Some(t) = tier_from_u8(TIER.load(Ordering::Relaxed)) {
        return t;
    }
    let t = match std::env::var("DTRNET_SIMD") {
        Ok(v) => match parse_tier(&v) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("[simd] DTRNET_SIMD: {e}; using auto");
                detect()
            }
        },
        Err(_) => detect(),
    };
    // First writer wins; a concurrent set_tier may already have landed.
    let _ = TIER.compare_exchange(0, tier_to_u8(t), Ordering::Relaxed, Ordering::Relaxed);
    tier_from_u8(TIER.load(Ordering::Relaxed)).unwrap_or(t)
}

/// The process-wide precision. First use seeds it from
/// `DTRNET_PRECISION` (`exact|fast`) or defaults to exact.
pub fn precision() -> Precision {
    match PRECISION.load(Ordering::Relaxed) {
        1 => return Precision::Exact,
        2 => return Precision::Fast,
        _ => {}
    }
    let p = match std::env::var("DTRNET_PRECISION") {
        Ok(v) => match parse_precision(&v) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("[simd] DTRNET_PRECISION: {e}; using exact");
                Precision::Exact
            }
        },
        Err(_) => Precision::Exact,
    };
    let new = match p {
        Precision::Exact => 1,
        Precision::Fast => 2,
    };
    let _ = PRECISION.compare_exchange(0, new, Ordering::Relaxed, Ordering::Relaxed);
    match PRECISION.load(Ordering::Relaxed) {
        2 => Precision::Fast,
        _ => Precision::Exact,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_supported() {
        assert!(SimdTier::Scalar.supported());
        // detect() never returns something the host can't run
        assert!(detect().supported());
    }

    #[test]
    fn parse_spellings_round_trip() {
        assert_eq!(parse_tier("scalar").unwrap(), SimdTier::Scalar);
        assert_eq!(parse_tier("auto").unwrap(), detect());
        assert!(parse_tier("sse9").is_err());
        assert_eq!(parse_precision("exact").unwrap(), Precision::Exact);
        assert_eq!(parse_precision("fast").unwrap(), Precision::Fast);
        assert!(parse_precision("loose").is_err());
    }

    #[test]
    fn ctx_builders_compose() {
        let c = KernelCtx::scalar().with_precision(Precision::Fast);
        assert_eq!(c.tier, SimdTier::Scalar);
        assert_eq!(c.precision, Precision::Fast);
        assert_eq!(c.with_tier(detect()).tier, detect());
        assert_eq!(SimdTier::Avx2.name(), "avx2");
        assert_eq!(Precision::Fast.name(), "fast");
    }
}
