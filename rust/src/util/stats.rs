//! Summary statistics helpers (means, percentiles, linear fits).

/// Mean of a slice; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance (0.0 for fewer than two samples).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Least-squares slope+intercept of y over x (for throughput trend checks).
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut den = 0.0;
    for i in 0..x.len() {
        num += (x[i] - mx) * (y[i] - my);
        den += (x[i] - mx) * (x[i] - mx);
    }
    let slope = if den == 0.0 { 0.0 } else { num / den };
    (slope, my - slope * mx)
}

/// Exponential moving average accumulator (loss-curve smoothing).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    /// A fresh EMA with smoothing factor `alpha` in (0, 1].
    pub fn new(alpha: f64) -> Ema {
        Ema { alpha, value: None }
    }

    /// Fold in one observation; returns the updated average.
    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    /// Current average (None before the first update).
    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fit_recovers_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + 1.0).collect();
        let (s, b) = linear_fit(&x, &y);
        assert!((s - 3.0).abs() < 1e-9 && (b - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }
}
