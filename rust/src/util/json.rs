//! Minimal JSON: recursive-descent parser + serializer.
//!
//! Replaces serde_json (unavailable offline). Supports the full JSON
//! grammar; numbers are f64 (adequate for manifests/results — the largest
//! integers we exchange are array shapes and token counts < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (f64; integers are exact below 2^53).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Json>),
    /// JSON object (sorted keys — serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------
    /// An empty object.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// An object from (key, value) pairs.
    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// An array of numbers.
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    /// An array of strings.
    pub fn arr_str(v: &[&str]) -> Json {
        Json::Arr(v.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---- accessors ---------------------------------------------------------
    /// Object field lookup (None on non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Insert/replace an object field (no-op on non-objects).
    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    /// The number value, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number value truncated to usize, if this is a `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// `get` chained through a dotted path, e.g. `j.path("config.d_model")`.
    pub fn path(&self, p: &str) -> Option<&Json> {
        let mut cur = self;
        for part in p.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // ---- parsing -----------------------------------------------------------
    /// Parse a complete JSON document (rejects trailing data).
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Parse a JSON file.
    pub fn parse_file(path: &std::path::Path) -> Result<Json, String> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Json::parse(&s)
    }

    // ---- serialization -----------------------------------------------------
    /// Compact serialization (no whitespace).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Indented serialization (2 spaces).
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    nl(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    nl(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    nl(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn nl(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            // Surrogate pairs: join with the low half if present.
                            // Only consume the second escape when it really is a
                            // low surrogate — a high surrogate followed by e.g.
                            // A must fall back to U+FFFD + 'A', not
                            // underflow the pair arithmetic.
                            let lo = if (0xd800..0xdc00).contains(&cp)
                                && self.i + 6 <= self.b.len()
                                && self.b[self.i..].starts_with(b"\\u")
                            {
                                std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                    .ok()
                                    .and_then(|h| u32::from_str_radix(h, 16).ok())
                                    .filter(|lo| (0xdc00..0xe000).contains(lo))
                            } else {
                                None
                            };
                            let cp = match lo {
                                Some(lo) => {
                                    self.i += 6;
                                    0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00)
                                }
                                None => cp,
                            };
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    let chunk = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| "bad utf8")?;
                    out.push_str(chunk);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\n\"y\""}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.path("b.c").unwrap().as_bool(), Some(true));
        assert_eq!(j.path("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn integers_stay_integral() {
        let j = Json::Num(12345.0);
        assert_eq!(j.to_string(), "12345");
    }
}
