//! Deterministic PRNG (xoshiro256**) — replaces the `rand` crate.
//!
//! Every stochastic component in the coordinator (data generation, request
//! arrival processes, property tests) takes an explicit `Rng` so runs are
//! reproducible from a single seed recorded in EXPERIMENTS.md.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded construction (SplitMix64-expanded into the state).
    pub fn new(seed: u64) -> Rng {
        // SplitMix64 seeding, as recommended by the xoshiro authors.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Independent child stream (for per-worker seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Zipf-distributed value in [0, n) with exponent `s` (token frequency
    /// modeling in the synthetic corpus; inverse-CDF over precomputed
    /// weights would be heavy, so this uses rejection sampling).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Rejection method (Devroye) — O(1) expected.
        let n_f = n as f64;
        loop {
            let u = self.f64();
            let v = self.f64();
            let x = ((n_f + 1.0).powf(1.0 - s) * u + 1.0 - u).powf(1.0 / (1.0 - s));
            let k = x.floor().max(1.0);
            if k <= n_f {
                let ratio = (1.0 + 1.0 / k).powf(s - 1.0) * k / x;
                if v * x / k <= ratio {
                    return k as usize - 1;
                }
            }
        }
    }

    /// Fisher-Yates shuffle in place.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.usize_below(i + 1);
            v.swap(i, j);
        }
    }

    /// Uniformly chosen element (panics on an empty slice).
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.usize_below(v.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mut counts = vec![0usize; 100];
        for _ in 0..n {
            counts[r.zipf(100, 1.2)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[50]);
    }
}
