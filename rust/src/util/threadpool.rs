//! Minimal scoped thread pool over std::thread + mpsc (replaces rayon).
//!
//! The serving coordinator uses OS threads for its workers; this pool is
//! for fan-out helper work (data generation, eval sharding). Work items
//! are boxed closures; results come back through a channel.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool {
            workers,
            tx: Some(tx),
        }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker hung up");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let _ = rtx.send((i, f(item)));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rrx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("missing result")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }
}
