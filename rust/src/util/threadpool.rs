//! Scoped, work-chunking thread pool — the parallel substrate under the
//! native CPU backend's kernels (`runtime::cpu::kernels`).
//!
//! Design constraints (see DESIGN.md §Benchmarking):
//!
//! * **Bit-determinism.** Parallel kernels must produce the same bits as
//!   their serial form, so the pool only ever hands out *disjoint index
//!   ranges* — which thread computes a range never affects any value.
//!   `--threads 1` (a [`Pool`] with no workers) runs every region inline
//!   on the caller, reproducing the single-threaded code path exactly.
//! * **Scoped borrows.** Kernel closures borrow stack data (weight
//!   slices, output buffers). [`Pool::run`] erases the closure lifetime
//!   to ship it to persistent workers, then blocks until every worker
//!   job for the region has finished — the borrow outlives all uses.
//! * **Cheap dispatch.** Workers are spawned once per pool and fed
//!   through a channel; a parallel region costs a few channel sends and
//!   one condvar wait, so layer-sized kernels (tens of microseconds) can
//!   afford it. Regions below their grain run inline with no dispatch.
//!
//! The process-wide pool is shared through [`global`]; its size defaults
//! to [`available_threads`] and can be pinned once at startup with
//! [`set_global_threads`] (the CLI `--threads` knob).
//!
//! # Example
//!
//! ```
//! use dtrnet::util::threadpool::Pool;
//!
//! let pool = Pool::with_threads(4);
//! let mut squares = vec![0u64; 1000];
//! // Disjoint row chunks may be filled concurrently; the result is
//! // identical for any thread count, including Pool::serial().
//! pool.run_rows(&mut squares, 1, 64, |row0, rows| {
//!     for (i, r) in rows.iter_mut().enumerate() {
//!         *r = ((row0 + i) as u64).pow(2);
//!     }
//! });
//! assert_eq!(squares[31], 31 * 31);
//! ```

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread;

use crate::util::simd::KernelCtx;

/// A queued worker job (one helper per parallel region per worker).
type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// Set on pool worker threads: a kernel that re-enters [`Pool::run`]
    /// from inside a region body runs inline instead of re-dispatching
    /// (nested parallelism would only add queueing latency and, with
    /// blocking joins, could deadlock).
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// One parallel region: a lifetime-erased chunk body plus the shared
/// claim/completion state. Workers and the caller claim chunk indices
/// from `next` until exhausted; the caller blocks until `pending`
/// helper jobs have all finished, which is what makes the lifetime
/// erasure in [`Pool::run`] sound.
struct Region {
    /// Erased `&'scope (dyn Fn(usize, usize) + Sync)` — valid until the
    /// submitting call returns (it joins the region first).
    body: *const (dyn Fn(usize, usize) + Sync),
    total: usize,
    chunk: usize,
    n_chunks: usize,
    next: AtomicUsize,
    panicked: AtomicBool,
    pending: Mutex<usize>,
    done: Condvar,
}

// SAFETY: `body` points at a `Sync` closure that the submitting thread
// keeps alive until the region is joined; all other fields are Sync.
unsafe impl Send for Region {}
unsafe impl Sync for Region {}

impl Region {
    /// Claim and run chunks until none remain. Runs on workers and on
    /// the submitting thread alike.
    fn work(&self) {
        // SAFETY: see the Send impl — the pointee outlives the region.
        let body = unsafe { &*self.body };
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n_chunks {
                return;
            }
            let start = i * self.chunk;
            let end = self.total.min(start + self.chunk);
            // A panicking chunk must not wedge the pool: record it,
            // keep the region draining, re-panic on the caller.
            if catch_unwind(AssertUnwindSafe(|| body(start, end))).is_err() {
                self.panicked.store(true, Ordering::Relaxed);
            }
        }
    }

    fn finish_helper(&self) {
        let mut pending = self.pending.lock().unwrap();
        *pending -= 1;
        if *pending == 0 {
            self.done.notify_all();
        }
    }
}

/// Fixed set of persistent worker threads fed through an MPSC channel.
/// Dropping the pool closes the channel and joins every worker.
struct Workers {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl Workers {
    fn new(n: usize) -> Workers {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("dtrnet-pool-{i}"))
                    .spawn(move || {
                        IN_POOL_WORKER.with(|f| f.set(true));
                        loop {
                            let job = rx.lock().unwrap().recv();
                            match job {
                                Ok(job) => job(),
                                Err(_) => break,
                            }
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Workers {
            tx: Some(tx),
            handles,
        }
    }

    fn send(&self, job: Job) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(job)
            .expect("pool worker hung up");
    }
}

impl Drop for Workers {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Handle to a parallel execution context: either the serial inline path
/// (`threads == 1`, no workers) or a shared set of persistent workers.
///
/// The pool also carries the [`KernelCtx`] (SIMD tier + precision,
/// DESIGN.md §SIMD dispatch) that its kernels dispatch with: it is
/// snapshotted from the process-wide selection at construction, so the
/// `--simd`/`--precision` flags apply to every pool built after CLI
/// startup, while tests and the bench harness can pin a different
/// context per pool via [`Pool::with_ctx`] without touching globals.
///
/// Cloning is cheap (an `Arc` bump) and clones share the same workers.
#[derive(Clone)]
pub struct Pool {
    workers: Option<Arc<Workers>>,
    threads: usize,
    ctx: KernelCtx,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool")
            .field("threads", &self.threads)
            .field("ctx", &self.ctx)
            .finish()
    }
}

impl Pool {
    /// The serial pool: every region runs inline on the caller. This is
    /// the `--threads 1` determinism baseline.
    pub fn serial() -> Pool {
        Pool {
            workers: None,
            threads: 1,
            ctx: KernelCtx::current(),
        }
    }

    /// A pool with `n` total threads of concurrency (the caller counts
    /// as one, so `n - 1` workers are spawned). `n <= 1` is serial.
    pub fn with_threads(n: usize) -> Pool {
        if n <= 1 {
            return Pool::serial();
        }
        Pool {
            workers: Some(Arc::new(Workers::new(n - 1))),
            threads: n,
            ctx: KernelCtx::current(),
        }
    }

    /// Total concurrency of this pool (including the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The SIMD tier + precision this pool's kernels dispatch with.
    pub fn kernel_ctx(&self) -> KernelCtx {
        self.ctx
    }

    /// This pool with a pinned kernel context (shares the same workers).
    pub fn with_ctx(mut self, ctx: KernelCtx) -> Pool {
        self.ctx = ctx;
        self
    }

    /// Run `body(start, end)` over disjoint chunks partitioning
    /// `0..total`, at least `grain` items per chunk. Blocks until every
    /// chunk has run. Chunk assignment is dynamic (work-stealing via an
    /// atomic cursor), which is safe for determinism because chunks are
    /// data-disjoint by construction in every caller.
    ///
    /// Runs inline (no dispatch, no catch_unwind) when the pool is
    /// serial, the region is smaller than one grain, or the caller is
    /// itself a pool worker (nested regions serialize).
    pub fn run(&self, total: usize, grain: usize, body: impl Fn(usize, usize) + Sync) {
        if total == 0 {
            return;
        }
        let grain = grain.max(1);
        let workers = match &self.workers {
            Some(w) if total > grain && !IN_POOL_WORKER.with(|f| f.get()) => w,
            _ => {
                body(0, total);
                return;
            }
        };
        // Over-chunk ~4x vs the thread count so early finishers keep
        // helping, but never below the caller's grain.
        let chunk = grain.max(total.div_ceil(self.threads * 4));
        let n_chunks = total.div_ceil(chunk);
        if n_chunks <= 1 {
            body(0, total);
            return;
        }
        let helpers = (self.threads - 1).min(n_chunks - 1);
        let body_ref: &(dyn Fn(usize, usize) + Sync) = &body;
        // SAFETY: the pointee outlives this call, and this call joins
        // every helper before returning (the wait loop below). The
        // transmute (not a cast) erases the borrow's lifetime from the
        // trait object so it can live in the shared Region.
        #[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
        let body_ptr: *const (dyn Fn(usize, usize) + Sync) =
            unsafe { std::mem::transmute(body_ref) };
        let region = Arc::new(Region {
            body: body_ptr,
            total,
            chunk,
            n_chunks,
            next: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            pending: Mutex::new(helpers),
            done: Condvar::new(),
        });
        for _ in 0..helpers {
            let r = Arc::clone(&region);
            workers.send(Box::new(move || {
                r.work();
                r.finish_helper();
            }));
        }
        region.work();
        let mut pending = region.pending.lock().unwrap();
        while *pending > 0 {
            pending = region.done.wait(pending).unwrap();
        }
        drop(pending);
        if region.panicked.load(Ordering::Relaxed) {
            panic!("a parallel kernel chunk panicked (see worker backtrace above)");
        }
    }

    /// Row-parallel mutation: split `data` (rows of `width` elements)
    /// into disjoint chunks of at least `grain` rows and run
    /// `body(first_row, rows)` on each, possibly concurrently. The
    /// mutable disjointness is what lets kernels write one shared output
    /// buffer from many threads without locks.
    pub fn run_rows<T: Send>(
        &self,
        data: &mut [T],
        width: usize,
        grain: usize,
        body: impl Fn(usize, &mut [T]) + Sync,
    ) {
        let width = width.max(1);
        let n_rows = data.len() / width;
        let base = SendPtr(data.as_mut_ptr());
        self.run(n_rows, grain, move |start, end| {
            // SAFETY: [start, end) row ranges from `run` are disjoint,
            // so the derived sub-slices never alias.
            let rows = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(start * width), (end - start) * width)
            };
            body(start, rows);
        });
    }
}

/// Raw-pointer wrapper that may cross threads. Soundness is the
/// caller's obligation: derived accesses must be disjoint and must not
/// outlive the pointee (both hold for [`Pool::run_rows`] chunks).
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Hardware concurrency of this host (`std::thread::available_parallelism`,
/// falling back to 1 when undetectable).
pub fn available_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();
static REQUESTED: AtomicUsize = AtomicUsize::new(0);

/// Pin the size of the process-wide pool (the CLI `--threads` knob).
/// Effective only before the first [`global`] call; returns `false` if
/// the pool already exists at a different size.
pub fn set_global_threads(n: usize) -> bool {
    REQUESTED.store(n.max(1), Ordering::Relaxed);
    match GLOBAL.get() {
        None => true,
        Some(p) => p.threads() == n.max(1),
    }
}

/// The process-wide shared pool. Sized by [`set_global_threads`] if
/// called first, else [`available_threads`]. All `CpuBackend`s use this
/// unless given an explicit pool.
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(|| {
        let req = REQUESTED.load(Ordering::Relaxed);
        Pool::with_threads(if req == 0 { available_threads() } else { req })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = Pool::with_threads(4);
        for total in [0usize, 1, 7, 64, 1000] {
            let hits: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
            pool.run(total, 3, |s, e| {
                for i in s..e {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "total={total}"
            );
        }
    }

    #[test]
    fn run_rows_matches_serial_bits() {
        let body = |row0: usize, rows: &mut [f32]| {
            for (i, v) in rows.iter_mut().enumerate() {
                let r = row0 + i / 3;
                *v = (r as f32).sqrt() * 0.37 + (i % 3) as f32;
            }
        };
        let mut serial = vec![0.0f32; 333 * 3];
        Pool::serial().run_rows(&mut serial, 3, 8, body);
        let mut par = vec![0.0f32; 333 * 3];
        Pool::with_threads(4).run_rows(&mut par, 3, 8, body);
        assert_eq!(serial, par, "parallel chunking changed bits");
    }

    #[test]
    fn small_regions_run_inline() {
        let pool = Pool::with_threads(4);
        let tid = std::thread::current().id();
        pool.run(4, 8, |_, _| {
            assert_eq!(std::thread::current().id(), tid, "sub-grain region dispatched");
        });
    }

    #[test]
    fn nested_regions_serialize() {
        let pool = Pool::with_threads(3);
        let count = AtomicU64::new(0);
        pool.run(32, 1, |s, e| {
            // Re-entering run() from a region body must not deadlock.
            pool.run(4, 1, |s2, e2| {
                count.fetch_add(((e - s) * (e2 - s2)) as u64, Ordering::Relaxed);
            });
        });
        assert!(count.load(Ordering::Relaxed) >= 32 * 4 / 8);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = Pool::with_threads(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(100, 1, |s, _| {
                if s >= 50 {
                    panic!("chunk boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must propagate to the caller");
        // the pool still works after a panicked region
        let n = AtomicU64::new(0);
        pool.run(10, 1, |s, e| {
            n.fetch_add((e - s) as u64, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let p1 = global();
        let p2 = global();
        assert_eq!(p1.threads(), p2.threads());
        assert!(p1.threads() >= 1);
    }
}
