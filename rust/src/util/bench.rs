//! Micro-benchmark harness (replaces criterion, unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this directly.
//! Reports mean / p50 / p95 wall-clock over timed iterations after a
//! warmup, and can append structured rows to `results/*.json` so
//! EXPERIMENTS.md tables regenerate from artifacts rather than prose.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark name (also the JSON key).
    pub name: String,
    /// Timed iterations (excluding warmup).
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub p50_s: f64,
    /// 95th-percentile seconds per iteration.
    pub p95_s: f64,
    /// Sample standard deviation of the iteration times.
    pub stddev_s: f64,
}

impl Measurement {
    /// Serialize as a flat JSON object (one row of a results table).
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_s", Json::Num(self.mean_s)),
            ("p50_s", Json::Num(self.p50_s)),
            ("p95_s", Json::Num(self.p95_s)),
            ("stddev_s", Json::Num(self.stddev_s)),
        ])
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let m = Measurement {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&times),
        p50_s: stats::percentile(&times, 50.0),
        p95_s: stats::percentile(&times, 95.0),
        stddev_s: stats::stddev(&times),
    };
    println!(
        "{:<48} {:>10.4} ms/iter  (p50 {:.4}, p95 {:.4}, n={})",
        m.name,
        m.mean_s * 1e3,
        m.p50_s * 1e3,
        m.p95_s * 1e3,
        iters
    );
    m
}

/// Adaptive variant: runs for at least `min_time_s` wall-clock.
pub fn bench_for<F: FnMut()>(name: &str, min_time_s: f64, mut f: F) -> Measurement {
    // One calibration run decides the iteration count.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((min_time_s / once).ceil() as usize).clamp(3, 10_000);
    bench(name, 1, iters, f)
}

/// Write a result table to `results/<file>` (pretty JSON), creating dirs.
pub fn write_results(file: &str, payload: Json) {
    let dir = crate::artifacts_dir()
        .parent()
        .map(|p| p.join("results"))
        .unwrap_or_else(|| "results".into());
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(file);
    std::fs::write(&path, payload.to_string_pretty() + "\n")
        .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
    println!("[results] wrote {}", path.display());
}

/// Render an aligned text table (paper-style rows) to stdout.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0usize;
        let m = bench("noop", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(m.iters, 10);
        assert!(m.mean_s >= 0.0);
    }
}
