//! Metrics: counters, gauges, histograms + JSONL emission.
//!
//! The coordinator reports through a `Registry`; training/serving loops log
//! JSONL rows (one object per line) that EXPERIMENTS.md tables and the
//! bench harnesses consume.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats;

/// Monotone counter (lock-free).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time gauge (queue depth, active slots, pool pages). Stores
/// f64 bits in an atomic so readers never block the engine loop.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Latency/size histogram; stores raw samples (bounded) for percentiles.
#[derive(Debug, Default)]
pub struct Histogram {
    samples: Mutex<Vec<f64>>,
}

impl Histogram {
    pub fn record(&self, v: f64) {
        let mut s = self.samples.lock().unwrap();
        // Reservoir-free bound: cap memory, keep most recent window.
        if s.len() >= 1 << 20 {
            s.drain(..1 << 19);
        }
        s.push(v);
    }

    pub fn summary(&self) -> HistSummary {
        let s = self.samples.lock().unwrap();
        HistSummary {
            count: s.len(),
            mean: stats::mean(&s),
            p50: stats::percentile(&s, 50.0),
            p95: stats::percentile(&s, 95.0),
            p99: stats::percentile(&s, 99.0),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct HistSummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl HistSummary {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean", Json::Num(self.mean)),
            ("p50", Json::Num(self.p50)),
            ("p95", Json::Num(self.p95)),
            ("p99", Json::Num(self.p99)),
        ])
    }
}

/// Named metric registry shared across coordinator components.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn snapshot(&self) -> Json {
        let mut obj = Json::obj();
        for (k, c) in self.counters.lock().unwrap().iter() {
            obj.set(k, Json::Num(c.get() as f64));
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            obj.set(k, Json::Num(g.get()));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            obj.set(k, h.summary().to_json());
        }
        obj
    }
}

/// Append-only JSONL log (one JSON object per line).
pub struct JsonlWriter {
    file: Mutex<std::fs::File>,
}

impl JsonlWriter {
    pub fn create(path: &std::path::Path) -> std::io::Result<JsonlWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(JsonlWriter {
            file: Mutex::new(std::fs::File::create(path)?),
        })
    }

    pub fn write(&self, row: &Json) {
        let mut f = self.file.lock().unwrap();
        let _ = writeln!(f, "{}", row.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let reg = Registry::default();
        let c = reg.counter("reqs");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let h = reg.histogram("lat");
        for i in 1..=100 {
            h.record(i as f64);
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!((s.p50 - 50.5).abs() < 1.0);
        let snap = reg.snapshot();
        assert_eq!(snap.path("reqs").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn gauges_hold_latest_value() {
        let reg = Registry::default();
        let g = reg.gauge("queue_depth");
        assert_eq!(g.get(), 0.0);
        g.set(7.5);
        g.set(3.0);
        assert_eq!(g.get(), 3.0);
        assert_eq!(reg.snapshot().path("queue_depth").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn jsonl_rows() {
        let dir = std::env::temp_dir().join("dtrnet_test_jsonl");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("log.jsonl");
        let w = JsonlWriter::create(&path).unwrap();
        w.write(&Json::from_pairs(vec![("a", Json::Num(1.0))]));
        w.write(&Json::from_pairs(vec![("a", Json::Num(2.0))]));
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
    }
}
