//! Metrics: counters, gauges, histograms, lightweight timers + JSONL
//! emission.
//!
//! The coordinator reports through a `Registry`; training/serving loops log
//! JSONL rows (one object per line) that EXPERIMENTS.md tables and the
//! bench harnesses consume. [`KernelTimers`] is the per-kernel wall-clock
//! accountant the CPU backend feeds and the `bench` harness reads into
//! `BENCH_*.json` (see DESIGN.md §Benchmarking).

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// Monotone counter (lock-free).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Increment by one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Increment by `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time gauge (queue depth, active slots, pool pages). Stores
/// f64 bits in an atomic so readers never block the engine loop.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Store a new value.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Most recently stored value (0.0 initially).
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Lightweight section timer: total wall-clock + call count, stored in
/// atomics so `&self` hot paths (the CPU backend's kernel sections) can
/// record from any thread with two `Instant` reads and two relaxed adds
/// per section — cheap enough to stay on permanently.
///
/// A [`named`](Timer::named) timer additionally emits a
/// [`telemetry`](crate::telemetry) duration span per invocation while
/// tracing is enabled (one relaxed load per call when it is not), which
/// is how the serve and train loops' kernel-section boundaries appear
/// in `--trace` output with no extra call sites.
#[derive(Debug)]
pub struct Timer {
    ns: AtomicU64,
    calls: AtomicU64,
    name: &'static str,
}

impl Default for Timer {
    fn default() -> Timer {
        Timer::named("")
    }
}

impl Timer {
    /// An anonymous or named timer. A non-empty name makes every timed
    /// invocation a `--trace` span of that name.
    pub const fn named(name: &'static str) -> Timer {
        Timer {
            ns: AtomicU64::new(0),
            calls: AtomicU64::new(0),
            name,
        }
    }

    /// Time one invocation of `f`, folding its duration into the total.
    #[inline]
    pub fn time<R>(&self, f: impl FnOnce() -> R) -> R {
        let traced = !self.name.is_empty() && crate::telemetry::enabled();
        if traced {
            crate::telemetry::begin(self.name);
        }
        let t0 = Instant::now();
        let r = f();
        self.ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.calls.fetch_add(1, Ordering::Relaxed);
        if traced {
            crate::telemetry::end(self.name);
        }
        r
    }

    /// Accumulated wall-clock seconds.
    pub fn total_s(&self) -> f64 {
        self.ns.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Number of timed invocations.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Zero the accumulators (between bench scenarios).
    pub fn reset(&self) {
        self.ns.store(0, Ordering::Relaxed);
        self.calls.store(0, Ordering::Relaxed);
    }

    /// `{calls, total_ms, mean_us}` snapshot.
    pub fn to_json(&self) -> Json {
        let calls = self.calls();
        let s = self.total_s();
        Json::from_pairs(vec![
            ("calls", Json::Num(calls as f64)),
            ("total_ms", Json::Num(s * 1e3)),
            (
                "mean_us",
                Json::Num(if calls > 0 { s * 1e6 / calls as f64 } else { 0.0 }),
            ),
        ])
    }
}

/// Per-kernel wall-clock accounting for one execution backend: one
/// [`Timer`] per hot section of the transformer block. The CPU backend
/// owns one and wraps each kernel family; `Backend::kernel_timings`
/// exposes the snapshot to the serve report and the bench harness.
/// Every section timer is [named](Timer::named), so with tracing
/// enabled the same wrap points double as `--trace` spans.
#[derive(Debug)]
pub struct KernelTimers {
    /// RMSNorm (pre-attention, pre-MLP).
    pub norm: Timer,
    /// DTR router scores (Eq. 1).
    pub router: Timer,
    /// Q/K/V projection + RoPE + (routed/decode) attention + Wo.
    pub attention: Timer,
    /// Linear bypass `x Wv Wo` for non-routed tokens (Eq. 5).
    pub bypass: Timer,
    /// SwiGLU MLP.
    pub mlp: Timer,
    /// Final norm + `[·, V]` unembed matmul.
    pub unembed: Timer,
    /// Backward: RMSNorm (both sublayer norms + the output norm).
    pub bwd_norm: Timer,
    /// Backward: router softmax head + its two matmuls.
    pub bwd_router: Timer,
    /// Backward: attention (softmax dQ/dK/dV), RoPE transpose,
    /// projection matmuls, and the bypass path.
    pub bwd_attention: Timer,
    /// Backward: SwiGLU MLP.
    pub bwd_mlp: Timer,
    /// Backward: cross-entropy head + unembed matmuls + embedding
    /// scatter.
    pub bwd_unembed: Timer,
    /// AdamW moment/parameter update (incl. global-norm clip).
    pub optimizer: Timer,
}

impl Default for KernelTimers {
    fn default() -> KernelTimers {
        KernelTimers {
            norm: Timer::named("norm"),
            router: Timer::named("router"),
            attention: Timer::named("attention"),
            bypass: Timer::named("bypass"),
            mlp: Timer::named("mlp"),
            unembed: Timer::named("unembed"),
            bwd_norm: Timer::named("bwd_norm"),
            bwd_router: Timer::named("bwd_router"),
            bwd_attention: Timer::named("bwd_attention"),
            bwd_mlp: Timer::named("bwd_mlp"),
            bwd_unembed: Timer::named("bwd_unembed"),
            optimizer: Timer::named("optimizer"),
        }
    }
}

impl KernelTimers {
    /// Per-section `{calls, total_ms, mean_us}` plus the summed total.
    pub fn snapshot(&self) -> Json {
        let mut obj = Json::obj();
        let mut total_ms = 0.0;
        for (name, t) in self.sections() {
            total_ms += t.total_s() * 1e3;
            obj.set(name, t.to_json());
        }
        obj.set("total_ms", Json::Num(total_ms));
        obj
    }

    /// [`snapshot`](Self::snapshot) annotated with the SIMD dispatch
    /// context the owning backend's pool runs under: string fields
    /// `simd_tier` and `precision` so serve reports and bench rows
    /// record which kernel tier produced the timings.
    pub fn snapshot_with_ctx(&self, ctx: crate::util::simd::KernelCtx) -> Json {
        let mut obj = self.snapshot();
        obj.set("simd_tier", Json::Str(ctx.tier.name().to_string()));
        obj.set("precision", Json::Str(ctx.precision.name().to_string()));
        obj
    }

    /// Zero every section (between bench scenarios).
    pub fn reset(&self) {
        for (_, t) in self.sections() {
            t.reset();
        }
    }

    fn sections(&self) -> [(&'static str, &Timer); 12] {
        [
            ("norm", &self.norm),
            ("router", &self.router),
            ("attention", &self.attention),
            ("bypass", &self.bypass),
            ("mlp", &self.mlp),
            ("unembed", &self.unembed),
            ("bwd_norm", &self.bwd_norm),
            ("bwd_router", &self.bwd_router),
            ("bwd_attention", &self.bwd_attention),
            ("bwd_mlp", &self.bwd_mlp),
            ("bwd_unembed", &self.bwd_unembed),
            ("optimizer", &self.optimizer),
        ]
    }
}

/// Latency/size histogram; stores raw samples (bounded) for percentiles.
#[derive(Debug, Default)]
pub struct Histogram {
    samples: Mutex<Vec<f64>>,
}

impl Histogram {
    /// Record one sample.
    pub fn record(&self, v: f64) {
        let mut s = self.samples.lock().unwrap();
        // Reservoir-free bound: cap memory, keep most recent window.
        if s.len() >= 1 << 20 {
            s.drain(..1 << 19);
        }
        s.push(v);
    }

    /// Count/mean/percentile summary of the recorded samples. An empty
    /// histogram yields `count == 0` with every statistic `None` — an
    /// explicit "no data" marker instead of fabricated zeros.
    pub fn summary(&self) -> HistSummary {
        let s = self.samples.lock().unwrap();
        if s.is_empty() {
            return HistSummary {
                count: 0,
                mean: None,
                p50: None,
                p95: None,
                p99: None,
            };
        }
        HistSummary {
            count: s.len(),
            mean: Some(stats::mean(&s)),
            p50: Some(stats::percentile(&s, 50.0)),
            p95: Some(stats::percentile(&s, 95.0)),
            p99: Some(stats::percentile(&s, 99.0)),
        }
    }
}

#[derive(Debug, Clone, Default)]
/// Summary statistics of a [`Histogram`]. Every statistic is `None`
/// when no samples were recorded (`count == 0`) — consumers that need
/// a plain number use `.unwrap_or(0.0)` explicitly rather than being
/// handed a silent garbage percentile.
pub struct HistSummary {
    /// Samples recorded.
    pub count: usize,
    /// Arithmetic mean (`None` when empty).
    pub mean: Option<f64>,
    /// Median (`None` when empty).
    pub p50: Option<f64>,
    /// 95th percentile (`None` when empty).
    pub p95: Option<f64>,
    /// 99th percentile (`None` when empty).
    pub p99: Option<f64>,
}

impl HistSummary {
    /// Serialize as a flat JSON object. Missing statistics (empty
    /// histogram) serialize as JSON `null`, never as fake numbers.
    pub fn to_json(&self) -> Json {
        let num = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        Json::from_pairs(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean", num(self.mean)),
            ("p50", num(self.p50)),
            ("p95", num(self.p95)),
            ("p99", num(self.p99)),
        ])
    }
}

/// Named metric registry shared across coordinator components.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    /// The counter registered under `name` (created on first use).
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// The gauge registered under `name` (created on first use).
    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// The histogram registered under `name` (created on first use).
    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Every registered metric as one JSON object (histograms summarized).
    pub fn snapshot(&self) -> Json {
        let mut obj = Json::obj();
        for (k, c) in self.counters.lock().unwrap().iter() {
            obj.set(k, Json::Num(c.get() as f64));
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            obj.set(k, Json::Num(g.get()));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            obj.set(k, h.summary().to_json());
        }
        obj
    }
}

/// Append-only JSONL log (one JSON object per line).
pub struct JsonlWriter {
    file: Mutex<std::fs::File>,
}

impl JsonlWriter {
    /// Create/truncate the log file at `path` (parent dirs created).
    pub fn create(path: &std::path::Path) -> std::io::Result<JsonlWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        Ok(JsonlWriter {
            file: Mutex::new(std::fs::File::create(path)?),
        })
    }

    /// Append one JSON object as a line.
    pub fn write(&self, row: &Json) {
        let mut f = self.file.lock().unwrap();
        let _ = writeln!(f, "{}", row.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let reg = Registry::default();
        let c = reg.counter("reqs");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let h = reg.histogram("lat");
        for i in 1..=100 {
            h.record(i as f64);
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert!((s.p50.unwrap() - 50.5).abs() < 1.0);
        let snap = reg.snapshot();
        assert_eq!(snap.path("reqs").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn empty_histogram_summary_is_explicitly_empty() {
        let h = Histogram::default();
        let s = h.summary();
        assert_eq!(s.count, 0);
        assert!(s.mean.is_none() && s.p50.is_none() && s.p95.is_none() && s.p99.is_none());
        // to_json must be safe for the empty summary: count 0, stats null.
        let j = s.to_json();
        assert_eq!(j.path("count").and_then(Json::as_f64), Some(0.0));
        assert!(matches!(j.path("p50"), Some(Json::Null)));
        assert!(matches!(j.path("mean"), Some(Json::Null)));
        // round-trips through the parser without NaN/garbage
        let re = Json::parse(&j.to_string()).unwrap();
        assert!(matches!(re.path("p99"), Some(Json::Null)));
        // one sample flips everything to Some
        h.record(2.5);
        let s1 = h.summary();
        assert_eq!(s1.count, 1);
        assert_eq!(s1.p50, Some(2.5));
        assert_eq!(s1.mean, Some(2.5));
    }

    #[test]
    fn gauges_hold_latest_value() {
        let reg = Registry::default();
        let g = reg.gauge("queue_depth");
        assert_eq!(g.get(), 0.0);
        g.set(7.5);
        g.set(3.0);
        assert_eq!(g.get(), 3.0);
        assert_eq!(reg.snapshot().path("queue_depth").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn timers_accumulate_and_reset() {
        let kt = KernelTimers::default();
        let x = kt.norm.time(|| 21 * 2);
        assert_eq!(x, 42);
        kt.mlp.time(|| std::thread::sleep(std::time::Duration::from_millis(1)));
        assert_eq!(kt.norm.calls(), 1);
        assert!(kt.mlp.total_s() >= 1e-3);
        let snap = kt.snapshot();
        assert!(snap.path("total_ms").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(
            snap.path("norm").unwrap().path("calls").unwrap().as_f64(),
            Some(1.0)
        );
        kt.reset();
        assert_eq!(kt.norm.calls(), 0);
        assert_eq!(kt.mlp.total_s(), 0.0);
    }

    #[test]
    fn jsonl_rows() {
        let dir = std::env::temp_dir().join("dtrnet_test_jsonl");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("log.jsonl");
        let w = JsonlWriter::create(&path).unwrap();
        w.write(&Json::from_pairs(vec![("a", Json::Num(1.0))]));
        w.write(&Json::from_pairs(vec![("a", Json::Num(2.0))]));
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
    }
}
