//! Trainable byte-pair-encoding tokenizer (byte-fallback, LLaMA family).
//!
//! Training: iteratively merge the most frequent adjacent pair until the
//! target vocabulary size is reached. Encoding: greedy highest-priority
//! merge first (same as GPT-2/LLaMA BPE inference).

use std::collections::HashMap;

use super::Tokenizer;

/// A trained BPE model. Token ids 0..256 are raw bytes; ids ≥256 are merges
/// in training order.
#[derive(Debug, Clone)]
pub struct BpeTokenizer {
    /// merge rank -> (left id, right id); new token id = 256 + rank.
    merges: Vec<(u32, u32)>,
    /// (left, right) -> merged id, for O(1) encode lookups.
    merge_map: HashMap<(u32, u32), u32>,
}

impl BpeTokenizer {
    /// Train on `corpus` until `vocab_size` tokens (≥256) exist.
    pub fn train(corpus: &str, vocab_size: usize) -> BpeTokenizer {
        assert!(vocab_size >= 256, "vocab must include all bytes");
        let mut ids: Vec<u32> = corpus.as_bytes().iter().map(|&b| b as u32).collect();
        let mut merges = Vec::new();
        let mut merge_map = HashMap::new();
        while 256 + merges.len() < vocab_size {
            // Count adjacent pairs.
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            // Deterministic argmax: highest count, ties by smallest pair.
            let best = counts
                .iter()
                .max_by_key(|(&pair, &c)| (c, std::cmp::Reverse(pair)))
                .map(|(&pair, &c)| (pair, c));
            let Some((pair, count)) = best else { break };
            if count < 2 {
                break; // no compression left
            }
            let new_id = 256 + merges.len() as u32;
            merges.push(pair);
            merge_map.insert(pair, new_id);
            ids = Self::apply_merge(&ids, pair, new_id);
        }
        BpeTokenizer { merges, merge_map }
    }

    fn apply_merge(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
        let mut out = Vec::with_capacity(ids.len());
        let mut i = 0;
        while i < ids.len() {
            if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                out.push(new_id);
                i += 2;
            } else {
                out.push(ids[i]);
                i += 1;
            }
        }
        out
    }

    /// Expand a token id to its byte sequence.
    fn expand(&self, id: u32, out: &mut Vec<u8>) {
        if id < 256 {
            out.push(id as u8);
        } else {
            let (l, r) = self.merges[(id - 256) as usize];
            self.expand(l, out);
            self.expand(r, out);
        }
    }

    /// Number of learned merges.
    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }
}

impl Tokenizer for BpeTokenizer {
    fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = text.as_bytes().iter().map(|&b| b as u32).collect();
        // Apply merges in priority (training) order: repeatedly find the
        // lowest-rank applicable merge. O(n · merges) worst case; fine for
        // the corpus sizes here.
        loop {
            let mut best: Option<(usize, u32, usize)> = None; // (rank, id, pos)
            for i in 0..ids.len().saturating_sub(1) {
                if let Some(&m) = self.merge_map.get(&(ids[i], ids[i + 1])) {
                    let rank = (m - 256) as usize;
                    if best.map_or(true, |(br, _, _)| rank < br) {
                        best = Some((rank, m, i));
                    }
                }
            }
            let Some((_, m, i)) = best else { break };
            ids.splice(i..i + 2, [m]);
        }
        ids
    }

    fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            self.expand(id, &mut bytes);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn vocab_size(&self) -> usize {
        256 + self.merges.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CORPUS: &str = "the quick brown fox jumps over the lazy dog. \
        the dog barks. the fox runs. the quick dog jumps over the brown fox. \
        lazy foxes and quick dogs. the the the quick quick brown brown.";

    #[test]
    fn train_compresses() {
        let t = BpeTokenizer::train(CORPUS, 300);
        assert!(t.n_merges() > 0);
        let ids = t.encode("the quick brown fox");
        assert!(ids.len() < "the quick brown fox".len());
    }

    #[test]
    fn roundtrip() {
        let t = BpeTokenizer::train(CORPUS, 320);
        for s in ["the quick brown fox", "unseen wörds ok", "", "a"] {
            assert_eq!(t.decode(&t.encode(s)), s);
        }
    }

    #[test]
    fn byte_fallback_for_unseen() {
        let t = BpeTokenizer::train(CORPUS, 280);
        let ids = t.encode("zzzyyqq");
        assert_eq!(t.decode(&ids), "zzzyyqq");
    }

    #[test]
    fn deterministic_training() {
        let a = BpeTokenizer::train(CORPUS, 300);
        let b = BpeTokenizer::train(CORPUS, 300);
        assert_eq!(a.merges, b.merges);
    }
}
