//! Tokenizers.
//!
//! The paper uses the LLaMA-2 32k SentencePiece tokenizer; offline we
//! substitute (a) a plain byte tokenizer (vocab 256, used by the tiny
//! configs whose artifacts bake `vocab_size=256`) and (b) a trainable
//! byte-pair-encoding tokenizer for larger vocabularies — functionally the
//! same family as the paper's (byte-fallback BPE). See DESIGN.md
//! §Substitutions.

pub mod bpe;

pub use bpe::BpeTokenizer;

/// Trait implemented by all tokenizers in the crate.
pub trait Tokenizer: Send + Sync {
    /// Text → token ids.
    fn encode(&self, text: &str) -> Vec<u32>;
    /// Token ids → text (lossy on invalid sequences).
    fn decode(&self, ids: &[u32]) -> String;
    /// Number of distinct token ids this tokenizer can produce.
    fn vocab_size(&self) -> usize;
}

/// Identity byte tokenizer: one token per UTF-8 byte. Vocabulary is exactly
/// 256, matching the tiny/xs artifact configs.
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl Tokenizer for ByteTokenizer {
    fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids.iter().map(|&i| (i & 0xff) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn vocab_size(&self) -> usize {
        256
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_roundtrip() {
        let t = ByteTokenizer;
        let s = "hello, DTRNet! é";
        assert_eq!(t.decode(&t.encode(s)), s);
        assert!(t.encode(s).iter().all(|&i| i < 256));
    }
}
