//! Typed configuration: model presets, layer layouts, train/serve settings.
//!
//! Mirrors `python/compile/model.py` exactly — `layer_kinds` here and
//! `layer_kinds` there must agree (tested in `rust/tests/` against the
//! manifest, which records the Python-side layout per artifact).

use anyhow::{ensure, Result};

use crate::util::json::Json;

/// Which block occupies a layer slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Dense transformer layer (full attention + MLP for every token).
    Dense,
    /// DTRNet layer: router → quadratic (attention) or linear (bypass) path.
    Dtr,
    /// Mixture-of-Depths block (expert-choice top-k; skipped = residual).
    Mod,
    /// D-LLM block (token-choice whole-block skip).
    Dllm,
}

impl LayerKind {
    /// One-letter layout code (T/D/M/L) used in layout strings.
    pub fn letter(self) -> char {
        match self {
            LayerKind::Dense => 'T',
            LayerKind::Dtr => 'D',
            LayerKind::Mod => 'M',
            LayerKind::Dllm => 'L',
        }
    }
}

/// Architecture variant (paper Tables 1/3/4/5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// All-dense baseline.
    Dense,
    /// DTR every second layer (paper default).
    DtrBilayer,
    /// DTR two of every three layers.
    DtrTrilayer,
    /// Dense first half, DTR second half.
    DtrLaterhalf,
    /// Six dense anchors (ends/middle), DTR elsewhere.
    Dtr6T,
    /// Ablation: DTR layers forced to bypass every token.
    DtrSkip,
    /// Mixture-of-Depths baseline.
    Mod,
    /// D-LLM baseline.
    Dllm,
}

impl Variant {
    /// Parse a variant name (the CLI `--variant` values).
    pub fn from_str(s: &str) -> Option<Variant> {
        Some(match s {
            "dense" => Variant::Dense,
            "dtr_bilayer" => Variant::DtrBilayer,
            "dtr_trilayer" => Variant::DtrTrilayer,
            "dtr_laterhalf" => Variant::DtrLaterhalf,
            "dtr_6t" => Variant::Dtr6T,
            "dtr_skip" => Variant::DtrSkip,
            "mod" => Variant::Mod,
            "dllm" => Variant::Dllm,
            _ => return None,
        })
    }

    /// Canonical lowercase name.
    pub fn as_str(self) -> &'static str {
        match self {
            Variant::Dense => "dense",
            Variant::DtrBilayer => "dtr_bilayer",
            Variant::DtrTrilayer => "dtr_trilayer",
            Variant::DtrLaterhalf => "dtr_laterhalf",
            Variant::Dtr6T => "dtr_6t",
            Variant::DtrSkip => "dtr_skip",
            Variant::Mod => "mod",
            Variant::Dllm => "dllm",
        }
    }

    /// Whether this is one of the DTR variants.
    pub fn is_dtr(self) -> bool {
        matches!(
            self,
            Variant::DtrBilayer
                | Variant::DtrTrilayer
                | Variant::DtrLaterhalf
                | Variant::Dtr6T
                | Variant::DtrSkip
        )
    }
}

/// Model hyperparameters (mirror of python ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Preset name (or "custom" for manifest-derived configs).
    pub name: String,
    /// Vocabulary size V.
    pub vocab_size: usize,
    /// Residual stream width d.
    pub d_model: usize,
    /// Layer count L.
    pub n_layers: usize,
    /// Attention heads H.
    pub n_heads: usize,
    /// MLP hidden width.
    pub d_ff: usize,
    /// Maximum sequence length / decode position cap.
    pub max_seq: usize,
    /// Architecture variant (decides the layer layout).
    pub variant: Variant,
    /// Expected attention-routing fraction for DTR layers after training
    /// (paper: ~0.10). Used by the analytical FLOPs/memory models; measured
    /// values from artifacts override it where available.
    pub dtr_attn_frac: f64,
    /// MoD expert-choice capacity (fraction of tokens kept).
    pub mod_capacity: f64,
    /// D-LLM keep probability.
    pub dllm_omega: f64,
}

impl ModelConfig {
    /// All known preset names (the single source of truth for CLI
    /// validation and [`Self::try_preset`]).
    pub const PRESET_NAMES: [&'static str; 5] =
        ["xs", "tiny", "small", "smollm-360m", "smollm-1b3"];

    /// Look up a preset by name; panics on unknown names.
    pub fn preset(name: &str, variant: Variant) -> ModelConfig {
        Self::try_preset(name, variant)
            .unwrap_or_else(|| panic!("unknown preset {name:?}"))
    }

    /// Fallible variant of [`Self::preset`] for user-facing inputs.
    ///
    /// ```
    /// use dtrnet::config::{ModelConfig, Variant};
    ///
    /// let cfg = ModelConfig::try_preset("tiny", Variant::DtrBilayer).unwrap();
    /// assert_eq!(cfg.n_layers, 6);
    /// // First/last layers are forced dense; DTR alternates between.
    /// assert_eq!(cfg.layout_string(), "TDTDTT");
    /// assert!(ModelConfig::try_preset("nope", Variant::Dense).is_none());
    /// ```
    pub fn try_preset(name: &str, variant: Variant) -> Option<ModelConfig> {
        let (vocab, d, l, h, ff, seq) = match name {
            "xs" => (256, 64, 4, 4, 176, 64),
            "tiny" => (256, 128, 6, 4, 352, 128),
            "small" => (256, 256, 8, 8, 704, 256),
            // Paper-scale configs (config-only on this testbed; the
            // analytical FLOPs/memory models run at these scales).
            "smollm-360m" => (32000, 960, 32, 15, 2560, 2048),
            "smollm-1b3" => (32000, 2048, 24, 32, 5632, 2048),
            _ => return None,
        };
        Some(ModelConfig {
            name: name.to_string(),
            vocab_size: vocab,
            d_model: d,
            n_layers: l,
            n_heads: h,
            d_ff: ff,
            max_seq: seq,
            variant,
            dtr_attn_frac: 0.10,
            mod_capacity: 0.7,
            dllm_omega: 0.85,
        })
    }

    /// Per-head dimension (`d_model / n_heads`). Only meaningful on a
    /// config that passes [`ModelConfig::validate`] — integer division
    /// truncates otherwise.
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Structural sanity checks, enforced wherever a config enters an
    /// execution path (backend/trainer construction, CLI parsing).
    ///
    /// The load-bearing one is `d_model % n_heads == 0`: `head_dim()`
    /// silently truncates otherwise, which would desync
    /// `DecodeState::lens(d_model)` (KV rows are `H·hd` wide) from the
    /// real cache row width and corrupt every length/paging computation
    /// built on it.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.vocab_size > 0, "config {}: vocab_size must be > 0", self.name);
        ensure!(self.d_model > 0, "config {}: d_model must be > 0", self.name);
        ensure!(self.d_ff > 0, "config {}: d_ff must be > 0", self.name);
        ensure!(
            self.n_layers >= 2,
            "config {}: need at least 2 layers (first/last are forced dense)",
            self.name
        );
        ensure!(self.n_heads > 0, "config {}: n_heads must be > 0", self.name);
        ensure!(
            self.d_model % self.n_heads == 0,
            "config {}: d_model {} is not divisible by n_heads {} — head_dim \
             would truncate to {} and desync the KV cache row width (rows \
             are H*hd = {} floats, not d_model = {})",
            self.name,
            self.d_model,
            self.n_heads,
            self.d_model / self.n_heads,
            (self.d_model / self.n_heads) * self.n_heads,
            self.d_model
        );
        Ok(())
    }

    /// Per-layer block kinds — MUST match python `model.layer_kinds`.
    pub fn layer_kinds(&self) -> Vec<LayerKind> {
        let l = self.n_layers;
        let mut kinds: Vec<LayerKind> = match self.variant {
            Variant::Dense => vec![LayerKind::Dense; l],
            Variant::DtrBilayer | Variant::DtrSkip => (0..l)
                .map(|i| {
                    if i % 2 == 1 {
                        LayerKind::Dtr
                    } else {
                        LayerKind::Dense
                    }
                })
                .collect(),
            Variant::DtrTrilayer => (0..l)
                .map(|i| {
                    if i % 3 == 0 {
                        LayerKind::Dense
                    } else {
                        LayerKind::Dtr
                    }
                })
                .collect(),
            Variant::DtrLaterhalf => (0..l)
                .map(|i| {
                    if i < l / 2 {
                        LayerKind::Dense
                    } else {
                        LayerKind::Dtr
                    }
                })
                .collect(),
            Variant::Dtr6T => {
                let mut k = vec![LayerKind::Dtr; l];
                for a in [0, 1, l / 2 - 1, l / 2, l - 2, l - 1] {
                    k[a] = LayerKind::Dense;
                }
                k
            }
            Variant::Mod => (0..l)
                .map(|i| {
                    if i % 2 == 1 {
                        LayerKind::Mod
                    } else {
                        LayerKind::Dense
                    }
                })
                .collect(),
            Variant::Dllm => (0..l)
                .map(|i| if i < 2 { LayerKind::Dense } else { LayerKind::Dllm })
                .collect(),
        };
        kinds[0] = LayerKind::Dense;
        kinds[l - 1] = LayerKind::Dense;
        // python applies the first/last override AFTER the pattern too,
        // except for mod/dllm whose kinds[0] is already dense; keep exact
        // parity by re-applying unconditionally (matches model.py).
        if self.variant == Variant::Mod || self.variant == Variant::Dllm {
            kinds[0] = LayerKind::Dense;
            kinds[l - 1] = LayerKind::Dense;
        }
        kinds
    }

    /// Layer kinds as a string of one-letter codes, e.g. "TDTDTT".
    pub fn layout_string(&self) -> String {
        self.layer_kinds().iter().map(|k| k.letter()).collect()
    }

    /// Expected fraction of tokens routed through attention at layer `i`
    /// (1.0 for dense layers). Drives the analytical models.
    pub fn attn_frac(&self, i: usize) -> f64 {
        match self.layer_kinds()[i] {
            LayerKind::Dense => 1.0,
            LayerKind::Dtr => {
                if self.variant == Variant::DtrSkip {
                    0.0
                } else {
                    self.dtr_attn_frac
                }
            }
            LayerKind::Mod => self.mod_capacity,
            LayerKind::Dllm => self.dllm_omega,
        }
    }

    /// Parameter count (exact, mirrors init_params shapes).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let ff = self.d_ff;
        let mut n = self.vocab_size * d * 2 + d; // embed + unembed + out_norm
        for k in self.layer_kinds() {
            n += 2 * d; // norms
            n += 4 * d * d; // wq wk wv wo
            n += 3 * d * ff; // gate up down
            match k {
                LayerKind::Dtr | LayerKind::Dllm => n += d * (d / 2) + (d / 2) * 2,
                LayerKind::Mod => n += 2 * d,
                LayerKind::Dense => {}
            }
        }
        n
    }

    /// Rebuild a config from an artifact manifest's config object.
    pub fn from_manifest(cfg: &Json) -> ModelConfig {
        let variant = Variant::from_str(cfg.get("variant").and_then(|v| v.as_str()).unwrap())
            .expect("bad variant in manifest");
        ModelConfig {
            name: cfg
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("custom")
                .to_string(),
            vocab_size: cfg.get("vocab_size").and_then(|v| v.as_usize()).unwrap(),
            d_model: cfg.get("d_model").and_then(|v| v.as_usize()).unwrap(),
            n_layers: cfg.get("n_layers").and_then(|v| v.as_usize()).unwrap(),
            n_heads: cfg.get("n_heads").and_then(|v| v.as_usize()).unwrap(),
            d_ff: cfg.get("d_ff").and_then(|v| v.as_usize()).unwrap(),
            max_seq: cfg.get("max_seq").and_then(|v| v.as_usize()).unwrap(),
            variant,
            dtr_attn_frac: 0.10,
            mod_capacity: cfg
                .get("mod_capacity")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.7),
            dllm_omega: cfg.get("dllm_omega").and_then(|v| v.as_f64()).unwrap_or(0.85),
        }
    }
}

/// Training-run settings (the L3 trainer owns the schedule; the
/// optimizer constants mirror `python/compile/train.py` — AdamW per the
/// paper's §Training Setup).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Optimizer steps.
    pub steps: usize,
    /// Sequences per step.
    pub batch: usize,
    /// Tokens per sequence.
    pub seq: usize,
    /// Peak learning rate (after warmup).
    pub peak_lr: f64,
    /// Fraction of steps spent in linear warmup.
    pub warmup_ratio: f64,
    /// Data/init RNG seed.
    pub seed: u64,
    /// Emit a log row every this many steps.
    pub log_every: usize,
    /// Eq. 7 routing-penalty weight (paper lambda, train.py `lambda_reg`).
    pub lambda_reg: f64,
    /// AdamW first-moment decay.
    pub beta1: f64,
    /// AdamW second-moment decay.
    pub beta2: f64,
    /// AdamW denominator epsilon.
    pub adam_eps: f64,
    /// Decoupled weight decay, applied to matrices only (norm gains
    /// exempt — train.py `WEIGHT_DECAY`).
    pub weight_decay: f64,
    /// Global-norm gradient clip (train.py `GRAD_CLIP`).
    pub grad_clip: f64,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            steps: 200,
            batch: 4,
            seq: 128,
            peak_lr: 3e-4,
            warmup_ratio: 0.1,
            seed: 0,
            log_every: 10,
            lambda_reg: 8e-4,
            beta1: 0.9,
            beta2: 0.95,
            adam_eps: 1e-8,
            weight_decay: 0.01,
            grad_clip: 0.1,
        }
    }
}

impl TrainConfig {
    /// Cosine schedule with linear warmup (paper §Training Setup).
    pub fn lr_at(&self, step: usize) -> f64 {
        let warmup = (self.steps as f64 * self.warmup_ratio).max(1.0);
        let s = step as f64;
        if s < warmup {
            self.peak_lr * s / warmup
        } else {
            let t = (s - warmup) / (self.steps as f64 - warmup).max(1.0);
            0.5 * self.peak_lr * (1.0 + (std::f64::consts::PI * t.min(1.0)).cos())
        }
    }
}

/// Serving-engine settings.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Decode slots (concurrent sequences).
    pub max_batch: usize,
    /// KV budget in tokens per sequence.
    pub max_kv: usize,
    /// KV page granularity in tokens.
    pub kv_page_size: usize,
    /// Per-sequence position cap.
    pub max_seq_len: usize,
    /// Request queue bound.
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 4,
            max_kv: 512,
            kv_page_size: 16,
            max_seq_len: 512,
            queue_depth: 256,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layouts_match_paper_patterns() {
        let c = ModelConfig::preset("tiny", Variant::DtrBilayer);
        assert_eq!(c.layout_string(), "TDTDTT"); // L=6, first/last forced T
        let c = ModelConfig::preset("tiny", Variant::DtrTrilayer);
        assert_eq!(c.layout_string(), "TDDTDT");
        let c = ModelConfig::preset("tiny", Variant::Dllm);
        assert_eq!(c.layout_string(), "TTLLLT");
        let c = ModelConfig::preset("tiny", Variant::Mod);
        assert_eq!(c.layout_string(), "TMTMTT");
    }

    #[test]
    fn param_count_plausible() {
        let c = ModelConfig::preset("tiny", Variant::DtrBilayer);
        let n = c.param_count();
        assert!(n > 1_000_000 && n < 3_000_000, "n={n}");
        // dense variant has fewer params (no routers)
        let d = ModelConfig::preset("tiny", Variant::Dense);
        assert!(d.param_count() < n);
    }

    #[test]
    fn lr_schedule_shape() {
        let t = TrainConfig {
            steps: 100,
            peak_lr: 1.0,
            warmup_ratio: 0.1,
            ..Default::default()
        };
        assert!(t.lr_at(0) < 1e-9);
        assert!((t.lr_at(10) - 1.0).abs() < 1e-9);
        assert!(t.lr_at(55) < 1.0);
        assert!(t.lr_at(100) < 0.01);
    }

    #[test]
    fn validate_rejects_truncating_head_dim() {
        let mut c = ModelConfig::preset("tiny", Variant::DtrBilayer);
        assert!(c.validate().is_ok());
        c.n_heads = 5; // 128 % 5 != 0 — head_dim would truncate
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("not divisible"), "unexpected error: {err}");
        c.n_heads = 0;
        assert!(c.validate().is_err());
        // every shipped preset must validate
        for name in ModelConfig::PRESET_NAMES {
            ModelConfig::preset(name, Variant::DtrBilayer).validate().unwrap();
        }
    }

    #[test]
    fn attn_frac_by_kind() {
        let c = ModelConfig::preset("tiny", Variant::DtrBilayer);
        assert_eq!(c.attn_frac(0), 1.0);
        assert!((c.attn_frac(1) - 0.10).abs() < 1e-12);
        let s = ModelConfig::preset("tiny", Variant::DtrSkip);
        assert_eq!(s.attn_frac(1), 0.0);
    }
}
