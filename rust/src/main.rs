//! `dtrnet` CLI — the leader entrypoint.
//!
//! Always available (native CPU backend / analytical models):
//!   info                           — version, backend, artifact inventory
//!   demo    --preset xs --variant dtr_bilayer — CPU backend tour:
//!                                    forward perplexity, routing stats,
//!                                    greedy/sampled decode
//!   train   --steps 200 --save ckpt.dtck — native training on the CPU
//!                                    backend: forward + hand-derived
//!                                    backward + AdamW + Eq. 7 routing
//!                                    penalty, fully offline
//!                                    (DESIGN.md §Native training)
//!   eval    [--load ckpt.dtck]     — perplexity + routing stats on the
//!                                    CPU backend (fresh init or a
//!                                    trained checkpoint)
//!   serve   --requests 8           — continuous-batching engine on the
//!                                    CPU backend: synthetic workload,
//!                                    throughput/latency/KV-page report
//!                                    (see DESIGN.md §Serving for flags)
//!   serve   --listen 127.0.0.1:8080 — same engine behind the
//!                                    zero-dependency HTTP/1.1 front end:
//!                                    JSON generate requests, chunked
//!                                    token streaming, 429 backpressure
//!                                    (DESIGN.md §Network front end)
//!   bench   [--test] [--out BENCH_pr7.json] — reproducible perf harness:
//!                                    fixed-seed forward/decode/serve/
//!                                    train/quant/spec-decode scenarios
//!                                    swept across thread counts
//!                                    (DESIGN.md §Benchmarking);
//!                                    `--quant off` skips the int8
//!                                    scenarios; `--gate-pct 20` turns
//!                                    the baseline-delta readout into a
//!                                    regression gate (nonzero exit when
//!                                    any scenario's primary throughput
//!                                    metric falls more than 20% below
//!                                    `--baseline BENCH_baseline.json`)
//!   flops   [--preset smollm-1b3]  — Fig. 4 analytical table
//!   kvmem   [--preset smollm-1b3]  — Fig. 6 analytical table
//!
//! Global flags:
//!   --threads N — kernel-thread count for the CPU backend (default:
//!                 available parallelism; 1 = the single-threaded
//!                 determinism baseline — outputs are bit-identical
//!                 either way, only throughput changes)
//!   --simd {auto,avx2,neon,scalar} — SIMD kernel tier (default auto =
//!                 best supported; also settable via DTRNET_SIMD).
//!                 Under the default exact precision this is a pure
//!                 throughput knob: every kernel is bit-identical
//!                 across tiers (DESIGN.md §SIMD dispatch)
//!   --precision {exact,fast} — fast additionally vectorizes the f32
//!                 dot/variance reductions; not bitwise vs exact,
//!                 gated by the bench harness's routing-equivalence +
//!                 perplexity-delta checks
//!   --quant int8 — on demo/eval/serve: int8-quantize the weights on
//!                 load (~3.7x smaller residency, per-output-row scales;
//!                 DESIGN.md §Quantization). Accuracy is gated by the
//!                 bench harness: routing decisions must match f32
//!                 wherever the router is decisive, eval perplexity
//!                 within 0.5%.
//!   --speculate K — on demo/eval/serve: bypass-path self-speculative
//!                 decoding — draft K tokens per iteration with every DTR
//!                 layer forced onto the linear bypass, then verify the
//!                 window in one batched full-router pass. Greedy token
//!                 streams are bitwise unchanged; acceptance telemetry
//!                 lands in the serve report (DESIGN.md §Speculative
//!                 decoding)
//!   --trace out.trace.json — on train/serve: record telemetry spans for
//!                 the run and export Chrome trace-event JSON (load in
//!                 Perfetto or chrome://tracing; DESIGN.md
//!                 §Observability). Off by default: disabled tracing
//!                 costs one relaxed atomic load per span site.
//!   --metrics-jsonl m.jsonl — on serve: stream per-step and per-request
//!                 metric rows as JSONL while the run progresses (train
//!                 accepts it as an alias of --log, its per-step stream)
//!   --kv-budget-pages N — on serve (both forms): cap *resident* KV
//!                 pages per decode slot at N (page size from --page);
//!                 LRU overflow spills to a temp file and is faulted
//!                 back on demand. Bounds memory only — token streams
//!                 are bitwise identical to the unbounded default
//!                 (0 = unbounded resident slab; DESIGN.md §KV paging)
//!
//! Requiring the `pjrt` build + AOT artifacts (`make artifacts`):
//!   train   --tag tiny_dtr_bilayer — train the fused AOT train_step
//!                                    artifact instead of the CPU path
//!   eval    --tag tiny_dtr_bilayer — score the AOT fwd artifact
//!   serve   --artifact tiny_dtr_bilayer — serve the AOT decode artifact
//!                                    instead of the CPU backend

use anyhow::{bail, Result};

use dtrnet::config::{ModelConfig, TrainConfig, Variant};
use dtrnet::coordinator::{
    generate_workload, PrefillMode, SamplingParams, Server, ServerConfig, SpeculativeDecoder,
    Trainer, WorkloadSpec,
};
use dtrnet::data::{corpus, Dataset};
use dtrnet::metrics::JsonlWriter;
use dtrnet::model::{flops, memory};
use dtrnet::runtime::{Backend, CpuBackend, CpuTrainer, TrainBackend};
use dtrnet::tokenizer::{ByteTokenizer, Tokenizer};
use dtrnet::util::bench::print_table;
use dtrnet::util::cli::Args;
use dtrnet::util::rng::Rng;

#[cfg(feature = "pjrt")]
use dtrnet::coordinator::ArtifactTrainer;
#[cfg(feature = "pjrt")]
use dtrnet::runtime::Engine;

fn main() -> Result<()> {
    let args = Args::parse();
    // Pin the process-wide kernel pool before any backend is built.
    // Thread count is a throughput knob only: outputs are bit-identical
    // for every value (--threads 1 is the serial determinism baseline).
    if let Some(n) = args.get("threads").and_then(|v| v.parse::<usize>().ok()) {
        dtrnet::util::threadpool::set_global_threads(n);
    }
    // Pin the process-wide SIMD tier / precision before any pool snapshots
    // a KernelCtx. `--simd auto` (the default) picks the best tier the host
    // supports; explicit tiers fail fast when unsupported.
    if let Some(s) = args.get("simd") {
        match dtrnet::util::simd::parse_tier(s) {
            Ok(t) => dtrnet::util::simd::set_tier(t),
            Err(e) => bail!("--simd {s}: {e}"),
        }
    }
    if let Some(s) = args.get("precision") {
        match dtrnet::util::simd::parse_precision(s) {
            Ok(p) => dtrnet::util::simd::set_precision(p),
            Err(e) => bail!("--precision {s}: {e}"),
        }
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("info");
    match cmd {
        "info" => info(),
        "demo" => demo(&args),
        "train" => train(&args),
        "eval" => eval(&args),
        "serve" => serve(&args),
        "bench" => bench_cmd(&args),
        "flops" => flops_cmd(&args),
        "kvmem" => kvmem_cmd(&args),
        other => {
            bail!("unknown command {other:?} (try info/demo/train/eval/serve/bench/flops/kvmem)")
        }
    }
}

/// Reproducible perf harness: run the fixed-seed scenario suite across a
/// thread sweep and write the machine-readable bench document.
fn bench_cmd(args: &Args) -> Result<()> {
    let quick = args.has("test") || args.has("quick");
    let mut opts = dtrnet::perf::BenchOptions::new(quick);
    if let Some(n) = args.get("threads").and_then(|v| v.parse::<usize>().ok()) {
        opts.threads = if n <= 1 { vec![1] } else { vec![1, n] };
    }
    // `--quant off` skips the quant_* scenarios (they are part of the
    // default suite: int8 accuracy gates run on every bench/CI pass).
    opts.include_quant = parse_quant(args, "int8")?;
    println!(
        "[bench] {} mode, thread sweep {:?} (hw {}), quant scenarios {}, simd tier {} (detected {})",
        if quick { "smoke" } else { "full" },
        opts.threads,
        dtrnet::util::threadpool::available_threads(),
        if opts.include_quant { "on" } else { "off" },
        dtrnet::util::simd::tier().name(),
        dtrnet::util::simd::detect().name(),
    );
    let doc = dtrnet::perf::run(&opts)?;
    // Speedup-vs-baseline readout. Without --gate-pct it is informational
    // only; with it, scenarios whose primary throughput metric fell more
    // than that many percent below the baseline fail the run (the CI
    // bench-regression gate). The JSON is written either way — it is the
    // artifact CI promotes into the next baseline
    // (cp results/bench_ci.json BENCH_baseline.json).
    let baseline = args.get_or("baseline", "BENCH_baseline.json");
    let gate = args.get("gate-pct").and_then(|v| v.parse::<f64>().ok());
    let regressions =
        dtrnet::perf::print_baseline_deltas(&doc, std::path::Path::new(baseline), gate);
    let out = args.get_or("out", "BENCH_pr7.json");
    dtrnet::perf::write(std::path::Path::new(out), &doc)?;
    if regressions > 0 {
        bail!(
            "{regressions} scenario(s) regressed more than {:.1}% vs {baseline} (--gate-pct)",
            gate.unwrap_or(0.0)
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn engine() -> Result<Engine> {
    Engine::new(&dtrnet::artifacts_dir())
}

fn info() -> Result<()> {
    println!("dtrnet {}", dtrnet::version());
    #[cfg(feature = "pjrt")]
    {
        let e = engine()?;
        println!("execution backend: PJRT ({})", e.platform());
        println!("artifacts ({}):", e.manifest.artifacts.len());
        for a in &e.manifest.artifacts {
            println!(
                "  {:<36} kind={:<11} layout={} in/out={}/{}",
                a.name,
                a.kind,
                a.config.layout_string(),
                a.inputs.len(),
                a.outputs.len()
            );
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!(
        "execution backend: native cpu (rebuild with --features pjrt for the \
         XLA/PJRT artifact path)"
    );
    println!(
        "simd: active tier {} (detected {}), precision {}",
        dtrnet::util::simd::tier().name(),
        dtrnet::util::simd::detect().name(),
        dtrnet::util::simd::precision().name(),
    );
    Ok(())
}

fn make_dataset(args: &Args, seq: usize) -> Dataset {
    match args.get_or("corpus", "markov") {
        "text" => {
            let text = corpus::embedded_corpus();
            let toks = ByteTokenizer.encode(&text);
            Dataset::new(toks, seq)
        }
        _ => {
            let mut rng = Rng::new(args.get_u64("data-seed", 7));
            Dataset::new(corpus::markov_corpus(&mut rng, 256, 600 * seq, 12), seq)
        }
    }
}

/// `--trace out.trace.json` handling: turn span recording on for the run
/// and return the export path (None = tracing stays off, its disabled
/// cost being one relaxed atomic load per span site).
fn start_trace(args: &Args) -> Option<std::path::PathBuf> {
    let path = args.get("trace").map(std::path::PathBuf::from);
    if path.is_some() {
        dtrnet::telemetry::set_enabled(true);
    }
    path
}

/// Export the recorded spans as Chrome trace-event JSON and disable
/// tracing again.
fn finish_trace(path: &std::path::Path) -> Result<()> {
    dtrnet::telemetry::set_enabled(false);
    println!(
        "[trace] wrote {} events to {} ({} dropped to ring wraparound) — load in Perfetto",
        dtrnet::telemetry::snapshot_events().len(),
        path.display(),
        dtrnet::telemetry::dropped_events(),
    );
    dtrnet::telemetry::write_chrome_trace(path)
}

/// Shared `--quant` parsing: `int8` opts into the quantized path,
/// `off`/`f32`/`none` stays full precision. `default` is the value used
/// when the flag is absent (`"off"` for demo/eval/serve, `"int8"` for
/// bench, whose quant scenarios are part of the default suite).
fn parse_quant(args: &Args, default: &str) -> Result<bool> {
    match args.get_or("quant", default) {
        "off" | "f32" | "none" => Ok(false),
        "int8" => Ok(true),
        other => bail!("unknown --quant mode {other:?} (try int8 or off)"),
    }
}

/// Build the CPU execution backend for `demo`/`eval`/`serve`: fresh
/// seeded init or a DTCK checkpoint load, optionally int8-quantized on
/// load (`--quant int8`; DESIGN.md §Quantization).
fn build_backend(
    cfg: &ModelConfig,
    seed: u64,
    load: Option<&str>,
    quant: bool,
) -> Result<Box<dyn Backend>> {
    let be = match load {
        Some(path) => {
            let ck = dtrnet::runtime::Checkpoint::load(std::path::Path::new(path))?;
            CpuBackend::from_checkpoint(cfg, &ck)?
        }
        None => CpuBackend::init(cfg, seed)?,
    };
    Ok(if quant {
        Box::new(be.quantized()?)
    } else {
        Box::new(be)
    })
}

/// Shared `--preset` / `--variant` / `--seed` parsing for the CPU-backend
/// commands (`demo`, `serve`).
fn parse_model(args: &Args, default_preset: &str) -> Result<(ModelConfig, Variant, u64)> {
    let preset = args.get_or("preset", default_preset);
    let variant = Variant::from_str(args.get_or("variant", "dtr_bilayer"))
        .ok_or_else(|| anyhow::anyhow!("unknown variant (try dense/dtr_bilayer/dtr_trilayer)"))?;
    let cfg = ModelConfig::try_preset(preset, variant).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown preset {preset:?} (try one of {:?})",
            ModelConfig::PRESET_NAMES
        )
    })?;
    Ok((cfg, variant, args.get_u64("seed", 0)))
}

/// Native CPU backend tour: forward perplexity + routing + decode — runs
/// on any machine, no artifacts, no XLA.
fn demo(args: &Args) -> Result<()> {
    let (cfg, variant, seed) = parse_model(args, "xs")?;
    let backend = build_backend(&cfg, seed, None, parse_quant(args, "off")?)?;
    let wb = backend.weight_bytes();
    println!(
        "backend={} model={} variant={} layout={} params={} weight_mb={:.2} ({:.2}x vs f32)",
        backend.name(),
        cfg.name,
        variant.as_str(),
        cfg.layout_string(),
        cfg.param_count(),
        wb.resident as f64 / 1e6,
        wb.compression(),
    );

    let data = make_dataset(args, cfg.max_seq.min(64));
    let r = dtrnet::eval::perplexity_backend(&backend, &data, 2, args.get_usize("batches", 2))?;
    println!(
        "[fwd] ppl {:.3} over {} tokens; attention fractions {:?}",
        r.ppl,
        r.n_tokens,
        r.routing.fractions()
    );

    let mut rng = Rng::new(seed.wrapping_add(1));
    let prompt: Vec<i32> = (0..args.get_usize("prompt", 8))
        .map(|_| rng.below(cfg.vocab_size as u64) as i32)
        .collect();
    let sampling = SamplingParams::temperature(args.get_f64("temp", 0.0) as f32);
    let gen = backend.generate(&prompt, args.get_usize("gen", 16), &sampling, &mut rng)?;
    println!(
        "[decode] prompt {:?} -> generated {:?}",
        prompt, gen.tokens
    );
    println!("[decode] per-layer attention fractions {:?}", gen.attn_frac);

    let speculate = args.get_usize("speculate", 0);
    if speculate > 0 {
        let gen_len = args.get_usize("gen", 16);
        let base = backend.generate(&prompt, gen_len, &sampling, &mut Rng::new(seed))?;
        let mut dec = SpeculativeDecoder::new(backend.as_ref(), speculate)?;
        let spec = dec.generate(&prompt, gen_len, &sampling, &mut Rng::new(seed))?;
        anyhow::ensure!(
            spec.tokens == base.tokens,
            "speculative stream diverged from plain decode"
        );
        let s = dec.stats;
        println!(
            "[speculate] k={} identical stream over {} tokens; drafted {} accepted {} \
             (rate {:.2}, mean {:.2} tok/iter over {} iterations)",
            speculate,
            spec.tokens.len(),
            s.drafted,
            s.accepted,
            s.acceptance_rate(),
            s.mean_accepted_len(),
            s.iterations,
        );
    }
    Ok(())
}

/// Native training: one dispatch for both execution paths. The default
/// trains the CPU backend (works on every build, fully offline);
/// `--tag <artifact>` opts into the fused AOT train_step path (pjrt
/// builds only).
fn train(args: &Args) -> Result<()> {
    if args.get("tag").is_some() {
        return train_artifact(args);
    }
    let (cfg, variant, seed) = parse_model(args, "tiny")?;
    let tcfg = TrainConfig {
        steps: args.get_usize("steps", 200),
        batch: args.get_usize("batch", 4),
        seq: args.get_usize("seq", cfg.max_seq.min(128)),
        peak_lr: args.get_f64("lr", 3e-4),
        seed,
        log_every: args.get_usize("log-every", 10),
        lambda_reg: args.get_f64("lambda", 8e-4),
        ..Default::default()
    };
    let mut backend = CpuTrainer::new(&cfg, &tcfg)?;
    println!(
        "backend=cpu model={} variant={} layout={} params={} batch={}x{} steps={} threads={} simd={}",
        cfg.name,
        variant.as_str(),
        cfg.layout_string(),
        cfg.param_count(),
        tcfg.batch,
        tcfg.seq,
        tcfg.steps,
        backend.threads(),
        dtrnet::util::simd::tier().name(),
    );
    let data = make_dataset(args, tcfg.seq);
    let n_windows = data.n_windows();
    anyhow::ensure!(
        n_windows >= 4,
        "corpus yields only {n_windows} windows of {} tokens (need >= 4 for a \
         train/held-out split) — reduce --seq or use a larger corpus",
        tcfg.seq
    );
    // At least 2 held-out windows: a 1-window split would be degenerate
    // (Dataset requires strictly more than one window's tokens).
    let (train_data, eval_data) = data.split((2.5 / n_windows as f64).max(0.1));
    let label = format!("{}_{}", cfg.name, variant.as_str());
    // --metrics-jsonl is an alias of --log here: train's per-step JSONL
    // stream predates the flag and carries the same rows.
    let log = match args.get("log").or_else(|| args.get("metrics-jsonl")) {
        Some(p) => Some(JsonlWriter::create(std::path::Path::new(p))?),
        None => None,
    };
    let trace_path = start_trace(args);
    let report = {
        let mut trainer = Trainer::new(&mut backend, &label);
        let report = trainer.run(&tcfg, &train_data, log.as_ref())?;
        if let Some(path) = args.get("save") {
            trainer.save_checkpoint(std::path::Path::new(path))?;
        }
        report
    };
    if let Some(p) = &trace_path {
        finish_trace(p)?;
    }
    println!(
        "[done] {} final_loss={:.4} tokens/s={:.0} attn_frac {:?} (step-1 {:?})",
        report.tag, report.final_loss, report.tokens_per_s, report.attn_frac,
        report.attn_frac_first
    );
    if let Some(kt) = backend.kernel_timings() {
        let ms = |k: &str| {
            kt.path(&format!("{k}.total_ms")).and_then(|v| v.as_f64()).unwrap_or(0.0)
        };
        println!(
            "kernel ms: fwd attn {:.1} mlp {:.1} router {:.1} | bwd attn {:.1} \
             mlp {:.1} router {:.1} norm {:.1} head {:.1} | optimizer {:.1}",
            ms("attention"),
            ms("mlp"),
            ms("router"),
            ms("bwd_attention"),
            ms("bwd_mlp"),
            ms("bwd_router"),
            ms("bwd_norm"),
            ms("bwd_unembed"),
            ms("optimizer"),
        );
    }
    // Held-out eval through the real train→serve handoff: export the
    // checkpoint and score it on the serving backend.
    let ck = backend.to_checkpoint()?;
    let serve_be = CpuBackend::from_checkpoint(&cfg, &ck)?;
    let eval_batch = tcfg.batch.min(eval_data.n_windows()).max(1);
    let r = dtrnet::eval::perplexity_backend(
        &serve_be,
        &eval_data,
        eval_batch,
        args.get_usize("eval-batches", 4),
    )?;
    println!(
        "[eval] held-out ppl {:.3} over {} tokens; routing {:?}",
        r.ppl,
        r.n_tokens,
        r.routing.fractions()
    );
    if args.has("smoke-assert") {
        smoke_assert(&cfg, &report)?;
    }
    Ok(())
}

/// CI train-smoke gate: the run must have actually learned (loss
/// decreased) and the DTR routers must have moved off the ceiling,
/// trending toward the paper's sparse attention fractions.
fn smoke_assert(cfg: &ModelConfig, report: &dtrnet::coordinator::TrainReport) -> Result<()> {
    let k = (report.losses.len() / 5).max(1);
    let first: f64 = report.losses[..k].iter().sum::<f64>() / k as f64;
    let last: f64 =
        report.losses[report.losses.len() - k..].iter().sum::<f64>() / k as f64;
    anyhow::ensure!(
        last < first,
        "smoke: loss did not decrease (first-{k} mean {first:.4} -> last-{k} mean {last:.4})"
    );
    for (l, kind) in cfg.layout_string().chars().enumerate() {
        if kind != 'D' {
            continue;
        }
        let tail = report.attn_frac[l];
        let init = report.attn_frac_first[l];
        anyhow::ensure!(
            tail < 0.9,
            "smoke: layer {l} attention fraction {tail:.3} stayed at the ceiling"
        );
        anyhow::ensure!(
            tail < init + 0.05,
            "smoke: layer {l} attention fraction rose ({init:.3} -> {tail:.3})"
        );
    }
    println!(
        "[smoke] OK: loss {first:.4} -> {last:.4}; dtr attention fractions {:?} (from {:?})",
        report.attn_frac, report.attn_frac_first
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn train_artifact(args: &Args) -> Result<()> {
    let e = engine()?;
    let tag = args.get_or("tag", "tiny_dtr_bilayer").to_string();
    let tcfg = TrainConfig {
        steps: args.get_usize("steps", 200),
        peak_lr: args.get_f64("lr", 3e-4),
        seed: args.get_u64("seed", 0),
        log_every: args.get_usize("log-every", 10),
        ..Default::default()
    };
    let mut trainer = ArtifactTrainer::new(&e, &tag, tcfg.seed as i32)?;
    let data = make_dataset(args, trainer.seq);
    let (train_data, eval_data) = data.split(0.1);
    let report = trainer.run(&tcfg, &train_data, None)?;
    println!(
        "[done] {} final_loss={:.4} tokens/s={:.0} attn_frac={:?}",
        report.tag, report.final_loss, report.tokens_per_s, report.attn_frac
    );
    if let Some(path) = args.get("save") {
        trainer.save_checkpoint(std::path::Path::new(path))?;
    }
    // quick held-out eval if a fwd artifact exists
    let fwd_name = e
        .manifest
        .artifacts
        .iter()
        .find(|a| a.kind == "fwd" && a.name.starts_with(&tag))
        .map(|a| a.name.clone());
    if let Some(fwd) = fwd_name {
        let r = dtrnet::eval::perplexity(&e, &fwd, trainer.params(), &eval_data, 8)?;
        println!("[eval] held-out ppl {:.2} routing {:?}", r.ppl, r.routing.fractions());
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn train_artifact(_args: &Args) -> Result<()> {
    bail!(
        "`train --tag` drives AOT train_step artifacts and needs the `pjrt` \
         build; omit --tag to train natively on the CPU backend"
    )
}

/// Perplexity + routing stats: one dispatch for both execution paths.
/// The default scores the CPU backend (fresh init, or `--load ckpt.dtck`
/// for trained weights); `--tag <artifact>` opts into the AOT fwd
/// artifact path (pjrt builds only).
fn eval(args: &Args) -> Result<()> {
    if args.get("tag").is_some() {
        return eval_artifact(args);
    }
    let (cfg, variant, seed) = parse_model(args, "tiny")?;
    let backend = build_backend(&cfg, seed, args.get("load"), parse_quant(args, "off")?)?;
    let data = make_dataset(args, args.get_usize("seq", cfg.max_seq.min(128)));
    let r = dtrnet::eval::perplexity_backend(
        backend.as_ref(),
        &data,
        args.get_usize("batch", 2),
        args.get_usize("batches", 4),
    )?;
    println!(
        "backend={} model={} variant={} ppl {:.3} over {} tokens; attention fractions {:?}",
        backend.name(),
        cfg.name,
        variant.as_str(),
        r.ppl,
        r.n_tokens,
        r.routing.fractions()
    );
    let speculate = args.get_usize("speculate", 0);
    if speculate > 0 {
        let mut rng = Rng::new(seed.wrapping_add(1));
        let prompt: Vec<i32> = (0..8)
            .map(|_| rng.below(cfg.vocab_size as u64) as i32)
            .collect();
        let gen_len = args.get_usize("gen", 32);
        let params = SamplingParams::greedy();
        let base = backend.generate(&prompt, gen_len, &params, &mut Rng::new(0))?;
        let mut dec = SpeculativeDecoder::new(backend.as_ref(), speculate)?;
        let spec = dec.generate(&prompt, gen_len, &params, &mut Rng::new(0))?;
        anyhow::ensure!(
            spec.tokens == base.tokens,
            "speculative stream diverged from plain decode"
        );
        let s = dec.stats;
        println!(
            "[speculate] k={speculate} greedy identity holds over {} tokens; \
             acceptance {:.2}, mean {:.2} tok/iter",
            spec.tokens.len(),
            s.acceptance_rate(),
            s.mean_accepted_len(),
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn eval_artifact(args: &Args) -> Result<()> {
    let e = engine()?;
    let tag = args.get_or("tag", "tiny_dtr_bilayer").to_string();
    let fwd = e
        .manifest
        .artifacts
        .iter()
        .find(|a| a.kind == "fwd" && a.name.starts_with(&tag))
        .map(|a| a.name.clone())
        .ok_or_else(|| anyhow::anyhow!("no fwd artifact for {tag}"))?;
    // Use fresh init params (untrained) unless a training run is chained.
    let init = e.load(&format!("{tag}_init"))?;
    let params = init.call_literals(&[dtrnet::runtime::Tensor::scalar_i32(
        args.get_usize("seed", 0) as i32,
    )
    .to_literal()?])?;
    let seq = e.manifest.get(&fwd)?.seq.unwrap();
    let data = make_dataset(args, seq);
    let r = dtrnet::eval::perplexity(&e, &fwd, &params, &data, args.get_usize("batches", 4))?;
    println!(
        "ppl {:.3} over {} tokens; attention fractions {:?}",
        r.ppl,
        r.n_tokens,
        r.routing.fractions()
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn eval_artifact(_args: &Args) -> Result<()> {
    bail!(
        "`eval --tag` scores AOT fwd artifacts and needs the `pjrt` build; \
         omit --tag to evaluate the native CPU backend"
    )
}

/// Continuous-batching serve: one dispatch for both execution paths. The
/// default drives the backend-generic engine on the native CPU backend
/// (works on every build); `--artifact <tag>` opts into the AOT decode
/// artifact path (pjrt builds only).
fn serve(args: &Args) -> Result<()> {
    if args.get("artifact").is_some() {
        return serve_artifact(args);
    }
    let (cfg, variant, seed) = parse_model(args, "tiny")?;
    // --load ckpt.dtck serves trained weights; default is fresh init.
    // --quant int8 quantizes the weights on load (4x smaller residency).
    let backend = build_backend(&cfg, seed, args.get("load"), parse_quant(args, "off")?)?;
    if let Some(addr) = args.get("listen") {
        return serve_listen(args, &cfg, variant, seed, backend.as_ref(), addr);
    }

    let mut spec = WorkloadSpec::smoke(args.get_usize("requests", 8));
    spec.arrival_rate = args.get_f64("rate", spec.arrival_rate);
    spec.prompt_len_mean = args.get_usize("prompt-mean", spec.prompt_len_mean);
    spec.prompt_len_max = args.get_usize("prompt-max", spec.prompt_len_max);
    spec.gen_len_mean = args.get_usize("gen", spec.gen_len_mean);
    spec.gen_len_max = args.get_usize("gen-max", spec.gen_len_max);
    spec.temperature = args.get_f64("temp", 0.0) as f32;
    spec.vocab = cfg.vocab_size;
    let trace = generate_workload(&spec, args.get_u64("workload-seed", 1));

    let chunk = args.get_usize("prefill-chunk", 32);
    let scfg = ServerConfig {
        slots: args.get_usize("slots", 4),
        kv_page_size: args.get_usize("page", 16),
        kv_budget_pages: args.get_usize("kv-budget-pages", 0),
        prefill: if chunk == 0 {
            PrefillMode::Decode
        } else {
            PrefillMode::Chunked(chunk)
        },
        seed,
        speculate: args.get_usize("speculate", 0),
        ..Default::default()
    };
    println!(
        "backend={} model={} variant={} layout={} slots={} page={} prefill={:?} threads={} simd={} precision={}",
        backend.name(),
        cfg.name,
        variant.as_str(),
        cfg.layout_string(),
        scfg.slots,
        scfg.kv_page_size,
        scfg.prefill,
        dtrnet::util::threadpool::global().threads(),
        dtrnet::util::simd::tier().name(),
        dtrnet::util::simd::precision().name(),
    );
    let mut srv = Server::new(backend.as_ref(), scfg)?;
    if let Some(p) = args.get("metrics-jsonl") {
        srv.set_metrics_log(JsonlWriter::create(std::path::Path::new(p))?);
    }
    let trace_path = start_trace(args);
    let report = srv.run_workload(&trace, args.get_usize("max-steps", 1_000_000))?;
    if let Some(p) = &trace_path {
        finish_trace(p)?;
    }

    println!(
        "requests: {} completed, {} evicted, {} rejected ({} steps, occupancy {:.2})",
        report.completed, report.evicted, report.rejected, report.steps, report.batch_occupancy
    );
    println!(
        "tokens: {} generated (+{} prompt) in {:.3}s -> {:.1} tok/s",
        report.tokens_generated, report.prompt_tokens, report.wall_s, report.tokens_per_s
    );
    println!(
        "latency ms: request p50 {:.2} p99 {:.2} | ttft p50 {:.2} p99 {:.2} | step p50 {:.3} p99 {:.3}",
        report.latency_ms_p50,
        report.latency_ms_p99,
        report.ttft_ms_p50,
        report.ttft_ms_p99,
        report.decode_step_ms_p50,
        report.decode_step_ms_p99,
    );
    if report.spec.iterations > 0 {
        println!(
            "speculate: drafted {} accepted {} rejected {} (rate {:.2}, \
             mean accepted len {:.2} over {} iterations)",
            report.spec.drafted,
            report.spec.accepted,
            report.spec.drafted - report.spec.accepted,
            report.spec.acceptance_rate(),
            report.spec.mean_accepted_len(),
            report.spec.iterations,
        );
    }
    let saved = report.dense_pages_peak.saturating_sub(report.pool.pages_peak);
    println!(
        "kv pages: peak {} vs dense-equivalent {} ({} pages saved, {:.1}%); \
         token-granular footprint {:.3}x dense",
        report.pool.pages_peak,
        report.dense_pages_peak,
        saved,
        if report.dense_pages_peak > 0 {
            100.0 * saved as f64 / report.dense_pages_peak as f64
        } else {
            0.0
        },
        report.kv_savings_ratio,
    );
    println!(
        "weights: {:.2} MB resident vs {:.2} MB f32-equivalent ({:.2}x compression)",
        report.weight_bytes.resident as f64 / 1e6,
        report.weight_bytes.f32_equiv as f64 / 1e6,
        report.weight_bytes.compression(),
    );
    let fracs: Vec<String> = report.attn_fracs.iter().map(|f| format!("{f:.3}")).collect();
    println!(
        "attention fraction per layer [{}]: {} (DTR capacity target ~{:.2})",
        cfg.layout_string(),
        fracs.join(" "),
        cfg.dtr_attn_frac,
    );
    if let Some(kt) = &report.kernel_timings {
        let ms = |k: &str| {
            kt.path(&format!("{k}.total_ms"))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        println!(
            "kernel ms: attention {:.1} | mlp {:.1} | bypass {:.1} | router {:.1} | \
             norm {:.1} | unembed {:.1}",
            ms("attention"),
            ms("mlp"),
            ms("bypass"),
            ms("router"),
            ms("norm"),
            ms("unembed"),
        );
    }
    if let Some(mf) = &report.measured_flops {
        let f = |k: &str| mf.path(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let ratios: Vec<String> = match mf.path("layers") {
            Some(dtrnet::util::json::Json::Arr(rows)) => rows
                .iter()
                .map(|r| {
                    format!(
                        "{:.3}",
                        r.path("ratio_vs_dense").and_then(|v| v.as_f64()).unwrap_or(1.0)
                    )
                })
                .collect(),
            _ => Vec::new(),
        };
        println!(
            "measured flops: {:.1}M executed vs {:.1}M dense-equivalent \
             ({:.3}x); per layer [{}]: {}",
            f("total") / 1e6,
            f("dense_equiv_total") / 1e6,
            f("ratio_vs_dense"),
            cfg.layout_string(),
            ratios.join(" "),
        );
    }
    if let Some(p) = args.get("json-out") {
        std::fs::write(p, report.to_json().to_string() + "\n")?;
        println!("[json] wrote {p}");
    }
    if args.has("json") {
        println!("{}", report.to_json().to_string_pretty());
    }
    Ok(())
}

/// `serve --listen ADDR`: the continuous-batching engine behind the
/// zero-dependency HTTP/1.1 front end. Requests arrive as JSON over real
/// TCP, tokens stream back via chunked transfer encoding, and engine
/// backpressure surfaces as prompt 429s (DESIGN.md §Network front end).
fn serve_listen(
    args: &Args,
    cfg: &ModelConfig,
    variant: Variant,
    seed: u64,
    backend: &dyn Backend,
    addr: &str,
) -> Result<()> {
    use dtrnet::coordinator::http::{Limits, ListenConfig, NetFrontend};
    let chunk = args.get_usize("prefill-chunk", 32);
    let scfg = ServerConfig {
        slots: args.get_usize("slots", 4),
        max_queue: args.get_usize("queue", 4096),
        kv_page_size: args.get_usize("page", 16),
        kv_budget_pages: args.get_usize("kv-budget-pages", 0),
        prefill: if chunk == 0 {
            PrefillMode::Decode
        } else {
            PrefillMode::Chunked(chunk)
        },
        seed,
        speculate: args.get_usize("speculate", 0),
        ..Default::default()
    };
    let lcfg = ListenConfig {
        limits: Limits {
            max_head_bytes: args.get_usize("max-head", 16 * 1024),
            max_body_bytes: args.get_usize("max-body", 256 * 1024),
            max_headers: args.get_usize("max-headers", 64),
        },
        max_conns: args.get_usize("max-conns", 64),
        read_timeout_ms: args.get_u64("read-timeout-ms", 5_000),
        idle_timeout_ms: args.get_u64("idle-timeout-ms", 30_000),
        stream_timeout_ms: args.get_u64("stream-timeout-ms", 60_000),
        max_requests: args.get_u64("max-requests", 0),
    };
    let metrics = match args.get("metrics-jsonl") {
        Some(p) => Some(JsonlWriter::create(std::path::Path::new(p))?),
        None => None,
    };
    let fe = NetFrontend::bind(addr, lcfg)?;
    println!(
        "[listen] http://{} backend={} model={} variant={} slots={} queue={} (POST /generate, GET /health)",
        fe.local_addr()?,
        backend.name(),
        cfg.name,
        variant.as_str(),
        scfg.slots,
        scfg.max_queue,
    );
    let trace_path = start_trace(args);
    let report = fe.run(backend, scfg, metrics)?;
    if let Some(p) = &trace_path {
        finish_trace(p)?;
    }
    let statuses: Vec<String> = report
        .net
        .by_status
        .iter()
        .map(|(k, v)| format!("{k}:{v}"))
        .collect();
    println!(
        "[net] {} conns ({} refused), {} requests, statuses {{{}}}, {} parse errors, {} early closes, {}/{} bytes in/out",
        report.net.connections,
        report.net.conns_refused,
        report.net.requests,
        statuses.join(" "),
        report.net.parse_errors,
        report.net.early_closes,
        report.net.bytes_in,
        report.net.bytes_out,
    );
    println!(
        "[engine] {} completed, {} evicted, {} rejected; {} tokens in {:.3}s -> {:.1} tok/s; kv pages now {} (peak {})",
        report.engine.completed,
        report.engine.evicted,
        report.engine.rejected,
        report.engine.tokens_generated,
        report.engine.wall_s,
        report.engine.tokens_per_s,
        report.engine.pool.pages_allocated,
        report.engine.pool.pages_peak,
    );
    if let Some(p) = args.get("json-out") {
        std::fs::write(p, report.to_json().to_string() + "\n")?;
        println!("[json] wrote {p}");
    }
    if args.has("json") {
        println!("{}", report.to_json().to_string_pretty());
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn serve_artifact(args: &Args) -> Result<()> {
    use dtrnet::coordinator::{Request, ServeEngine};
    let e = engine()?;
    let tag = args.get_or("artifact", "tiny_dtr_bilayer").to_string();
    let decode = e
        .manifest
        .artifacts
        .iter()
        .find(|a| a.kind == "decode" && a.name.starts_with(&tag))
        .map(|a| a.name.clone())
        .ok_or_else(|| anyhow::anyhow!("no decode artifact for {tag}"))?;
    // --load ckpt.dtck serves trained weights; default is fresh init
    let params = if let Some(path) = args.get("load") {
        dtrnet::coordinator::trainer::load_params_for(
            &e,
            &decode,
            std::path::Path::new(path),
        )?
    } else {
        let init = e.load(&format!("{tag}_init"))?;
        init.call_literals(&[dtrnet::runtime::Tensor::scalar_i32(0).to_literal()?])?
    };
    let mut srv = ServeEngine::new(&e, &decode, params, args.get_usize("page", 16))?;
    let n = args.get_usize("requests", 8);
    let mut rng = Rng::new(1);
    let now = std::time::Instant::now();
    for i in 0..n {
        srv.submit(Request {
            id: i as u64,
            prompt: (0..16).map(|_| rng.below(256) as i32).collect(),
            max_new_tokens: args.get_usize("gen", 32),
            temperature: args.get_f64("temp", 0.0) as f32,
            arrival: now,
        });
    }
    let report = srv.run_to_completion(100_000)?;
    println!("{}", report.to_json().to_string_pretty());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn serve_artifact(_args: &Args) -> Result<()> {
    bail!(
        "`serve --artifact` drives AOT decode artifacts and needs the `pjrt` \
         build; omit --artifact to serve on the native CPU backend"
    )
}

fn flops_cmd(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "smollm-1b3");
    let lengths = [2048usize, 4096, 8192, 12288, 16384, 20480];
    let variants = [
        Variant::Dense,
        Variant::DtrBilayer,
        Variant::DtrTrilayer,
        Variant::Mod,
        Variant::Dllm,
    ];
    let mut rows = Vec::new();
    for &n in &lengths {
        let mut row = vec![n.to_string()];
        for &v in &variants {
            let cfg = ModelConfig::preset(preset, v);
            row.push(format!("{:.4}", flops::flops_ratio_vs_dense(&cfg, n, None)));
        }
        rows.push(row);
    }
    print_table(
        &format!("Fig. 4 — FLOPs ratio vs dense ({preset})"),
        &["seq", "dense", "dtr_bi", "dtr_tri", "mod", "dllm"],
        &rows,
    );
    Ok(())
}

fn kvmem_cmd(args: &Args) -> Result<()> {
    let preset = args.get_or("preset", "smollm-1b3");
    let lengths = [1024usize, 2048, 4096, 8192, 16384];
    let variants = [
        Variant::Dense,
        Variant::DtrBilayer,
        Variant::Mod,
        Variant::Dllm,
    ];
    let mut rows = Vec::new();
    for &n in &lengths {
        let mut row = vec![n.to_string()];
        for &v in &variants {
            let cfg = ModelConfig::preset(preset, v);
            let m = memory::kv_bytes(&cfg, n, None);
            row.push(format!("{:.1}", m.allocated_bytes / 1e6));
        }
        rows.push(row);
    }
    print_table(
        &format!("Fig. 6 — KV cache MB ({preset})"),
        &["seq", "dense", "dtr_bi", "mod", "dllm"],
        &rows,
    );
    Ok(())
}
