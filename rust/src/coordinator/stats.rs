//! Routing statistics collector (paper Fig. 5 telemetry).
//!
//! Accumulates, per layer, how many tokens were routed to attention vs
//! bypassed — fed by the serving engine (decode `routed` outputs) and the
//! eval harness (fwd `route` outputs).

use crate::util::json::Json;

/// Per-layer routing counters.
#[derive(Debug, Clone)]
pub struct RoutingStats {
    /// Per-layer count of tokens that took the attention path.
    pub attended: Vec<u64>,
    /// Per-layer count of tokens observed.
    pub total: Vec<u64>,
}

impl RoutingStats {
    /// Zeroed statistics for `n_layers` layers.
    pub fn new(n_layers: usize) -> RoutingStats {
        RoutingStats {
            attended: vec![0; n_layers],
            total: vec![0; n_layers],
        }
    }

    /// Record a batch of routing decisions: `routed[l][b]`-style flat input
    /// of layer-major decisions for `n` tokens.
    pub fn record_layer(&mut self, layer: usize, attended: u64, total: u64) {
        self.attended[layer] += attended;
        self.total[layer] += total;
    }

    /// Record from a fwd artifact `route` tensor laid out [B, L, n].
    pub fn record_route_tensor(&mut self, route: &[f32], batch: usize, n_layers: usize, n: usize) {
        assert_eq!(route.len(), batch * n_layers * n);
        for b in 0..batch {
            for l in 0..n_layers {
                let off = (b * n_layers + l) * n;
                let att = route[off..off + n].iter().filter(|&&x| x > 0.5).count();
                self.record_layer(l, att as u64, n as u64);
            }
        }
    }

    /// Fraction of tokens routed to attention at each layer (Fig. 5 y-axis).
    pub fn fractions(&self) -> Vec<f64> {
        self.attended
            .iter()
            .zip(&self.total)
            .map(|(&a, &t)| if t == 0 { 0.0 } else { a as f64 / t as f64 })
            .collect()
    }

    /// Mean attention fraction across layers of a given subset (e.g. only
    /// DTR layers — the paper's "~10% of tokens" number).
    pub fn mean_fraction(&self, layers: &[usize]) -> f64 {
        if layers.is_empty() {
            return 0.0;
        }
        layers.iter().map(|&l| self.fractions()[l]).sum::<f64>() / layers.len() as f64
    }

    /// Accumulate another run's counts into this one.
    pub fn merge(&mut self, other: &RoutingStats) {
        for l in 0..self.attended.len() {
            self.attended[l] += other.attended[l];
            self.total[l] += other.total[l];
        }
    }

    /// Per-layer `{attended, total, fraction}` rows.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("fractions", Json::arr_f64(&self.fractions())),
            (
                "attended",
                Json::Arr(self.attended.iter().map(|&a| Json::Num(a as f64)).collect()),
            ),
            (
                "total",
                Json::Arr(self.total.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
        ])
    }
}

/// Token-position bucket boundaries for [`PositionBuckets`]: each entry
/// is the inclusive upper bound of a bucket starting after the previous
/// one (`0-7`, `8-15`, `16-31`, `32-63`, `64-127`, `128+`).
const BUCKET_UPPER: [usize; 5] = [7, 15, 31, 63, 127];

/// Attention-fraction telemetry resolved by layer × token position
/// bucket — shows *where in the sequence* the router spends attention
/// (early positions are cheap context; late positions decide whether
/// the quadratic term actually grows).
#[derive(Debug, Clone)]
pub struct PositionBuckets {
    /// `attended[bucket][layer]` tokens that took the attention path.
    attended: Vec<Vec<u64>>,
    /// `total[bucket][layer]` tokens observed.
    total: Vec<Vec<u64>>,
}

impl PositionBuckets {
    /// Zeroed counters for `n_layers` layers.
    pub fn new(n_layers: usize) -> PositionBuckets {
        let n_buckets = BUCKET_UPPER.len() + 1;
        PositionBuckets {
            attended: vec![vec![0; n_layers]; n_buckets],
            total: vec![vec![0; n_layers]; n_buckets],
        }
    }

    /// Bucket index for an absolute token position.
    fn bucket(pos: usize) -> usize {
        BUCKET_UPPER
            .iter()
            .position(|&hi| pos <= hi)
            .unwrap_or(BUCKET_UPPER.len())
    }

    /// Human-readable bucket labels, in index order.
    pub fn labels() -> Vec<String> {
        let mut lo = 0usize;
        let mut out = Vec::with_capacity(BUCKET_UPPER.len() + 1);
        for &hi in &BUCKET_UPPER {
            out.push(format!("{lo}-{hi}"));
            lo = hi + 1;
        }
        out.push(format!("{lo}+"));
        out
    }

    /// Record one routing decision for the token at absolute `pos`.
    pub fn record(&mut self, layer: usize, pos: usize, routed: bool) {
        let b = Self::bucket(pos);
        self.attended[b][layer] += u64::from(routed);
        self.total[b][layer] += 1;
    }

    /// Per-bucket rows: `{bucket, fractions[L], total}` (fraction is 0.0
    /// for layers with no tokens observed in that bucket). Buckets with
    /// no observations at all are omitted.
    pub fn to_json(&self) -> Json {
        let labels = Self::labels();
        let rows = labels
            .iter()
            .enumerate()
            .filter(|&(b, _)| self.total[b].iter().any(|&t| t > 0))
            .map(|(b, label)| {
                let fr: Vec<f64> = self.attended[b]
                    .iter()
                    .zip(&self.total[b])
                    .map(|(&a, &t)| if t == 0 { 0.0 } else { a as f64 / t as f64 })
                    .collect();
                let tokens: u64 = self.total[b].iter().sum::<u64>()
                    / (self.total[b].len().max(1) as u64);
                Json::from_pairs(vec![
                    ("bucket", Json::Str(label.clone())),
                    ("tokens", Json::Num(tokens as f64)),
                    ("fractions", Json::arr_f64(&fr)),
                ])
            })
            .collect();
        Json::Arr(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_from_tensor() {
        let mut s = RoutingStats::new(2);
        // B=1, L=2, n=4: layer0 all attended, layer1 one of four.
        let route = vec![1., 1., 1., 1., 1., 0., 0., 0.];
        s.record_route_tensor(&route, 1, 2, 4);
        let f = s.fractions();
        assert_eq!(f[0], 1.0);
        assert_eq!(f[1], 0.25);
        assert_eq!(s.mean_fraction(&[1]), 0.25);
    }

    #[test]
    fn position_buckets_resolve_and_label() {
        let mut pb = PositionBuckets::new(2);
        // position 0 (bucket 0-7): layer0 routed, layer1 not.
        pb.record(0, 0, true);
        pb.record(1, 0, false);
        // position 200 (bucket 128+): both routed.
        pb.record(0, 200, true);
        pb.record(1, 200, true);
        let j = pb.to_json();
        let rows = match &j {
            Json::Arr(r) => r,
            _ => panic!("expected array"),
        };
        // Only the two touched buckets appear.
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].path("bucket").and_then(|b| b.as_str().map(String::from)),
            Some("0-7".to_string())
        );
        assert_eq!(
            rows[1].path("bucket").and_then(|b| b.as_str().map(String::from)),
            Some("128+".to_string())
        );
        assert_eq!(PositionBuckets::labels().len(), 6);
        assert_eq!(PositionBuckets::bucket(7), 0);
        assert_eq!(PositionBuckets::bucket(8), 1);
        assert_eq!(PositionBuckets::bucket(127), 4);
        assert_eq!(PositionBuckets::bucket(128), 5);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = RoutingStats::new(1);
        a.record_layer(0, 1, 4);
        let mut b = RoutingStats::new(1);
        b.record_layer(0, 3, 4);
        a.merge(&b);
        assert_eq!(a.fractions()[0], 0.5);
    }
}
