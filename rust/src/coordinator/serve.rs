//! Serving engine: continuous-batched decode over the AOT decode artifact.
//!
//! Drives `{tag}_decode_b{B}m{M}`: every iteration feeds one token per
//! slot (prefill and generation are both decode steps — iteration-level
//! scheduling), samples from the returned logits, updates the paged KV
//! pool from the per-layer routing decisions, and admits queued requests
//! into freed slots. The KV cache and parameters stay resident as XLA
//! literals across steps.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::{Batcher, Request};
use super::kv_cache::{KvPool, PoolStats};
use super::stats::RoutingStats;
use crate::metrics::Registry;
use crate::runtime::{Engine, Executable, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats as ustats;

/// Serving run summary.
#[derive(Debug)]
pub struct ServeReport {
    /// Requests that ran to completion.
    pub completed: usize,
    /// Total generated tokens.
    pub tokens_generated: usize,
    /// Decode iterations executed.
    pub steps: usize,
    /// Wall-clock seconds.
    pub wall_s: f64,
    /// Generated tokens per second.
    pub tokens_per_s: f64,
    /// Median decode step time.
    pub decode_step_ms_p50: f64,
    /// 95th-percentile decode step time.
    pub decode_step_ms_p95: f64,
    /// Median time to first token.
    pub ttft_ms_p50: f64,
    /// Mean gap between consecutive tokens of a request.
    pub inter_token_ms_mean: f64,
    /// KV pool counters.
    pub pool: PoolStats,
    /// Per-layer routing counters.
    pub routing: RoutingStats,
    /// Cached-token fraction vs a cache-everything model.
    pub kv_savings_ratio: f64,
}

impl ServeReport {
    /// Serialize as JSON (the CLI's report output).
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("completed", Json::Num(self.completed as f64)),
            ("tokens_generated", Json::Num(self.tokens_generated as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("tokens_per_s", Json::Num(self.tokens_per_s)),
            ("decode_step_ms_p50", Json::Num(self.decode_step_ms_p50)),
            ("decode_step_ms_p95", Json::Num(self.decode_step_ms_p95)),
            ("ttft_ms_p50", Json::Num(self.ttft_ms_p50)),
            ("inter_token_ms_mean", Json::Num(self.inter_token_ms_mean)),
            ("kv_bytes_peak", Json::Num(self.pool.bytes_peak as f64)),
            ("kv_savings_ratio", Json::Num(self.kv_savings_ratio)),
            ("routing", self.routing.to_json()),
        ])
    }
}

/// Continuous-batching serving engine over one decode artifact.
pub struct ServeEngine {
    exe: Arc<Executable>,
    params: Vec<xla::Literal>,
    // Resident decode state.
    cache_k: xla::Literal,
    cache_v: xla::Literal,
    lens: Tensor, // host-authoritative [L, B] i32
    /// Admission queue + slot table.
    pub batcher: Batcher,
    /// Routing-aware paged KV accountant.
    pub pool: KvPool,
    rng: Rng,
    n_layers: usize,
    batch: usize,
    max_kv: usize,
    vocab: usize,
    routing: RoutingStats,
    registry: Registry,
    sampling_defaults: super::sampling::SamplingParams,
}

impl ServeEngine {
    /// Build from a decode artifact + parameter literals (trained weights
    /// exported from a [`super::Trainer`], or `{tag}_init` output).
    pub fn new(
        engine: &Engine,
        artifact: &str,
        params: Vec<xla::Literal>,
        kv_page_size: usize,
    ) -> Result<ServeEngine> {
        let exe = engine.load(artifact)?;
        let spec = &exe.spec;
        let nparams = spec.nparams.context("decode artifact missing nparams")?;
        anyhow::ensure!(
            params.len() == nparams,
            "expected {nparams} param literals, got {}",
            params.len()
        );
        let cache_shape = &spec.inputs[nparams].shape; // [L, B, M, H, hd]
        let (n_layers, batch, max_kv) = (cache_shape[0], cache_shape[1], cache_shape[2]);
        let vocab = spec.config.vocab_size;
        let cache = Tensor::zeros_f32(cache_shape.clone());
        // Page budget: a dense model at full context exactly fits; the DTR
        // model should stay well under it (that headroom IS the Fig. 6 win).
        let pages_per_slot_layer = max_kv.div_ceil(kv_page_size);
        let max_pages = n_layers * batch * pages_per_slot_layer;
        let pool = KvPool::new(&spec.config, batch, kv_page_size, max_pages);
        Ok(ServeEngine {
            exe,
            params,
            cache_k: cache.to_literal()?,
            cache_v: cache.to_literal()?,
            lens: Tensor::zeros_i32(vec![n_layers, batch]),
            batcher: Batcher::new(batch, 4096),
            pool,
            rng: Rng::new(0x5e11),
            n_layers,
            batch,
            max_kv,
            vocab,
            routing: RoutingStats::new(n_layers),
            registry: Registry::default(),
            sampling_defaults: super::sampling::SamplingParams::greedy(),
        })
    }

    /// Enqueue a request; false when the queue is full.
    pub fn submit(&mut self, req: Request) -> bool {
        self.batcher.submit(req)
    }

    /// One engine iteration: admit → decode → sample → advance.
    /// Returns the number of requests completed this step.
    pub fn step(&mut self) -> Result<usize> {
        for slot in self.batcher.admit() {
            // Fresh sequence in a recycled slot: reset its cache lengths.
            for l in 0..self.n_layers {
                let idx = l * self.batch + slot;
                match &mut self.lens.data {
                    crate::runtime::tensor::Data::I32(v) => v[idx] = 0,
                    _ => unreachable!(),
                }
            }
            self.pool.release(slot);
        }
        if self.batcher.idle() {
            return Ok(0);
        }

        let mut tokens = vec![0i32; self.batch];
        let mut pos = vec![0i32; self.batch];
        for slot in 0..self.batch {
            if let Some(st) = self.batcher.active[slot].as_ref() {
                tokens[slot] = st.next_input();
                pos[slot] = st.position as i32;
            }
        }

        let tok_lit = Tensor::i32(vec![self.batch], tokens).to_literal()?;
        let pos_lit = Tensor::i32(vec![self.batch], pos).to_literal()?;
        let lens_lit = self.lens.to_literal()?;
        let t0 = Instant::now();
        let mut inputs: Vec<&xla::Literal> = self.params.iter().collect();
        inputs.push(&self.cache_k);
        inputs.push(&self.cache_v);
        inputs.push(&lens_lit);
        inputs.push(&tok_lit);
        inputs.push(&pos_lit);
        let outs = self.exe.call_literals_ref(&inputs)?;
        self.registry
            .histogram("decode_step_ms")
            .record(t0.elapsed().as_secs_f64() * 1e3);

        // outputs: logits, ck', cv', lens', routed [L,B], g_attn [L,B]
        let mut outs = outs;
        let _g_attn = outs.pop().unwrap();
        let routed = Tensor::from_literal(&outs.pop().unwrap())?;
        let new_lens = Tensor::from_literal(&outs.pop().unwrap())?;
        let cv = outs.pop().unwrap();
        let ck = outs.pop().unwrap();
        let logits = Tensor::from_literal(&outs.pop().unwrap())?;

        self.cache_k = ck;
        self.cache_v = cv;

        let now = Instant::now();
        let mut completed = 0;
        let routed_f = routed.as_f32();
        for slot in 0..self.batch {
            let Some(st) = self.batcher.active[slot].as_ref() else {
                continue;
            };
            let _ = st;
            // Commit lens for this active slot from the artifact output.
            let mut routed_bools = vec![false; self.n_layers];
            for l in 0..self.n_layers {
                let idx = l * self.batch + slot;
                routed_bools[l] = routed_f[idx] > 0.5;
                let v = new_lens.as_i32()[idx];
                match &mut self.lens.data {
                    crate::runtime::tensor::Data::I32(hv) => hv[idx] = v,
                    _ => unreachable!(),
                }
            }
            self.routing_record(&routed_bools);
            if !self.pool.append(slot, &routed_bools) {
                // Pool exhausted — in production this evicts/preempts; here
                // we finish the request early and free the slot.
                self.force_finish(slot, now);
                completed += 1;
                continue;
            }
            // Guard: artifact cache is full → stop the sequence.
            let hit_cap = (0..self.n_layers).any(|l| {
                self.lens.as_i32()[l * self.batch + slot] as usize >= self.max_kv
            });
            let sampled = self.sample(&logits, slot);
            if self.batcher.advance(slot, sampled, now) || hit_cap {
                if hit_cap && self.batcher.active[slot].is_some() {
                    self.force_finish(slot, now);
                }
                self.pool.release(slot);
                completed += 1;
            }
        }
        Ok(completed)
    }

    fn routing_record(&mut self, routed: &[bool]) {
        for (l, &r) in routed.iter().enumerate() {
            self.routing.record_layer(l, r as u64, 1);
        }
    }

    fn force_finish(&mut self, slot: usize, now: Instant) {
        if let Some(mut st) = self.batcher.active[slot].take() {
            if st.first_token_at.is_none() {
                st.first_token_at = Some(now);
            }
            self.batcher.completed.push(st);
        }
        self.pool.release(slot);
    }

    fn sample(&mut self, logits: &Tensor, slot: usize) -> i32 {
        let v = self.vocab;
        let row = &logits.as_f32()[slot * v..(slot + 1) * v];
        let (params, history) = match self.batcher.active[slot].as_ref() {
            Some(st) => (
                super::sampling::SamplingParams {
                    temperature: st.req.temperature,
                    ..self.sampling_defaults
                },
                st.generated.as_slice(),
            ),
            None => (super::sampling::SamplingParams::greedy(), &[][..]),
        };
        super::sampling::sample(row, &params, history, &mut self.rng)
    }

    /// Engine-wide sampling defaults (top-k/top-p/repetition penalty);
    /// per-request temperature still comes from the request.
    pub fn set_sampling_defaults(&mut self, p: super::sampling::SamplingParams) {
        self.sampling_defaults = p;
    }

    /// Run until all submitted requests complete (or `max_steps`).
    pub fn run_to_completion(&mut self, max_steps: usize) -> Result<ServeReport> {
        let t0 = Instant::now();
        let mut steps = 0;
        while !self.batcher.idle() && steps < max_steps {
            self.step()?;
            steps += 1;
        }
        let wall = t0.elapsed().as_secs_f64();
        let completed = &self.batcher.completed;
        let tokens: usize = completed.iter().map(|c| c.generated.len()).sum();
        let ttfts: Vec<f64> = completed
            .iter()
            .filter_map(|c| {
                c.first_token_at
                    .map(|t| (t - c.req.arrival).as_secs_f64() * 1e3)
            })
            .collect();
        let step_hist = self.registry.histogram("decode_step_ms").summary();
        let pool = self.pool.stats();
        // Token-granular savings vs a dense model over the same stream
        // (page quantization overhead is visible separately via bytes_peak).
        let kv_savings_ratio = if pool.tokens_seen > 0 {
            pool.tokens_cached as f64 / (pool.tokens_seen * self.n_layers) as f64
        } else {
            1.0
        };
        Ok(ServeReport {
            completed: completed.len(),
            tokens_generated: tokens,
            steps,
            wall_s: wall,
            tokens_per_s: tokens as f64 / wall,
            decode_step_ms_p50: step_hist.p50,
            decode_step_ms_p95: step_hist.p95,
            ttft_ms_p50: ustats::percentile(&ttfts, 50.0),
            inter_token_ms_mean: if tokens > 0 {
                wall * 1e3 / tokens as f64
            } else {
                0.0
            },
            pool,
            routing: self.routing.clone(),
            kv_savings_ratio,
        })
    }
}
