//! Bypass-path self-speculative decoding.
//!
//! DTRNet's linear bypass is a free draft model living inside the target
//! model's own weights: a decode step with every DTR layer forced onto the
//! bypass ([`RouteOverride::ForceBypass`], router weights untouched) skips
//! all attention mixing, so a draft token costs only the linear path. The
//! [`SpeculativeDecoder`] turns that into standard draft/verify decoding:
//!
//! 1. **Draft** up to `k` tokens by greedy argmax over force-bypassed
//!    steps, then rewind the KV cache to the pre-draft mark
//!    ([`DecodeState::rollback`]) — draft KV (dense layers still cache)
//!    is transient by construction.
//! 2. **Verify** the window `[last, c1..ck]` in one batched full-router
//!    pass ([`Backend::decode_rows`]), the same multi-row machinery
//!    chunked prefill rides on.
//! 3. **Accept** the longest prefix whose sampled verify tokens equal the
//!    drafts, plus the bonus token from the first mismatching row, then
//!    truncate the cache to exactly the committed rows' routed lens
//!    ([`DecodeState::truncate_to`]).
//!
//! Every emitted token is sampled from full-router logits conditioned on
//! previously emitted tokens only, drafts never touch the RNG, and
//! [`sample`] runs exactly once per emitted token in stream order — so
//! the emitted stream is bitwise identical to plain decode at any
//! temperature. At temperature 0 this is the greedy-identity contract
//! `tests/speculative.rs` pins (DESIGN.md §Speculative decoding).

use anyhow::{ensure, Result};

use super::sampling::{sample, SamplingParams};
use crate::runtime::{Backend, DecodeState, GenerateOutput, RouteOverride, StepOutput};
use crate::util::rng::Rng;

/// Cumulative acceptance accounting for a speculative decode run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Draft tokens proposed across all iterations.
    pub drafted: u64,
    /// Draft tokens accepted by verification.
    pub accepted: u64,
    /// Draft/verify iterations executed (plain fallback steps included).
    pub iterations: u64,
    /// Tokens emitted across all iterations.
    pub emitted: u64,
}

impl SpecStats {
    /// Fraction of drafted tokens the verifier accepted (1.0 when nothing
    /// was drafted — an empty speculation run is vacuously perfect).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            1.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Mean tokens emitted per iteration — the speedup lever: each
    /// iteration costs one bypass draft pass plus one full verify pass.
    pub fn mean_accepted_len(&self) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.emitted as f64 / self.iterations as f64
        }
    }

    /// Fold `other` into `self` (per-request → engine-wide totals).
    pub fn merge(&mut self, other: &SpecStats) {
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.iterations += other.iterations;
        self.emitted += other.emitted;
    }
}

/// One draft/verify iteration's outcome.
#[derive(Debug)]
pub struct SpecIteration {
    /// Tokens emitted this iteration, in stream order (never empty).
    pub emitted: Vec<i32>,
    /// Verify-pass outputs for the committed rows only
    /// (`rows.len() == emitted.len()`); row `i` fed the token *before*
    /// `emitted[i]` and carries the routed flags the KV pool must mirror.
    pub rows: Vec<StepOutput>,
    /// Per-layer routed flags of every draft step (transient KV: dense
    /// layers cache, DTR layers bypass) — rolled back before verification.
    pub draft_routed: Vec<Vec<bool>>,
    /// Per-layer routed flags of every verify row, rejected rows included
    /// — rows past `emitted.len()` were truncated out of the cache.
    pub verify_routed: Vec<Vec<bool>>,
    /// Draft tokens proposed this iteration.
    pub drafted: usize,
    /// Draft tokens accepted this iteration.
    pub accepted: usize,
}

/// Draft-on-bypass / verify-with-router speculative decoder over any
/// [`Backend`] that implements the [`RouteOverride::ForceBypass`] hook
/// (both CPU backends do).
pub struct SpeculativeDecoder<'b> {
    backend: &'b dyn Backend,
    k: usize,
    d_model: usize,
    max_seq: usize,
    /// Cumulative acceptance statistics across every call.
    pub stats: SpecStats,
}

impl<'b> SpeculativeDecoder<'b> {
    /// A decoder drafting up to `k` tokens per iteration on `backend`.
    pub fn new(backend: &'b dyn Backend, k: usize) -> Result<SpeculativeDecoder<'b>> {
        ensure!(k > 0, "speculation depth k must be positive");
        let cfg = backend.config();
        Ok(SpeculativeDecoder {
            backend,
            k,
            d_model: cfg.d_model,
            max_seq: cfg.max_seq,
            stats: SpecStats::default(),
        })
    }

    /// One draft/verify iteration. `last` is the most recently emitted
    /// (not yet fed) token, `budget` caps how many tokens may still be
    /// emitted, `history` is every token generated so far (feeds the
    /// repetition penalty exactly as the plain decode loop would).
    /// Degenerates to a plain [`Backend::decode_step`] when the budget or
    /// the position cap leaves no room to speculate.
    pub fn step(
        &mut self,
        state: &mut DecodeState,
        last: i32,
        budget: usize,
        params: &SamplingParams,
        history: &[i32],
        rng: &mut Rng,
    ) -> Result<SpecIteration> {
        ensure!(budget > 0, "speculative step needs a positive token budget");
        self.stats.iterations += 1;
        let headroom = self.max_seq.saturating_sub(state.position);
        let k_rows = (self.k + 1).min(budget).min(headroom.max(1));
        if k_rows < 2 {
            // No room to speculate — the baseline path, bit for bit.
            let out = self.backend.decode_step(state, last)?;
            let tok = sample(out.logits.as_f32(), params, history, rng);
            self.stats.emitted += 1;
            return Ok(SpecIteration {
                emitted: vec![tok],
                rows: vec![out],
                draft_routed: Vec::new(),
                verify_routed: Vec::new(),
                drafted: 0,
                accepted: 0,
            });
        }

        // Draft k_rows-1 tokens on the bypass, then rewind the cache.
        let mark = state.mark(self.d_model);
        let mut drafts: Vec<i32> = Vec::with_capacity(k_rows - 1);
        let mut draft_routed: Vec<Vec<bool>> = Vec::with_capacity(k_rows - 1);
        let mut cur = last;
        for _ in 0..k_rows - 1 {
            let out = self
                .backend
                .decode_step_routed(state, cur, RouteOverride::ForceBypass)?;
            cur = argmax(out.logits.as_f32());
            drafts.push(cur);
            draft_routed.push(out.routed);
        }
        state.rollback(&mark, self.d_model);

        // One batched full-router pass over the whole window.
        let mut window: Vec<i32> = Vec::with_capacity(k_rows);
        window.push(last);
        window.extend_from_slice(&drafts);
        let mut outs = self.backend.decode_rows(state, &window)?;
        let verify_routed: Vec<Vec<bool>> = outs.iter().map(|o| o.routed.clone()).collect();

        // Longest matching prefix, plus the bonus token from the row that
        // broke the match (or the final row when everything matched).
        let mut hist: Vec<i32> = history.to_vec();
        let mut emitted: Vec<i32> = Vec::with_capacity(k_rows);
        let mut accepted = 0usize;
        for (i, out) in outs.iter().enumerate() {
            let tok = sample(out.logits.as_f32(), params, &hist, rng);
            emitted.push(tok);
            hist.push(tok);
            if i + 1 < k_rows && tok == drafts[i] {
                accepted += 1;
            } else {
                break;
            }
        }

        // Commit exactly the rows that fed an emitted token: per-layer
        // lens grow by the committed rows' routed flags only, so the
        // cache ends bitwise where a plain decode loop would leave it.
        let m = emitted.len();
        let mut keep = mark.lens.clone();
        for out in outs.iter().take(m) {
            for (l, &r) in out.routed.iter().enumerate() {
                keep[l] += usize::from(r);
            }
        }
        state.truncate_to(&keep, mark.position + m, self.d_model);
        outs.truncate(m);

        self.stats.drafted += (k_rows - 1) as u64;
        self.stats.accepted += accepted as u64;
        self.stats.emitted += m as u64;
        Ok(SpecIteration {
            emitted,
            rows: outs,
            draft_routed,
            verify_routed,
            drafted: k_rows - 1,
            accepted,
        })
    }

    /// Speculative counterpart of [`Backend::generate`]: prefill, sample
    /// the first token from the prefill logits, then emit the rest
    /// through draft/verify iterations. Token stream and `attn_frac` are
    /// bitwise identical to the plain path (the committed rows are the
    /// same fed tokens with the same routing decisions).
    pub fn generate(
        &mut self,
        prompt: &[i32],
        max_new_tokens: usize,
        params: &SamplingParams,
        rng: &mut Rng,
    ) -> Result<GenerateOutput> {
        let mut state = self.backend.begin_decode();
        let step = self.backend.prefill(&mut state, prompt)?;
        let mut routed_counts: Vec<u64> = state
            .lens(self.d_model)
            .iter()
            .map(|&len| len as u64)
            .collect();
        let mut total_steps = prompt.len() as u64;

        let mut out_tokens: Vec<i32> = Vec::with_capacity(max_new_tokens);
        if max_new_tokens > 0 {
            let first = sample(step.logits.as_f32(), params, &out_tokens, rng);
            out_tokens.push(first);
            while out_tokens.len() < max_new_tokens {
                let budget = max_new_tokens - out_tokens.len();
                let last = *out_tokens.last().expect("stream is non-empty");
                let it = self.step(&mut state, last, budget, params, &out_tokens, rng)?;
                for row in &it.rows {
                    total_steps += 1;
                    for (l, &r) in row.routed.iter().enumerate() {
                        routed_counts[l] += u64::from(r);
                    }
                }
                out_tokens.extend_from_slice(&it.emitted);
            }
        }

        let attn_frac = routed_counts
            .iter()
            .map(|&c| c as f64 / (total_steps as f64).max(1.0))
            .collect();
        Ok(GenerateOutput {
            tokens: out_tokens,
            attn_frac,
        })
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Variant};
    use crate::runtime::CpuBackend;

    fn backend() -> CpuBackend {
        CpuBackend::init(&ModelConfig::preset("xs", Variant::DtrBilayer), 11).unwrap()
    }

    fn prompt(seed: i32, len: usize) -> Vec<i32> {
        (0..len as i32).map(|i| (i * 13 + seed * 7) % 256).collect()
    }

    #[test]
    fn greedy_stream_bitwise_identical_to_plain_decode() {
        let be = backend();
        let params = SamplingParams::greedy();
        for k in [1, 2, 4, 7] {
            for p in 0..3 {
                let pr = prompt(p, 9 + p as usize);
                let base = be
                    .generate(&pr, 20, &params, &mut Rng::new(5))
                    .unwrap();
                let mut dec = SpeculativeDecoder::new(&be, k).unwrap();
                let spec = dec.generate(&pr, 20, &params, &mut Rng::new(5)).unwrap();
                assert_eq!(spec.tokens, base.tokens, "k={k} prompt {p}");
                assert_eq!(spec.attn_frac, base.attn_frac, "k={k} prompt {p}");
            }
        }
    }

    #[test]
    fn sampled_stream_matches_plain_decode_with_same_seed() {
        // Drafts never touch the RNG and sample() runs once per emitted
        // token, so identity holds beyond temperature 0 too.
        let be = backend();
        let params = SamplingParams {
            temperature: 0.8,
            top_k: 12,
            repetition_penalty: 1.2,
            ..Default::default()
        };
        let pr = prompt(1, 8);
        let base = be.generate(&pr, 16, &params, &mut Rng::new(42)).unwrap();
        let mut dec = SpeculativeDecoder::new(&be, 3).unwrap();
        let spec = dec.generate(&pr, 16, &params, &mut Rng::new(42)).unwrap();
        assert_eq!(spec.tokens, base.tokens);
    }

    #[test]
    fn stats_account_for_every_token() {
        let be = backend();
        let mut dec = SpeculativeDecoder::new(&be, 4).unwrap();
        let out = dec
            .generate(&prompt(2, 10), 24, &SamplingParams::greedy(), &mut Rng::new(0))
            .unwrap();
        let s = dec.stats;
        // First token comes from prefill; the rest from iterations.
        assert_eq!(s.emitted, out.tokens.len() as u64 - 1);
        assert!(s.accepted <= s.drafted, "{s:?}");
        assert!(s.iterations > 0);
        assert!((0.0..=1.0).contains(&s.acceptance_rate()));
        assert!(s.mean_accepted_len() >= 1.0, "{s:?}");
    }

    #[test]
    fn draft_rollback_restores_state_bitwise() {
        let be = backend();
        let d = be.config().d_model;
        let mut state = be.begin_decode();
        be.prefill(&mut state, &prompt(3, 7)).unwrap();
        let before = state.clone();
        let mark = state.mark(d);
        let mut cur = 5i32;
        for _ in 0..4 {
            let out = be
                .decode_step_routed(&mut state, cur, RouteOverride::ForceBypass)
                .unwrap();
            cur = argmax(out.logits.as_f32());
        }
        assert_ne!(state.position, before.position);
        state.rollback(&mark, d);
        assert_eq!(state.position, before.position);
        assert_eq!(state.snapshot_kv(), before.snapshot_kv());
    }

    #[test]
    fn force_bypass_skips_dtr_caching_but_not_dense() {
        let be = backend();
        let mut state = be.begin_decode();
        be.prefill(&mut state, &prompt(0, 6)).unwrap();
        let out = be
            .decode_step_routed(&mut state, 3, RouteOverride::ForceBypass)
            .unwrap();
        for (l, &r) in out.routed.iter().enumerate() {
            // DtrBilayer: even layers dense (always cache), odd layers DTR
            // (forced onto the bypass, never cache).
            assert_eq!(r, l % 2 == 0, "layer {l}");
        }
    }
}
