//! Routing-aware paged KV-cache pool.
//!
//! The paper's Fig. 6 claim — "DTRNet achieves true memory savings by
//! avoiding KV allocation for unselected tokens entirely" — is realized
//! here. The pool manages fixed-size pages per (slot, layer); a token
//! consumes cache capacity at layer l only if layer l routed it to
//! attention. Dense layers append every token; DTR layers ~10%; D-LLM (per
//! the paper's observation) masks instead of evicting, so its accounting
//! charges the dense footprint.
//!
//! The pool is the allocator + accountant for the serving engine: the
//! decode artifact owns the (dense, scratch) device cache, while the pool
//! tracks real per-layer lengths, enforces capacity, and reports
//! allocated-byte telemetry that `fig6_kv_memory` turns into the figure.

use crate::config::ModelConfig;
use crate::model::memory::KV_ELEM_BYTES;

/// Pool-wide statistics snapshot.
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    /// Pages currently held across all slots.
    pub pages_allocated: usize,
    /// High-water mark of `pages_allocated`.
    pub pages_peak: usize,
    /// Bytes currently held (pages x page bytes).
    pub bytes_allocated: usize,
    /// High-water mark of `bytes_allocated`.
    pub bytes_peak: usize,
    /// Tokens actually cached (routed tokens, summed over layers).
    pub tokens_cached: usize,
    /// Tokens fed through the model (per-slot, not per-layer).
    pub tokens_seen: usize,
}

/// Per-(slot, layer) cache accounting.
///
/// Stores only the real cached length — the same quantity the decode
/// artifact's [`crate::runtime::KvCache`] reports for this layer. Page
/// counts are *derived* (`len.div_ceil(page_size)`) rather than tracked
/// as shadow state, so pool accounting can never drift from storage.
#[derive(Debug, Clone, Default)]
struct SlotLayer {
    /// Number of cached (routed) tokens at this layer.
    len: usize,
}

impl SlotLayer {
    /// Pages backing `len` tokens (each page holds `page_size` entries).
    fn pages(&self, page_size: usize) -> usize {
        self.len.div_ceil(page_size)
    }
}

/// Snapshot of one slot's page lists plus the pool-wide counters, taken
/// by [`KvPool::spec_begin`] before a speculative draft window. Opaque
/// to callers: hand it back to [`KvPool::spec_rollback`] to undo every
/// append the window made.
#[derive(Debug, Clone)]
pub struct SpecMark {
    slot: usize,
    layers: Vec<SlotLayer>,
    stats: PoolStats,
}

/// Paged KV pool over `n_slots` concurrent sequences × `n_layers`.
#[derive(Debug)]
pub struct KvPool {
    page_size: usize,
    bytes_per_token_layer: usize,
    max_pages: usize,
    slots: Vec<Vec<SlotLayer>>, // [slot][layer]
    stats: PoolStats,
}

impl KvPool {
    /// A pool for `n_slots` sequences with `page_size`-token pages and a `max_pages` budget.
    pub fn new(cfg: &ModelConfig, n_slots: usize, page_size: usize, max_pages: usize) -> KvPool {
        KvPool {
            page_size,
            // K + V, fp16 elements, d_model per token per layer.
            bytes_per_token_layer: 2 * cfg.d_model * KV_ELEM_BYTES,
            max_pages,
            slots: vec![vec![SlotLayer::default(); cfg.n_layers]; n_slots],
            stats: PoolStats::default(),
        }
    }

    /// Record one decoded token for `slot`: `routed[l]` says whether layer
    /// l cached it. Returns false (and caches nothing) if the pool would
    /// exceed `max_pages` — the engine treats that as slot exhaustion.
    pub fn append(&mut self, slot: usize, routed: &[bool]) -> bool {
        // Dry-run the page demand first so failure is atomic.
        let ps = self.page_size;
        let mut new_pages = 0;
        for (l, &r) in routed.iter().enumerate() {
            if r {
                let sl = &self.slots[slot][l];
                new_pages += (sl.len + 1).div_ceil(ps) - sl.pages(ps);
            }
        }
        if self.stats.pages_allocated + new_pages > self.max_pages {
            return false;
        }
        self.stats.tokens_seen += 1;
        for (l, &r) in routed.iter().enumerate() {
            if r {
                let sl = &mut self.slots[slot][l];
                let before = sl.pages(ps);
                sl.len += 1;
                self.stats.pages_allocated += sl.pages(ps) - before;
                self.stats.tokens_cached += 1;
            }
        }
        self.refresh_peaks();
        true
    }

    /// Bulk-charge a chunked prefill for `slot`: of `n_tokens` prompt
    /// tokens fed, layer l cached `routed_counts[l]` of them (the decode
    /// state's lens delta). Atomic like [`KvPool::append`]: returns false
    /// and charges nothing if the page budget would be exceeded.
    pub fn append_prefill(
        &mut self,
        slot: usize,
        routed_counts: &[usize],
        n_tokens: usize,
    ) -> bool {
        let ps = self.page_size;
        let mut new_pages = 0;
        for (l, &cnt) in routed_counts.iter().enumerate() {
            let sl = &self.slots[slot][l];
            new_pages += (sl.len + cnt).div_ceil(ps) - sl.pages(ps);
        }
        if self.stats.pages_allocated + new_pages > self.max_pages {
            return false;
        }
        self.stats.tokens_seen += n_tokens;
        for (l, &cnt) in routed_counts.iter().enumerate() {
            let sl = &mut self.slots[slot][l];
            let before = sl.pages(ps);
            sl.len += cnt;
            self.stats.pages_allocated += sl.pages(ps) - before;
            self.stats.tokens_cached += cnt;
        }
        self.refresh_peaks();
        true
    }

    /// Open a speculative window on `slot`: snapshot its page lists and
    /// the pool-wide counters so every append made inside the window
    /// (draft rows, verify rows) can be undone bitwise by
    /// [`KvPool::spec_rollback`]. Only `slot` may be appended to while
    /// the window is open — the snapshot covers the shared counters, so
    /// a rollback would also revert appends made to other slots.
    pub fn spec_begin(&self, slot: usize) -> SpecMark {
        SpecMark {
            slot,
            layers: self.slots[slot].clone(),
            stats: self.stats.clone(),
        }
    }

    /// Close a speculative window: restore the marked slot's page lists
    /// and the pool counters to their [`KvPool::spec_begin`] snapshot,
    /// releasing every page the window allocated. The restore is bitwise
    /// — peaks included — so a rejected draft leaves no trace and the
    /// committed accounting (and the pages-to-zero shutdown invariant)
    /// matches a run that never speculated. Callers re-append the
    /// accepted rows after rolling back.
    pub fn spec_rollback(&mut self, mark: &SpecMark) {
        self.slots[mark.slot] = mark.layers.clone();
        self.stats = mark.stats.clone();
    }

    /// Release everything held by `slot` (sequence finished / evicted).
    pub fn release(&mut self, slot: usize) {
        let ps = self.page_size;
        for sl in &mut self.slots[slot] {
            self.stats.pages_allocated -= sl.pages(ps);
            *sl = SlotLayer::default();
        }
    }

    /// Per-layer cached lengths for `slot` (must mirror the artifact lens).
    pub fn lens(&self, slot: usize) -> Vec<usize> {
        self.slots[slot].iter().map(|sl| sl.len).collect()
    }

    /// Currently allocated bytes across the pool.
    pub fn allocated_bytes(&self) -> usize {
        self.stats.pages_allocated * self.page_size * self.bytes_per_token_layer
    }

    /// Bytes a dense model would hold for the same token stream.
    pub fn dense_equivalent_bytes(&self) -> usize {
        let n_layers = self.slots.first().map(|s| s.len()).unwrap_or(0);
        self.stats.tokens_seen * n_layers * self.bytes_per_token_layer
    }

    /// Current allocation counters and peaks.
    pub fn stats(&self) -> PoolStats {
        let mut s = self.stats.clone();
        s.bytes_allocated = self.allocated_bytes();
        s
    }

    fn refresh_peaks(&mut self) {
        self.stats.pages_peak = self.stats.pages_peak.max(self.stats.pages_allocated);
        let b = self.stats.pages_allocated * self.page_size * self.bytes_per_token_layer;
        self.stats.bytes_peak = self.stats.bytes_peak.max(b);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Variant;

    fn pool() -> KvPool {
        let cfg = ModelConfig::preset("tiny", Variant::DtrBilayer);
        KvPool::new(&cfg, 2, 16, 1000)
    }

    #[test]
    fn routed_only_allocation() {
        let mut p = pool();
        // 6 layers; only layers 0 and 2 route.
        let routed = [true, false, true, false, false, false];
        for _ in 0..16 {
            assert!(p.append(0, &routed));
        }
        assert_eq!(p.lens(0), vec![16, 0, 16, 0, 0, 0]);
        assert_eq!(p.stats().pages_allocated, 2);
        // 17th token at those layers opens new pages
        assert!(p.append(0, &routed));
        assert_eq!(p.stats().pages_allocated, 4);
    }

    #[test]
    fn release_returns_pages() {
        let mut p = pool();
        for _ in 0..40 {
            p.append(0, &[true; 6]);
            p.append(1, &[true, false, false, false, false, true]);
        }
        let before = p.stats().pages_allocated;
        assert!(before > 0);
        p.release(0);
        assert!(p.stats().pages_allocated < before);
        p.release(1);
        assert_eq!(p.stats().pages_allocated, 0);
        // peak survives release
        assert_eq!(p.stats().pages_peak, before);
    }

    #[test]
    fn bulk_prefill_matches_per_token_appends() {
        let mut a = pool();
        let mut b = pool();
        // 37 prompt tokens; layers 0/2/4/5 cache all, layers 1/3 every 4th.
        let routed_of = |i: usize| {
            let dtr = i % 4 == 0;
            [true, dtr, true, dtr, true, true]
        };
        for i in 0..37 {
            assert!(a.append(0, &routed_of(i)));
        }
        let mut counts = [0usize; 6];
        for i in 0..37 {
            for (l, &r) in routed_of(i).iter().enumerate() {
                counts[l] += r as usize;
            }
        }
        assert!(b.append_prefill(0, &counts, 37));
        assert_eq!(a.lens(0), b.lens(0));
        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!(sa.pages_allocated, sb.pages_allocated);
        assert_eq!(sa.tokens_cached, sb.tokens_cached);
        assert_eq!(sa.tokens_seen, sb.tokens_seen);
        assert_eq!(sa.bytes_allocated, sb.bytes_allocated);
    }

    #[test]
    fn bulk_prefill_capacity_atomic() {
        let cfg = ModelConfig::preset("tiny", Variant::DtrBilayer);
        let mut p = KvPool::new(&cfg, 1, 4, 6);
        // needs ceil(5/4)=2 pages on each of 6 layers > 6 budget
        assert!(!p.append_prefill(0, &[5; 6], 5));
        assert_eq!(p.stats().pages_allocated, 0);
        assert_eq!(p.stats().tokens_seen, 0);
        // 4 tokens on 6 layers = 6 pages fits exactly
        assert!(p.append_prefill(0, &[4; 6], 4));
        assert_eq!(p.stats().pages_allocated, 6);
    }

    #[test]
    fn capacity_enforced_atomically() {
        let cfg = ModelConfig::preset("tiny", Variant::DtrBilayer);
        let mut p = KvPool::new(&cfg, 1, 4, 6); // tiny budget
        let all = [true; 6];
        assert!(p.append(0, &all)); // 6 pages
        // after the first append every layer has a page with 3 free slots:
        for _ in 0..3 {
            assert!(p.append(0, &all));
        }
        // next append needs 6 fresh pages > budget → rejected atomically
        let before = p.stats().pages_allocated;
        assert!(!p.append(0, &all));
        assert_eq!(p.stats().pages_allocated, before);
    }

    #[test]
    fn spec_rollback_restores_accounting_bitwise() {
        let mut p = pool();
        for i in 0..21 {
            let dtr = i % 3 == 0;
            assert!(p.append(0, &[true, dtr, true, dtr, true, true]));
            assert!(p.append(1, &[true, false, true, false, true, true]));
        }
        let before = p.stats();
        let lens_before = p.lens(0);
        let mark = p.spec_begin(0);
        for _ in 0..9 {
            assert!(p.append(0, &[true; 6]));
        }
        assert_ne!(p.lens(0), lens_before, "window must have allocated");
        p.spec_rollback(&mark);
        let after = p.stats();
        assert_eq!(p.lens(0), lens_before);
        assert_eq!(after.pages_allocated, before.pages_allocated);
        assert_eq!(after.pages_peak, before.pages_peak, "peaks rewind too");
        assert_eq!(after.bytes_peak, before.bytes_peak);
        assert_eq!(after.tokens_cached, before.tokens_cached);
        assert_eq!(after.tokens_seen, before.tokens_seen);
        assert_eq!(after.bytes_allocated, before.bytes_allocated);
    }

    #[test]
    fn spec_commit_equals_never_speculated() {
        // rollback + re-append of the accepted prefix must leave the pool
        // bitwise identical to a run that only ever appended the prefix.
        let mut spec = pool();
        let mut plain = pool();
        let rows: Vec<[bool; 6]> = (0..7)
            .map(|i| [true, i % 2 == 0, true, i % 3 == 0, true, true])
            .collect();
        let accepted = 3usize;
        let mark = spec.spec_begin(0);
        for r in &rows {
            assert!(spec.append(0, r));
        }
        spec.spec_rollback(&mark);
        for r in rows.iter().take(accepted) {
            assert!(spec.append(0, r));
            assert!(plain.append(0, r));
        }
        assert_eq!(spec.lens(0), plain.lens(0));
        let (a, b) = (spec.stats(), plain.stats());
        assert_eq!(a.pages_allocated, b.pages_allocated);
        assert_eq!(a.pages_peak, b.pages_peak);
        assert_eq!(a.bytes_peak, b.bytes_peak);
        assert_eq!(a.tokens_cached, b.tokens_cached);
        assert_eq!(a.tokens_seen, b.tokens_seen);
    }

    #[test]
    fn savings_ratio_tracks_routing() {
        let mut p = pool();
        // dense layers: 4 of 6 route always; DTR layers 1,3 route 10%
        for i in 0..100 {
            let dtr = i % 10 == 0;
            p.append(0, &[true, dtr, true, dtr, true, true]);
        }
        let s = p.stats();
        let dense = p.dense_equivalent_bytes() as f64;
        let ratio = s.bytes_allocated as f64 / dense;
        assert!(ratio < 0.85, "ratio={ratio}");
        assert!(ratio > 0.5); // page quantization overhead keeps it above exact
    }
}
