//! Training orchestrator — backend-generic (feature-free).
//!
//! Owns everything the paper's §Training Setup puts host-side: the cosine
//! LR schedule with warmup, data batching, seeding, the step loop, metric
//! logging (JSONL) and the final report. Execution is delegated to a
//! [`TrainBackend`] (one optimizer step: forward + backward + AdamW):
//!
//! * the native [`crate::runtime::CpuTrainer`] on the default build —
//!   `dtrnet train` works offline, end to end, with no artifacts;
//! * `ArtifactTrainer` (`pjrt` feature) — the original XLA path,
//!   driving the fused `{tag}_train_step` executable with parameters and
//!   Adam moments resident as device literals between steps.
//!
//! Either way the trained parameters leave as a DTCK checkpoint that
//! every serving/eval path loads (`dtrnet serve --load ckpt.dtck`).

use std::time::Instant;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::metrics::JsonlWriter;
use crate::runtime::TrainBackend;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Outcome of a training run.
#[derive(Debug)]
pub struct TrainReport {
    /// Model/artifact tag that was trained.
    pub tag: String,
    /// Optimizer steps executed.
    pub steps: usize,
    /// Total loss per logged step.
    pub losses: Vec<f64>,
    /// Cross-entropy component per logged step.
    pub ce_losses: Vec<f64>,
    /// Router load-balance penalty per logged step.
    pub penalties: Vec<f64>,
    /// Final total loss.
    pub final_loss: f64,
    /// Per-layer attention fraction at the first step (the routing
    /// starting point the trained fractions are compared against).
    pub attn_frac_first: Vec<f64>,
    /// Mean attention fraction per layer over the last 10% of steps.
    pub attn_frac: Vec<f64>,
    /// Wall-clock seconds.
    pub wall_s: f64,
    /// Training throughput.
    pub tokens_per_s: f64,
}

impl TrainReport {
    /// Serialize as JSON (one EXPERIMENTS.md row).
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("tag", Json::Str(self.tag.clone())),
            ("steps", Json::Num(self.steps as f64)),
            ("final_loss", Json::Num(self.final_loss)),
            ("attn_frac", Json::arr_f64(&self.attn_frac)),
            ("attn_frac_first", Json::arr_f64(&self.attn_frac_first)),
            ("wall_s", Json::Num(self.wall_s)),
            ("tokens_per_s", Json::Num(self.tokens_per_s)),
            ("losses", Json::arr_f64(&self.losses)),
        ])
    }
}

/// Drives a [`TrainBackend`] through a full training run.
pub struct Trainer<'a> {
    backend: &'a mut dyn TrainBackend,
    tag: String,
}

impl<'a> Trainer<'a> {
    /// Wrap a backend; `tag` labels log lines and the report.
    pub fn new(backend: &'a mut dyn TrainBackend, tag: &str) -> Trainer<'a> {
        Trainer {
            backend,
            tag: tag.to_string(),
        }
    }

    /// Full training loop per `TrainConfig` over `data`: sample a batch,
    /// step the backend at the scheduled LR, log, report.
    pub fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &Dataset,
        log: Option<&JsonlWriter>,
    ) -> Result<TrainReport> {
        let (batch, seq) = (self.backend.batch(), self.backend.seq());
        anyhow::ensure!(
            data.seq == seq,
            "dataset windows are {} tokens but the backend trains on {seq}",
            data.seq
        );
        anyhow::ensure!(cfg.steps >= 1, "need at least one training step");
        let mut rng = Rng::new(cfg.seed);
        let t0 = Instant::now();
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut ces = Vec::with_capacity(cfg.steps);
        let mut pens = Vec::with_capacity(cfg.steps);
        let mut frac_first = Vec::new();
        let mut fracs_tail: Vec<Vec<f64>> = Vec::new();
        let tail_from = cfg.steps - (cfg.steps / 10).max(1) + 1;
        for s in 1..=cfg.steps {
            let tokens = data.sample_batch(&mut rng, batch);
            let lr = cfg.lr_at(s);
            // Trace one span per optimizer step; the backend's named
            // kernel timers nest the fwd/bwd/optimizer phases inside it.
            let span = crate::telemetry::scoped("train_step");
            let m = self.backend.train_step(&tokens, s, lr, cfg.seed)?;
            span.end_with_args(vec![
                ("step", crate::telemetry::ArgValue::from(s)),
                ("loss", crate::telemetry::ArgValue::from(m.loss)),
            ]);
            losses.push(m.loss);
            ces.push(m.ce);
            pens.push(m.penalty);
            if s == 1 {
                frac_first = m.attn_frac.clone();
            }
            if s >= tail_from {
                fracs_tail.push(m.attn_frac.clone());
            }
            if s % cfg.log_every == 0 || s == cfg.steps {
                println!(
                    "[train {}] step {s}/{} loss {:.4} ce {:.4} pen {:.5} \
                     gnorm {:.3} lr {lr:.2e} frac {:?}",
                    self.tag,
                    cfg.steps,
                    m.loss,
                    m.ce,
                    m.penalty,
                    m.grad_norm,
                    m.attn_frac
                        .iter()
                        .map(|f| (f * 100.0).round() / 100.0)
                        .collect::<Vec<_>>()
                );
            }
            if let Some(w) = log {
                w.write(&Json::from_pairs(vec![
                    ("step", Json::Num(s as f64)),
                    ("loss", Json::Num(m.loss)),
                    ("ce", Json::Num(m.ce)),
                    ("penalty", Json::Num(m.penalty)),
                    ("grad_norm", Json::Num(m.grad_norm)),
                    ("lr", Json::Num(lr)),
                    ("attn_frac", Json::arr_f64(&m.attn_frac)),
                ]));
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let n_layers = self.backend.config().n_layers;
        let mut attn_frac = vec![0.0; n_layers];
        for f in &fracs_tail {
            for (i, v) in f.iter().enumerate() {
                attn_frac[i] += v / fracs_tail.len() as f64;
            }
        }
        Ok(TrainReport {
            tag: self.tag.clone(),
            steps: cfg.steps,
            final_loss: *losses.last().unwrap_or(&f64::NAN),
            losses,
            ce_losses: ces,
            penalties: pens,
            attn_frac_first: frac_first,
            attn_frac,
            wall_s: wall,
            tokens_per_s: (cfg.steps * batch * seq) as f64 / wall,
        })
    }

    /// Save the backend's current parameters as a DTCK checkpoint.
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let ck = self.backend.to_checkpoint()?;
        ck.save(path)?;
        println!("[ckpt] saved {} tensors to {}", ck.entries.len(), path.display());
        Ok(())
    }
}

/// The XLA/PJRT training backend: drives `{tag}_train_init` +
/// `{tag}_train_step` artifacts with parameters and Adam moments
/// resident as device literals between steps (no host round-trip of the
/// weights on the hot path).
#[cfg(feature = "pjrt")]
pub struct ArtifactTrainer {
    tag: String,
    step_exe: std::sync::Arc<crate::runtime::Executable>,
    /// params ++ m ++ v, in manifest flat order, resident as literals.
    state: Vec<xla::Literal>,
    nparams: usize,
    config: crate::config::ModelConfig,
    /// Sequences per step (from the artifact shape).
    pub batch: usize,
    /// Tokens per sequence (from the artifact shape).
    pub seq: usize,
}

#[cfg(feature = "pjrt")]
impl ArtifactTrainer {
    /// Initialize from artifacts: runs `{tag}_train_init(seed)`.
    pub fn new(
        engine: &crate::runtime::Engine,
        tag: &str,
        seed: i32,
    ) -> Result<ArtifactTrainer> {
        use anyhow::Context;
        use crate::runtime::Tensor;
        let init = engine
            .load(&format!("{tag}_train_init"))
            .with_context(|| format!("load {tag}_train_init"))?;
        let step_exe = engine.load(&format!("{tag}_train_step"))?;
        let spec = &step_exe.spec;
        let nparams = spec.nparams.context("train_step missing nparams")?;
        let batch = spec.batch.context("train_step missing batch")?;
        let seq = spec.seq.context("train_step missing seq")?;
        let config = spec.config.clone();
        let state = init.call_literals(&[Tensor::scalar_i32(seed).to_literal()?])?;
        anyhow::ensure!(
            state.len() == 3 * nparams,
            "train_init returned {} leaves, want {}",
            state.len(),
            3 * nparams
        );
        Ok(ArtifactTrainer {
            tag: tag.to_string(),
            step_exe,
            state,
            nparams,
            config,
            batch,
            seq,
        })
    }

    /// One optimizer step on `tokens` ([batch*seq] i32, row-major).
    /// Returns (loss, ce, penalty, grad_norm, attn_frac).
    pub fn step(
        &mut self,
        tokens: &[i32],
        step_no: usize,
        lr: f64,
        seed: i32,
    ) -> Result<(f64, f64, f64, f64, Vec<f64>)> {
        use crate::runtime::Tensor;
        anyhow::ensure!(tokens.len() == self.batch * self.seq);
        let tok = Tensor::i32(vec![self.batch, self.seq], tokens.to_vec()).to_literal()?;
        let step_lit = Tensor::scalar_f32(step_no as f32).to_literal()?;
        let lr_lit = Tensor::scalar_f32(lr as f32).to_literal()?;
        let seed_lit = Tensor::scalar_i32(seed).to_literal()?;

        // state ++ [tokens, step, lr, seed] — literals are borrowed by
        // execute, so pass references without cloning weights.
        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.push(&tok);
        inputs.push(&step_lit);
        inputs.push(&lr_lit);
        inputs.push(&seed_lit);
        let mut outs = self.step_exe.call_literals_ref(&inputs)?;

        // Outputs: 3*nparams state ++ [loss, ce, penalty, gnorm, attn_frac].
        anyhow::ensure!(outs.len() == 3 * self.nparams + 5);
        let metrics = outs.split_off(3 * self.nparams);
        self.state = outs;
        let loss = Tensor::from_literal(&metrics[0])?.scalar() as f64;
        let ce = Tensor::from_literal(&metrics[1])?.scalar() as f64;
        let pen = Tensor::from_literal(&metrics[2])?.scalar() as f64;
        let gnorm = Tensor::from_literal(&metrics[3])?.scalar() as f64;
        let frac = Tensor::from_literal(&metrics[4])?
            .as_f32()
            .iter()
            .map(|&f| f as f64)
            .collect();
        Ok((loss, ce, pen, gnorm, frac))
    }

    /// Full training loop (convenience: wraps the generic [`Trainer`]).
    pub fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &Dataset,
        log: Option<&JsonlWriter>,
    ) -> Result<TrainReport> {
        let tag = self.tag.clone();
        Trainer::new(self, &tag).run(cfg, data, log)
    }

    /// The current parameter literals (flat manifest order) — feed these to
    /// fwd/decode artifacts of the same tag for evaluation/serving.
    pub fn params(&self) -> &[xla::Literal] {
        &self.state[..self.nparams]
    }

    /// Clone parameters out (literal deep copy via host roundtrip).
    pub fn export_params(&self) -> Result<Vec<crate::runtime::Tensor>> {
        self.state[..self.nparams]
            .iter()
            .map(crate::runtime::Tensor::from_literal)
            .collect()
    }

    /// Save trained parameters to a DTCK checkpoint (manifest-validated).
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let ck = TrainBackend::to_checkpoint(self)?;
        ck.save(path)?;
        println!("[ckpt] saved {} tensors to {}", ck.entries.len(), path.display());
        Ok(())
    }

    /// Restore parameters from a checkpoint (Adam moments reset to zero —
    /// matching the paper's from-scratch pretraining setup, checkpoints
    /// are for train→serve handoff, not resume).
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let ck = crate::runtime::Checkpoint::load(path)?;
        let lits = ck.to_literals(&self.step_exe.spec.params)?;
        for (i, l) in lits.into_iter().enumerate() {
            self.state[i] = l;
        }
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
impl TrainBackend for ArtifactTrainer {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn config(&self) -> &crate::config::ModelConfig {
        &self.config
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn train_step(
        &mut self,
        tokens: &[i32],
        step: usize,
        lr: f64,
        seed: u64,
    ) -> Result<crate::runtime::TrainMetrics> {
        let (loss, ce, penalty, grad_norm, attn_frac) =
            self.step(tokens, step, lr, seed as i32)?;
        Ok(crate::runtime::TrainMetrics {
            loss,
            ce,
            penalty,
            grad_norm,
            attn_frac,
        })
    }

    fn to_checkpoint(&self) -> Result<crate::runtime::Checkpoint> {
        crate::runtime::Checkpoint::from_literals(
            &self.step_exe.spec.params,
            &self.state[..self.nparams],
        )
    }
}

/// Load checkpointed parameters as literals for a given artifact's layout
/// (serving-side handoff: `ServeEngine::new(engine, artifact, params, …)`).
#[cfg(feature = "pjrt")]
pub fn load_params_for(
    engine: &crate::runtime::Engine,
    artifact: &str,
    path: &std::path::Path,
) -> Result<Vec<xla::Literal>> {
    let exe = engine.load(artifact)?;
    let ck = crate::runtime::Checkpoint::load(path)?;
    ck.to_literals(&exe.spec.params)
}
