//! Training orchestrator: drives the fused `train_step` artifact.
//!
//! Owns everything the paper's §Training Setup puts host-side: the cosine
//! LR schedule with warmup, data batching, seeding, step loop, metric
//! logging (JSONL) and periodic held-out evaluation. Parameters and Adam
//! moments stay as XLA literals between steps (no host round-trip of the
//! weights on the hot path).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::metrics::JsonlWriter;
use crate::runtime::{Engine, Executable, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Outcome of a training run.
#[derive(Debug)]
pub struct TrainReport {
    /// Artifact tag that was trained.
    pub tag: String,
    /// Optimizer steps executed.
    pub steps: usize,
    /// Total loss per logged step.
    pub losses: Vec<f64>,
    /// Cross-entropy component per logged step.
    pub ce_losses: Vec<f64>,
    /// Router load-balance penalty per logged step.
    pub penalties: Vec<f64>,
    /// Final total loss.
    pub final_loss: f64,
    /// Mean attention fraction per layer over the last 10% of steps.
    pub attn_frac: Vec<f64>,
    /// Wall-clock seconds.
    pub wall_s: f64,
    /// Training throughput.
    pub tokens_per_s: f64,
}

impl TrainReport {
    /// Serialize as JSON (one EXPERIMENTS.md row).
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("tag", Json::Str(self.tag.clone())),
            ("steps", Json::Num(self.steps as f64)),
            ("final_loss", Json::Num(self.final_loss)),
            ("attn_frac", Json::arr_f64(&self.attn_frac)),
            ("wall_s", Json::Num(self.wall_s)),
            ("tokens_per_s", Json::Num(self.tokens_per_s)),
            ("losses", Json::arr_f64(&self.losses)),
        ])
    }
}

/// Drives `{tag}_train_init` + `{tag}_train_step` artifacts.
pub struct Trainer {
    tag: String,
    step_exe: Arc<Executable>,
    /// params ++ m ++ v, in manifest flat order, resident as literals.
    state: Vec<xla::Literal>,
    nparams: usize,
    /// Sequences per step (from the artifact shape).
    pub batch: usize,
    /// Tokens per sequence (from the artifact shape).
    pub seq: usize,
    n_layers: usize,
}

impl Trainer {
    /// Initialize from artifacts: runs `{tag}_train_init(seed)`.
    pub fn new(engine: &Engine, tag: &str, seed: i32) -> Result<Trainer> {
        let init = engine
            .load(&format!("{tag}_train_init"))
            .with_context(|| format!("load {tag}_train_init"))?;
        let step_exe = engine.load(&format!("{tag}_train_step"))?;
        let spec = &step_exe.spec;
        let nparams = spec.nparams.context("train_step missing nparams")?;
        let batch = spec.batch.context("train_step missing batch")?;
        let seq = spec.seq.context("train_step missing seq")?;
        let state = init.call_literals(&[Tensor::scalar_i32(seed).to_literal()?])?;
        anyhow::ensure!(
            state.len() == 3 * nparams,
            "train_init returned {} leaves, want {}",
            state.len(),
            3 * nparams
        );
        let n_layers = spec.config.n_layers;
        Ok(Trainer {
            tag: tag.to_string(),
            step_exe,
            state,
            nparams,
            batch,
            seq,
            n_layers,
        })
    }

    /// One optimizer step on `tokens` ([batch*seq] i32, row-major).
    /// Returns (loss, ce, penalty, grad_norm, attn_frac).
    pub fn step(
        &mut self,
        tokens: &[i32],
        step_no: usize,
        lr: f64,
        seed: i32,
    ) -> Result<(f64, f64, f64, f64, Vec<f64>)> {
        anyhow::ensure!(tokens.len() == self.batch * self.seq);
        let tok = Tensor::i32(vec![self.batch, self.seq], tokens.to_vec()).to_literal()?;
        let step_lit = Tensor::scalar_f32(step_no as f32).to_literal()?;
        let lr_lit = Tensor::scalar_f32(lr as f32).to_literal()?;
        let seed_lit = Tensor::scalar_i32(seed).to_literal()?;

        // state ++ [tokens, step, lr, seed] — literals are borrowed by
        // execute, so pass references without cloning weights.
        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        inputs.push(&tok);
        inputs.push(&step_lit);
        inputs.push(&lr_lit);
        inputs.push(&seed_lit);
        let mut outs = self.step_exe.call_literals_ref(&inputs)?;

        // Outputs: 3*nparams state ++ [loss, ce, penalty, gnorm, attn_frac].
        anyhow::ensure!(outs.len() == 3 * self.nparams + 5);
        let metrics = outs.split_off(3 * self.nparams);
        self.state = outs;
        let loss = Tensor::from_literal(&metrics[0])?.scalar() as f64;
        let ce = Tensor::from_literal(&metrics[1])?.scalar() as f64;
        let pen = Tensor::from_literal(&metrics[2])?.scalar() as f64;
        let gnorm = Tensor::from_literal(&metrics[3])?.scalar() as f64;
        let frac = Tensor::from_literal(&metrics[4])?
            .as_f32()
            .iter()
            .map(|&f| f as f64)
            .collect();
        Ok((loss, ce, pen, gnorm, frac))
    }

    /// Full training loop per `TrainConfig` over `data`.
    pub fn run(
        &mut self,
        cfg: &TrainConfig,
        data: &Dataset,
        log: Option<&JsonlWriter>,
    ) -> Result<TrainReport> {
        let mut rng = Rng::new(cfg.seed);
        let t0 = Instant::now();
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut ces = Vec::with_capacity(cfg.steps);
        let mut pens = Vec::with_capacity(cfg.steps);
        let mut fracs_tail: Vec<Vec<f64>> = Vec::new();
        let tail_from = cfg.steps - (cfg.steps / 10).max(1);
        for s in 1..=cfg.steps {
            let tokens = data.sample_batch(&mut rng, self.batch);
            let lr = cfg.lr_at(s);
            let (loss, ce, pen, gnorm, frac) =
                self.step(&tokens, s, lr, cfg.seed as i32)?;
            losses.push(loss);
            ces.push(ce);
            pens.push(pen);
            if s >= tail_from {
                fracs_tail.push(frac.clone());
            }
            if s % cfg.log_every == 0 || s == cfg.steps {
                println!(
                    "[train {}] step {s}/{} loss {loss:.4} ce {ce:.4} pen {pen:.5} \
                     gnorm {gnorm:.3} lr {lr:.2e} frac {:?}",
                    self.tag,
                    cfg.steps,
                    frac.iter().map(|f| (f * 100.0).round() / 100.0).collect::<Vec<_>>()
                );
            }
            if let Some(w) = log {
                w.write(&Json::from_pairs(vec![
                    ("step", Json::Num(s as f64)),
                    ("loss", Json::Num(loss)),
                    ("ce", Json::Num(ce)),
                    ("penalty", Json::Num(pen)),
                    ("grad_norm", Json::Num(gnorm)),
                    ("lr", Json::Num(lr)),
                    ("attn_frac", Json::arr_f64(&frac)),
                ]));
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut attn_frac = vec![0.0; self.n_layers];
        for f in &fracs_tail {
            for (i, v) in f.iter().enumerate() {
                attn_frac[i] += v / fracs_tail.len() as f64;
            }
        }
        Ok(TrainReport {
            tag: self.tag.clone(),
            steps: cfg.steps,
            final_loss: *losses.last().unwrap_or(&f64::NAN),
            losses,
            ce_losses: ces,
            penalties: pens,
            attn_frac,
            wall_s: wall,
            tokens_per_s: (cfg.steps * self.batch * self.seq) as f64 / wall,
        })
    }

    /// The current parameter literals (flat manifest order) — feed these to
    /// fwd/decode artifacts of the same tag for evaluation/serving.
    pub fn params(&self) -> &[xla::Literal] {
        &self.state[..self.nparams]
    }

    /// Clone parameters out (literal deep copy via host roundtrip).
    pub fn export_params(&self) -> Result<Vec<Tensor>> {
        self.state[..self.nparams]
            .iter()
            .map(Tensor::from_literal)
            .collect()
    }

    /// Save trained parameters to a DTCK checkpoint (manifest-validated).
    pub fn save_checkpoint(&self, path: &std::path::Path) -> Result<()> {
        let ck = crate::runtime::Checkpoint::from_literals(
            &self.step_exe.spec.params,
            &self.state[..self.nparams],
        )?;
        ck.save(path)?;
        println!("[ckpt] saved {} tensors to {}", ck.entries.len(), path.display());
        Ok(())
    }

    /// Restore parameters from a checkpoint (Adam moments reset to zero —
    /// matching the paper's from-scratch pretraining setup, checkpoints
    /// are for train→serve handoff, not resume).
    pub fn load_checkpoint(&mut self, path: &std::path::Path) -> Result<()> {
        let ck = crate::runtime::Checkpoint::load(path)?;
        let lits = ck.to_literals(&self.step_exe.spec.params)?;
        for (i, l) in lits.into_iter().enumerate() {
            self.state[i] = l;
        }
        Ok(())
    }
}

/// Load checkpointed parameters as literals for a given artifact's layout
/// (serving-side handoff: `ServeEngine::new(engine, artifact, params, …)`).
pub fn load_params_for(
    engine: &Engine,
    artifact: &str,
    path: &std::path::Path,
) -> Result<Vec<xla::Literal>> {
    let exe = engine.load(artifact)?;
    let ck = crate::runtime::Checkpoint::load(path)?;
    ck.to_literals(&exe.spec.params)
}
