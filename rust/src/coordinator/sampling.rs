//! Token sampling policies for the serving engine.
//!
//! Greedy, temperature, top-k, nucleus (top-p), and repetition penalty —
//! the standard decode-time controls a deployable engine needs. All
//! sampling is driven by the engine's seeded [`Rng`] so serving runs are
//! reproducible.

use crate::util::rng::Rng;

/// Decode-time sampling configuration (per request).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    /// Softmax temperature; 0 = greedy argmax.
    pub temperature: f32,
    /// 0 = disabled; otherwise keep only the k highest logits.
    pub top_k: usize,
    /// 1.0 = disabled; otherwise nucleus sampling mass.
    pub top_p: f32,
    /// 1.0 = disabled; >1 divides logits of already-generated tokens.
    pub repetition_penalty: f32,
}

impl Default for SamplingParams {
    fn default() -> SamplingParams {
        SamplingParams {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
        }
    }
}

impl SamplingParams {
    /// Greedy decoding (temperature 0).
    pub fn greedy() -> SamplingParams {
        SamplingParams::default()
    }

    /// Pure temperature sampling at `t`.
    pub fn temperature(t: f32) -> SamplingParams {
        SamplingParams {
            temperature: t,
            ..Default::default()
        }
    }
}

/// Sample one token id from `logits` under `params`. `history` feeds the
/// repetition penalty (pass `&[]` to disable).
pub fn sample(logits: &[f32], params: &SamplingParams, history: &[i32], rng: &mut Rng) -> i32 {
    let v = logits.len();
    let mut work: Vec<f32> = logits.to_vec();

    // repetition penalty (CTRL-style: divide positive logits, multiply
    // negative ones, for every token already generated)
    if params.repetition_penalty != 1.0 {
        for &t in history {
            let t = t as usize;
            if t < v {
                if work[t] > 0.0 {
                    work[t] /= params.repetition_penalty;
                } else {
                    work[t] *= params.repetition_penalty;
                }
            }
        }
    }

    if params.temperature <= 0.0 {
        return argmax(&work) as i32;
    }
    for x in work.iter_mut() {
        *x /= params.temperature;
    }

    // top-k filter
    let mut candidates: Vec<usize> = (0..v).collect();
    if params.top_k > 0 && params.top_k < v {
        candidates.sort_by(|&a, &b| work[b].partial_cmp(&work[a]).unwrap());
        candidates.truncate(params.top_k);
    }

    // softmax over candidates
    let m = candidates
        .iter()
        .map(|&i| work[i])
        .fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<(usize, f64)> = candidates
        .iter()
        .map(|&i| (i, ((work[i] - m) as f64).exp()))
        .collect();
    let z: f64 = probs.iter().map(|(_, p)| p).sum();
    for (_, p) in probs.iter_mut() {
        *p /= z;
    }

    // nucleus filter: keep the smallest prefix (by prob) reaching top_p
    if params.top_p < 1.0 {
        probs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut acc = 0.0;
        let mut cut = probs.len();
        for (i, (_, p)) in probs.iter().enumerate() {
            acc += p;
            if acc >= params.top_p as f64 {
                cut = i + 1;
                break;
            }
        }
        probs.truncate(cut);
        let z: f64 = probs.iter().map(|(_, p)| p).sum();
        for (_, p) in probs.iter_mut() {
            *p /= z;
        }
    }

    // inverse-CDF draw
    let mut u = rng.f64();
    for (i, p) in &probs {
        u -= p;
        if u <= 0.0 {
            return *i as i32;
        }
    }
    probs.last().map(|(i, _)| *i as i32).unwrap_or(0)
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..xs.len() {
        if xs[i] > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn peaked(v: usize, peak: usize) -> Vec<f32> {
        let mut l = vec![0.0f32; v];
        l[peak] = 8.0;
        l
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Rng::new(0);
        let logits = peaked(16, 5);
        assert_eq!(sample(&logits, &SamplingParams::greedy(), &[], &mut rng), 5);
    }

    #[test]
    fn temperature_sampling_mostly_picks_peak() {
        let mut rng = Rng::new(1);
        let logits = peaked(16, 3);
        let p = SamplingParams::temperature(1.0);
        let hits = (0..200)
            .filter(|_| sample(&logits, &p, &[], &mut rng) == 3)
            .count();
        assert!(hits > 150, "hits={hits}");
    }

    #[test]
    fn top_k_restricts_support() {
        let mut rng = Rng::new(2);
        let mut logits = vec![0.0f32; 16];
        logits[0] = 3.0;
        logits[1] = 2.9;
        let p = SamplingParams {
            temperature: 2.0,
            top_k: 2,
            ..Default::default()
        };
        for _ in 0..100 {
            let t = sample(&logits, &p, &[], &mut rng);
            assert!(t == 0 || t == 1, "got {t}");
        }
    }

    #[test]
    fn top_p_restricts_support() {
        let mut rng = Rng::new(3);
        let mut logits = vec![-5.0f32; 64];
        logits[7] = 5.0; // ~all mass on 7
        let p = SamplingParams {
            temperature: 1.0,
            top_p: 0.5,
            ..Default::default()
        };
        for _ in 0..50 {
            assert_eq!(sample(&logits, &p, &[], &mut rng), 7);
        }
    }

    #[test]
    fn repetition_penalty_discourages_history() {
        let mut rng = Rng::new(4);
        let mut logits = vec![0.0f32; 8];
        logits[2] = 1.0;
        logits[5] = 0.9;
        let p = SamplingParams {
            temperature: 0.0,
            repetition_penalty: 3.0,
            ..Default::default()
        };
        // without history, 2 wins; with 2 in history, 5 wins
        assert_eq!(sample(&logits, &p, &[], &mut rng), 2);
        assert_eq!(sample(&logits, &p, &[2], &mut rng), 5);
    }

    #[test]
    fn deterministic_under_seed() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let p = SamplingParams {
            temperature: 1.0,
            top_k: 8,
            top_p: 0.9,
            ..Default::default()
        };
        let run = |seed| {
            let mut rng = Rng::new(seed);
            (0..20).map(|_| sample(&logits, &p, &[], &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}
