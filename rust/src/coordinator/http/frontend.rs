//! The TCP serving front end: `serve --listen ADDR`.
//!
//! Thread model (no `Send`/`Sync` bound on [`Backend`] needed):
//!
//! * the **engine loop** runs on the caller's thread — the thread that
//!   built the backend — pumping [`super::super::server::Server`] steps
//!   and fanning generated tokens out to per-request channels;
//! * an **accept thread** polls the listener (non-blocking + stop flag)
//!   and spawns one **connection thread** per socket, each owning its
//!   [`PushParser`] and feeding complete requests to the engine over an
//!   mpsc channel.
//!
//! Backpressure is the engine's own admission machinery: the connection
//! thread submits and the engine answers `Accepted` or `Rejected`
//! within one engine step (submissions are drained before every step),
//! so an overloaded server returns **429 + Retry-After** promptly
//! instead of hanging — `rejected` in the report counts them, keeping
//! `completed + evicted + rejected == submissions` closed at the HTTP
//! edge too.
//!
//! Responses deliberately carry no `Date` header: a generation under
//! greedy sampling is a pure function of (weights, prompt, params,
//! seed), so whole response byte streams are reproducible and the
//! torture tests compare them bitwise across request segmentations.
//!
//! Status mapping (DESIGN.md §Network front end): parse failures map
//! via [`HttpError::status`] (400/411/413/431/501/505), engine
//! validation → 400, queue-full → 429, connection cap → 503,
//! mid-request read deadline → 408, engine stall → 503, engine death
//! → 500. `HEAD` answers with the matching `GET`'s headers and no body;
//! `OPTIONS` answers 204 + `Allow`. Two distinct silence timeouts: the
//! mid-request read deadline (stalled half-request → 408) and the
//! longer idle keep-alive timeout (quiet connection between requests →
//! silent close).
//!
//! A client that disconnects mid-stream cancels its generation: the
//! engine loop notices the dead sink on its next pass and calls
//! [`Server::cancel_request`], draining the slot and every KV page it
//! held. `GET /metrics` serves live counters — the socket-edge
//! [`NetStats`] plus the engine loop's latest counters snapshot — as
//! one JSON document, readable from any connection thread without
//! touching the engine.

use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::super::batcher::Request;
use super::super::server::{ServeReport, Server, ServerConfig, SubmitError};
use super::bjson;
use super::parser::{HttpError, Limits, ParsedRequest, PushParser};
use crate::metrics::JsonlWriter;
use crate::runtime::Backend;
use crate::telemetry::{self, ArgValue};
use crate::util::json::Json;

/// Front-end configuration (`serve --listen` flags).
#[derive(Debug, Clone)]
pub struct ListenConfig {
    /// Per-connection parse limits.
    pub limits: Limits,
    /// Concurrent-connection cap; excess connections get an immediate
    /// 503 and a close.
    pub max_conns: usize,
    /// Mid-request read deadline in ms: longest silence tolerated after
    /// a request has started arriving before the connection gets a 408
    /// and a close. Idle keep-alive connections are governed by
    /// [`ListenConfig::idle_timeout_ms`] instead.
    pub read_timeout_ms: u64,
    /// Idle keep-alive timeout in ms: how long a connection may sit
    /// between requests (no request bytes in flight) before a silent
    /// close. Deliberately separate from — and typically much longer
    /// than — the mid-request read deadline: a quiet keep-alive socket
    /// is normal client behavior, a stalled half-request is not.
    pub idle_timeout_ms: u64,
    /// How long a connection waits on the engine for the next stream
    /// event before giving up (503 / stream abort).
    pub stream_timeout_ms: u64,
    /// Stop after this many responses (0 = run until stopped) — gives
    /// CI a deterministic exit.
    pub max_requests: u64,
}

impl Default for ListenConfig {
    fn default() -> ListenConfig {
        ListenConfig {
            limits: Limits::default(),
            max_conns: 64,
            read_timeout_ms: 5_000,
            idle_timeout_ms: 30_000,
            stream_timeout_ms: 60_000,
            max_requests: 0,
        }
    }
}

/// Socket-edge counters, merged into the run report.
#[derive(Debug, Clone, Default)]
pub struct NetStats {
    /// Connections accepted (including ones refused at the cap).
    pub connections: u64,
    /// Connections refused with 503 at the concurrency cap.
    pub conns_refused: u64,
    /// Complete HTTP requests parsed.
    pub requests: u64,
    /// Responses written, by status code.
    pub by_status: BTreeMap<u16, u64>,
    /// Streams that terminated a connection (push-parser rejections and
    /// mid-body JSON rejections).
    pub parse_errors: u64,
    /// Connections the peer dropped mid-request (no response owed).
    pub early_closes: u64,
    /// Bytes read off accepted sockets.
    pub bytes_in: u64,
    /// Bytes written to accepted sockets.
    pub bytes_out: u64,
}

impl NetStats {
    /// Responses with this status.
    pub fn status(&self, code: u16) -> u64 {
        self.by_status.get(&code).copied().unwrap_or(0)
    }

    /// JSON form (the report's `net` block).
    pub fn to_json(&self) -> Json {
        let statuses: BTreeMap<String, Json> = self
            .by_status
            .iter()
            .map(|(k, v)| (k.to_string(), Json::Num(*v as f64)))
            .collect();
        Json::from_pairs(vec![
            ("connections", Json::Num(self.connections as f64)),
            ("conns_refused", Json::Num(self.conns_refused as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("by_status", Json::Obj(statuses)),
            ("parse_errors", Json::Num(self.parse_errors as f64)),
            ("early_closes", Json::Num(self.early_closes as f64)),
            ("bytes_in", Json::Num(self.bytes_in as f64)),
            ("bytes_out", Json::Num(self.bytes_out as f64)),
        ])
    }
}

/// Full `serve --listen` run summary: the engine report plus the
/// socket-edge counters.
#[derive(Debug, Clone)]
pub struct HttpReport {
    /// The engine-side serving report.
    pub engine: ServeReport,
    /// The socket-edge counters.
    pub net: NetStats,
}

impl HttpReport {
    /// The engine report's JSON with a `net` block added.
    pub fn to_json(&self) -> Json {
        let mut j = self.engine.to_json();
        j.set("net", self.net.to_json());
        j
    }
}

/// Cancel handle for a running front end (safe to clone across threads).
#[derive(Debug, Clone)]
pub struct StopHandle(Arc<AtomicBool>);

impl StopHandle {
    /// Ask the front end to stop accepting and wind down.
    pub fn stop(&self) {
        self.0.store(true, Ordering::SeqCst);
    }
}

/// A bound-but-not-yet-running HTTP front end.
#[derive(Debug)]
pub struct NetFrontend {
    listener: TcpListener,
    cfg: ListenConfig,
    stop: Arc<AtomicBool>,
}

/// State shared between the accept loop and connection threads.
struct Shared {
    cfg: ListenConfig,
    stop: Arc<AtomicBool>,
    stats: Mutex<NetStats>,
    /// Live engine-counters snapshot (`GET /metrics`), refreshed by the
    /// engine loop after every step — connection threads read it without
    /// ever touching the engine itself.
    engine: Mutex<Json>,
    responded: AtomicU64,
    active_conns: AtomicUsize,
}

/// A generate submission from a connection thread to the engine loop.
struct Submission {
    prompt: Vec<i32>,
    max_new_tokens: usize,
    temperature: f32,
    reply: mpsc::Sender<StreamEvent>,
}

/// Engine → connection stream protocol.
enum StreamEvent {
    /// Admitted with this engine request id.
    Accepted {
        /// Engine-assigned request id.
        id: u64,
    },
    /// Refused before admission.
    Rejected {
        /// `true` for backpressure (429), `false` for validation (400).
        retryable: bool,
        /// Machine-readable reason.
        reason: &'static str,
    },
    /// One generated token.
    Token(i32),
    /// The request retired.
    Done {
        /// Finish reason (`completed`, `kv_exhausted`, …).
        finish: &'static str,
        /// Total generated tokens.
        n_tokens: usize,
    },
}

static REQ_SPAN_ID: AtomicU64 = AtomicU64::new(0);

impl NetFrontend {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port).
    pub fn bind(addr: &str, cfg: ListenConfig) -> Result<NetFrontend> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("cannot bind {addr}: {e}"))?;
        Ok(NetFrontend {
            listener,
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A handle that stops the front end from another thread.
    pub fn stop_handle(&self) -> StopHandle {
        StopHandle(Arc::clone(&self.stop))
    }

    /// Serve until stopped ([`StopHandle`], or
    /// [`ListenConfig::max_requests`] responses). The engine runs on
    /// *this* thread (the backend never crosses threads); accept and
    /// connection handling run on their own threads and wind down
    /// before this returns.
    pub fn run(
        self,
        backend: &dyn Backend,
        scfg: ServerConfig,
        metrics: Option<JsonlWriter>,
    ) -> Result<HttpReport> {
        let t0 = Instant::now();
        self.listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            cfg: self.cfg.clone(),
            stop: Arc::clone(&self.stop),
            stats: Mutex::new(NetStats::default()),
            engine: Mutex::new(Json::Null),
            responded: AtomicU64::new(0),
            active_conns: AtomicUsize::new(0),
        });
        let (tx, rx) = mpsc::channel::<Submission>();
        let accept = {
            let sh = Arc::clone(&shared);
            let listener = self.listener;
            thread::spawn(move || accept_loop(listener, tx, sh))
        };
        let engine = engine_loop(backend, scfg, metrics, rx, t0, &shared);
        // Engine exit (error or drained) implies shutdown; make sure the
        // accept thread sees it and join everything.
        self.stop.store(true, Ordering::SeqCst);
        accept
            .join()
            .map_err(|_| anyhow!("accept thread panicked"))?;
        let net = lock_stats(&shared).clone();
        Ok(HttpReport {
            engine: engine?,
            net,
        })
    }
}

/// Stats access that survives a poisoned mutex (a panicking connection
/// thread must not wedge the report).
fn lock_stats(sh: &Shared) -> std::sync::MutexGuard<'_, NetStats> {
    match sh.stats.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn stat(sh: &Shared, f: impl FnOnce(&mut NetStats)) {
    f(&mut lock_stats(sh));
}

/// Engine-snapshot access with the same poison tolerance as the stats.
fn lock_engine(sh: &Shared) -> std::sync::MutexGuard<'_, Json> {
    match sh.engine.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

// ---------------------------------------------------------------------------
// Engine loop (caller thread)
// ---------------------------------------------------------------------------

struct Sink {
    tx: mpsc::Sender<StreamEvent>,
    /// Tokens already streamed.
    sent: usize,
    /// The receiving connection went away mid-stream. The engine loop
    /// cancels the request on the next pass ([`Server::cancel_request`])
    /// so its slot and KV pages drain instead of generating tokens
    /// nobody will read.
    dead: bool,
}

fn engine_loop(
    backend: &dyn Backend,
    scfg: ServerConfig,
    metrics: Option<JsonlWriter>,
    rx: mpsc::Receiver<Submission>,
    t0: Instant,
    sh: &Shared,
) -> Result<ServeReport> {
    let mut srv = Server::new(backend, scfg)?;
    if let Some(m) = metrics {
        srv.set_metrics_log(m);
    }
    *lock_engine(sh) = srv.counters_json();
    let mut sinks: BTreeMap<u64, Sink> = BTreeMap::new();
    let mut next_id: u64 = 1;
    let mut cursor = 0usize;
    let mut open = true;
    loop {
        // Drain every pending submission before stepping, so queue-full
        // rejections surface within one step of latency.
        while open {
            match rx.try_recv() {
                Ok(sub) => admit(&mut srv, sub, &mut next_id, &mut sinks),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => open = false,
            }
        }
        if srv.batcher.idle() {
            if !open {
                break;
            }
            // Idle: block briefly for the next submission.
            match rx.recv_timeout(Duration::from_millis(5)) {
                Ok(sub) => admit(&mut srv, sub, &mut next_id, &mut sinks),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
            continue;
        }
        srv.step()?;
        // Stream freshly generated tokens to live sinks.
        for rs in srv.batcher.active.iter().flatten() {
            if let Some(sink) = sinks.get_mut(&rs.req.id) {
                for &t in &rs.generated[sink.sent..] {
                    if !sink.dead && sink.tx.send(StreamEvent::Token(t)).is_err() {
                        sink.dead = true;
                    }
                }
                sink.sent = rs.generated.len();
            }
        }
        // A dead sink means the client disconnected mid-stream: cancel
        // the request so its slot and every KV page it held drain now.
        let gone: Vec<u64> = sinks
            .iter()
            .filter(|(_, s)| s.dead)
            .map(|(&id, _)| id)
            .collect();
        for id in gone {
            sinks.remove(&id);
            srv.cancel_request(id);
        }
        *lock_engine(sh) = srv.counters_json();
        // Flush requests that retired this step.
        let recs = srv.finished_since(cursor).to_vec();
        cursor += recs.len();
        for r in &recs {
            let Some(sink) = sinks.remove(&r.id) else {
                continue;
            };
            if sink.dead {
                continue;
            }
            let mut ok = true;
            for &t in r.tokens.get(sink.sent..).unwrap_or(&[]) {
                if sink.tx.send(StreamEvent::Token(t)).is_err() {
                    ok = false;
                    break;
                }
            }
            if ok {
                let _ = sink.tx.send(StreamEvent::Done {
                    finish: r.finish.as_str(),
                    n_tokens: r.tokens.len(),
                });
            }
        }
    }
    Ok(srv.report_now(t0.elapsed().as_secs_f64()))
}

fn admit(
    srv: &mut Server<'_>,
    sub: Submission,
    next_id: &mut u64,
    sinks: &mut BTreeMap<u64, Sink>,
) {
    let id = *next_id;
    let req = Request {
        id,
        prompt: sub.prompt,
        max_new_tokens: sub.max_new_tokens,
        temperature: sub.temperature,
        arrival: Instant::now(),
    };
    match srv.try_submit(req) {
        Ok(()) => {
            *next_id += 1;
            let _ = sub.reply.send(StreamEvent::Accepted { id });
            sinks.insert(
                id,
                Sink {
                    tx: sub.reply,
                    sent: 0,
                    dead: false,
                },
            );
        }
        Err(SubmitError::QueueFull) => {
            let _ = sub.reply.send(StreamEvent::Rejected {
                retryable: true,
                reason: "queue full",
            });
        }
        Err(SubmitError::Invalid(reason)) => {
            let _ = sub.reply.send(StreamEvent::Rejected {
                retryable: false,
                reason,
            });
        }
    }
}

// ---------------------------------------------------------------------------
// Accept loop + connection threads
// ---------------------------------------------------------------------------

fn accept_loop(listener: TcpListener, tx: mpsc::Sender<Submission>, sh: Arc<Shared>) {
    let mut handles: Vec<thread::JoinHandle<()>> = Vec::new();
    let mut conn_id: u64 = 0;
    while !sh.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                conn_id += 1;
                stat(&sh, |s| s.connections += 1);
                if sh.active_conns.load(Ordering::SeqCst) >= sh.cfg.max_conns {
                    refuse_at_cap(stream, &sh);
                    continue;
                }
                sh.active_conns.fetch_add(1, Ordering::SeqCst);
                let tx = tx.clone();
                let sh2 = Arc::clone(&sh);
                handles.push(thread::spawn(move || {
                    handle_conn(stream, peer, conn_id, tx, &sh2);
                    sh2.active_conns.fetch_sub(1, Ordering::SeqCst);
                }));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                handles.retain(|h| !h.is_finished());
                thread::sleep(Duration::from_millis(2));
            }
            Err(_) => thread::sleep(Duration::from_millis(2)),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    // `tx` drops here: once every connection is done, the engine's
    // receiver disconnects and the engine loop drains out.
}

fn refuse_at_cap(mut stream: TcpStream, sh: &Shared) {
    stat(sh, |s| s.conns_refused += 1);
    let body = "{\"error\":\"too many connections\"}";
    let resp = simple_response(503, body, false, &[("Retry-After", "1")]);
    let _ = stream.write_all(&resp);
    stat(sh, |s| {
        s.bytes_out += resp.len() as u64;
        *s.by_status.entry(503).or_insert(0) += 1;
    });
}

fn handle_conn(
    mut stream: TcpStream,
    peer: SocketAddr,
    conn_id: u64,
    tx: mpsc::Sender<Submission>,
    sh: &Shared,
) {
    let _ = stream.set_nodelay(true);
    // Short socket poll under the logical deadlines: each wakeup checks
    // the stop flag and whichever timeout currently applies — the
    // mid-request read deadline while request bytes are in flight, the
    // (typically much longer) idle keep-alive timeout between requests.
    let poll_ms = sh.cfg.read_timeout_ms.min(sh.cfg.idle_timeout_ms).clamp(10, 100);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(poll_ms)));
    telemetry::async_begin(
        "http_conn",
        conn_id,
        vec![("peer", ArgValue::from(peer.to_string().as_str()))],
    );
    let mut parser = PushParser::new(sh.cfg.limits);
    let mut body_check: Option<bjson::JsonPush> = None;
    let mut continue_handled = false;
    let mut served: u64 = 0;
    let mut buf = [0u8; 4096];
    let mut last_activity = Instant::now();
    'conn: loop {
        if sh.stop.load(Ordering::SeqCst) {
            break;
        }
        let n = match stream.read(&mut buf) {
            Ok(0) => {
                if parser.mid_request() {
                    stat(sh, |s| s.early_closes += 1);
                }
                break;
            }
            Ok(n) => {
                last_activity = Instant::now();
                n
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                let silent_ms = last_activity.elapsed().as_millis() as u64;
                if parser.mid_request() {
                    if silent_ms >= sh.cfg.read_timeout_ms {
                        // Read deadline fired with a request in flight.
                        write_error(&mut stream, sh, 408, "read deadline", &[]);
                        break;
                    }
                } else if silent_ms >= sh.cfg.idle_timeout_ms {
                    // Quiet keep-alive connection past its window: close
                    // silently — no response is owed.
                    break;
                }
                continue;
            }
            Err(_) => break,
        };
        stat(sh, |s| s.bytes_in += n as u64);
        if let Err(e) = parser.push(&buf[..n]) {
            reject_stream(&mut stream, sh, e);
            break;
        }
        // Interim 100 Continue once the head of an expecting request is
        // parsed and its body is still outstanding.
        if !continue_handled {
            if let Some(h) = parser.head() {
                if h.expect_continue && !parser.ready() {
                    let _ = write_counted(&mut stream, sh, b"HTTP/1.1 100 Continue\r\n\r\n");
                }
                continue_handled = true;
            }
        }
        // Incremental JSON validation while a generate body streams in:
        // cut hopeless bodies short instead of buffering Content-Length
        // bytes of garbage.
        if let Some(h) = parser.head() {
            if h.method == "POST" && h.target == "/generate" && !parser.ready() {
                let jp = body_check.get_or_insert_with(bjson::JsonPush::new);
                let fresh = parser.body_new_bytes();
                if !fresh.is_empty() && jp.feed(fresh).is_err() {
                    stat(sh, |s| s.parse_errors += 1);
                    write_error(&mut stream, sh, 400, "malformed json body", &[]);
                    break;
                }
            }
        }
        while let Some(req) = parser.take() {
            body_check = None;
            continue_handled = false;
            stat(sh, |s| s.requests += 1);
            served += 1;
            let keep = respond(&mut stream, sh, &tx, &req);
            // The idle window starts when the response finishes, not at
            // the last read — generation time must not eat into it.
            last_activity = Instant::now();
            if !keep || sh.stop.load(Ordering::SeqCst) {
                break 'conn;
            }
        }
        if let Some(e) = parser.failure() {
            // Pipelined bytes behind a completed request went bad.
            reject_stream(&mut stream, sh, e);
            break;
        }
    }
    telemetry::async_end("http_conn", conn_id, vec![("requests", ArgValue::from(served))]);
}

/// The byte stream is unsalvageable: respond with the mapped status and
/// let the caller close.
fn reject_stream(stream: &mut TcpStream, sh: &Shared, e: HttpError) {
    stat(sh, |s| s.parse_errors += 1);
    telemetry::instant(
        "http_reject",
        vec![
            ("status", ArgValue::from(e.status() as usize)),
            ("reason", ArgValue::from(e.reason())),
        ],
    );
    write_error(stream, sh, e.status(), e.reason(), &[]);
}

/// Route one parsed request; returns whether the connection may be kept
/// alive.
fn respond(
    stream: &mut TcpStream,
    sh: &Shared,
    tx: &mpsc::Sender<Submission>,
    req: &ParsedRequest,
) -> bool {
    let rid = REQ_SPAN_ID.fetch_add(1, Ordering::SeqCst) + 1;
    let head = req.head();
    telemetry::async_begin(
        "http_request",
        rid,
        vec![
            ("method", ArgValue::from(head.method.as_str())),
            ("target", ArgValue::from(head.target.as_str())),
        ],
    );
    let keep = !head.close;
    let (status, keep) = match (head.method.as_str(), head.target.as_str()) {
        ("GET", "/health") => {
            write_response(stream, sh, 200, "{\"ok\":true}", keep, &[]);
            (200, keep)
        }
        ("HEAD", "/health") => {
            // HEAD mirrors the GET headers (Content-Length included)
            // without the body (RFC 9110 §9.3.2).
            write_head_only(stream, sh, 200, "{\"ok\":true}".len(), keep, &[]);
            (200, keep)
        }
        ("OPTIONS", "/health") => {
            write_options(stream, sh, keep, "GET, HEAD, OPTIONS");
            (204, keep)
        }
        ("POST", "/generate") => respond_generate(stream, sh, tx, req, keep),
        ("OPTIONS", "/generate") => {
            write_options(stream, sh, keep, "POST, OPTIONS");
            (204, keep)
        }
        ("GET", "/metrics") => {
            let body = metrics_body(sh);
            write_response(stream, sh, 200, &body, keep, &[]);
            (200, keep)
        }
        ("HEAD", "/metrics") => {
            write_head_only(stream, sh, 200, metrics_body(sh).len(), keep, &[]);
            (200, keep)
        }
        ("OPTIONS", "/metrics") => {
            write_options(stream, sh, keep, "GET, HEAD, OPTIONS");
            (204, keep)
        }
        (_, "/metrics") => {
            write_error(
                stream,
                sh,
                405,
                "method not allowed",
                &[("Allow", "GET, HEAD, OPTIONS")],
            );
            (405, keep)
        }
        (_, "/health") => {
            write_error(
                stream,
                sh,
                405,
                "method not allowed",
                &[("Allow", "GET, HEAD, OPTIONS")],
            );
            (405, keep)
        }
        (_, "/generate") => {
            write_error(stream, sh, 405, "method not allowed", &[("Allow", "POST, OPTIONS")]);
            (405, keep)
        }
        ("HEAD", _) => {
            write_head_only(stream, sh, 404, "{\"error\":\"not found\"}".len(), keep, &[]);
            (404, keep)
        }
        _ => {
            write_error(stream, sh, 404, "not found", &[]);
            (404, keep)
        }
    };
    telemetry::async_end(
        "http_request",
        rid,
        vec![("status", ArgValue::from(status as usize))],
    );
    keep
}

/// The `GET /metrics` body: live socket-edge counters plus the engine
/// loop's latest counters snapshot, as one JSON document.
fn metrics_body(sh: &Shared) -> String {
    let net = lock_stats(sh).to_json();
    let engine = lock_engine(sh).clone();
    Json::from_pairs(vec![("net", net), ("engine", engine)]).to_string()
}

/// Validated generate parameters extracted from the JSON body.
struct GenParams {
    prompt: Vec<i32>,
    max_new_tokens: usize,
    temperature: f32,
    stream: bool,
}

fn extract_generate(v: &bjson::Value<'_>) -> Result<GenParams, &'static str> {
    let bjson::Value::Obj(pairs) = v else {
        return Err("body must be a json object");
    };
    let mut prompt: Option<Vec<i32>> = None;
    let mut text: Option<Vec<i32>> = None;
    let mut max_new_tokens = 16usize;
    let mut temperature = 0.0f32;
    let mut stream = false;
    for (key, val) in pairs {
        match key.as_ref() {
            "prompt" => {
                let arr = val.as_arr().ok_or("prompt must be an array of token ids")?;
                let mut toks = Vec::with_capacity(arr.len());
                for t in arr {
                    let f = t.as_f64().ok_or("prompt tokens must be integers")?;
                    if f.fract() != 0.0 || !(-2147483648.0..=2147483647.0).contains(&f) {
                        return Err("prompt tokens must be integers");
                    }
                    toks.push(f as i32);
                }
                prompt = Some(toks);
            }
            "text" => {
                // Byte-level tokenization: presets use a 256-way vocab,
                // so raw bytes are the token ids.
                let s = val.as_str().ok_or("text must be a string")?;
                text = Some(s.bytes().map(i32::from).collect());
            }
            "max_new_tokens" => {
                let f = val.as_f64().ok_or("max_new_tokens must be an integer")?;
                if f.fract() != 0.0 || !(0.0..=1e9).contains(&f) {
                    return Err("max_new_tokens must be an integer");
                }
                max_new_tokens = f as usize;
            }
            "temperature" => {
                let f = val.as_f64().ok_or("temperature must be a number")?;
                if !f.is_finite() || f < 0.0 {
                    return Err("temperature must be finite and non-negative");
                }
                temperature = f as f32;
            }
            "stream" => {
                stream = val.as_bool().ok_or("stream must be a boolean")?;
            }
            _ => return Err("unknown field"),
        }
    }
    let prompt = match (prompt, text) {
        (Some(_), Some(_)) => return Err("prompt and text are mutually exclusive"),
        (Some(p), None) => p,
        (None, Some(t)) => t,
        (None, None) => return Err("missing prompt"),
    };
    Ok(GenParams {
        prompt,
        max_new_tokens,
        temperature,
        stream,
    })
}

fn respond_generate(
    stream: &mut TcpStream,
    sh: &Shared,
    tx: &mpsc::Sender<Submission>,
    req: &ParsedRequest,
    keep: bool,
) -> (u16, bool) {
    let parsed = match bjson::parse(req.body()) {
        Ok(v) => v,
        Err(_) => {
            write_error(stream, sh, 400, "malformed json body", &[]);
            return (400, keep);
        }
    };
    let params = match extract_generate(&parsed) {
        Ok(p) => p,
        Err(msg) => {
            write_error(stream, sh, 400, msg, &[]);
            return (400, keep);
        }
    };
    // Chunked streaming needs HTTP/1.1; 1.0 clients get the buffered form.
    let stream_mode = params.stream && req.head().http11;
    let (etx, erx) = mpsc::channel();
    let sent = tx.send(Submission {
        prompt: params.prompt,
        max_new_tokens: params.max_new_tokens,
        temperature: params.temperature,
        reply: etx,
    });
    if sent.is_err() {
        write_error(stream, sh, 500, "engine unavailable", &[]);
        return (500, false);
    }
    let deadline = Duration::from_millis(sh.cfg.stream_timeout_ms);
    let id = match erx.recv_timeout(deadline) {
        Ok(StreamEvent::Accepted { id }) => id,
        Ok(StreamEvent::Rejected { retryable: true, reason }) => {
            telemetry::instant("http_reject", vec![("reason", ArgValue::from(reason))]);
            let body = format!("{{\"error\":\"{reason}\"}}");
            write_response(stream, sh, 429, &body, keep, &[("Retry-After", "1")]);
            return (429, keep);
        }
        Ok(StreamEvent::Rejected { retryable: false, reason }) => {
            write_error(stream, sh, 400, reason, &[]);
            return (400, keep);
        }
        Ok(_) => {
            write_error(stream, sh, 500, "engine protocol error", &[]);
            return (500, false);
        }
        Err(_) => {
            write_error(stream, sh, 503, "engine stalled", &[]);
            return (503, false);
        }
    };
    if stream_mode {
        stream_tokens(stream, sh, &erx, id, keep, deadline)
    } else {
        collect_tokens(stream, sh, &erx, id, keep, deadline)
    }
}

/// Chunked ndjson streaming: one row per token, a final `done` row, then
/// the zero-length terminator chunk.
fn stream_tokens(
    stream: &mut TcpStream,
    sh: &Shared,
    erx: &mpsc::Receiver<StreamEvent>,
    id: u64,
    keep: bool,
    deadline: Duration,
) -> (u16, bool) {
    let conn = if keep { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\r\nTransfer-Encoding: chunked\r\nConnection: {conn}\r\n\r\n"
    );
    note_response(sh, 200);
    if write_counted(stream, sh, head.as_bytes()).is_err() {
        return (200, false);
    }
    loop {
        match erx.recv_timeout(deadline) {
            Ok(StreamEvent::Token(t)) => {
                let row = format!("{{\"token\":{t}}}\n");
                if write_chunk(stream, sh, row.as_bytes()).is_err() {
                    return (200, false);
                }
            }
            Ok(StreamEvent::Done { finish, n_tokens }) => {
                let row = format!(
                    "{{\"done\":true,\"id\":{id},\"finish\":\"{finish}\",\"n_tokens\":{n_tokens}}}\n"
                );
                let ok = write_chunk(stream, sh, row.as_bytes()).is_ok()
                    && write_counted(stream, sh, b"0\r\n\r\n").is_ok();
                return (200, keep && ok);
            }
            Ok(_) => return (200, false),
            Err(_) => {
                // Engine stalled or died mid-stream: terminate the chunk
                // stream so the client sees a clean (if short) end.
                let _ = write_counted(stream, sh, b"0\r\n\r\n");
                return (200, false);
            }
        }
    }
}

/// Buffered (non-streaming) response: collect every token, answer once.
fn collect_tokens(
    stream: &mut TcpStream,
    sh: &Shared,
    erx: &mpsc::Receiver<StreamEvent>,
    id: u64,
    keep: bool,
    deadline: Duration,
) -> (u16, bool) {
    let mut tokens: Vec<i32> = Vec::new();
    loop {
        match erx.recv_timeout(deadline) {
            Ok(StreamEvent::Token(t)) => tokens.push(t),
            Ok(StreamEvent::Done { finish, .. }) => {
                let toks = tokens
                    .iter()
                    .map(|t| t.to_string())
                    .collect::<Vec<_>>()
                    .join(",");
                let body = format!(
                    "{{\"id\":{id},\"finish\":\"{finish}\",\"n_tokens\":{},\"tokens\":[{toks}]}}",
                    tokens.len()
                );
                write_response(stream, sh, 200, &body, keep, &[]);
                return (200, keep);
            }
            Ok(_) => {
                write_error(stream, sh, 500, "engine protocol error", &[]);
                return (500, false);
            }
            Err(_) => {
                write_error(stream, sh, 503, "generation timed out", &[]);
                return (503, false);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Response plumbing
// ---------------------------------------------------------------------------

fn http_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        204 => "No Content",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Response",
    }
}

/// Status line + headers of a sized JSON response — shared by the full
/// form and the `HEAD` headers-only form. No `Date` header by design
/// (see module docs).
fn response_head(status: u16, body_len: usize, keep: bool, extra: &[(&str, &str)]) -> String {
    let mut head = format!("HTTP/1.1 {} {}\r\n", status, http_reason(status));
    head.push_str("Content-Type: application/json\r\n");
    for (name, value) in extra {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str(&format!("Content-Length: {body_len}\r\n"));
    let conn = if keep { "keep-alive" } else { "close" };
    head.push_str(&format!("Connection: {conn}\r\n\r\n"));
    head
}

/// A sized JSON response.
fn simple_response(status: u16, body: &str, keep: bool, extra: &[(&str, &str)]) -> Vec<u8> {
    let mut out = response_head(status, body.len(), keep, extra).into_bytes();
    out.extend_from_slice(body.as_bytes());
    out
}

/// Headers-only response for `HEAD`: identical status line and headers
/// (`Content-Length` describing the body the `GET` form would carry),
/// no body bytes on the wire.
fn write_head_only(
    stream: &mut TcpStream,
    sh: &Shared,
    status: u16,
    body_len: usize,
    keep: bool,
    extra: &[(&str, &str)],
) {
    note_response(sh, status);
    let bytes = response_head(status, body_len, keep, extra).into_bytes();
    let _ = write_counted(stream, sh, &bytes);
}

/// `OPTIONS` answer: 204 No Content plus the target's `Allow` set.
fn write_options(stream: &mut TcpStream, sh: &Shared, keep: bool, allow: &str) {
    note_response(sh, 204);
    let conn = if keep { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 204 No Content\r\nAllow: {allow}\r\nContent-Length: 0\r\nConnection: {conn}\r\n\r\n"
    );
    let _ = write_counted(stream, sh, head.as_bytes());
}

/// Count a response toward the stats and the `max_requests` stop bound.
fn note_response(sh: &Shared, status: u16) {
    stat(sh, |s| *s.by_status.entry(status).or_insert(0) += 1);
    let count = sh.responded.fetch_add(1, Ordering::SeqCst) + 1;
    if sh.cfg.max_requests > 0 && count >= sh.cfg.max_requests {
        sh.stop.store(true, Ordering::SeqCst);
    }
}

fn write_response(
    stream: &mut TcpStream,
    sh: &Shared,
    status: u16,
    body: &str,
    keep: bool,
    extra: &[(&str, &str)],
) {
    note_response(sh, status);
    let bytes = simple_response(status, body, keep, extra);
    let _ = write_counted(stream, sh, &bytes);
}

/// An error response with a `{"error": …}` body; connection policy is
/// the caller's call.
fn write_error(
    stream: &mut TcpStream,
    sh: &Shared,
    status: u16,
    msg: &str,
    extra: &[(&str, &str)],
) {
    let body = format!("{{\"error\":\"{msg}\"}}");
    // Error paths close the connection except pure routing errors, which
    // keep framing intact; the caller decides by its return value — the
    // wire header always says close only when the caller will close.
    let keep = matches!(status, 400 | 404 | 405 | 429);
    note_response(sh, status);
    let bytes = simple_response(status, &body, keep, extra);
    let _ = write_counted(stream, sh, &bytes);
}

fn write_counted(stream: &mut TcpStream, sh: &Shared, bytes: &[u8]) -> std::io::Result<()> {
    stream.write_all(bytes)?;
    stat(sh, |s| s.bytes_out += bytes.len() as u64);
    Ok(())
}

fn write_chunk(stream: &mut TcpStream, sh: &Shared, payload: &[u8]) -> std::io::Result<()> {
    let framed = format!("{:x}\r\n", payload.len());
    let mut out = framed.into_bytes();
    out.extend_from_slice(payload);
    out.extend_from_slice(b"\r\n");
    write_counted(stream, sh, &out)
}
