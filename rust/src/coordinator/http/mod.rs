//! Zero-dependency HTTP/1.1 serving front end.
//!
//! Everything here is built on `std::net` — no external crates — and
//! splits into five pieces:
//!
//! * [`parser`] — the incremental **push parser** for request heads:
//!   resumable at any byte boundary, strict CRLF framing, per-connection
//!   limits, zero-copy body handoff.
//! * [`bjson`] — the strict JSON machines: a borrowing tree parser
//!   ([`bjson::parse`], `Cow` strings when escape-free) and a
//!   byte-at-a-time validator ([`bjson::JsonPush`]) that accept exactly
//!   the same documents.
//! * [`frontend`] — the socket front end behind `serve --listen`:
//!   accept/connection threads, chunked token streaming, engine
//!   backpressure mapped to HTTP statuses (429 on queue-full, …).
//! * [`client`] — a minimal blocking client used by the perf load-test
//!   scenario and the integration tests.
//! * [`torture`] — the differential split-invariance oracles shared by
//!   the tests and the `dtrnet-fuzz` fuzzers.

pub mod bjson;
pub mod client;
pub mod frontend;
pub mod parser;
pub mod torture;

pub use client::{generate_request, get_request, ClientResponse, HttpClient};
pub use frontend::{HttpReport, ListenConfig, NetFrontend, NetStats, StopHandle};
pub use parser::{Head, HttpError, Limits, ParsedRequest, PushParser};
