//! Incremental (push) HTTP/1.1 request parser.
//!
//! The connection loop feeds raw socket reads into [`PushParser::push`];
//! the parser is resumable at **any** byte boundary — head and body both
//! — and its outcome (parsed requests, terminal error, leftover bytes)
//! is invariant under the segmentation, which `tests/http_parser.rs`
//! pins at every split point and the fuzz suite hammers with random
//! splits.
//!
//! Zero-copy body handoff: the parser owns one contiguous buffer per
//! in-flight request. When a request completes, [`PushParser::take`]
//! detaches that buffer wholesale (`split_off` keeps any pipelined bytes
//! for the next request) and [`ParsedRequest::body`] is a slice into it —
//! body bytes are never copied between the socket read and the JSON
//! parse ([`super::bjson`]).
//!
//! Framing is strict (DESIGN.md §Network front end): CRLF line endings
//! only, token header names (which also rejects obs-fold continuations),
//! single-value `Content-Length`, no request `Transfer-Encoding`. Every
//! rejection maps to a definite status via [`HttpError::status`].

/// Per-connection parse limits.
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    /// Request-head bound in bytes (request line + headers + terminator).
    pub max_head_bytes: usize,
    /// Body bound, enforced against `Content-Length` before any body
    /// byte arrives (an oversized declaration is refused up front).
    pub max_body_bytes: usize,
    /// Header-count bound (header bombs → 431).
    pub max_headers: usize,
}

impl Default for Limits {
    fn default() -> Limits {
        Limits {
            max_head_bytes: 16 * 1024,
            max_body_bytes: 256 * 1024,
            max_headers: 64,
        }
    }
}

/// Terminal parse failures, each with a definite HTTP status.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HttpError {
    /// Request line is not `METHOD SP TARGET SP HTTP/1.x`.
    BadRequestLine,
    /// A line ended with a bare LF, or a stray CR appeared mid-line.
    BadLineEnding,
    /// Header line is not `token ":" value` with printable value bytes.
    BadHeader,
    /// `Content-Length` is non-numeric, overlong, or conflicting.
    BadContentLength,
    /// Head grew past [`Limits::max_head_bytes`].
    HeadTooLarge,
    /// More than [`Limits::max_headers`] header lines.
    TooManyHeaders,
    /// Declared `Content-Length` exceeds [`Limits::max_body_bytes`].
    BodyTooLarge,
    /// Body-bearing method without a `Content-Length`.
    LengthRequired,
    /// `Transfer-Encoding` on a request (this server never accepts
    /// chunked *requests*; responses are chunked, requests are sized).
    UnsupportedTransferEncoding,
    /// HTTP version other than 1.0 / 1.1.
    UnsupportedVersion,
}

impl HttpError {
    /// The response status this failure maps to.
    pub fn status(self) -> u16 {
        match self {
            HttpError::BadRequestLine
            | HttpError::BadLineEnding
            | HttpError::BadHeader
            | HttpError::BadContentLength => 400,
            HttpError::HeadTooLarge | HttpError::TooManyHeaders => 431,
            HttpError::BodyTooLarge => 413,
            HttpError::LengthRequired => 411,
            HttpError::UnsupportedTransferEncoding => 501,
            HttpError::UnsupportedVersion => 505,
        }
    }

    /// Short machine-readable name (error-body payload).
    pub fn reason(self) -> &'static str {
        match self {
            HttpError::BadRequestLine => "bad request line",
            HttpError::BadLineEnding => "bad line ending",
            HttpError::BadHeader => "bad header",
            HttpError::BadContentLength => "bad content-length",
            HttpError::HeadTooLarge => "request head too large",
            HttpError::TooManyHeaders => "too many headers",
            HttpError::BodyTooLarge => "body too large",
            HttpError::LengthRequired => "length required",
            HttpError::UnsupportedTransferEncoding => "transfer-encoding not supported",
            HttpError::UnsupportedVersion => "http version not supported",
        }
    }
}

/// Parsed request head (everything before the body).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Head {
    /// Method, verbatim (`GET`, `POST`, …).
    pub method: String,
    /// Request target (origin-form path), verbatim.
    pub target: String,
    /// `true` for HTTP/1.1, `false` for HTTP/1.0.
    pub http11: bool,
    /// `(name, value)` pairs in arrival order, names verbatim.
    pub headers: Vec<(String, String)>,
    /// Declared body length (0 when no `Content-Length` was sent).
    pub content_length: usize,
    /// Client sent `Expect: 100-continue` and wants an interim response
    /// before transmitting the body.
    pub expect_continue: bool,
    /// The connection must close after this response (`Connection:
    /// close`, or HTTP/1.0 without `keep-alive`).
    pub close: bool,
}

impl Head {
    /// First value of header `name`, ASCII-case-insensitively.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// A complete request detached from the connection buffer. Owns exactly
/// its own bytes (head + body); the body accessor is a zero-copy slice.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParsedRequest {
    head: Head,
    buf: Vec<u8>,
    body_start: usize,
}

impl ParsedRequest {
    /// The parsed head.
    pub fn head(&self) -> &Head {
        &self.head
    }

    /// The body bytes (borrowed from the request's own buffer).
    pub fn body(&self) -> &[u8] {
        &self.buf[self.body_start..]
    }

    /// The raw request bytes, head included (torture tests compare these
    /// bitwise across read segmentations).
    pub fn raw(&self) -> &[u8] {
        &self.buf
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum State {
    /// Scanning the request line.
    RequestLine,
    /// Scanning header lines.
    Headers,
    /// Head parsed; waiting for `content_length` body bytes.
    Body,
    /// A full request is buffered; `take()` will detach it.
    Ready,
    /// Terminal failure (sticky).
    Failed(HttpError),
}

/// The incremental request parser; one per connection.
#[derive(Debug)]
pub struct PushParser {
    limits: Limits,
    /// Bytes of the *current* request (compacted on `take`), plus any
    /// already-received pipelined bytes beyond it.
    buf: Vec<u8>,
    /// Scan cursor: first byte not yet examined for a line terminator.
    scan: usize,
    /// Start of the line currently being scanned.
    line_start: usize,
    state: State,
    head: Option<Head>,
    /// Byte length of the head (through the blank line) once parsed.
    head_len: usize,
    /// Body bytes already handed out via [`PushParser::body_new_bytes`].
    body_seen: usize,
    headers_parsed: usize,
}

impl PushParser {
    /// A fresh parser with `limits`.
    pub fn new(limits: Limits) -> PushParser {
        PushParser {
            limits,
            buf: Vec::new(),
            scan: 0,
            line_start: 0,
            state: State::RequestLine,
            head: None,
            head_len: 0,
            body_seen: 0,
            headers_parsed: 0,
        }
    }

    /// Feed the next socket read. Errors are sticky: once a connection's
    /// byte stream is bad, it stays bad (the caller responds and closes).
    pub fn push(&mut self, bytes: &[u8]) -> Result<(), HttpError> {
        if let State::Failed(e) = self.state {
            return Err(e);
        }
        self.buf.extend_from_slice(bytes);
        self.process()
    }

    /// A complete request is buffered and `take()` will return it.
    pub fn ready(&self) -> bool {
        self.state == State::Ready
    }

    /// The sticky failure, if the stream went bad (possibly while
    /// resuming on pipelined bytes inside `take()`).
    pub fn failure(&self) -> Option<HttpError> {
        match self.state {
            State::Failed(e) => Some(e),
            _ => None,
        }
    }

    /// The parsed head of the in-flight request, available as soon as the
    /// blank line arrives (used for `Expect: 100-continue` and for
    /// incremental body validation while the body is still arriving).
    pub fn head(&self) -> Option<&Head> {
        self.head.as_ref()
    }

    /// Bytes currently buffered (torture outcome: leftover accounting).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// A request (or part of one) is in flight — a read deadline firing
    /// now warrants a 408 rather than a silent idle close.
    pub fn mid_request(&self) -> bool {
        !self.buf.is_empty() || self.state != State::RequestLine
    }

    /// Body bytes that arrived since the last call (and were not yet
    /// handed out), for incremental JSON validation during reads.
    /// Empty while the head is still being parsed.
    pub fn body_new_bytes(&mut self) -> &[u8] {
        let (avail, start) = match self.state {
            State::Body | State::Ready => {
                let head = self.head.as_ref().expect("body state has a head");
                let have = self.buf.len() - self.head_len;
                (have.min(head.content_length), self.head_len)
            }
            _ => return &[],
        };
        let from = self.body_seen;
        self.body_seen = avail;
        &self.buf[start + from..start + avail]
    }

    /// Detach the completed request, then resume parsing any pipelined
    /// bytes that arrived behind it (check [`PushParser::ready`] /
    /// [`PushParser::failure`] afterwards).
    pub fn take(&mut self) -> Option<ParsedRequest> {
        if self.state != State::Ready {
            return None;
        }
        let head = self.head.take().expect("ready state has a head");
        let total = self.head_len + head.content_length;
        let rest = self.buf.split_off(total);
        let reqbuf = std::mem::replace(&mut self.buf, rest);
        let req = ParsedRequest {
            head,
            buf: reqbuf,
            body_start: self.head_len,
        };
        self.state = State::RequestLine;
        self.scan = 0;
        self.line_start = 0;
        self.head_len = 0;
        self.body_seen = 0;
        self.headers_parsed = 0;
        // Resume on the pipelined remainder; a failure becomes sticky and
        // surfaces through `failure()` / the next `push`.
        let _ = self.process();
        Some(req)
    }

    fn fail(&mut self, e: HttpError) -> Result<(), HttpError> {
        self.state = State::Failed(e);
        Err(e)
    }

    fn process(&mut self) -> Result<(), HttpError> {
        loop {
            match self.state {
                State::RequestLine | State::Headers => {
                    while self.scan < self.buf.len() && self.buf[self.scan] != b'\n' {
                        self.scan += 1;
                    }
                    if self.scan > self.limits.max_head_bytes {
                        return self.fail(HttpError::HeadTooLarge);
                    }
                    if self.scan >= self.buf.len() {
                        return Ok(()); // incomplete line: wait for more
                    }
                    // Line terminator found; strict CRLF framing.
                    let line = &self.buf[self.line_start..self.scan];
                    if line.last() != Some(&b'\r') {
                        return self.fail(HttpError::BadLineEnding);
                    }
                    let content = &line[..line.len() - 1];
                    if content.contains(&b'\r') {
                        return self.fail(HttpError::BadLineEnding);
                    }
                    let content = content.to_vec();
                    self.scan += 1;
                    self.line_start = self.scan;
                    if self.state == State::RequestLine {
                        let head = match parse_request_line(&content) {
                            Ok(h) => h,
                            Err(e) => return self.fail(e),
                        };
                        self.head = Some(head);
                        self.state = State::Headers;
                    } else if content.is_empty() {
                        // Blank line: head complete.
                        self.head_len = self.scan;
                        let head = self.head.as_mut().expect("headers state has a head");
                        if let Err(e) = finalize_head(head, &self.limits) {
                            return self.fail(e);
                        }
                        self.state = State::Body;
                    } else {
                        if self.headers_parsed >= self.limits.max_headers {
                            return self.fail(HttpError::TooManyHeaders);
                        }
                        let head = self.head.as_mut().expect("headers state has a head");
                        if let Err(e) = parse_header_line(&content, head) {
                            return self.fail(e);
                        }
                        self.headers_parsed += 1;
                    }
                }
                State::Body => {
                    let want = self.head.as_ref().expect("body state has a head").content_length;
                    if self.buf.len() - self.head_len >= want {
                        self.state = State::Ready;
                    }
                    return Ok(());
                }
                // Parsing pauses until `take()` detaches the request;
                // pipelined bytes simply accumulate behind it.
                State::Ready => return Ok(()),
                State::Failed(e) => return Err(e),
            }
        }
    }
}

/// RFC 7230 token byte (header names, methods).
fn is_tchar(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b"!#$%&'*+-.^_`|~".contains(&b)
}

fn parse_request_line(line: &[u8]) -> Result<Head, HttpError> {
    let mut parts = line.split(|&b| b == b' ');
    let method = parts.next().unwrap_or(b"");
    let target = parts.next().ok_or(HttpError::BadRequestLine)?;
    let version = parts.next().ok_or(HttpError::BadRequestLine)?;
    if parts.next().is_some() {
        return Err(HttpError::BadRequestLine);
    }
    if method.is_empty() || !method.iter().all(|&b| is_tchar(b)) {
        return Err(HttpError::BadRequestLine);
    }
    if target.is_empty() || !target.iter().all(|&b| (0x21..=0x7E).contains(&b)) {
        return Err(HttpError::BadRequestLine);
    }
    let http11 = match version {
        b"HTTP/1.1" => true,
        b"HTTP/1.0" => false,
        v if v.starts_with(b"HTTP/") => return Err(HttpError::UnsupportedVersion),
        _ => return Err(HttpError::BadRequestLine),
    };
    Ok(Head {
        method: String::from_utf8(method.to_vec()).expect("tchars are ascii"),
        target: String::from_utf8(target.to_vec()).expect("visible ascii"),
        http11,
        headers: Vec::new(),
        content_length: 0,
        expect_continue: false,
        close: !http11, // refined by finalize_head from Connection
    })
}

fn parse_header_line(line: &[u8], head: &mut Head) -> Result<(), HttpError> {
    let colon = line
        .iter()
        .position(|&b| b == b':')
        .ok_or(HttpError::BadHeader)?;
    let name = &line[..colon];
    // Token-only names also reject obs-fold: a folded continuation line
    // starts with SP/HTAB, which is not a tchar.
    if name.is_empty() || !name.iter().all(|&b| is_tchar(b)) {
        return Err(HttpError::BadHeader);
    }
    let mut value = &line[colon + 1..];
    while value.first() == Some(&b' ') || value.first() == Some(&b'\t') {
        value = &value[1..];
    }
    while value.last() == Some(&b' ') || value.last() == Some(&b'\t') {
        value = &value[..value.len() - 1];
    }
    if !value.iter().all(|&b| b == b'\t' || (0x20..=0x7E).contains(&b)) {
        return Err(HttpError::BadHeader);
    }
    head.headers.push((
        String::from_utf8(name.to_vec()).expect("tchars are ascii"),
        String::from_utf8(value.to_vec()).expect("printable ascii"),
    ));
    Ok(())
}

/// Resolve framing once the blank line arrives: Content-Length,
/// Transfer-Encoding rejection, Expect, Connection semantics, and the
/// up-front body-size check.
fn finalize_head(head: &mut Head, limits: &Limits) -> Result<(), HttpError> {
    let mut content_length: Option<usize> = None;
    for (name, value) in &head.headers {
        if name.eq_ignore_ascii_case("transfer-encoding") {
            return Err(HttpError::UnsupportedTransferEncoding);
        }
        if name.eq_ignore_ascii_case("content-length") {
            if value.is_empty() || value.len() > 18 {
                return Err(HttpError::BadContentLength);
            }
            if !value.bytes().all(|b| b.is_ascii_digit()) {
                return Err(HttpError::BadContentLength);
            }
            let n: usize = value.parse().map_err(|_| HttpError::BadContentLength)?;
            // Duplicate Content-Length headers must agree (RFC 7230 §3.3.2).
            if content_length.is_some_and(|prev| prev != n) {
                return Err(HttpError::BadContentLength);
            }
            content_length = Some(n);
        }
        if name.eq_ignore_ascii_case("expect") && value.eq_ignore_ascii_case("100-continue") {
            head.expect_continue = true;
        }
        if name.eq_ignore_ascii_case("connection") {
            if value.eq_ignore_ascii_case("close") {
                head.close = true;
            } else if value.eq_ignore_ascii_case("keep-alive") {
                head.close = false;
            }
        }
    }
    match content_length {
        Some(n) if n > limits.max_body_bytes => return Err(HttpError::BodyTooLarge),
        Some(n) => head.content_length = n,
        None => {
            if matches!(head.method.as_str(), "POST" | "PUT" | "PATCH") {
                return Err(HttpError::LengthRequired);
            }
            head.content_length = 0;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_shot(data: &[u8]) -> (Vec<ParsedRequest>, Option<HttpError>) {
        let mut p = PushParser::new(Limits::default());
        let mut reqs = Vec::new();
        let err = p.push(data).err();
        while let Some(r) = p.take() {
            reqs.push(r);
        }
        (reqs, err.or_else(|| p.failure()))
    }

    #[test]
    fn parses_a_simple_get() {
        let (reqs, err) = one_shot(b"GET /health HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(err, None);
        assert_eq!(reqs.len(), 1);
        let h = reqs[0].head();
        assert_eq!(h.method, "GET");
        assert_eq!(h.target, "/health");
        assert!(h.http11);
        assert!(!h.close);
        assert_eq!(h.header("host"), Some("x"));
        assert!(reqs[0].body().is_empty());
    }

    #[test]
    fn parses_a_post_with_body_and_pipelined_get() {
        let data =
            b"POST /generate HTTP/1.1\r\nContent-Length: 5\r\n\r\nhelloGET / HTTP/1.1\r\n\r\n";
        let (reqs, err) = one_shot(data);
        assert_eq!(err, None);
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].body(), b"hello");
        assert_eq!(reqs[1].head().method, "GET");
        assert!(reqs[1].body().is_empty());
    }

    #[test]
    fn error_mapping() {
        let cases: Vec<(&[u8], HttpError)> = vec![
            (b"GET\r\n\r\n", HttpError::BadRequestLine),
            (b"GET / HTTP/2.0\r\n\r\n", HttpError::UnsupportedVersion),
            (b"GET / HTTP/1.1\nHost: x\r\n\r\n", HttpError::BadLineEnding),
            (b"GET / HTTP/1.1\r\nBad Header: x\r\n\r\n", HttpError::BadHeader),
            (
                b"POST / HTTP/1.1\r\nContent-Length: zzz\r\n\r\n",
                HttpError::BadContentLength,
            ),
            (
                b"POST / HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 6\r\n\r\n",
                HttpError::BadContentLength,
            ),
            (
                b"POST / HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n",
                HttpError::BodyTooLarge,
            ),
            (b"POST / HTTP/1.1\r\nHost: x\r\n\r\n", HttpError::LengthRequired),
            (
                b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
                HttpError::UnsupportedTransferEncoding,
            ),
        ];
        for (data, want) in cases {
            let (_, err) = one_shot(data);
            assert_eq!(err, Some(want), "input {:?}", String::from_utf8_lossy(data));
        }
    }

    #[test]
    fn head_limit_trips_without_a_newline() {
        let mut p = PushParser::new(Limits {
            max_head_bytes: 64,
            ..Limits::default()
        });
        let junk = vec![b'a'; 100];
        assert_eq!(p.push(&junk), Err(HttpError::HeadTooLarge));
    }

    #[test]
    fn header_bomb_trips_the_count_limit() {
        let mut p = PushParser::new(Limits {
            max_headers: 4,
            ..Limits::default()
        });
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..6 {
            req.extend_from_slice(format!("H{i}: v\r\n").as_bytes());
        }
        req.extend_from_slice(b"\r\n");
        assert_eq!(p.push(&req), Err(HttpError::TooManyHeaders));
    }

    #[test]
    fn body_new_bytes_is_incremental_and_complete() {
        let data = b"POST / HTTP/1.1\r\nContent-Length: 8\r\n\r\nabcdefgh";
        let mut p = PushParser::new(Limits::default());
        let mut seen = Vec::new();
        for &b in data.iter() {
            p.push(&[b]).unwrap();
            seen.extend_from_slice(p.body_new_bytes());
        }
        assert_eq!(seen, b"abcdefgh");
        assert!(p.ready());
    }
}
