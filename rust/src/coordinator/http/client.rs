//! Minimal blocking HTTP/1.1 client for the perf harness and the
//! socket-level integration tests (zero-dependency like the server).
//!
//! Supports exactly what the front end emits: `Content-Length` bodies
//! and `Transfer-Encoding: chunked` streams. Chunk arrival times are
//! recorded relative to the request send, which is how the load-test
//! scenario measures client-side TTFT / time-to-last-token. Reads are
//! buffered byte-exactly, so one connection can read back-to-back
//! (keep-alive / pipelined) responses without over-consuming.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

/// A fully received response.
#[derive(Debug, Clone)]
pub struct ClientResponse {
    /// Status code from the status line.
    pub status: u16,
    /// Header `(name, value)` pairs in arrival order.
    pub headers: Vec<(String, String)>,
    /// Body bytes (chunked framing already decoded).
    pub body: Vec<u8>,
    /// The response used chunked transfer encoding.
    pub chunked: bool,
    /// Per-chunk arrival offsets in ms, measured from the last `send`
    /// (first entry = client-side TTFT for streamed generations).
    pub chunk_ms: Vec<f64>,
    /// Raw undecoded response bytes (bitwise-equality torture tests).
    pub raw: Vec<u8>,
}

impl ClientResponse {
    /// First value of header `name`, ASCII-case-insensitively.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// A buffered client connection.
#[derive(Debug)]
pub struct HttpClient {
    stream: TcpStream,
    buf: Vec<u8>,
    pos: usize,
    sent_at: Instant,
}

impl HttpClient {
    /// Connect to `addr`; `timeout` bounds connect and every read.
    pub fn connect(addr: SocketAddr, timeout: Duration) -> std::io::Result<HttpClient> {
        let stream = TcpStream::connect_timeout(&addr, timeout)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_nodelay(true)?;
        Ok(HttpClient {
            stream,
            buf: Vec::new(),
            pos: 0,
            sent_at: Instant::now(),
        })
    }

    /// Write raw request bytes and stamp the send instant.
    pub fn send(&mut self, request: &[u8]) -> std::io::Result<()> {
        self.stream.write_all(request)?;
        self.sent_at = Instant::now();
        Ok(())
    }

    /// Raw stream access (torture tests dribble partial writes).
    pub fn stream(&mut self) -> &mut TcpStream {
        &mut self.stream
    }

    /// Read exactly one response (head + framed body).
    pub fn read_response(&mut self) -> std::io::Result<ClientResponse> {
        let raw_start = self.pos;
        let status_line = self.take_line()?;
        let status = parse_status_line(&status_line)?;
        let mut headers = Vec::new();
        loop {
            let line = self.take_line()?;
            if line.is_empty() {
                break;
            }
            if let Some(colon) = line.iter().position(|&b| b == b':') {
                let name = String::from_utf8_lossy(&line[..colon]).into_owned();
                let value = String::from_utf8_lossy(&line[colon + 1..])
                    .trim()
                    .to_string();
                headers.push((name, value));
            }
        }
        let te_chunked = headers
            .iter()
            .any(|(n, v)| n.eq_ignore_ascii_case("transfer-encoding") && v.contains("chunked"));
        let mut body = Vec::new();
        let mut chunk_ms = Vec::new();
        if te_chunked {
            loop {
                let size_line = self.take_line()?;
                let size = usize::from_str_radix(
                    std::str::from_utf8(&size_line)
                        .map_err(|_| bad_data("chunk size not utf-8"))?
                        .trim(),
                    16,
                )
                .map_err(|_| bad_data("bad chunk size"))?;
                if size == 0 {
                    let crlf = self.take_line()?;
                    if !crlf.is_empty() {
                        return Err(bad_data("bad chunk terminator"));
                    }
                    break;
                }
                let payload = self.take_n(size)?;
                chunk_ms.push(self.sent_at.elapsed().as_secs_f64() * 1e3);
                body.extend_from_slice(&payload);
                let crlf = self.take_n(2)?;
                if crlf != b"\r\n" {
                    return Err(bad_data("chunk not CRLF-terminated"));
                }
            }
        } else {
            let len = headers
                .iter()
                .find(|(n, _)| n.eq_ignore_ascii_case("content-length"))
                .and_then(|(_, v)| v.parse::<usize>().ok())
                .unwrap_or(0);
            body = self.take_n(len)?;
        }
        let raw = self.buf[raw_start..self.pos].to_vec();
        // Drop consumed bytes so long-lived connections don't grow the
        // buffer without bound.
        self.buf.drain(..self.pos);
        self.pos = 0;
        Ok(ClientResponse {
            status,
            headers,
            body,
            chunked: te_chunked,
            chunk_ms,
            raw,
        })
    }

    /// One full round trip on this connection.
    pub fn roundtrip(&mut self, request: &[u8]) -> std::io::Result<ClientResponse> {
        self.send(request)?;
        self.read_response()
    }

    fn fill(&mut self) -> std::io::Result<()> {
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                ErrorKind::UnexpectedEof,
                "connection closed mid-response",
            ));
        }
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(())
    }

    /// Consume through the next CRLF; returns the line without it.
    fn take_line(&mut self) -> std::io::Result<Vec<u8>> {
        loop {
            let hay = &self.buf[self.pos..];
            if let Some(i) = hay.windows(2).position(|w| w == b"\r\n") {
                let line = hay[..i].to_vec();
                self.pos += i + 2;
                return Ok(line);
            }
            self.fill()?;
        }
    }

    /// Consume exactly `n` bytes.
    fn take_n(&mut self, n: usize) -> std::io::Result<Vec<u8>> {
        while self.buf.len() - self.pos < n {
            self.fill()?;
        }
        let out = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(out)
    }
}

fn bad_data(msg: &'static str) -> std::io::Error {
    std::io::Error::new(ErrorKind::InvalidData, msg)
}

fn parse_status_line(line: &[u8]) -> std::io::Result<u16> {
    let s = std::str::from_utf8(line).map_err(|_| bad_data("status line not utf-8"))?;
    let code = s
        .split(' ')
        .nth(1)
        .and_then(|c| c.parse::<u16>().ok())
        .ok_or_else(|| bad_data("bad status line"))?;
    Ok(code)
}

/// Build a `POST /generate` request with the given JSON body.
pub fn generate_request(body: &str, close: bool) -> Vec<u8> {
    let conn = if close { "close" } else { "keep-alive" };
    format!(
        "POST /generate HTTP/1.1\r\nHost: dtrnet\r\nConnection: {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
        conn,
        body.len(),
        body
    )
    .into_bytes()
}

/// Build a `GET` request for `target`.
pub fn get_request(target: &str, close: bool) -> Vec<u8> {
    let conn = if close { "close" } else { "keep-alive" };
    format!("GET {target} HTTP/1.1\r\nHost: dtrnet\r\nConnection: {conn}\r\n\r\n").into_bytes()
}
