//! Borrowed JSON for the HTTP body path.
//!
//! Two coupled machines over the same strict RFC 8259 grammar:
//!
//! * [`parse`] — a tree parser whose string values **borrow from the
//!   connection buffer** ([`Cow::Borrowed`]) whenever the raw bytes can
//!   be used verbatim (no escapes), so the socket-read → JSON-value path
//!   does zero string copies in the common case (the serde_json_bytes
//!   design).
//! * [`JsonPush`] — a resumable byte-at-a-time validator (the
//!   picojson-rs push-parser design) that the connection loop feeds
//!   while a request body is still arriving, so malformed bodies are
//!   rejected at the first bad byte instead of after buffering
//!   `Content-Length` bytes. It holds no references into the input:
//!   feeding may stop and resume at **any** byte boundary.
//!
//! The two machines accept exactly the same set of documents (the fuzz
//! suite's standing oracle, `torture::check_json_bytes`): strict number
//! grammar (no leading zeros, no bare `.`/trailing `.`), strict escape
//! set, full shortest-form UTF-8 validation, and a shared nesting bound
//! ([`MAX_DEPTH`]). Anything they accept, the lenient
//! [`crate::util::json::Json`] parser accepts too — strictly a subset.

use std::borrow::Cow;

/// Container nesting bound shared by [`parse`] and [`JsonPush`] so their
/// verdicts agree byte-for-byte (also the recursion bound of the tree
/// parser, making stack use on hostile input a constant).
pub const MAX_DEPTH: usize = 64;

/// Why (and where) a document was rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset of the offending byte (input length for truncation).
    pub offset: usize,
    /// Static description of the violation.
    pub msg: &'static str,
}

/// A parsed JSON value; strings borrow from the input when escape-free.
#[derive(Debug, Clone, PartialEq)]
pub enum Value<'a> {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (strict grammar, parsed as f64).
    Num(f64),
    /// A string — borrowed when the raw bytes needed no unescaping.
    Str(Cow<'a, str>),
    /// An array.
    Arr(Vec<Value<'a>>),
    /// An object as key/value pairs in document order (keys borrow too).
    Obj(Vec<(Cow<'a, str>, Value<'a>)>),
}

impl<'a> Value<'a> {
    /// Object member by key (first match in document order).
    pub fn get(&self, key: &str) -> Option<&Value<'a>> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// String payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value<'a>]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Shortest-form UTF-8 classification of a lead byte: `(continuation
/// count, low bound, high bound)` where the bounds constrain the *first*
/// continuation byte (later ones are always `0x80..=0xBF`). `None` for
/// bytes that can never start a multi-byte sequence (stray continuation
/// bytes, overlong prefixes `0xC0`/`0xC1`, and `0xF5..=0xFF`).
fn utf8_class(b: u8) -> Option<(u8, u8, u8)> {
    match b {
        0xC2..=0xDF => Some((1, 0x80, 0xBF)),
        0xE0 => Some((2, 0xA0, 0xBF)),
        0xE1..=0xEC => Some((2, 0x80, 0xBF)),
        0xED => Some((2, 0x80, 0x9F)),
        0xEE..=0xEF => Some((2, 0x80, 0xBF)),
        0xF0 => Some((3, 0x90, 0xBF)),
        0xF1..=0xF3 => Some((3, 0x80, 0xBF)),
        0xF4 => Some((3, 0x80, 0x8F)),
        _ => None,
    }
}

fn is_ws(b: u8) -> bool {
    matches!(b, b' ' | b'\t' | b'\n' | b'\r')
}

// ---------------------------------------------------------------------------
// Tree parser (borrowing)
// ---------------------------------------------------------------------------

/// Parse a complete document. Strings borrow from `input` when they
/// contain no escapes; trailing whitespace is allowed, trailing data is
/// not.
pub fn parse(input: &[u8]) -> Result<Value<'_>, JsonError> {
    let mut p = Parser { b: input, i: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &'static str) -> JsonError {
        JsonError { offset: self.i, msg }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && is_ws(self.b[self.i]) {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn value(&mut self, depth: usize) -> Result<Value<'a>, JsonError> {
        match self.peek() {
            Some(b'{') => {
                if depth >= MAX_DEPTH {
                    return Err(self.err("nesting too deep"));
                }
                self.obj(depth)
            }
            Some(b'[') => {
                if depth >= MAX_DEPTH {
                    return Err(self.err("nesting too deep"));
                }
                self.arr(depth)
            }
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal(b"true", Value::Bool(true)),
            Some(b'f') => self.literal(b"false", Value::Bool(false)),
            Some(b'n') => self.literal(b"null", Value::Null),
            Some(b'-' | b'0'..=b'9') => Ok(Value::Num(self.number()?)),
            Some(_) => Err(self.err("expected a value")),
            None => Err(self.err("truncated document")),
        }
    }

    fn literal(&mut self, word: &'static [u8], v: Value<'a>) -> Result<Value<'a>, JsonError> {
        if self.b.len() - self.i >= word.len() && &self.b[self.i..self.i + word.len()] == word {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn arr(&mut self, depth: usize) -> Result<Value<'a>, JsonError> {
        self.i += 1; // '['
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                Some(_) => return Err(self.err("expected ',' or ']'")),
                None => return Err(self.err("truncated array")),
            }
        }
    }

    fn obj(&mut self, depth: usize) -> Result<Value<'a>, JsonError> {
        self.i += 1; // '{'
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            if self.peek() != Some(b'"') {
                return Err(self.err("expected object key"));
            }
            let key = self.string()?;
            self.skip_ws();
            if self.peek() != Some(b':') {
                return Err(self.err("expected ':'"));
            }
            self.i += 1;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            out.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                Some(_) => return Err(self.err("expected ',' or '}'")),
                None => return Err(self.err("truncated object")),
            }
        }
    }

    /// Strict number: `-? (0 | [1-9][0-9]*) (.[0-9]+)? ([eE][+-]?[0-9]+)?`.
    fn number(&mut self) -> Result<f64, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        match self.peek() {
            Some(b'0') => self.i += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.i += 1;
                }
            }
            _ => return Err(self.err("bad number")),
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("bad number fraction"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("bad number exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).expect("number bytes are ascii");
        s.parse::<f64>().map_err(|_| self.err("unparseable number"))
    }

    /// String body after the opening quote. Fast path: no escapes → the
    /// value borrows the input slice verbatim (validated as UTF-8).
    fn string(&mut self) -> Result<Cow<'a, str>, JsonError> {
        self.i += 1; // opening '"'
        let start = self.i;
        let mut j = self.i;
        while j < self.b.len() {
            match self.b[j] {
                b'"' => {
                    let s = std::str::from_utf8(&self.b[start..j]).map_err(|e| JsonError {
                        offset: start + e.valid_up_to(),
                        msg: "invalid utf-8 in string",
                    })?;
                    self.i = j + 1;
                    return Ok(Cow::Borrowed(s));
                }
                b'\\' => return self.string_slow(start),
                c if c < 0x20 => {
                    self.i = j;
                    return Err(self.err("control byte in string"));
                }
                _ => j += 1,
            }
        }
        self.i = j;
        Err(self.err("truncated string"))
    }

    /// Escape-bearing slow path: decodes into an owned `String`.
    fn string_slow(&mut self, start: usize) -> Result<Cow<'a, str>, JsonError> {
        self.i = start;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err(self.err("truncated string"));
            };
            match c {
                b'"' => {
                    self.i += 1;
                    return Ok(Cow::Owned(out));
                }
                b'\\' => {
                    self.i += 1;
                    let Some(e) = self.peek() else {
                        return Err(self.err("truncated escape"));
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let ch = if (0xD800..=0xDBFF).contains(&cp) {
                                // High surrogate: pair with a following
                                // \uDC00..\uDFFF when present, else U+FFFD
                                // (same policy as util::json).
                                self.try_low_surrogate(cp)
                            } else if (0xDC00..=0xDFFF).contains(&cp) {
                                '\u{FFFD}'
                            } else {
                                char::from_u32(cp).unwrap_or('\u{FFFD}')
                            };
                            out.push(ch);
                        }
                        _ => {
                            self.i -= 1;
                            return Err(self.err("bad escape"));
                        }
                    }
                }
                c if c < 0x20 => return Err(self.err("control byte in string")),
                c if c < 0x80 => {
                    out.push(c as char);
                    self.i += 1;
                }
                c => {
                    let Some((n, lo, hi)) = utf8_class(c) else {
                        return Err(self.err("invalid utf-8 in string"));
                    };
                    let n = n as usize;
                    if self.i + n + 1 > self.b.len() {
                        return Err(JsonError {
                            offset: self.b.len(),
                            msg: "truncated string",
                        });
                    }
                    let seq = &self.b[self.i..self.i + n + 1];
                    let cont_ok = seq[1] >= lo
                        && seq[1] <= hi
                        && seq[2..].iter().all(|&b| (0x80..=0xBF).contains(&b));
                    if !cont_ok {
                        return Err(self.err("invalid utf-8 in string"));
                    }
                    out.push_str(std::str::from_utf8(seq).expect("validated utf-8"));
                    self.i += n + 1;
                }
            }
        }
    }

    /// Peek a `\uXXXX` low surrogate right after a high one; consume and
    /// combine when present.
    fn try_low_surrogate(&mut self, hi: u32) -> char {
        let b = self.b;
        if self.i + 1 < b.len() && b[self.i] == b'\\' && b[self.i + 1] == b'u' {
            let save = self.i;
            self.i += 2;
            if let Ok(lo) = self.hex4() {
                if (0xDC00..=0xDFFF).contains(&lo) {
                    let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                    return char::from_u32(cp).unwrap_or('\u{FFFD}');
                }
            }
            // Not a low surrogate: rewind, leave it for the main loop.
            self.i = save;
        }
        '\u{FFFD}'
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            self.i = self.b.len();
            return Err(self.err("truncated \\u escape"));
        }
        let mut cp = 0u32;
        for k in 0..4 {
            let d = self.b[self.i + k];
            let v = match d {
                b'0'..=b'9' => d - b'0',
                b'a'..=b'f' => d - b'a' + 10,
                b'A'..=b'F' => d - b'A' + 10,
                _ => {
                    self.i += k;
                    return Err(self.err("bad \\u escape"));
                }
            };
            cp = cp * 16 + v as u32;
        }
        self.i += 4;
        Ok(cp)
    }
}

// ---------------------------------------------------------------------------
// Push validator (resumable)
// ---------------------------------------------------------------------------

/// Number sub-state of [`JsonPush`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NumState {
    Minus,
    Zero,
    Int,
    Dot,
    Frac,
    Exp,
    ExpSign,
    ExpDigit,
}

impl NumState {
    /// A number may legally end in this state.
    fn terminal(self) -> bool {
        matches!(self, NumState::Zero | NumState::Int | NumState::Frac | NumState::ExpDigit)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PushState {
    /// Expecting a value.
    Value,
    /// Right after `[`: a value or `]`.
    ValueOrClose,
    /// A value just ended inside a container: `,` or the closer.
    AfterValue,
    /// Right after `{`: a key string or `}`.
    KeyOrClose,
    /// After `,` in an object: a key string.
    Key,
    /// After a key: `:`.
    Colon,
    /// Inside a string.
    Str,
    /// After a backslash.
    StrEsc,
    /// Inside `\uXXXX`, n hex digits remain.
    StrHex(u8),
    /// Inside a multi-byte UTF-8 sequence: remaining count + bounds for
    /// the next byte.
    Utf8(u8, u8, u8),
    /// Inside a number.
    Num(NumState),
    /// Inside `true`/`false`/`null` at byte `pos`.
    Lit(&'static [u8], u8),
    /// Complete document seen; only whitespace may follow.
    Done,
}

/// Resumable strict-JSON validator: feed bytes as they arrive off the
/// socket, in segments of any size; the verdict is independent of the
/// segmentation. Accepts exactly the documents [`parse`] accepts.
#[derive(Debug, Clone)]
pub struct JsonPush {
    state: PushState,
    /// Open containers, `b'['` / `b'{'`; capped at [`MAX_DEPTH`].
    stack: Vec<u8>,
    /// The string being scanned is an object key.
    in_key: bool,
    /// Bytes consumed so far (error offsets).
    offset: usize,
    err: Option<JsonError>,
}

impl Default for JsonPush {
    fn default() -> JsonPush {
        JsonPush::new()
    }
}

impl JsonPush {
    /// A fresh validator expecting a document.
    pub fn new() -> JsonPush {
        JsonPush {
            state: PushState::Value,
            stack: Vec::new(),
            in_key: false,
            offset: 0,
            err: None,
        }
    }

    /// Feed the next segment. The first violation is returned and sticky:
    /// every later call reports the same error.
    pub fn feed(&mut self, bytes: &[u8]) -> Result<(), JsonError> {
        if let Some(e) = self.err {
            return Err(e);
        }
        for &b in bytes {
            // A byte that terminates a number is re-examined in the
            // post-value state; `step` consumes at most twice per byte.
            loop {
                match self.step(b) {
                    Ok(true) => break,
                    Ok(false) => continue,
                    Err(e) => {
                        self.err = Some(e);
                        return Err(e);
                    }
                }
            }
            self.offset += 1;
        }
        Ok(())
    }

    /// End-of-input verdict: `Ok` iff the bytes fed so far form exactly
    /// one complete document.
    pub fn finish(&self) -> Result<(), JsonError> {
        if let Some(e) = self.err {
            return Err(e);
        }
        match self.state {
            PushState::Done => Ok(()),
            PushState::Num(ns) if ns.terminal() && self.stack.is_empty() => Ok(()),
            _ => Err(JsonError {
                offset: self.offset,
                msg: "truncated document",
            }),
        }
    }

    /// The sticky error, if a violation was seen.
    pub fn error(&self) -> Option<JsonError> {
        self.err
    }

    fn fail(&self, msg: &'static str) -> Result<bool, JsonError> {
        Err(JsonError {
            offset: self.offset,
            msg,
        })
    }

    /// A value just completed: back to the enclosing container (or done).
    fn close_value(&mut self) {
        self.state = if self.stack.is_empty() {
            PushState::Done
        } else {
            PushState::AfterValue
        };
    }

    fn open(&mut self, c: u8) -> Result<bool, JsonError> {
        if self.stack.len() >= MAX_DEPTH {
            return self.fail("nesting too deep");
        }
        self.stack.push(c);
        self.state = if c == b'[' {
            PushState::ValueOrClose
        } else {
            PushState::KeyOrClose
        };
        Ok(true)
    }

    /// One byte. `Ok(true)` = consumed; `Ok(false)` = state advanced,
    /// re-examine the same byte.
    fn step(&mut self, b: u8) -> Result<bool, JsonError> {
        match self.state {
            PushState::Value | PushState::ValueOrClose => match b {
                _ if is_ws(b) => Ok(true),
                b'[' | b'{' => self.open(b),
                b']' if self.state == PushState::ValueOrClose => {
                    self.stack.pop();
                    self.close_value();
                    Ok(true)
                }
                b'"' => {
                    self.state = PushState::Str;
                    self.in_key = false;
                    Ok(true)
                }
                b't' => {
                    self.state = PushState::Lit(b"true", 1);
                    Ok(true)
                }
                b'f' => {
                    self.state = PushState::Lit(b"false", 1);
                    Ok(true)
                }
                b'n' => {
                    self.state = PushState::Lit(b"null", 1);
                    Ok(true)
                }
                b'-' => {
                    self.state = PushState::Num(NumState::Minus);
                    Ok(true)
                }
                b'0' => {
                    self.state = PushState::Num(NumState::Zero);
                    Ok(true)
                }
                b'1'..=b'9' => {
                    self.state = PushState::Num(NumState::Int);
                    Ok(true)
                }
                _ => self.fail("expected a value"),
            },
            PushState::KeyOrClose => match b {
                _ if is_ws(b) => Ok(true),
                b'"' => {
                    self.state = PushState::Str;
                    self.in_key = true;
                    Ok(true)
                }
                b'}' => {
                    self.stack.pop();
                    self.close_value();
                    Ok(true)
                }
                _ => self.fail("expected object key"),
            },
            PushState::Key => match b {
                _ if is_ws(b) => Ok(true),
                b'"' => {
                    self.state = PushState::Str;
                    self.in_key = true;
                    Ok(true)
                }
                _ => self.fail("expected object key"),
            },
            PushState::Colon => match b {
                _ if is_ws(b) => Ok(true),
                b':' => {
                    self.state = PushState::Value;
                    Ok(true)
                }
                _ => self.fail("expected ':'"),
            },
            PushState::AfterValue => match (b, self.stack.last()) {
                _ if is_ws(b) => Ok(true),
                (b',', Some(b'[')) => {
                    self.state = PushState::Value;
                    Ok(true)
                }
                (b']', Some(b'[')) => {
                    self.stack.pop();
                    self.close_value();
                    Ok(true)
                }
                (b',', Some(b'{')) => {
                    self.state = PushState::Key;
                    Ok(true)
                }
                (b'}', Some(b'{')) => {
                    self.stack.pop();
                    self.close_value();
                    Ok(true)
                }
                _ => self.fail("expected ',' or close"),
            },
            PushState::Str => match b {
                b'"' => {
                    if self.in_key {
                        self.in_key = false;
                        self.state = PushState::Colon;
                    } else {
                        self.close_value();
                    }
                    Ok(true)
                }
                b'\\' => {
                    self.state = PushState::StrEsc;
                    Ok(true)
                }
                _ if b < 0x20 => self.fail("control byte in string"),
                _ if b < 0x80 => Ok(true),
                _ => match utf8_class(b) {
                    Some((n, lo, hi)) => {
                        self.state = PushState::Utf8(n, lo, hi);
                        Ok(true)
                    }
                    None => self.fail("invalid utf-8 in string"),
                },
            },
            PushState::StrEsc => match b {
                b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't' => {
                    self.state = PushState::Str;
                    Ok(true)
                }
                b'u' => {
                    self.state = PushState::StrHex(4);
                    Ok(true)
                }
                _ => self.fail("bad escape"),
            },
            PushState::StrHex(n) => match b {
                b'0'..=b'9' | b'a'..=b'f' | b'A'..=b'F' => {
                    self.state = if n == 1 {
                        PushState::Str
                    } else {
                        PushState::StrHex(n - 1)
                    };
                    Ok(true)
                }
                _ => self.fail("bad \\u escape"),
            },
            PushState::Utf8(left, lo, hi) => {
                if b < lo || b > hi {
                    return self.fail("invalid utf-8 in string");
                }
                self.state = if left == 1 {
                    PushState::Str
                } else {
                    PushState::Utf8(left - 1, 0x80, 0xBF)
                };
                Ok(true)
            }
            PushState::Num(ns) => self.step_num(ns, b),
            PushState::Lit(word, pos) => {
                if (pos as usize) < word.len() && b == word[pos as usize] {
                    if pos as usize + 1 == word.len() {
                        self.close_value();
                    } else {
                        self.state = PushState::Lit(word, pos + 1);
                    }
                    Ok(true)
                } else {
                    self.fail("bad literal")
                }
            }
            PushState::Done => {
                if is_ws(b) {
                    Ok(true)
                } else {
                    self.fail("trailing data after document")
                }
            }
        }
    }

    fn step_num(&mut self, ns: NumState, b: u8) -> Result<bool, JsonError> {
        use NumState::*;
        let next = match (ns, b) {
            (Minus, b'0') => Some(Zero),
            (Minus, b'1'..=b'9') => Some(Int),
            (Zero, b'.') | (Int, b'.') => Some(Dot),
            (Zero, b'e' | b'E') | (Int, b'e' | b'E') => Some(Exp),
            (Int, b'0'..=b'9') => Some(Int),
            (Dot, b'0'..=b'9') | (Frac, b'0'..=b'9') => Some(Frac),
            (Frac, b'e' | b'E') => Some(Exp),
            (Exp, b'+' | b'-') => Some(ExpSign),
            (Exp, b'0'..=b'9') | (ExpSign, b'0'..=b'9') | (ExpDigit, b'0'..=b'9') => {
                Some(ExpDigit)
            }
            _ => None,
        };
        match next {
            Some(s) => {
                self.state = PushState::Num(s);
                Ok(true)
            }
            None if ns.terminal() => {
                // The number ends here; the byte belongs to the enclosing
                // context — re-examine it there.
                self.close_value();
                Ok(false)
            }
            None => self.fail("bad number"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn accept(s: &str) -> bool {
        parse(s.as_bytes()).is_ok()
    }

    fn push_accept(data: &[u8]) -> bool {
        let mut jp = JsonPush::new();
        jp.feed(data).is_ok() && jp.finish().is_ok()
    }

    #[test]
    fn strict_grammar_verdicts() {
        for good in [
            "null",
            "true",
            " false ",
            "0",
            "-0",
            "12.5e-3",
            "1E+9",
            "\"\"",
            "\"a\\n\\u0041\"",
            "[]",
            "[1,2,3]",
            "{\"a\":[{\"b\":null}],\"a\":2}",
            "\"\\ud83d\\ude00\"",
            "\"\\ud800\"",
        ] {
            assert!(accept(good), "must accept {good:?}");
            assert!(push_accept(good.as_bytes()), "push must accept {good:?}");
        }
        for bad in [
            "", " ", "01", "1.", ".5", "+1", "-", "1e", "1e+", "tru", "nulll", "[1,]",
            "{\"a\":}", "{\"a\" 1}", "{a:1}", "[1 2]", "\"\\x\"", "\"", "[", "{\"a\":1",
            "1 2", "\"\u{0007}\"",
        ] {
            assert!(!accept(bad), "must reject {bad:?}");
            assert!(!push_accept(bad.as_bytes()), "push must reject {bad:?}");
        }
    }

    #[test]
    fn borrows_escape_free_strings() {
        let doc = b"{\"key\":\"plain value\"}";
        let v = parse(doc).unwrap();
        let Value::Obj(pairs) = &v else { panic!("obj") };
        assert!(matches!(pairs[0].0, Cow::Borrowed(_)), "key must borrow");
        let Value::Str(s) = &pairs[0].1 else { panic!("str") };
        assert!(matches!(s, Cow::Borrowed(_)), "escape-free value must borrow");
        let v2 = parse(b"\"a\\tb\"").unwrap();
        let Value::Str(s2) = &v2 else { panic!("str") };
        assert!(matches!(s2, Cow::Owned(_)), "escaped value must own");
        assert_eq!(&**s2, "a\tb");
    }

    #[test]
    fn depth_limit_is_shared() {
        let deep_ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
        let deep_bad = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
        assert!(accept(&deep_ok));
        assert!(push_accept(deep_ok.as_bytes()));
        assert!(!accept(&deep_bad));
        assert!(!push_accept(deep_bad.as_bytes()));
    }

    #[test]
    fn push_is_split_invariant() {
        let doc = b"{\"p\":[1,2,-3.5e2],\"t\":\"x\\u00e9\",\"s\":true}";
        let one = push_accept(doc);
        for cut in 0..=doc.len() {
            let mut jp = JsonPush::new();
            let a = jp.feed(&doc[..cut]);
            let b = jp.feed(&doc[cut..]);
            assert_eq!(a.and(b).and(jp.finish()).is_ok(), one, "cut at {cut}");
        }
    }

    #[test]
    fn utf8_shortest_form_enforced() {
        // Overlong '/' (0xC0 0xAF), surrogate half (0xED 0xA0 0x80),
        // out-of-range (0xF5 ...), bare continuation.
        for bad in [
            &b"\"\xC0\xAF\""[..],
            &b"\"\xED\xA0\x80\""[..],
            &b"\"\xF5\x80\x80\x80\""[..],
            &b"\"\x80\""[..],
            &b"\"\xE2\x82\""[..],
        ] {
            assert!(parse(bad).is_err(), "must reject {bad:?}");
            assert!(!push_accept(bad), "push must reject {bad:?}");
        }
        let good = "\"\u{20AC}\u{10348}é\"".as_bytes();
        assert!(parse(good).is_ok());
        assert!(push_accept(good));
    }
}
