//! Differential torture oracles shared by the split-read tests, the
//! corpus replay test, and the `dtrnet-fuzz` mutational fuzzers.
//!
//! Two entry points, both taking arbitrary bytes and panicking only if
//! an *invariant* breaks (never on malformed input — malformed input is
//! the point):
//!
//! * [`check_http_bytes`]: the [`PushParser`] must produce the same
//!   outcome — same parsed requests, same error, same leftover count —
//!   whether fed in one shot, byte by byte, or at pseudo-random split
//!   points derived deterministically from the input hash.
//! * [`check_json_bytes`]: the [`JsonPush`] validator must be split
//!   invariant, must agree with the tree parser [`bjson::parse`] on
//!   accept/reject, and anything it accepts must also parse under the
//!   lenient [`Json::parse`] (strictness is one-directional: the
//!   lenient parser accepts e.g. `01`, so only strict-accept ⟹
//!   lenient-accept is checked).
//!
//! No wall-clock or OS randomness is used anywhere: the pseudo-random
//! splits are seeded from an FNV-1a hash of the input, so every run —
//! CI replay included — sees identical behaviour for identical bytes.

use super::bjson::{self, JsonPush};
use super::parser::{Head, HttpError, Limits, PushParser};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Everything observable about feeding one byte stream to the parser.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpOutcome {
    /// Completed requests in order: parsed head + raw body bytes.
    pub requests: Vec<(Head, Vec<u8>)>,
    /// The sticky error, if the stream went bad.
    pub error: Option<HttpError>,
    /// Bytes left buffered (a trailing incomplete request).
    pub buffered: usize,
}

/// Limits small enough that fuzz inputs can actually trip them.
pub fn torture_limits() -> Limits {
    Limits {
        max_head_bytes: 2048,
        max_body_bytes: 4096,
        max_headers: 32,
    }
}

/// Feed `data` split at `splits` (ascending byte offsets) and collect
/// the outcome. Completed requests are drained after every segment, so
/// zero-copy buffer handoff and pipelining carry-over are exercised at
/// each boundary.
pub fn http_outcome(data: &[u8], splits: &[usize]) -> HttpOutcome {
    let mut parser = PushParser::new(torture_limits());
    let mut out = HttpOutcome {
        requests: Vec::new(),
        error: None,
        buffered: 0,
    };
    let mut prev = 0usize;
    let mut bounds: Vec<usize> = splits.to_vec();
    bounds.push(data.len());
    for b in bounds {
        let b = b.min(data.len()).max(prev);
        if parser.push(&data[prev..b]).is_err() {
            break;
        }
        prev = b;
        while let Some(req) = parser.take() {
            out.requests
                .push((req.head().clone(), req.body().to_vec()));
        }
        if parser.failure().is_some() {
            break;
        }
    }
    out.error = parser.failure();
    out.buffered = parser.buffered();
    out
}

/// Deterministic pseudo-random split offsets for `data`: FNV-1a of the
/// bytes seeds the repo's own [`Rng`], which picks up to 16 cut points.
pub fn pseudo_splits(data: &[u8]) -> Vec<usize> {
    if data.len() < 2 {
        return Vec::new();
    }
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut rng = Rng::new(h | 1);
    let n = (data.len() / 7).clamp(1, 16);
    let mut cuts: Vec<usize> = (0..n).map(|_| 1 + rng.usize_below(data.len() - 1)).collect();
    cuts.sort_unstable();
    cuts.dedup();
    cuts
}

/// The HTTP invariant bundle. Panics (with context) iff the push parser
/// is split sensitive. Returns the one-shot outcome for further checks.
pub fn check_http_bytes(data: &[u8]) -> HttpOutcome {
    let oneshot = http_outcome(data, &[]);
    let bytewise: Vec<usize> = (1..data.len()).collect();
    let by_byte = http_outcome(data, &bytewise);
    assert_eq!(
        oneshot, by_byte,
        "push parser is split sensitive (byte-by-byte) for {data:?}"
    );
    let random = http_outcome(data, &pseudo_splits(data));
    assert_eq!(
        oneshot, random,
        "push parser is split sensitive (pseudo-random splits) for {data:?}"
    );
    // Every parsed body must itself hold up under the JSON oracles — the
    // real server validates generate bodies with exactly these machines.
    for (_, body) in &oneshot.requests {
        check_json_bytes(body);
    }
    oneshot
}

/// The JSON invariant bundle: push-validator split invariance, push vs
/// tree agreement, and strict ⊆ lenient. Returns the strict verdict.
pub fn check_json_bytes(data: &[u8]) -> bool {
    let mut oneshot = JsonPush::new();
    let oneshot_ok = oneshot.feed(data).is_ok() && oneshot.finish().is_ok();

    let mut bytewise = JsonPush::new();
    let mut fed_ok = true;
    for &b in data {
        if bytewise.feed(&[b]).is_err() {
            fed_ok = false;
            break;
        }
    }
    let bytewise_ok = fed_ok && bytewise.finish().is_ok();
    assert_eq!(
        oneshot_ok, bytewise_ok,
        "JsonPush is split sensitive for {data:?}"
    );

    let mut random = JsonPush::new();
    let mut prev = 0usize;
    let mut ok = true;
    let mut bounds = pseudo_splits(data);
    bounds.push(data.len());
    for b in bounds {
        let b = b.min(data.len()).max(prev);
        if random.feed(&data[prev..b]).is_err() {
            ok = false;
            break;
        }
        prev = b;
    }
    let random_ok = ok && random.finish().is_ok();
    assert_eq!(
        oneshot_ok, random_ok,
        "JsonPush is split sensitive (pseudo-random splits) for {data:?}"
    );

    let tree_ok = bjson::parse(data).is_ok();
    assert_eq!(
        oneshot_ok, tree_ok,
        "JsonPush and bjson::parse disagree for {data:?}"
    );

    if oneshot_ok {
        let text = std::str::from_utf8(data)
            .expect("strict JSON machines accepted non-UTF-8 input");
        assert!(
            Json::parse(text).is_ok(),
            "strict machines accepted what the lenient parser rejects: {text:?}"
        );
    }
    oneshot_ok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_passes_on_a_mixed_stream() {
        let data = b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Length: 15\r\n\r\n{\"prompt\":[1,2]}GET /health HTTP/1.1\r\n\r\n";
        // Body length is deliberately off by one from the JSON text so
        // the second request starts with a stray byte — the oracle must
        // stay split invariant even on that degenerate framing.
        let out = check_http_bytes(data);
        assert_eq!(out.requests.len(), 1);
    }

    #[test]
    fn oracle_passes_on_clean_pipelining() {
        let body = "{\"prompt\":[1,2]}";
        let req = format!(
            "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let data = format!("{req}{req}GET /health HTTP/1.1\r\n\r\n");
        let out = check_http_bytes(data.as_bytes());
        assert_eq!(out.requests.len(), 3);
        assert_eq!(out.error, None);
        assert_eq!(out.buffered, 0);
    }

    #[test]
    fn oracle_is_quiet_on_garbage() {
        check_http_bytes(b"\xff\xfe garbage \r\n\r\n");
        check_json_bytes(b"\xff\xfe");
        assert!(check_json_bytes(b"{\"a\":[1,2.5e3,null,true,\"x\"]}"));
        assert!(!check_json_bytes(b"{\"a\":01}"));
    }

    #[test]
    fn pseudo_splits_are_deterministic_and_in_range() {
        let data = b"POST / HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi";
        let a = pseudo_splits(data);
        let b = pseudo_splits(data);
        assert_eq!(a, b);
        assert!(a.iter().all(|&c| c >= 1 && c < data.len()));
        assert!(!a.is_empty());
    }
}
