//! Backend-generic continuous-batching serving engine.
//!
//! Runs on any [`Backend`] — the native CPU backend on the default build,
//! the PJRT path once that implements the trait — and wires the host-side
//! coordinator pieces into a real engine loop:
//!
//! * **admission** — [`Batcher`] queue → free decode slots, iteration-level
//!   (vLLM-style) scheduling;
//! * **prefill** — either chunked at admission through
//!   [`Backend::prefill_chunked`] (default: one batched pass per chunk,
//!   page-charged in bulk) or token-by-token through the decode loop
//!   ([`PrefillMode::Decode`], the decode-artifact semantics);
//! * **batched decode** — one [`Backend::decode_batch`] call per engine
//!   step over every active slot's [`DecodeState`]; bit-identical to
//!   per-sequence decode by the trait contract, so token streams never
//!   depend on batch composition;
//! * **routing-aware KV paging** — [`KvPool`] pages are allocated per
//!   (slot, layer) only for tokens the router sent through attention (the
//!   paper's Fig. 6 mechanism); a dense shadow pool tracks what a
//!   route-everything model would have allocated, making "pages saved vs
//!   dense" a measured quantity rather than an analytical one;
//! * **completion recycling** — finished/evicted slots release their pages
//!   and re-enter admission;
//! * **telemetry** — per-request TTFT and end-to-end latency, engine-step
//!   and throughput histograms ([`Registry`]), per-layer routing fractions
//!   ([`RoutingStats`]) resolved by token-position bucket
//!   ([`PositionBuckets`]), router-margin histograms, per-request
//!   routed-token counts, and the backend's measured per-layer FLOPs, all
//!   folded into a [`ServeReport`]. When span tracing is enabled
//!   ([`crate::telemetry`], the `--trace` flag), every engine step,
//!   chunked prefill, and request lifecycle (admission → first token →
//!   finish, as async spans keyed by request id) lands in the Chrome
//!   trace; [`Server::set_metrics_log`] additionally streams per-step and
//!   per-request JSONL rows (`--metrics-jsonl`).
//!
//! Determinism: sampling uses one RNG per request, seeded from
//! `engine seed ^ request id`, so generated token streams are a function
//! of (weights, prompt, sampling params, seed) only — never of arrival
//! timing, batch packing, or slot assignment. `integration_server.rs`
//! pins this.

use std::time::Instant;

use anyhow::{ensure, Result};

use super::batcher::{Batcher, Request};
use super::kv_cache::{KvPool, PoolStats};
use super::sampling::{sample, SamplingParams};
use super::speculate::{SpecStats, SpeculativeDecoder};
use super::stats::{PositionBuckets, RoutingStats};
use super::workload::TimedRequest;
use crate::config::LayerKind;
use crate::metrics::{JsonlWriter, Registry};
use crate::runtime::backend::PREFILL_CHUNK;
use crate::runtime::{Backend, DecodeState, WeightBytes};
use crate::telemetry::{self, ArgValue};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// How the engine ingests prompts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefillMode {
    /// Feed prompt tokens one per engine step through the batched decode
    /// call — pure iteration-level scheduling (the decode-artifact
    /// serving semantics; prefill and generation are the same step kind).
    Decode,
    /// Ingest the whole prompt at admission via
    /// [`Backend::prefill_chunked`] with this chunk width, bulk-charging
    /// the KV pool from the resulting cache lens.
    Chunked(usize),
}

/// Engine configuration. Zero means "derive a default" where noted.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Decode batch width (concurrent sequences).
    pub slots: usize,
    /// Request queue bound (submissions beyond it are rejected).
    pub max_queue: usize,
    /// KV page granularity in tokens.
    pub kv_page_size: usize,
    /// Page budget across the pool; 0 = the dense-equivalent footprint at
    /// full context (`slots × layers × ceil(max_seq / page)`), so a dense
    /// model exactly fits and the DTR model's headroom IS the Fig. 6 win.
    pub max_kv_pages: usize,
    /// Per-sequence *resident* page budget for the decode state's KV
    /// storage (`--kv-budget-pages`): admitted slots get a bounded/paged
    /// [`DecodeState`] whose resident pages never exceed this, with LRU
    /// overflow spilled to disk. 0 = the unbounded resident slab. Unlike
    /// `max_kv_pages` (an admission-control budget that *evicts*
    /// requests), this bounds memory only — token streams are bitwise
    /// identical either way (DESIGN.md §KV paging).
    pub kv_budget_pages: usize,
    /// Per-sequence position cap; 0 = the backend's `max_seq`.
    pub max_seq: usize,
    /// How prompts are ingested (see [`PrefillMode`]).
    pub prefill: PrefillMode,
    /// Engine-wide sampling defaults (top-k/top-p/repetition penalty);
    /// per-request temperature comes from each [`Request`].
    pub sampling: SamplingParams,
    /// Seed for the per-request sampling RNGs.
    pub seed: u64,
    /// Self-speculative decode depth: draft up to this many tokens per
    /// iteration on the bypass path and verify them in one full-router
    /// pass (`--speculate`). 0 disables. Only greedy (temperature 0)
    /// requests speculate — others take the plain batched-decode path —
    /// and emitted streams stay bitwise identical either way.
    pub speculate: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            slots: 4,
            max_queue: 4096,
            kv_page_size: 16,
            max_kv_pages: 0,
            kv_budget_pages: 0,
            max_seq: 0,
            prefill: PrefillMode::Chunked(PREFILL_CHUNK),
            sampling: SamplingParams::greedy(),
            seed: 0x5e11,
            speculate: 0,
        }
    }
}

/// Why [`Server::try_submit`] refused a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The admission queue is at capacity — retryable backpressure
    /// (HTTP 429 at the network edge).
    QueueFull,
    /// The request can never be served — a client error (HTTP 400).
    Invalid(&'static str),
}

/// Why a request left its slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated its full `max_new_tokens`.
    Completed,
    /// Evicted: the KV pool hit its page budget.
    KvExhausted,
    /// Evicted: the sequence reached the engine's position cap.
    ContextCap,
    /// Cancelled while queued or in flight — the run's step bound
    /// tripped, or the client disconnected mid-stream
    /// ([`Server::cancel_request`]). Accounting stays closed: nothing
    /// vanishes.
    Cancelled,
}

impl FinishReason {
    /// Stable snake_case name (the report's `finish` field).
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Completed => "completed",
            FinishReason::KvExhausted => "kv_exhausted",
            FinishReason::ContextCap => "context_cap",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// Per-request outcome (the engine's response object).
#[derive(Debug, Clone)]
pub struct RequestRecord {
    /// Request id (as submitted).
    pub id: u64,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Generated tokens (prompt not included).
    pub tokens: Vec<i32>,
    /// Time to first token; 0.0 if evicted before producing any.
    pub ttft_ms: f64,
    /// End-to-end latency from submission to retirement.
    pub latency_ms: f64,
    /// Why the request left its slot.
    pub finish: FinishReason,
    /// Per-layer count of this request's tokens (prompt + generated) that
    /// took the attention path — the request-granular routing telemetry.
    /// Empty for requests cancelled before admission.
    pub routed_tokens: Vec<u64>,
    /// Draft tokens proposed for this request (`--speculate`; 0 when
    /// speculation was off or never applied).
    pub spec_drafted: u64,
    /// Draft tokens the verifier accepted for this request
    /// (`spec_drafted - spec_accepted` is the rejected count).
    pub spec_accepted: u64,
}

/// Serving run summary.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Name of the backend that served the run.
    pub backend: String,
    /// Requests that spent their full generation budget.
    pub completed: usize,
    /// Requests finished early (KV budget, position cap, cancel).
    pub evicted: usize,
    /// Submissions refused by queue backpressure or validation.
    pub rejected: usize,
    /// Generated tokens across all requests.
    pub tokens_generated: usize,
    /// Prompt tokens across all requests.
    pub prompt_tokens: usize,
    /// Batched decode iterations executed.
    pub steps: usize,
    /// Wall-clock duration of the run in seconds.
    pub wall_s: f64,
    /// Generated tokens per wall-clock second.
    pub tokens_per_s: f64,
    /// Median batched-decode step time.
    pub decode_step_ms_p50: f64,
    /// 99th-percentile batched-decode step time.
    pub decode_step_ms_p99: f64,
    /// Median time to first token.
    pub ttft_ms_p50: f64,
    /// 99th-percentile time to first token.
    pub ttft_ms_p99: f64,
    /// Median end-to-end request latency.
    pub latency_ms_p50: f64,
    /// 99th-percentile end-to-end request latency.
    pub latency_ms_p99: f64,
    /// Mean fraction of slots doing useful work per step.
    pub batch_occupancy: f64,
    /// Routed-only pool (the real allocation).
    pub pool: PoolStats,
    /// Peak pages a dense-equivalent model would have allocated for the
    /// same token stream (measured by the shadow pool, same paging).
    pub dense_pages_peak: usize,
    /// High-water mark of *resident* KV pages in any one decode state
    /// (`--kv-budget-pages`): with a bounded cache this never exceeds
    /// the budget; 0 when every slot ran the unbounded resident slab.
    pub kv_resident_pages_peak: usize,
    /// tokens_cached / (tokens_seen × layers): the token-granular KV
    /// footprint ratio vs dense (page quantization visible via pages).
    pub kv_savings_ratio: f64,
    /// Backend weight-memory telemetry: resident vs f32-equivalent bytes
    /// (the int8 backend reports ~3.7× compression; f32 backends 1.0×).
    pub weight_bytes: WeightBytes,
    /// Per-layer routing counters for the whole run.
    pub routing: RoutingStats,
    /// Per-layer fraction of tokens routed to attention (Fig. 5 y-axis).
    pub attn_fracs: Vec<f64>,
    /// Attention fraction resolved by layer × token-position bucket
    /// ([`PositionBuckets::to_json`] rows).
    pub position_buckets: Json,
    /// Router-margin histogram summary (`|2·g_attn − 1|` over every DTR
    /// routing decision; near-0 margins mark tokens the router was
    /// uncertain about). Statistics are `null` when the model has no DTR
    /// layers.
    pub router_margin: Json,
    /// Measured per-layer FLOP counters from
    /// [`Backend::flop_counters`], when the backend instruments its
    /// kernels (both CPU backends do). Like `kernel_timings`, cumulative
    /// over the backend's lifetime, not just this run.
    pub measured_flops: Option<Json>,
    /// Engine-wide speculative-decode acceptance totals (`--speculate`;
    /// all zero when speculation is off).
    pub spec: SpecStats,
    /// Per-request outcomes, in retirement order.
    pub requests: Vec<RequestRecord>,
    /// Per-kernel wall-clock snapshot from
    /// [`Backend::kernel_timings`], when the backend records one (the
    /// CPU backend always does). Cumulative over the backend's lifetime,
    /// not just this run.
    pub kernel_timings: Option<Json>,
    /// Active SIMD kernel tier (`--simd`, DESIGN.md §SIMD dispatch).
    pub simd_tier: String,
    /// Active kernel precision mode (`--precision`).
    pub precision: String,
}

impl ServeReport {
    /// Serialize the full report (the `serve --json` document).
    pub fn to_json(&self) -> Json {
        let reqs = self
            .requests
            .iter()
            .map(|r| {
                Json::from_pairs(vec![
                    ("id", Json::Num(r.id as f64)),
                    ("prompt_len", Json::Num(r.prompt_len as f64)),
                    ("n_tokens", Json::Num(r.tokens.len() as f64)),
                    ("ttft_ms", Json::Num(r.ttft_ms)),
                    ("latency_ms", Json::Num(r.latency_ms)),
                    ("finish", Json::Str(r.finish.as_str().to_string())),
                    (
                        "routed_tokens",
                        Json::Arr(
                            r.routed_tokens.iter().map(|&c| Json::Num(c as f64)).collect(),
                        ),
                    ),
                    ("spec_drafted", Json::Num(r.spec_drafted as f64)),
                    ("spec_accepted", Json::Num(r.spec_accepted as f64)),
                ])
            })
            .collect();
        let mut out = Json::from_pairs(vec![
            ("backend", Json::Str(self.backend.clone())),
            ("simd_tier", Json::Str(self.simd_tier.clone())),
            ("precision", Json::Str(self.precision.clone())),
            ("completed", Json::Num(self.completed as f64)),
            ("evicted", Json::Num(self.evicted as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("tokens_generated", Json::Num(self.tokens_generated as f64)),
            ("prompt_tokens", Json::Num(self.prompt_tokens as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("tokens_per_s", Json::Num(self.tokens_per_s)),
            ("decode_step_ms_p50", Json::Num(self.decode_step_ms_p50)),
            ("decode_step_ms_p99", Json::Num(self.decode_step_ms_p99)),
            ("ttft_ms_p50", Json::Num(self.ttft_ms_p50)),
            ("ttft_ms_p99", Json::Num(self.ttft_ms_p99)),
            ("latency_ms_p50", Json::Num(self.latency_ms_p50)),
            ("latency_ms_p99", Json::Num(self.latency_ms_p99)),
            ("batch_occupancy", Json::Num(self.batch_occupancy)),
            ("kv_pages_peak", Json::Num(self.pool.pages_peak as f64)),
            ("kv_bytes_peak", Json::Num(self.pool.bytes_peak as f64)),
            ("dense_pages_peak", Json::Num(self.dense_pages_peak as f64)),
            (
                "kv_resident_pages_peak",
                Json::Num(self.kv_resident_pages_peak as f64),
            ),
            ("kv_savings_ratio", Json::Num(self.kv_savings_ratio)),
            (
                "weight_bytes_resident",
                Json::Num(self.weight_bytes.resident as f64),
            ),
            (
                "weight_bytes_f32",
                Json::Num(self.weight_bytes.f32_equiv as f64),
            ),
            (
                "weight_compression",
                Json::Num(self.weight_bytes.compression()),
            ),
            ("spec_drafted", Json::Num(self.spec.drafted as f64)),
            ("spec_accepted", Json::Num(self.spec.accepted as f64)),
            ("spec_iterations", Json::Num(self.spec.iterations as f64)),
            ("spec_acceptance_rate", Json::Num(self.spec.acceptance_rate())),
            (
                "spec_mean_accepted_len",
                Json::Num(self.spec.mean_accepted_len()),
            ),
            ("attn_fracs", Json::arr_f64(&self.attn_fracs)),
            ("routing", self.routing.to_json()),
            ("position_buckets", self.position_buckets.clone()),
            ("router_margin", self.router_margin.clone()),
            ("requests", Json::Arr(reqs)),
        ]);
        if let Some(kt) = &self.kernel_timings {
            out.set("kernel_timings", kt.clone());
        }
        if let Some(mf) = &self.measured_flops {
            out.set("measured_flops", mf.clone());
        }
        out
    }
}

/// Continuous-batching serving engine over any [`Backend`].
pub struct Server<'b> {
    backend: &'b dyn Backend,
    cfg: ServerConfig,
    /// Admission queue + slot table.
    pub batcher: Batcher,
    /// Routing-aware paged pool — the real allocation accountant.
    pub pool: KvPool,
    /// Shadow pool charged as if every layer cached every token.
    dense_shadow: KvPool,
    states: Vec<Option<DecodeState>>,
    rngs: Vec<Rng>,
    routing: RoutingStats,
    /// Attention fraction by layer × token-position bucket.
    buckets: PositionBuckets,
    /// Per-slot per-layer routed-token counts for the request currently
    /// occupying the slot (taken into its [`RequestRecord`] at finish).
    slot_routed: Vec<Vec<u64>>,
    /// Engine-wide speculative acceptance totals (`cfg.speculate`).
    spec: SpecStats,
    /// Per-slot speculative stats for the occupying request (taken into
    /// its [`RequestRecord`] at finish).
    slot_spec: Vec<SpecStats>,
    /// `is_dtr[l]`: layer has a router (margins are meaningless on dense
    /// layers, whose g_attn is pinned to 1.0).
    is_dtr: Vec<bool>,
    /// Per-step / per-request telemetry stream (`--metrics-jsonl`).
    metrics_log: Option<JsonlWriter>,
    registry: Registry,
    records: Vec<RequestRecord>,
    rejected: usize,
    /// Max resident-page peak over every *released* decode state (live
    /// states are folded in at report time).
    kv_resident_peak: usize,
    steps: usize,
    steps_active_sum: u64,
    d_model: usize,
    n_layers: usize,
    vocab: usize,
    all_routed: Vec<bool>,
}

impl<'b> Server<'b> {
    /// An engine over `backend` with `cfg` (slots/paging/prefill/seed).
    pub fn new(backend: &'b dyn Backend, cfg: ServerConfig) -> Result<Server<'b>> {
        ensure!(cfg.slots > 0, "server needs at least one decode slot");
        ensure!(cfg.kv_page_size > 0, "kv page size must be positive");
        if let PrefillMode::Chunked(c) = cfg.prefill {
            ensure!(c > 0, "chunked prefill needs a positive chunk width");
        }
        let mcfg = backend.config().clone();
        let max_seq = if cfg.max_seq == 0 { mcfg.max_seq } else { cfg.max_seq };
        let max_pages = if cfg.max_kv_pages == 0 {
            cfg.slots * mcfg.n_layers * max_seq.div_ceil(cfg.kv_page_size)
        } else {
            cfg.max_kv_pages
        };
        let pool = KvPool::new(&mcfg, cfg.slots, cfg.kv_page_size, max_pages);
        let dense_shadow = KvPool::new(&mcfg, cfg.slots, cfg.kv_page_size, usize::MAX / 2);
        // Placeholders — every admission reseeds its slot from the
        // request id, so streams never depend on slot assignment.
        let rngs = (0..cfg.slots).map(|_| Rng::new(cfg.seed)).collect();
        let slots = cfg.slots;
        let max_queue = cfg.max_queue;
        let is_dtr = mcfg
            .layer_kinds()
            .iter()
            .map(|k| !matches!(k, LayerKind::Dense))
            .collect();
        Ok(Server {
            backend,
            cfg: ServerConfig {
                max_seq,
                max_kv_pages: max_pages,
                ..cfg
            },
            batcher: Batcher::new(slots, max_queue),
            pool,
            dense_shadow,
            states: (0..slots).map(|_| None).collect(),
            rngs,
            routing: RoutingStats::new(mcfg.n_layers),
            buckets: PositionBuckets::new(mcfg.n_layers),
            slot_routed: vec![vec![0; mcfg.n_layers]; slots],
            spec: SpecStats::default(),
            slot_spec: vec![SpecStats::default(); slots],
            is_dtr,
            metrics_log: None,
            registry: Registry::default(),
            records: Vec::new(),
            rejected: 0,
            kv_resident_peak: 0,
            steps: 0,
            steps_active_sum: 0,
            d_model: mcfg.d_model,
            n_layers: mcfg.n_layers,
            vocab: mcfg.vocab_size,
            all_routed: vec![true; mcfg.n_layers],
        })
    }

    /// The effective configuration (defaults resolved).
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Engine metrics (step/prefill histograms, queue gauges).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Stream per-step and per-request telemetry rows into `log` as JSONL
    /// (the `--metrics-jsonl` flag). Step rows carry `{kind:"step", step,
    /// batch, decode_ms, kv_pages, queue_depth}`; request rows carry
    /// `{kind:"request", id, finish, prompt_len, n_tokens, ttft_ms,
    /// latency_ms, routed_tokens[L]}`.
    pub fn set_metrics_log(&mut self, log: JsonlWriter) {
        self.metrics_log = Some(log);
    }

    /// Per-layer decode-cache lens of a live slot (None if vacant) — the
    /// backend-reported routed counts the KV pool must mirror.
    pub fn decode_lens(&self, slot: usize) -> Option<Vec<usize>> {
        self.states[slot].as_ref().map(|s| s.lens(self.d_model))
    }

    /// Invariant check (used by tests after every step): for every live
    /// slot, pool pages cover exactly the tokens the backend cached —
    /// `pool.lens(slot) == DecodeState::lens` per layer.
    pub fn check_kv_invariant(&self) -> Result<()> {
        for slot in 0..self.cfg.slots {
            let Some(want) = self.decode_lens(slot) else {
                continue;
            };
            let got = self.pool.lens(slot);
            ensure!(
                got == want,
                "slot {slot}: pool lens {got:?} != decode cache lens {want:?}"
            );
        }
        Ok(())
    }

    /// Enqueue a request. Returns false (and drops it) when the queue is
    /// full or the request is malformed — see [`Server::try_submit`] for
    /// the distinction; this form collapses both into a bool.
    pub fn submit(&mut self, req: Request) -> bool {
        self.try_submit(req).is_ok()
    }

    /// Enqueue a request, telling refusals apart: `Invalid` for
    /// malformed requests (empty prompt, zero tokens, an
    /// out-of-vocabulary prompt token — which would make the backend
    /// error mid-run and kill every other in-flight request — or a
    /// prompt longer than the position cap) and `QueueFull` for
    /// backpressure. The HTTP front end maps these to 400 vs 429. The
    /// cap check keeps the two prefill modes equivalent — chunked
    /// prefill would otherwise ingest the whole oversized prompt while
    /// stepwise prefill stops at the cap mid-prompt, diverging streams
    /// and RoPE positions. Every refusal is counted into
    /// [`ServeReport::rejected`], so `completed + evicted + rejected`
    /// equals submissions on every run path.
    pub fn try_submit(&mut self, req: Request) -> Result<(), SubmitError> {
        let invalid = if req.prompt.is_empty() {
            Some("empty prompt")
        } else if req.max_new_tokens == 0 {
            Some("max_new_tokens must be positive")
        } else if req.prompt.len() > self.cfg.max_seq {
            Some("prompt exceeds context cap")
        } else if req
            .prompt
            .iter()
            .any(|&t| t < 0 || (t as usize) >= self.vocab)
        {
            Some("prompt token out of vocabulary")
        } else {
            None
        };
        if let Some(reason) = invalid {
            self.rejected += 1;
            return Err(SubmitError::Invalid(reason));
        }
        if !self.batcher.submit(req) {
            self.rejected += 1;
            return Err(SubmitError::QueueFull);
        }
        Ok(())
    }

    /// Records of requests retired after index `from` (in retirement
    /// order). Streaming callers keep a cursor and poll this after each
    /// [`Server::step`] to flush completions.
    pub fn finished_since(&self, from: usize) -> &[RequestRecord] {
        self.records.get(from..).unwrap_or(&[])
    }

    /// The serving report as of now, with `wall_s` as the elapsed wall
    /// clock — the open-ended (`--listen`) counterpart of the
    /// run-to-completion report.
    pub fn report_now(&self, wall_s: f64) -> ServeReport {
        self.report(wall_s)
    }

    /// Cancel a request by id wherever it currently lives: a queued
    /// entry is retired without ever being admitted; a live slot is
    /// evicted, so its decode state and every KV page it held drain
    /// immediately. Returns false if the id is unknown (already
    /// finished, or never submitted). Driven by the HTTP front end when
    /// a streaming client disconnects mid-generation.
    pub fn cancel_request(&mut self, id: u64) -> bool {
        let now = Instant::now();
        for slot in 0..self.cfg.slots {
            if self.batcher.active[slot].as_ref().map(|rs| rs.req.id) == Some(id) {
                self.evict_slot(slot, now, FinishReason::Cancelled);
                return true;
            }
        }
        if let Some(req) = self.batcher.remove_queued(id) {
            self.records.push(RequestRecord {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                ttft_ms: 0.0,
                latency_ms: now.duration_since(req.arrival).as_secs_f64() * 1e3,
                finish: FinishReason::Cancelled,
                routed_tokens: Vec::new(),
                spec_drafted: 0,
                spec_accepted: 0,
            });
            return true;
        }
        false
    }

    /// Cheap live-counters snapshot (the `GET /metrics` engine block):
    /// no record clones, no histogram summaries — safe to call between
    /// engine steps at any frequency.
    pub fn counters_json(&self) -> Json {
        let pool = self.pool.stats();
        let mut resident_peak = self.kv_resident_peak;
        for st in self.states.iter().flatten() {
            resident_peak = resident_peak.max(st.kv.resident_pages_peak());
        }
        Json::from_pairs(vec![
            ("steps", Json::Num(self.steps as f64)),
            ("requests_finished", Json::Num(self.records.len() as f64)),
            (
                "completed",
                Json::Num(
                    self.records
                        .iter()
                        .filter(|r| r.finish == FinishReason::Completed)
                        .count() as f64,
                ),
            ),
            (
                "cancelled",
                Json::Num(
                    self.records
                        .iter()
                        .filter(|r| r.finish == FinishReason::Cancelled)
                        .count() as f64,
                ),
            ),
            ("rejected", Json::Num(self.rejected as f64)),
            (
                "tokens_generated",
                Json::Num(self.records.iter().map(|r| r.tokens.len()).sum::<usize>() as f64),
            ),
            ("queue_depth", Json::Num(self.batcher.queue_len() as f64)),
            ("active_slots", Json::Num(self.batcher.n_active() as f64)),
            ("kv_pages_allocated", Json::Num(pool.pages_allocated as f64)),
            ("kv_pages_peak", Json::Num(pool.pages_peak as f64)),
            (
                "kv_resident_pages_peak",
                Json::Num(resident_peak as f64),
            ),
        ])
    }

    /// One engine iteration: admit (+ chunked prefill) → batched decode →
    /// sample → advance/recycle. Returns requests finished this step.
    pub fn step(&mut self) -> Result<usize> {
        let mut finished = 0;
        for slot in self.batcher.admit() {
            // Ownership rule: whoever vacates a slot releases its pages
            // and state, so an admitted slot is always clean here.
            debug_assert!(self.states[slot].is_none());
            debug_assert_eq!(self.pool.lens(slot).iter().sum::<usize>(), 0);
            self.states[slot] = Some(if self.cfg.kv_budget_pages > 0 {
                DecodeState::bounded(
                    self.n_layers,
                    self.d_model,
                    self.cfg.kv_page_size,
                    self.cfg.kv_budget_pages,
                    None,
                )
            } else {
                self.backend.begin_decode()
            });
            let (id, prompt_len) = {
                let rs = self.batcher.active[slot]
                    .as_ref()
                    .expect("admitted slot is active");
                (rs.req.id, rs.req.prompt.len())
            };
            self.rngs[slot] = Rng::new(self.cfg.seed ^ id);
            self.slot_routed[slot] = vec![0; self.n_layers];
            self.slot_spec[slot] = SpecStats::default();
            telemetry::async_begin(
                "request",
                id,
                vec![
                    ("prompt_len", ArgValue::from(prompt_len)),
                    ("slot", ArgValue::from(slot)),
                ],
            );
            if let PrefillMode::Chunked(chunk) = self.cfg.prefill {
                finished += self.prefill_slot(slot, chunk)?;
            }
        }
        if self.batcher.idle() {
            self.update_gauges();
            return Ok(finished);
        }

        // Partition the active slots: speculative slots run their own
        // draft/verify window (multi-row, single sequence); everyone else
        // shares one batched decode call. Streams are bitwise identical
        // either way, so the mix never changes any request's tokens.
        let mut slot_ids = Vec::with_capacity(self.cfg.slots);
        let mut toks = Vec::with_capacity(self.cfg.slots);
        let mut spec_slots = Vec::new();
        for (slot, st) in self.batcher.active.iter().enumerate() {
            if let Some(rs) = st {
                if self.cfg.speculate > 0 && !rs.in_prefill() && rs.req.temperature == 0.0 {
                    spec_slots.push(slot);
                } else {
                    slot_ids.push(slot);
                    toks.push(rs.next_input());
                }
            }
        }
        if slot_ids.is_empty() && spec_slots.is_empty() {
            // Everything admitted this step already finished in prefill;
            // queued requests (if any) admit next step. Not counted as a
            // step: `steps` tallies decode iterations only, so occupancy
            // and the step budget aren't skewed by prefill-only passes
            // (each of which retires at least one queued request, so
            // they are bounded by the queue and cannot spin).
            self.update_gauges();
            return Ok(finished);
        }
        self.steps += 1;
        self.steps_active_sum += (slot_ids.len() + spec_slots.len()) as u64;
        if !slot_ids.is_empty() {
            finished += self.decode_batch_slots(&slot_ids, &toks)?;
        }
        for slot in spec_slots {
            finished += self.spec_step_slot(slot)?;
        }
        self.update_gauges();
        Ok(finished)
    }

    /// One batched decode pass over the non-speculative active slots:
    /// per-row routing telemetry, KV paging, sampling, and batcher
    /// advance. Returns the number of requests finished.
    fn decode_batch_slots(&mut self, slot_ids: &[usize], toks: &[i32]) -> Result<usize> {
        let mut finished = 0;
        let mut refs: Vec<&mut DecodeState> = Vec::with_capacity(slot_ids.len());
        let mut k = 0;
        for (slot, st) in self.states.iter_mut().enumerate() {
            if k < slot_ids.len() && slot_ids[k] == slot {
                refs.push(st.as_mut().expect("active slot missing decode state"));
                k += 1;
            }
        }
        let span = telemetry::scoped("engine_step");
        let t0 = Instant::now();
        let outs = self.backend.decode_batch(&mut refs, toks)?;
        drop(refs);
        let step_ms = t0.elapsed().as_secs_f64() * 1e3;
        span.end_with_args(vec![
            ("step", ArgValue::from(self.steps)),
            ("batch", ArgValue::from(slot_ids.len())),
            ("kv_pages", ArgValue::from(self.pool.stats().pages_allocated)),
        ]);
        self.registry.histogram("decode_step_ms").record(step_ms);
        if let Some(log) = &self.metrics_log {
            log.write(&Json::from_pairs(vec![
                ("kind", Json::Str("step".to_string())),
                ("step", Json::Num(self.steps as f64)),
                ("batch", Json::Num(slot_ids.len() as f64)),
                ("decode_ms", Json::Num(step_ms)),
                (
                    "kv_pages",
                    Json::Num(self.pool.stats().pages_allocated as f64),
                ),
                ("queue_depth", Json::Num(self.batcher.queue_len() as f64)),
            ]));
        }

        let now = Instant::now();
        for (out, &slot) in outs.iter().zip(slot_ids.iter()) {
            // Position of the token this step just fed (advance() below
            // is what increments it).
            let pos = self.batcher.active[slot]
                .as_ref()
                .expect("slot is live")
                .position;
            for (l, (&r, &g)) in out.routed.iter().zip(&out.g_attn).enumerate() {
                self.routing.record_layer(l, r as u64, 1);
                self.buckets.record(l, pos, r);
                self.slot_routed[slot][l] += u64::from(r);
                if self.is_dtr[l] {
                    self.registry
                        .histogram("router_margin")
                        .record(f64::from((2.0 * g - 1.0).abs()));
                }
            }
            if !self.pool.append(slot, &out.routed) {
                // Page budget hit — a production engine would preempt and
                // requeue; this one finishes the request early.
                self.evict_slot(slot, now, FinishReason::KvExhausted);
                finished += 1;
                continue;
            }
            self.dense_shadow.append(slot, &self.all_routed);
            // Only sample when this step actually produces a generated
            // token (mid-prefill outputs are discarded). Keeps RNG draws
            // at exactly one per generated token, so token streams are
            // identical across prefill modes even with temperature > 0.
            let produces_token = {
                let rs = self.batcher.active[slot].as_ref().expect("slot is live");
                !rs.in_prefill() || rs.prompt_cursor + 1 == rs.req.prompt.len()
            };
            let sampled = if produces_token {
                self.sample_slot(slot, out.logits.as_f32())
            } else {
                0
            };
            if self.batcher.advance(slot, sampled, now) {
                self.record_finish(slot, now, FinishReason::Completed);
                self.release_slot(slot);
                finished += 1;
            } else if self.slot_at_cap(slot) {
                self.evict_slot(slot, now, FinishReason::ContextCap);
                finished += 1;
            }
        }
        Ok(finished)
    }

    /// One speculative draft/verify iteration for `slot` (greedy request,
    /// past prefill): draft up to `cfg.speculate` tokens on the bypass,
    /// verify them in one batched full-router pass, commit the accepted
    /// prefix. Transient windows (draft rows, then the verify rows) are
    /// written into the KV pool and rolled back, so speculative pages are
    /// released exactly on rejection while the committed accounting —
    /// peaks included — stays bitwise that of a plain decode run. Returns
    /// the number of requests finished (0 or 1).
    fn spec_step_slot(&mut self, slot: usize) -> Result<usize> {
        let (last, budget, history) = {
            let rs = self.batcher.active[slot].as_ref().expect("spec slot is live");
            let remaining = rs.req.max_new_tokens - rs.generated.len();
            // Cap the window at the engine's position cap so eviction
            // fires at exactly the token count of a plain run.
            let cap_room = self.cfg.max_seq.saturating_sub(rs.position).max(1);
            (rs.next_input(), remaining.min(cap_room), rs.generated.clone())
        };
        let params = SamplingParams {
            temperature: 0.0,
            ..self.cfg.sampling
        };
        let mut dec = SpeculativeDecoder::new(self.backend, self.cfg.speculate)?;
        let span = telemetry::scoped("spec_verify");
        let t0 = Instant::now();
        let state = self.states[slot].as_mut().expect("spec slot has state");
        let it = dec.step(state, last, budget, &params, &history, &mut self.rngs[slot])?;
        let step_ms = t0.elapsed().as_secs_f64() * 1e3;
        span.end_with_args(vec![
            ("slot", ArgValue::from(slot)),
            ("drafted", ArgValue::from(it.drafted)),
            ("accepted", ArgValue::from(it.accepted)),
            ("emitted", ArgValue::from(it.emitted.len())),
        ]);
        self.registry.histogram("decode_step_ms").record(step_ms);
        self.registry
            .histogram("spec_accepted_len")
            .record(it.emitted.len() as f64);
        let ds = SpecStats {
            drafted: it.drafted as u64,
            accepted: it.accepted as u64,
            iterations: 1,
            emitted: it.emitted.len() as u64,
        };
        self.slot_spec[slot].merge(&ds);
        self.spec.merge(&ds);

        // Speculative KV pages live only inside their window.
        self.spec_window(slot, &it.draft_routed);
        self.spec_window(slot, &it.verify_routed);

        // Commit the accepted rows: the same telemetry → paging → advance
        // sequence the plain decode path runs once per engine step.
        let now = Instant::now();
        let mut pos = self.batcher.active[slot]
            .as_ref()
            .expect("spec slot is live")
            .position;
        for (row, &tok) in it.rows.iter().zip(&it.emitted) {
            for (l, (&r, &g)) in row.routed.iter().zip(&row.g_attn).enumerate() {
                self.routing.record_layer(l, r as u64, 1);
                self.buckets.record(l, pos, r);
                self.slot_routed[slot][l] += u64::from(r);
                if self.is_dtr[l] {
                    self.registry
                        .histogram("router_margin")
                        .record(f64::from((2.0 * g - 1.0).abs()));
                }
            }
            if !self.pool.append(slot, &row.routed) {
                // The committed row a plain run would also have failed
                // on; eviction releases the cache rows past it too.
                self.evict_slot(slot, now, FinishReason::KvExhausted);
                return Ok(1);
            }
            self.dense_shadow.append(slot, &self.all_routed);
            pos += 1;
            if self.batcher.advance(slot, tok, now) {
                self.record_finish(slot, now, FinishReason::Completed);
                self.release_slot(slot);
                return Ok(1);
            }
        }
        if self.slot_at_cap(slot) {
            self.evict_slot(slot, now, FinishReason::ContextCap);
            return Ok(1);
        }
        Ok(0)
    }

    /// Write a transient speculative window into the pool, then roll it
    /// back: draft/rejected pages exist only between `spec_begin` and
    /// `spec_rollback`, and committed stats (peaks included) stay bitwise
    /// those of a never-speculated run. A window the budget cannot hold
    /// is simply abandoned — transient pages must never evict anyone.
    fn spec_window(&mut self, slot: usize, rows: &[Vec<bool>]) {
        if rows.is_empty() {
            return;
        }
        let pmark = self.pool.spec_begin(slot);
        let dmark = self.dense_shadow.spec_begin(slot);
        for r in rows {
            if !self.pool.append(slot, r) {
                break;
            }
            self.dense_shadow.append(slot, &self.all_routed);
        }
        self.pool.spec_rollback(&pmark);
        self.dense_shadow.spec_rollback(&dmark);
    }

    /// Run until every already-submitted request finishes. If the
    /// cumulative `max_steps` bound trips first, everything still queued
    /// or in flight is retired as [`FinishReason::Cancelled`], so the
    /// report's accounting stays closed.
    pub fn run_to_completion(&mut self, max_steps: usize) -> Result<ServeReport> {
        let t0 = Instant::now();
        while !self.batcher.idle() && self.steps < max_steps {
            self.step()?;
        }
        self.cancel_in_flight();
        Ok(self.report(t0.elapsed().as_secs_f64()))
    }

    /// Drive a timed workload trace end-to-end: open-loop arrivals (a
    /// request is submitted once its offset has elapsed), then drain.
    /// When the engine is otherwise idle, a virtual clock jumps to the
    /// next arrival instant instead of spinning — and because the jump
    /// moves *time* rather than submitting a single request, a burst of
    /// near-simultaneous delayed arrivals still lands together and gets
    /// batched rather than serialized.
    pub fn run_workload(
        &mut self,
        trace: &[TimedRequest],
        max_steps: usize,
    ) -> Result<ServeReport> {
        let t0 = Instant::now();
        let mut next = 0;
        let mut skipped_s = 0.0f64; // virtual time fast-forwarded while idle
        while (next < trace.len() || !self.batcher.idle()) && self.steps < max_steps {
            let now_s = t0.elapsed().as_secs_f64() + skipped_s;
            while next < trace.len() && trace[next].offset_s <= now_s {
                self.submit_traced(&trace[next]);
                next += 1;
            }
            if self.batcher.idle() && next < trace.len() {
                skipped_s += trace[next].offset_s - now_s;
                continue; // re-enter the submission loop at the new time
            }
            self.step()?;
        }
        self.cancel_in_flight();
        Ok(self.report(t0.elapsed().as_secs_f64()))
    }

    /// Retire every queued or in-flight request as cancelled (step bound
    /// exhausted). No-op when the engine is idle.
    fn cancel_in_flight(&mut self) {
        let now = Instant::now();
        for slot in 0..self.cfg.slots {
            if self.batcher.active[slot].is_some() {
                self.evict_slot(slot, now, FinishReason::Cancelled);
            }
        }
        for req in self.batcher.drain_queue() {
            self.records.push(RequestRecord {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                ttft_ms: 0.0,
                latency_ms: now.duration_since(req.arrival).as_secs_f64() * 1e3,
                finish: FinishReason::Cancelled,
                // Never admitted: no tokens ever fed, no routing decisions.
                routed_tokens: Vec::new(),
                spec_drafted: 0,
                spec_accepted: 0,
            });
        }
    }

    fn submit_traced(&mut self, tr: &TimedRequest) {
        let mut req = tr.request.clone();
        // The trace records offsets; latency is measured from actual
        // submission, not trace generation.
        req.arrival = Instant::now();
        self.submit(req); // refusals are counted by submit itself
    }

    /// Chunked prefill of a freshly admitted slot. Returns 1 if the
    /// request finished already (single-token generations, eviction).
    fn prefill_slot(&mut self, slot: usize, chunk: usize) -> Result<usize> {
        let prompt = self.batcher.active[slot]
            .as_ref()
            .expect("prefill target is active")
            .req
            .prompt
            .clone();
        let t0 = Instant::now();
        let span = telemetry::scoped("prefill");
        let state = self.states[slot].as_mut().expect("admitted slot has state");
        let out = self.backend.prefill_rows(state, &prompt, chunk)?;
        let lens = state.lens(self.d_model);
        span.end_with_args(vec![
            ("slot", ArgValue::from(slot)),
            ("prompt_len", ArgValue::from(prompt.len())),
        ]);
        self.registry
            .histogram("prefill_ms")
            .record(t0.elapsed().as_secs_f64() * 1e3);
        // Per-row routing telemetry: a freshly admitted slot starts at
        // position 0, so row index == absolute token position.
        for (row, (routed, g_row)) in out.routed.iter().zip(&out.g_attn).enumerate() {
            for (l, (&r, &g)) in routed.iter().zip(g_row).enumerate() {
                self.routing.record_layer(l, u64::from(r), 1);
                self.buckets.record(l, row, r);
                self.slot_routed[slot][l] += u64::from(r);
                if self.is_dtr[l] {
                    self.registry
                        .histogram("router_margin")
                        .record(f64::from((2.0 * g - 1.0).abs()));
                }
            }
        }
        let now = Instant::now();
        if !self.pool.append_prefill(slot, &lens, prompt.len()) {
            self.evict_slot(slot, now, FinishReason::KvExhausted);
            return Ok(1);
        }
        self.dense_shadow
            .append_prefill(slot, &vec![prompt.len(); self.n_layers], prompt.len());
        let sampled = self.sample_slot(slot, out.last.logits.as_f32());
        if self.batcher.complete_prefill(slot, sampled, now) {
            self.record_finish(slot, now, FinishReason::Completed);
            self.release_slot(slot);
            return Ok(1);
        }
        if self.slot_at_cap(slot) {
            self.evict_slot(slot, now, FinishReason::ContextCap);
            return Ok(1);
        }
        Ok(0)
    }

    fn slot_at_cap(&self, slot: usize) -> bool {
        self.batcher.active[slot]
            .as_ref()
            .map(|rs| rs.position >= self.cfg.max_seq)
            .unwrap_or(false)
    }

    fn sample_slot(&mut self, slot: usize, logits: &[f32]) -> i32 {
        let st = self.batcher.active[slot]
            .as_ref()
            .expect("sampling a vacant slot");
        let params = SamplingParams {
            temperature: st.req.temperature,
            ..self.cfg.sampling
        };
        sample(logits, &params, &st.generated, &mut self.rngs[slot])
    }

    /// Free a finished slot's pages and decode state (the request itself
    /// was already retired into `batcher.completed`).
    fn release_slot(&mut self, slot: usize) {
        if let Some(st) = &self.states[slot] {
            self.kv_resident_peak = self.kv_resident_peak.max(st.kv.resident_pages_peak());
        }
        self.pool.release(slot);
        self.dense_shadow.release(slot);
        self.states[slot] = None;
    }

    /// Force-finish a live slot (pool exhaustion / context cap).
    fn evict_slot(&mut self, slot: usize, now: Instant, reason: FinishReason) {
        if let Some(st) = self.batcher.active[slot].take() {
            telemetry::instant(
                "evict",
                vec![
                    ("slot", ArgValue::from(slot)),
                    ("reason", ArgValue::from(reason.as_str())),
                ],
            );
            self.batcher.completed.push(st);
            self.record_finish(slot, now, reason);
        }
        self.release_slot(slot);
    }

    /// Build the [`RequestRecord`] for the request most recently pushed
    /// onto `batcher.completed` (which vacated `slot`).
    fn record_finish(&mut self, slot: usize, now: Instant, reason: FinishReason) {
        let st = self
            .batcher
            .completed
            .last()
            .expect("finish without a completed request");
        // TTFT exists only if a first token was actually produced — a
        // zero-token eviction must not fabricate one into the histogram.
        let ttft = st
            .first_token_at
            .map(|t| t.duration_since(st.req.arrival).as_secs_f64() * 1e3);
        let latency_ms = now.duration_since(st.req.arrival).as_secs_f64() * 1e3;
        if let Some(ms) = ttft {
            self.registry.histogram("ttft_ms").record(ms);
        }
        self.registry.histogram("request_latency_ms").record(latency_ms);
        self.registry.counter("requests_finished").inc();
        let routed_tokens = std::mem::take(&mut self.slot_routed[slot]);
        let spec = std::mem::take(&mut self.slot_spec[slot]);
        telemetry::async_end(
            "request",
            st.req.id,
            vec![
                ("finish", ArgValue::from(reason.as_str())),
                ("n_tokens", ArgValue::from(st.generated.len())),
            ],
        );
        if let Some(log) = &self.metrics_log {
            log.write(&Json::from_pairs(vec![
                ("kind", Json::Str("request".to_string())),
                ("id", Json::Num(st.req.id as f64)),
                ("finish", Json::Str(reason.as_str().to_string())),
                ("prompt_len", Json::Num(st.req.prompt.len() as f64)),
                ("n_tokens", Json::Num(st.generated.len() as f64)),
                ("ttft_ms", ttft.map(Json::Num).unwrap_or(Json::Null)),
                ("latency_ms", Json::Num(latency_ms)),
                (
                    "routed_tokens",
                    Json::Arr(routed_tokens.iter().map(|&c| Json::Num(c as f64)).collect()),
                ),
                ("spec_drafted", Json::Num(spec.drafted as f64)),
                ("spec_accepted", Json::Num(spec.accepted as f64)),
            ]));
        }
        self.records.push(RequestRecord {
            id: st.req.id,
            prompt_len: st.req.prompt.len(),
            tokens: st.generated.clone(),
            ttft_ms: ttft.unwrap_or(0.0),
            latency_ms,
            finish: reason,
            routed_tokens,
            spec_drafted: spec.drafted,
            spec_accepted: spec.accepted,
        });
    }

    fn update_gauges(&self) {
        self.registry
            .gauge("queue_depth")
            .set(self.batcher.queue_len() as f64);
        self.registry
            .gauge("active_slots")
            .set(self.batcher.n_active() as f64);
        self.registry
            .gauge("kv_pages_allocated")
            .set(self.pool.stats().pages_allocated as f64);
    }

    fn report(&self, wall_s: f64) -> ServeReport {
        let step_h = self.registry.histogram("decode_step_ms").summary();
        let ttft_h = self.registry.histogram("ttft_ms").summary();
        let lat_h = self.registry.histogram("request_latency_ms").summary();
        let pool = self.pool.stats();
        let dense = self.dense_shadow.stats();
        let tokens_generated: usize = self.records.iter().map(|r| r.tokens.len()).sum();
        let prompt_tokens: usize = self.records.iter().map(|r| r.prompt_len).sum();
        let kv_savings_ratio = if pool.tokens_seen > 0 {
            pool.tokens_cached as f64 / (pool.tokens_seen * self.n_layers) as f64
        } else {
            1.0
        };
        ServeReport {
            backend: self.backend.name().to_string(),
            completed: self
                .records
                .iter()
                .filter(|r| r.finish == FinishReason::Completed)
                .count(),
            evicted: self
                .records
                .iter()
                .filter(|r| r.finish != FinishReason::Completed)
                .count(),
            rejected: self.rejected,
            tokens_generated,
            prompt_tokens,
            steps: self.steps,
            wall_s,
            tokens_per_s: if wall_s > 0.0 {
                tokens_generated as f64 / wall_s
            } else {
                0.0
            },
            decode_step_ms_p50: step_h.p50.unwrap_or(0.0),
            decode_step_ms_p99: step_h.p99.unwrap_or(0.0),
            ttft_ms_p50: ttft_h.p50.unwrap_or(0.0),
            ttft_ms_p99: ttft_h.p99.unwrap_or(0.0),
            latency_ms_p50: lat_h.p50.unwrap_or(0.0),
            latency_ms_p99: lat_h.p99.unwrap_or(0.0),
            batch_occupancy: if self.steps > 0 {
                self.steps_active_sum as f64 / (self.steps * self.cfg.slots) as f64
            } else {
                0.0
            },
            pool,
            dense_pages_peak: dense.pages_peak,
            kv_resident_pages_peak: {
                let mut peak = self.kv_resident_peak;
                for st in self.states.iter().flatten() {
                    peak = peak.max(st.kv.resident_pages_peak());
                }
                peak
            },
            kv_savings_ratio,
            weight_bytes: self.backend.weight_bytes(),
            routing: self.routing.clone(),
            attn_fracs: self.routing.fractions(),
            position_buckets: self.buckets.to_json(),
            router_margin: self.registry.histogram("router_margin").summary().to_json(),
            measured_flops: self.backend.flop_counters().map(|f| f.to_json()),
            spec: self.spec,
            requests: self.records.clone(),
            kernel_timings: self.backend.kernel_timings(),
            simd_tier: crate::util::simd::tier().name().to_string(),
            precision: crate::util::simd::precision().name().to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Variant};
    use crate::runtime::CpuBackend;

    fn backend() -> CpuBackend {
        CpuBackend::init(&ModelConfig::preset("xs", Variant::DtrBilayer), 3).unwrap()
    }

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request {
            id,
            prompt: (0..prompt_len).map(|i| (i as i32 * 7 + id as i32) % 256).collect(),
            max_new_tokens: gen,
            temperature: 0.0,
            arrival: Instant::now(),
        }
    }

    #[test]
    fn serves_more_requests_than_slots() {
        let be = backend();
        let cfg = ServerConfig {
            slots: 2,
            ..Default::default()
        };
        let mut srv = Server::new(&be, cfg).unwrap();
        for i in 0..5 {
            assert!(srv.submit(req(i, 6, 4)));
        }
        let rep = srv.run_to_completion(10_000).unwrap();
        assert_eq!(rep.completed, 5);
        assert_eq!(rep.evicted, 0);
        assert_eq!(rep.tokens_generated, 20);
        for r in &rep.requests {
            assert_eq!(r.tokens.len(), 4, "request {} short", r.id);
            assert_eq!(r.finish, FinishReason::Completed);
        }
        // all pages returned after the run
        assert_eq!(srv.pool.stats().pages_allocated, 0);
    }

    #[test]
    fn quant_backend_serves_and_reports_weight_compression() {
        let f32_be = backend();
        let be = f32_be.quantized().unwrap();
        let cfg = ServerConfig {
            slots: 2,
            ..Default::default()
        };
        let mut srv = Server::new(&be, cfg).unwrap();
        for i in 0..3 {
            assert!(srv.submit(req(i, 6, 4)));
        }
        let rep = srv.run_to_completion(10_000).unwrap();
        assert_eq!(rep.completed, 3);
        assert_eq!(rep.backend, "cpu-int8");
        assert!(
            rep.weight_bytes.compression() >= 3.5,
            "int8 serve must report >=3.5x weight compression, got {:.3}",
            rep.weight_bytes.compression()
        );
        // the f32 backend reports parity (resident == f32-equivalent)
        let wb = f32_be.weight_bytes();
        assert_eq!(wb.resident, wb.f32_equiv);
        assert_eq!(wb.compression(), 1.0);
        let js = rep.to_json();
        assert!(js.path("weight_compression").unwrap().as_f64().unwrap() >= 3.5);
    }

    #[test]
    fn report_carries_routing_and_flops_telemetry() {
        let be = backend();
        let cfg = ServerConfig {
            slots: 2,
            ..Default::default()
        };
        let mut srv = Server::new(&be, cfg).unwrap();
        be.flop_counters()
            .expect("cpu backend measures flops")
            .reset();
        for i in 0..3 {
            assert!(srv.submit(req(i, 12, 6)));
        }
        let rep = srv.run_to_completion(10_000).unwrap();
        assert_eq!(rep.completed, 3);

        // Per-request routed counts: one entry per layer; the tokens fed
        // through the model are the prompt plus all generated tokens but
        // the last (sampled without a further decode step).
        for r in &rep.requests {
            let fed = (r.prompt_len + r.tokens.len() - 1) as u64;
            assert!(!r.routed_tokens.is_empty(), "routed_tokens missing");
            for (l, &c) in r.routed_tokens.iter().enumerate() {
                assert!(c <= fed, "layer {l}: routed {c} > fed {fed}");
            }
            // Dense layers (even indices in DtrBilayer) route everything.
            assert_eq!(r.routed_tokens[0], fed, "dense layer must route all");
        }

        // Router margins were recorded for DTR layers only; all in [0, 1].
        let margin_count = rep
            .router_margin
            .path("count")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(margin_count > 0.0, "no router margins recorded");

        // Position buckets resolved at least one bucket row.
        match &rep.position_buckets {
            Json::Arr(rows) => assert!(!rows.is_empty(), "no position buckets"),
            other => panic!("position_buckets must be an array, got {other:?}"),
        }

        // Measured FLOPs: dense layers reconcile *exactly* against the
        // dense-equivalent tally (same terms, same actual cache lens);
        // every layer's ratio is positive and the totals are non-zero.
        let mf = rep.measured_flops.as_ref().expect("cpu backend flops");
        assert!(mf.path("total").and_then(Json::as_f64).unwrap() > 0.0);
        let layers = match mf.path("layers") {
            Some(Json::Arr(l)) => l.clone(),
            other => panic!("measured_flops.layers must be an array: {other:?}"),
        };
        for (l, row) in layers.iter().enumerate() {
            let ratio = row.path("ratio_vs_dense").and_then(Json::as_f64).unwrap();
            assert!(ratio > 0.0, "layer {l} ratio {ratio}");
            if l % 2 == 0 {
                assert!(
                    (ratio - 1.0).abs() < 1e-9,
                    "dense layer {l} must measure exactly dense: {ratio}"
                );
            }
        }

        // And the JSON document carries all three blocks.
        let js = rep.to_json();
        assert!(js.path("measured_flops.total").is_some());
        assert!(js.path("position_buckets").is_some());
        assert!(js.path("router_margin.count").is_some());
    }

    #[test]
    fn rejects_malformed_requests() {
        let be = backend();
        let mut srv = Server::new(&be, ServerConfig::default()).unwrap();
        assert!(!srv.submit(req(0, 0, 4)), "empty prompt");
        assert!(!srv.submit(req(1, 4, 0)), "zero generation budget");
        assert!(!srv.submit(req(2, 65, 4)), "prompt past the xs position cap");
        let oov = Request {
            id: 3,
            prompt: vec![0, 999],
            max_new_tokens: 4,
            temperature: 0.0,
            arrival: Instant::now(),
        };
        assert!(!srv.submit(oov), "out-of-vocabulary prompt token");
        assert!(srv.batcher.idle());
    }

    #[test]
    fn step_budget_cancels_cleanly() {
        let be = backend();
        let mut srv = Server::new(&be, ServerConfig::default()).unwrap();
        for i in 0..3 {
            assert!(srv.submit(req(i, 6, 50)));
        }
        let rep = srv.run_to_completion(2).unwrap();
        assert_eq!(rep.requests.len(), 3, "nothing may vanish at the step bound");
        assert!(rep
            .requests
            .iter()
            .all(|r| r.finish == FinishReason::Cancelled));
        assert_eq!(rep.completed + rep.evicted, 3);
        assert_eq!(srv.pool.stats().pages_allocated, 0);
        assert!(srv.batcher.idle());
    }

    #[test]
    fn decode_prefill_mode_matches_chunked_token_streams() {
        let be = backend();
        let run = |prefill| {
            let cfg = ServerConfig {
                slots: 2,
                prefill,
                ..Default::default()
            };
            let mut srv = Server::new(&be, cfg).unwrap();
            for i in 0..4 {
                srv.submit(req(i, 9, 5));
            }
            let mut rep = srv.run_to_completion(10_000).unwrap();
            rep.requests.sort_by_key(|r| r.id);
            rep.requests
                .into_iter()
                .map(|r| r.tokens)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(PrefillMode::Decode), run(PrefillMode::Chunked(4)));
    }

    #[test]
    fn speculative_serve_matches_plain_and_frees_pages() {
        let be = backend();
        let run = |speculate| {
            let cfg = ServerConfig {
                slots: 2,
                speculate,
                ..Default::default()
            };
            let mut srv = Server::new(&be, cfg).unwrap();
            for i in 0..4 {
                assert!(srv.submit(req(i, 7, 6)));
            }
            let mut rep = srv.run_to_completion(10_000).unwrap();
            assert_eq!(srv.pool.stats().pages_allocated, 0, "pages-to-zero");
            assert_eq!(srv.dense_shadow.stats().pages_allocated, 0);
            rep.requests.sort_by_key(|r| r.id);
            rep
        };
        let plain = run(0);
        let spec = run(3);
        let toks = |rep: &ServeReport| {
            rep.requests.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
        };
        assert_eq!(toks(&spec), toks(&plain), "greedy streams must be bitwise equal");
        assert_eq!(plain.spec, SpecStats::default());
        assert!(spec.spec.drafted > 0, "speculation never engaged");
        assert!(spec.spec.accepted <= spec.spec.drafted);
        // Every iteration emits at least one token, so speculation can
        // only cut engine steps (strictly, when any draft is accepted).
        assert!(spec.steps <= plain.steps);
        // Committed accounting — peaks included — matches the plain run.
        assert_eq!(spec.pool.pages_peak, plain.pool.pages_peak);
        assert_eq!(spec.pool.tokens_cached, plain.pool.tokens_cached);
        assert_eq!(spec.pool.tokens_seen, plain.pool.tokens_seen);
        assert_eq!(spec.attn_fracs, plain.attn_fracs);
        // Per-request counters sum to the engine totals and land in JSON.
        let drafted: u64 = spec.requests.iter().map(|r| r.spec_drafted).sum();
        let accepted: u64 = spec.requests.iter().map(|r| r.spec_accepted).sum();
        assert_eq!(drafted, spec.spec.drafted);
        assert_eq!(accepted, spec.spec.accepted);
        let js = spec.to_json();
        let rate = js.path("spec_acceptance_rate").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&rate));
        assert!(js.path("spec_mean_accepted_len").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn speculative_serve_respects_context_cap() {
        let be = backend();
        let cfg = ServerConfig {
            slots: 1,
            max_seq: 16,
            speculate: 4,
            ..Default::default()
        };
        let mut srv = Server::new(&be, cfg).unwrap();
        srv.submit(req(0, 8, 1000));
        let rep = srv.run_to_completion(10_000).unwrap();
        assert_eq!(rep.requests[0].finish, FinishReason::ContextCap);
        // The window cap keeps fed tokens at exactly the plain-run count.
        assert!(rep.requests[0].prompt_len + rep.requests[0].tokens.len() <= 17);
        assert_eq!(srv.pool.stats().pages_allocated, 0);
    }

    #[test]
    fn kv_budget_eviction_frees_the_slot() {
        let be = backend();
        // Budget fits barely one short sequence's pages (4 layers, page 4).
        let cfg = ServerConfig {
            slots: 1,
            kv_page_size: 4,
            max_kv_pages: 4,
            prefill: PrefillMode::Decode,
            ..Default::default()
        };
        let mut srv = Server::new(&be, cfg).unwrap();
        srv.submit(req(0, 8, 40));
        srv.submit(req(1, 8, 40));
        let rep = srv.run_to_completion(10_000).unwrap();
        assert_eq!(rep.requests.len(), 2, "both requests must leave the engine");
        assert!(
            rep.requests.iter().any(|r| r.finish == FinishReason::KvExhausted),
            "tiny page budget must evict: {:?}",
            rep.requests.iter().map(|r| r.finish).collect::<Vec<_>>()
        );
        assert_eq!(srv.pool.stats().pages_allocated, 0);
    }

    #[test]
    fn bounded_kv_budget_matches_resident_streams_and_caps_pages() {
        let be = backend();
        let run = |kv_budget_pages| {
            let cfg = ServerConfig {
                slots: 2,
                kv_page_size: 4,
                kv_budget_pages,
                ..Default::default()
            };
            let mut srv = Server::new(&be, cfg).unwrap();
            for i in 0..4 {
                assert!(srv.submit(req(i, 9, 6)));
            }
            let mut rep = srv.run_to_completion(10_000).unwrap();
            rep.requests.sort_by_key(|r| r.id);
            rep
        };
        let resident = run(0);
        let bounded = run(6);
        let toks = |rep: &ServeReport| {
            rep.requests.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
        };
        // The budget bounds memory, never what attention sees.
        assert_eq!(toks(&bounded), toks(&resident), "streams must be bitwise equal");
        assert_eq!(resident.kv_resident_pages_peak, 0, "slab path reports 0");
        assert!(bounded.kv_resident_pages_peak > 0, "bounded peak must be tracked");
        assert!(
            bounded.kv_resident_pages_peak <= 6,
            "resident pages exceeded the budget: {}",
            bounded.kv_resident_pages_peak
        );
        let js = bounded.to_json();
        assert!(js.path("kv_resident_pages_peak").unwrap().as_f64().unwrap() <= 6.0);
    }

    #[test]
    fn cancel_request_drains_pages_and_records_cancelled() {
        let be = backend();
        let cfg = ServerConfig {
            slots: 1,
            ..Default::default()
        };
        let mut srv = Server::new(&be, cfg).unwrap();
        assert!(srv.submit(req(0, 6, 50)));
        assert!(srv.submit(req(1, 6, 50)));
        // Request 0 admits and generates; request 1 waits in the queue.
        for _ in 0..3 {
            srv.step().unwrap();
        }
        assert!(srv.cancel_request(0), "live request must cancel");
        assert_eq!(srv.pool.stats().pages_allocated, 0, "cancelled slot must drain");
        assert!(srv.cancel_request(1), "queued request must cancel");
        assert!(!srv.cancel_request(7), "unknown id");
        assert!(srv.batcher.idle());
        let rep = srv.report_now(0.0);
        assert_eq!(rep.requests.len(), 2, "both cancellations must be recorded");
        assert!(rep
            .requests
            .iter()
            .all(|r| r.finish == FinishReason::Cancelled));
    }

    #[test]
    fn context_cap_stops_runaway_generation() {
        let be = backend();
        let cfg = ServerConfig {
            slots: 1,
            max_seq: 16,
            ..Default::default()
        };
        let mut srv = Server::new(&be, cfg).unwrap();
        srv.submit(req(0, 8, 1000));
        let rep = srv.run_to_completion(10_000).unwrap();
        assert_eq!(rep.requests.len(), 1);
        assert_eq!(rep.requests[0].finish, FinishReason::ContextCap);
        // fed tokens never exceed the cap
        assert!(rep.requests[0].prompt_len + rep.requests[0].tokens.len() <= 17);
    }
}
