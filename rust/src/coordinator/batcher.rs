//! Continuous batching: request queue + decode-slot management.
//!
//! The decode artifact has a fixed batch width B (slots). The batcher
//! admits queued requests into free slots between decode steps — the
//! vLLM-style iteration-level scheduling the paper's serving analysis
//! assumes — and recycles slots on completion. Inactive slots decode a pad
//! token whose outputs are discarded.

use std::collections::VecDeque;
use std::time::Instant;

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-assigned request id (unique per run; seeds the sampler).
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generation budget (the request retires after this many tokens).
    pub max_new_tokens: usize,
    /// Per-request sampling temperature (0 = greedy).
    pub temperature: f32,
    /// Submission timestamp (latency/TTFT are measured from it).
    pub arrival: Instant,
}

/// Lifecycle state of an admitted request.
#[derive(Debug)]
pub struct RequestState {
    /// The request being served.
    pub req: Request,
    /// Decode slot this request occupies.
    pub slot: usize,
    /// Tokens generated so far (excludes prompt).
    pub generated: Vec<i32>,
    /// Next prompt token index still to be fed (prefill-by-decode).
    pub prompt_cursor: usize,
    /// Absolute position of the next token fed to the model.
    pub position: usize,
    /// When the first generated token appeared (None until then).
    pub first_token_at: Option<Instant>,
    /// When the request left the queue for its slot.
    pub admitted_at: Instant,
}

impl RequestState {
    /// Still consuming prompt tokens (stepwise-prefill mode)?
    pub fn in_prefill(&self) -> bool {
        self.prompt_cursor < self.req.prompt.len()
    }

    /// Has the generation budget been spent?
    pub fn done(&self) -> bool {
        self.generated.len() >= self.req.max_new_tokens
    }

    /// The token to feed at the next decode step.
    pub fn next_input(&self) -> i32 {
        if self.in_prefill() {
            self.req.prompt[self.prompt_cursor]
        } else {
            *self.generated.last().unwrap_or(&0)
        }
    }
}

/// Slot-based continuous batcher.
pub struct Batcher {
    n_slots: usize,
    queue: VecDeque<Request>,
    /// One entry per decode slot (None = vacant).
    pub active: Vec<Option<RequestState>>,
    /// Requests retired from their slots, in completion order.
    pub completed: Vec<RequestState>,
    max_queue: usize,
}

impl Batcher {
    /// A batcher with `n_slots` decode slots and a `max_queue` bound.
    pub fn new(n_slots: usize, max_queue: usize) -> Batcher {
        Batcher {
            n_slots,
            queue: VecDeque::new(),
            active: (0..n_slots).map(|_| None).collect(),
            completed: Vec::new(),
            max_queue,
        }
    }

    /// Enqueue; returns false if the queue is full (backpressure).
    pub fn submit(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.max_queue {
            return false;
        }
        self.queue.push_back(req);
        true
    }

    /// Fill free slots from the queue; returns newly admitted slot ids.
    pub fn admit(&mut self) -> Vec<usize> {
        let mut admitted = Vec::new();
        for slot in 0..self.n_slots {
            if self.active[slot].is_none() {
                if let Some(req) = self.queue.pop_front() {
                    self.active[slot] = Some(RequestState {
                        req,
                        slot,
                        generated: Vec::new(),
                        prompt_cursor: 0,
                        position: 0,
                        first_token_at: None,
                        admitted_at: Instant::now(),
                    });
                    admitted.push(slot);
                }
            }
        }
        admitted
    }

    /// Apply one decode-step result for `slot`: the sampled token (only
    /// meaningful when the slot finished prefill). Advances cursors;
    /// retires the request when done. Returns true if the slot completed.
    pub fn advance(&mut self, slot: usize, sampled: i32, now: Instant) -> bool {
        let Some(st) = self.active[slot].as_mut() else {
            return false;
        };
        if st.in_prefill() {
            st.prompt_cursor += 1;
            st.position += 1;
            // Transition: the step that consumed the last prompt token also
            // produced the first generated token.
            if !st.in_prefill() {
                st.first_token_at = Some(now);
                st.generated.push(sampled);
            }
        } else {
            st.generated.push(sampled);
            st.position += 1;
        }
        if st.done() {
            let st = self.active[slot].take().unwrap();
            self.completed.push(st);
            return true;
        }
        false
    }

    /// Fast-forward `slot` through its entire prompt: the engine ingested
    /// every prompt token in one chunked-prefill shot and sampled `first`
    /// from the returned logits (chunked prefill collapses what
    /// [`Batcher::advance`] would see as `prompt.len()` separate steps).
    /// Records the first generated token and TTFT; retires the request if
    /// it is already done. Returns true if the slot completed.
    pub fn complete_prefill(&mut self, slot: usize, first: i32, now: Instant) -> bool {
        let Some(st) = self.active[slot].as_mut() else {
            return false;
        };
        st.prompt_cursor = st.req.prompt.len();
        st.position = st.req.prompt.len();
        st.first_token_at = Some(now);
        st.generated.push(first);
        if st.done() {
            let st = self.active[slot].take().unwrap();
            self.completed.push(st);
            return true;
        }
        false
    }

    /// Occupied decode slots.
    pub fn n_active(&self) -> usize {
        self.active.iter().filter(|s| s.is_some()).count()
    }

    /// Requests waiting for a slot.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Remove and return every queued (not-yet-admitted) request — used
    /// by the engine to retire the backlog when a run is cut short.
    pub fn drain_queue(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }

    /// Remove one queued request by id (client cancelled before
    /// admission). Returns it if it was still waiting.
    pub fn remove_queued(&mut self, id: u64) -> Option<Request> {
        let idx = self.queue.iter().position(|r| r.id == id)?;
        self.queue.remove(idx)
    }

    /// No queued work and no active slots.
    pub fn idle(&self) -> bool {
        self.n_active() == 0 && self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request {
            id,
            prompt: (0..prompt_len as i32).collect(),
            max_new_tokens: gen,
            temperature: 0.0,
            arrival: Instant::now(),
        }
    }

    #[test]
    fn admits_into_free_slots() {
        let mut b = Batcher::new(2, 10);
        for i in 0..3 {
            assert!(b.submit(req(i, 4, 2)));
        }
        let admitted = b.admit();
        assert_eq!(admitted, vec![0, 1]);
        assert_eq!(b.n_active(), 2);
        assert_eq!(b.queue_len(), 1);
    }

    #[test]
    fn lifecycle_prefill_then_generate() {
        let mut b = Batcher::new(1, 10);
        b.submit(req(1, 3, 2));
        b.admit();
        let now = Instant::now();
        // 3 prefill steps; last one yields first generated token
        assert!(!b.advance(0, 100, now));
        assert!(!b.advance(0, 101, now));
        assert!(!b.advance(0, 102, now)); // first gen token
        // one more generated token → done
        assert!(b.advance(0, 103, now));
        assert_eq!(b.completed.len(), 1);
        assert_eq!(b.completed[0].generated, vec![102, 103]);
        assert!(b.active[0].is_none());
    }

    #[test]
    fn complete_prefill_fast_forwards_prompt() {
        let mut b = Batcher::new(1, 10);
        b.submit(req(1, 5, 3));
        b.admit();
        let now = Instant::now();
        assert!(!b.complete_prefill(0, 42, now));
        let st = b.active[0].as_ref().unwrap();
        assert!(!st.in_prefill());
        assert_eq!(st.position, 5);
        assert_eq!(st.generated, vec![42]);
        assert!(st.first_token_at.is_some());
        // two more decode steps finish it
        assert!(!b.advance(0, 43, now));
        assert!(b.advance(0, 44, now));
        assert_eq!(b.completed[0].generated, vec![42, 43, 44]);
    }

    #[test]
    fn complete_prefill_retires_single_token_requests() {
        let mut b = Batcher::new(1, 10);
        b.submit(req(7, 4, 1));
        b.admit();
        assert!(b.complete_prefill(0, 9, Instant::now()));
        assert_eq!(b.completed.len(), 1);
        assert!(b.active[0].is_none());
    }

    #[test]
    fn backpressure() {
        let mut b = Batcher::new(1, 2);
        assert!(b.submit(req(1, 1, 1)));
        assert!(b.submit(req(2, 1, 1)));
        assert!(!b.submit(req(3, 1, 1)));
    }

    #[test]
    fn slot_recycled_after_completion() {
        let mut b = Batcher::new(1, 10);
        b.submit(req(1, 1, 1));
        b.submit(req(2, 1, 1));
        b.admit();
        let now = Instant::now();
        assert!(b.advance(0, 7, now)); // prompt len 1 → this is the gen token...
        b.admit();
        assert_eq!(b.n_active(), 1);
        assert_eq!(b.active[0].as_ref().unwrap().req.id, 2);
    }
}
