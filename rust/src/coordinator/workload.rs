//! Serving workload generation: request arrival processes + length
//! distributions for throughput/latency benchmarking.
//!
//! Models the standard serving-benchmark shape (Poisson arrivals,
//! heavy-tailed prompt/output lengths) so `coordinator_throughput` and the
//! serving examples exercise realistic queueing rather than lockstep
//! batches. Deterministic per seed.

use std::time::Instant;

use super::batcher::Request;
use crate::util::rng::Rng;

/// Workload description.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Number of requests in the trace.
    pub n_requests: usize,
    /// Mean requests/second of the Poisson arrival process.
    pub arrival_rate: f64,
    /// Mean prompt length (geometric-ish; see `generate`).
    pub prompt_len_mean: usize,
    /// Hard cap on prompt length.
    pub prompt_len_max: usize,
    /// Mean generation budget.
    pub gen_len_mean: usize,
    /// Hard cap on generation budget.
    pub gen_len_max: usize,
    /// Sampling temperature stamped on every request.
    pub temperature: f32,
    /// Vocabulary size prompts are drawn from.
    pub vocab: usize,
}

impl Default for WorkloadSpec {
    fn default() -> WorkloadSpec {
        WorkloadSpec {
            n_requests: 16,
            arrival_rate: 50.0,
            prompt_len_mean: 32,
            prompt_len_max: 96,
            gen_len_mean: 32,
            gen_len_max: 96,
            temperature: 0.0,
            vocab: 256,
        }
    }
}

impl WorkloadSpec {
    /// Small deterministic workload for CLI demos and CI smoke runs:
    /// `n` requests arriving in a fast burst, short prompts/generations
    /// sized so `tiny`-preset sequences stay far from the context cap.
    pub fn smoke(n: usize) -> WorkloadSpec {
        WorkloadSpec {
            n_requests: n,
            arrival_rate: 500.0,
            prompt_len_mean: 12,
            prompt_len_max: 32,
            gen_len_mean: 16,
            gen_len_max: 48,
            ..Default::default()
        }
    }
}

/// A request with its (relative) arrival offset in seconds.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    /// Arrival offset from the trace start, in seconds.
    pub offset_s: f64,
    /// The request to submit at that offset.
    pub request: Request,
}

/// Geometric-ish heavy-tailed length: exp draw clipped to [1, max].
fn length(rng: &mut Rng, mean: usize, max: usize) -> usize {
    (rng.exp(1.0 / mean as f64).round() as usize).clamp(1, max)
}

/// Generate the full trace. Arrival offsets are cumulative exponential
/// inter-arrival times (Poisson process at `arrival_rate`).
pub fn generate(spec: &WorkloadSpec, seed: u64) -> Vec<TimedRequest> {
    let mut rng = Rng::new(seed);
    let now = Instant::now();
    let mut t = 0.0f64;
    (0..spec.n_requests)
        .map(|i| {
            t += rng.exp(spec.arrival_rate);
            let plen = length(&mut rng, spec.prompt_len_mean, spec.prompt_len_max);
            let glen = length(&mut rng, spec.gen_len_mean, spec.gen_len_max);
            TimedRequest {
                offset_s: t,
                request: Request {
                    id: i as u64,
                    prompt: (0..plen).map(|_| rng.below(spec.vocab as u64) as i32).collect(),
                    max_new_tokens: glen,
                    temperature: spec.temperature,
                    arrival: now,
                },
            }
        })
        .collect()
}

/// Total decode steps a trace needs on an ideal engine (prefill+gen),
/// for utilization accounting in benches.
pub fn ideal_token_steps(trace: &[TimedRequest]) -> usize {
    trace
        .iter()
        .map(|t| t.request.prompt.len() + t.request.max_new_tokens)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_bounded() {
        let spec = WorkloadSpec::default();
        let a = generate(&spec, 42);
        let b = generate(&spec, 42);
        assert_eq!(a.len(), spec.n_requests);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.prompt, y.request.prompt);
            assert_eq!(x.offset_s, y.offset_s);
            assert!(x.request.prompt.len() <= spec.prompt_len_max);
            assert!(x.request.max_new_tokens <= spec.gen_len_max);
            assert!(x.request.prompt.iter().all(|&t| (t as usize) < spec.vocab));
        }
    }

    #[test]
    fn arrivals_monotone() {
        let trace = generate(&WorkloadSpec::default(), 1);
        for w in trace.windows(2) {
            assert!(w[1].offset_s >= w[0].offset_s);
        }
    }

    #[test]
    fn mean_lengths_in_ballpark() {
        let spec = WorkloadSpec {
            n_requests: 2000,
            prompt_len_mean: 40,
            prompt_len_max: 400,
            ..Default::default()
        };
        let trace = generate(&spec, 7);
        let mean: f64 = trace.iter().map(|t| t.request.prompt.len() as f64).sum::<f64>()
            / trace.len() as f64;
        assert!((mean - 40.0).abs() < 5.0, "mean={mean}");
    }
}
