//! The L3 coordinator — the paper-facing system.
//!
//! The host-side pieces (batcher, KV pool, sampling, stats, workload) are
//! feature-free; the artifact-driven loops ([`trainer`], [`serve`]) need
//! the `pjrt` feature (XLA/PJRT execution path).
//!
//! * [`trainer`] — training orchestrator: drives the fused `train_step`
//!   artifact, owns the LR schedule and logging, evaluates checkpoints.
//! * [`kv_cache`] — routing-aware paged KV-cache pool: pages are allocated
//!   per (sequence, layer) only when that layer routed the token to
//!   attention — the mechanism behind the paper's Fig. 6 memory savings.
//! * [`batcher`] — continuous batching: slot assignment, admission,
//!   completion recycling.
//! * [`serve`] — the serving engine: decode loop over the batched decode
//!   artifact, sampling, routing-stats collection, latency metrics.
//! * [`stats`] — routing statistics (Fig. 5 telemetry).

pub mod batcher;
pub mod kv_cache;
pub mod sampling;
#[cfg(feature = "pjrt")]
pub mod serve;
pub mod stats;
#[cfg(feature = "pjrt")]
pub mod trainer;
pub mod workload;

pub use batcher::{Batcher, Request, RequestState};
pub use kv_cache::{KvPool, PoolStats};
pub use sampling::{sample, SamplingParams};
#[cfg(feature = "pjrt")]
pub use serve::{ServeEngine, ServeReport};
pub use stats::RoutingStats;
#[cfg(feature = "pjrt")]
pub use trainer::{TrainReport, Trainer};
pub use workload::{generate as generate_workload, WorkloadSpec};
