//! The L3 coordinator — the paper-facing system.
//!
//! Everything here is feature-free except the artifact-driven loops
//! ([`trainer`], [`serve`]), which need the `pjrt` feature (XLA/PJRT
//! execution path).
//!
//! * [`server`] — the backend-generic continuous-batching serving engine:
//!   runs on any [`crate::runtime::Backend`] (the native CPU backend on
//!   the default build), batched decode + chunked prefill + routing-aware
//!   KV paging + latency/throughput/routing telemetry.
//! * [`kv_cache`] — routing-aware paged KV-cache pool: pages are allocated
//!   per (sequence, layer) only when that layer routed the token to
//!   attention — the mechanism behind the paper's Fig. 6 memory savings.
//! * [`batcher`] — continuous batching: slot assignment, admission,
//!   completion recycling.
//! * [`http`] — the zero-dependency HTTP/1.1 front end behind
//!   `serve --listen`: incremental push parser, strict JSON machines,
//!   chunked token streaming, backpressure → status mapping.
//! * [`speculate`] — bypass-path self-speculative decoding: draft tokens
//!   on the linear bypass (the free draft model inside the weights),
//!   verify the window in one batched full-router pass, accept the
//!   longest matching prefix, roll rejected KV back (DESIGN.md
//!   §Speculative decoding).
//! * [`workload`] — synthetic serving traces (Poisson arrivals,
//!   heavy-tailed lengths), deterministic per seed.
//! * [`stats`] — routing statistics (Fig. 5 telemetry).
//! * [`trainer`] — backend-generic training orchestrator: drives any
//!   [`crate::runtime::TrainBackend`] (the native CPU trainer by
//!   default; with `pjrt`, the fused `train_step` artifact via
//!   `trainer::ArtifactTrainer`), owns the LR schedule and logging.
//! * [`serve`] (`pjrt`) — the artifact-bound serving loop over the AOT
//!   batched decode executable (device-resident KV literals).

pub mod batcher;
pub mod http;
pub mod kv_cache;
pub mod sampling;
#[cfg(feature = "pjrt")]
pub mod serve;
pub mod server;
pub mod speculate;
pub mod stats;
pub mod trainer;
pub mod workload;

pub use batcher::{Batcher, Request, RequestState};
pub use http::{HttpReport, ListenConfig, NetFrontend};
pub use kv_cache::{KvPool, PoolStats, SpecMark};
pub use sampling::{sample, SamplingParams};
#[cfg(feature = "pjrt")]
pub use serve::ServeEngine;
pub use server::{
    FinishReason, PrefillMode, RequestRecord, ServeReport, Server, ServerConfig, SubmitError,
};
pub use speculate::{SpecIteration, SpecStats, SpeculativeDecoder};
pub use stats::{PositionBuckets, RoutingStats};
#[cfg(feature = "pjrt")]
pub use trainer::ArtifactTrainer;
pub use trainer::{TrainReport, Trainer};
pub use workload::{generate as generate_workload, WorkloadSpec};
