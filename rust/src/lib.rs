//! # DTRNet — Dynamic Token Routing Network
//!
//! Rust coordinator (L3) for the three-layer reproduction of
//! *"DTRNet: Dynamic Token Routing Network to Reduce Quadratic Costs in
//! Transformers"* (Sharma et al., 2025).
//!
//! Execution is **pluggable** (see [`runtime::Backend`] and DESIGN.md
//! §Backends):
//!
//! * The default build is pure Rust: the native CPU backend
//!   ([`runtime::CpuBackend`]) evaluates the DTRNet block end-to-end —
//!   router → routed attention / linear bypass → shared MLP — plus
//!   greedy/sampled decode, with kernels mirrored from
//!   `python/compile/kernels/ref.py` and held to it by golden vectors.
//!   Everything offline-testable lives on this path.
//! * With the `pjrt` cargo feature, the compute graphs (L2 JAX model +
//!   L1 Pallas kernels) are AOT-lowered to HLO text by
//!   `python/compile/aot.py` and executed through the PJRT C API
//!   (`xla` crate). Python never runs on the request path.
//!
//! Module map (see DESIGN.md for the full inventory):
//! - [`util`] — offline-environment substrates: JSON, PRNG, CLI, threadpool.
//! - [`config`] — typed model/train/serve configs + paper presets.
//! - [`tokenizer`] — byte tokenizer + trainable byte-pair encoding.
//! - [`data`] — synthetic corpora, tiny-corpus loader, batch pipeline.
//! - [`model`] — host-side analytics: layer layout, FLOPs (Fig. 4) and
//!   KV-memory (Fig. 6) models.
//! - [`runtime`] — execution backends: the [`runtime::Backend`] and
//!   [`runtime::TrainBackend`] traits, the native CPU backend and
//!   trainer (hand-derived backward kernels in `cpu/grads.rs`), the
//!   int8 quantized backend ([`runtime::quant`]: per-output-row scales,
//!   dequant-free kernels, accuracy-gated), DTCK checkpoints, and
//!   (behind `pjrt`) the PJRT artifact registry: load, compile, execute.
//! - [`coordinator`] — the system contribution: the backend-generic
//!   continuous-batching serving engine ([`coordinator::Server`]) over
//!   the routing-aware paged KV-cache pool and the backend-generic
//!   training orchestrator ([`coordinator::Trainer`]) — both
//!   feature-free, running on the CPU backend today — plus the
//!   artifact-bound serving loop (`pjrt`).
//! - [`eval`] — perplexity / routing-stats / cosine-probe harnesses;
//!   [`eval::perplexity_backend`] runs against any [`runtime::Backend`].
//! - [`metrics`] — counters, histograms, per-kernel timers, JSONL
//!   emission.
//! - [`perf`] — the reproducible perf harness behind `dtrnet bench`:
//!   fixed-seed scenarios swept across thread counts into
//!   `BENCH_*.json` (DESIGN.md §Benchmarking).
//! - [`telemetry`] — observability: span tracing into per-thread ring
//!   buffers exported as Chrome trace-event JSON (`--trace`), and
//!   measured per-layer FLOP accounting reconciled against the
//!   [`model`] analytic predictions (DESIGN.md §Observability).
//! - [`testing`] — in-repo property-testing harness (proptest is
//!   unavailable offline; see DESIGN.md §Substitutions).

// Every public item carries documentation — enforced as a warning here
// and promoted to an error by the CI `docs` job
// (RUSTDOCFLAGS="-D warnings" cargo doc --no-deps).
#![warn(missing_docs)]
// Style accommodations for the offline CI clippy gate: these lints are
// stylistic and pervasive in index-heavy numerical code; correctness
// lints stay enabled.
#![allow(unknown_lints)]
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::inherent_to_string,
    clippy::new_without_default,
    clippy::manual_div_ceil,
    clippy::unnecessary_map_or,
    clippy::type_complexity
)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod metrics;
pub mod model;
pub mod perf;
pub mod runtime;
pub mod telemetry;
pub mod testing;
pub mod tokenizer;
pub mod util;

/// Crate version (from Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Artifact directory: `$DTRNET_ARTIFACTS`, else the nearest ancestor of the
/// cwd containing `artifacts/manifest.json` (lets tests/benches run from any
/// workspace subdir).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("DTRNET_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
