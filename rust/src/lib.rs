//! # DTRNet — Dynamic Token Routing Network
//!
//! Rust coordinator (L3) for the three-layer reproduction of
//! *"DTRNet: Dynamic Token Routing Network to Reduce Quadratic Costs in
//! Transformers"* (Sharma et al., 2025).
//!
//! The compute graphs (L2 JAX model + L1 Pallas kernels) are AOT-lowered to
//! HLO text by `python/compile/aot.py` and executed here through the PJRT C
//! API (`xla` crate). Python never runs on the request path.
//!
//! Module map (see DESIGN.md for the full inventory):
//! - [`util`] — offline-environment substrates: JSON, PRNG, CLI, threadpool.
//! - [`config`] — typed model/train/serve configs + paper presets.
//! - [`tokenizer`] — byte tokenizer + trainable byte-pair encoding.
//! - [`data`] — synthetic corpora, tiny-corpus loader, batch pipeline.
//! - [`model`] — host-side analytics: layer layout, FLOPs (Fig. 4) and
//!   KV-memory (Fig. 6) models.
//! - [`runtime`] — PJRT artifact registry: load, compile, execute.
//! - [`coordinator`] — the system contribution: training orchestrator,
//!   serving engine with continuous batching and the routing-aware paged
//!   KV-cache pool.
//! - [`eval`] — perplexity / routing-stats / cosine-probe harnesses.
//! - [`metrics`] — counters, histograms, JSONL emission.
//! - [`testing`] — in-repo property-testing harness (proptest is
//!   unavailable offline; see DESIGN.md §Substitutions).

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod testing;
pub mod tokenizer;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Artifact directory: `$DTRNET_ARTIFACTS`, else the nearest ancestor of the
/// cwd containing `artifacts/manifest.json` (lets tests/benches run from any
/// workspace subdir).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("DTRNET_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
