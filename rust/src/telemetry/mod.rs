//! Observability: span tracing + measured-FLOP accounting.
//!
//! Two pillars, both designed to observe without perturbing (DESIGN.md
//! §Observability):
//!
//! * **Span tracing** — start/end spans recorded into per-thread ring
//!   buffers and exported as Chrome trace-event JSON (`--trace
//!   out.trace.json` on `serve`/`train`; load the file in Perfetto or
//!   chrome://tracing). Disabled by default: the only cost on every
//!   call site is one relaxed [`AtomicBool`] load. When enabled, each
//!   event is one uncontended per-thread mutex acquire (a single CAS —
//!   the lock is contended only while a trace is being exported) plus a
//!   ring push; the ring drops the **oldest** events on overflow so a
//!   long run keeps its tail. [`metrics::Timer`](crate::metrics::Timer)
//!   emits spans for every named kernel section automatically, so the
//!   serve engine's decode steps and the train loop's
//!   forward/backward/optimizer phases appear in the trace with no
//!   extra call sites.
//! * **Measured FLOPs** — [`FlopCounters`]: per-layer relaxed-atomic
//!   multiply-accumulate×2 tallies the CPU backends (f32 and int8)
//!   increment next to each kernel call with the *actual* dimensions
//!   (routed-row counts, real cache lengths), plus a dense-equivalent
//!   tally for the same tokens. Always on — the cost is a handful of
//!   relaxed adds per layer per step, noise next to a matmul. The
//!   measured numbers reconcile against the
//!   [`model::flops`](crate::model::flops) analytic predictions in
//!   `rust/tests/telemetry.rs`, and the measured-vs-dense ratio per
//!   layer is the paper's Fig. 1 claim as a live number in
//!   [`ServeReport`](crate::coordinator::ServeReport).
//!
//! Determinism contract: telemetry is read-only observation. Logits and
//! token streams are bitwise identical with tracing on vs off
//! (property-tested in `rust/tests/telemetry.rs`), and the `bench`
//! harness gates tracing-on overhead (`telemetry_overhead` scenario).

use std::cell::RefCell;
use std::collections::VecDeque;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::Result;

use crate::util::json::Json;

/// Default per-thread ring capacity (events). At ~10 spans per layer
/// per engine step this holds minutes of serving trace per thread.
pub const DEFAULT_RING_CAPACITY: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);
static RING_CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_RING_CAPACITY);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// Registry of every thread's ring, so export can drain them all.
static RINGS: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();

thread_local! {
    /// This thread's ring handle (registered in [`RINGS`] on first use).
    static LOCAL_RING: RefCell<Option<Arc<Mutex<Ring>>>> = const { RefCell::new(None) };
}

/// Whether span recording is active (one relaxed load — the entire cost
/// of a disabled call site).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on or off (the `--trace` flag sets this once at
/// CLI startup; the bench overhead scenario toggles it per run).
pub fn set_enabled(on: bool) {
    // Pin the epoch before the first event so timestamps are positive.
    let _ = EPOCH.get_or_init(Instant::now);
    ENABLED.store(on, Ordering::Relaxed);
}

/// Set the per-thread ring capacity in events (applies to subsequent
/// pushes on every ring, existing rings included). Tests use a small
/// capacity to exercise wraparound.
pub fn set_ring_capacity(cap: usize) {
    RING_CAPACITY.store(cap.max(1), Ordering::Relaxed);
}

/// One span argument value.
#[derive(Debug, Clone)]
pub enum ArgValue {
    /// Numeric argument.
    Num(f64),
    /// String argument (finish reasons, labels).
    Str(String),
}

impl From<f64> for ArgValue {
    fn from(v: f64) -> ArgValue {
        ArgValue::Num(v)
    }
}

impl From<usize> for ArgValue {
    fn from(v: usize) -> ArgValue {
        ArgValue::Num(v as f64)
    }
}

impl From<u64> for ArgValue {
    fn from(v: u64) -> ArgValue {
        ArgValue::Num(v as f64)
    }
}

impl From<&str> for ArgValue {
    fn from(v: &str) -> ArgValue {
        ArgValue::Str(v.to_string())
    }
}

/// Chrome trace-event phase of a recorded event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Duration begin (`"B"`): paired with a later [`Phase::End`] on the
    /// same thread.
    Begin,
    /// Duration end (`"E"`).
    End,
    /// Async begin (`"b"`): request lifecycles, keyed by id — may span
    /// threads and overlap.
    AsyncBegin,
    /// Async end (`"e"`).
    AsyncEnd,
    /// Instant marker (`"i"`): admissions, cancellations.
    Instant,
}

impl Phase {
    fn as_str(self) -> &'static str {
        match self {
            Phase::Begin => "B",
            Phase::End => "E",
            Phase::AsyncBegin => "b",
            Phase::AsyncEnd => "e",
            Phase::Instant => "i",
        }
    }
}

/// One recorded trace event (a row of the exported `traceEvents` array).
#[derive(Debug, Clone)]
pub struct Event {
    /// Span/event name (static — recording allocates only for args).
    pub name: &'static str,
    /// Chrome trace phase.
    pub ph: Phase,
    /// Microseconds since the process trace epoch.
    pub ts_us: f64,
    /// Recording thread's stable trace id.
    pub tid: u64,
    /// Async correlation id (request id); unused for duration events.
    pub id: Option<u64>,
    /// Event arguments (annotations: batch size, KV pages, …).
    pub args: Vec<(&'static str, ArgValue)>,
}

/// Fixed-capacity per-thread event buffer: overflow drops the oldest
/// event (`pop_front`), never the newest — a long run keeps its tail.
struct Ring {
    tid: u64,
    buf: VecDeque<Event>,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, ev: Event) {
        let cap = RING_CAPACITY.load(Ordering::Relaxed);
        while self.buf.len() >= cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }
}

fn now_us() -> f64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64() * 1e6
}

fn record(name: &'static str, ph: Phase, id: Option<u64>, args: Vec<(&'static str, ArgValue)>) {
    if !enabled() {
        return;
    }
    let ts_us = now_us();
    LOCAL_RING.with(|slot| {
        let mut slot = slot.borrow_mut();
        let ring = slot.get_or_insert_with(|| {
            let ring = Arc::new(Mutex::new(Ring {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                buf: VecDeque::new(),
                dropped: 0,
            }));
            RINGS
                .get_or_init(|| Mutex::new(Vec::new()))
                .lock()
                .unwrap()
                .push(Arc::clone(&ring));
            ring
        });
        let mut ring = ring.lock().unwrap();
        let tid = ring.tid;
        ring.push(Event {
            name,
            ph,
            ts_us,
            tid,
            id,
            args,
        });
    });
}

/// Record a duration-span begin (`"B"`). Pair with [`end`] on the same
/// thread.
pub fn begin(name: &'static str) {
    record(name, Phase::Begin, None, Vec::new());
}

/// Record a duration-span end (`"E"`).
pub fn end(name: &'static str) {
    record(name, Phase::End, None, Vec::new());
}

/// Record an instant event with arguments.
pub fn instant(name: &'static str, args: Vec<(&'static str, ArgValue)>) {
    record(name, Phase::Instant, None, args);
}

/// Begin an async span correlated by `id` (request lifecycles — may
/// overlap with other ids and cross engine steps).
pub fn async_begin(name: &'static str, id: u64, args: Vec<(&'static str, ArgValue)>) {
    record(name, Phase::AsyncBegin, Some(id), args);
}

/// End the async span with the matching `id`.
pub fn async_end(name: &'static str, id: u64, args: Vec<(&'static str, ArgValue)>) {
    record(name, Phase::AsyncEnd, Some(id), args);
}

/// RAII duration span: records `"B"` at construction (when tracing is
/// enabled) and the matching `"E"` on drop. Arms itself only if tracing
/// was enabled at construction, so a disabled span costs one relaxed
/// load.
pub struct Scoped {
    name: &'static str,
    armed: bool,
}

/// Open a [`Scoped`] duration span named `name`.
pub fn scoped(name: &'static str) -> Scoped {
    let armed = enabled();
    if armed {
        begin(name);
    }
    Scoped { name, armed }
}

impl Scoped {
    /// Attach arguments to the span by emitting them on the closing
    /// `"E"` event (Chrome merges begin/end args).
    pub fn end_with_args(mut self, args: Vec<(&'static str, ArgValue)>) {
        if self.armed {
            record(self.name, Phase::End, None, args);
            self.armed = false;
        }
    }
}

impl Drop for Scoped {
    fn drop(&mut self) {
        if self.armed {
            end(self.name);
        }
    }
}

/// Process-wide guard serializing code paths that flip the global
/// telemetry state (the test suites and the bench overhead scenario
/// toggle `set_enabled`/`clear` and would otherwise race each other
/// across parallel test threads). Recovers from poisoning so a
/// panicking holder doesn't cascade into unrelated tests.
#[doc(hidden)]
pub fn state_guard() -> std::sync::MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Total events dropped to ring wraparound across all threads.
pub fn dropped_events() -> u64 {
    match RINGS.get() {
        None => 0,
        Some(r) => r.lock().unwrap().iter().map(|r| r.lock().unwrap().dropped).sum(),
    }
}

/// Clear every thread's ring and dropped-event counter (between bench
/// iterations / tests). Recording threads stay registered.
pub fn clear() {
    if let Some(rings) = RINGS.get() {
        for ring in rings.lock().unwrap().iter() {
            let mut r = ring.lock().unwrap();
            r.buf.clear();
            r.dropped = 0;
        }
    }
}

/// Snapshot every ring's events (per-thread recording order preserved;
/// rings concatenated in registration order). Non-destructive.
pub fn snapshot_events() -> Vec<Event> {
    let mut out = Vec::new();
    if let Some(rings) = RINGS.get() {
        for ring in rings.lock().unwrap().iter() {
            let r = ring.lock().unwrap();
            out.extend(r.buf.iter().cloned());
        }
    }
    out
}

/// Export the recorded events as a Chrome trace-event JSON document
/// (`{"traceEvents": [...]}`) — loadable in Perfetto
/// (<https://ui.perfetto.dev>) or chrome://tracing. Non-destructive;
/// call [`clear`] to reset the rings.
pub fn export_chrome_trace() -> Json {
    let mut events = Vec::new();
    for ev in snapshot_events() {
        let mut row = Json::obj();
        row.set("name", Json::Str(ev.name.to_string()));
        row.set("ph", Json::Str(ev.ph.as_str().to_string()));
        row.set("ts", Json::Num(ev.ts_us));
        row.set("pid", Json::Num(0.0));
        row.set("tid", Json::Num(ev.tid as f64));
        match ev.ph {
            Phase::AsyncBegin | Phase::AsyncEnd => {
                // Async events need a category + correlation id.
                row.set("cat", Json::Str(ev.name.to_string()));
                row.set("id", Json::Num(ev.id.unwrap_or(0) as f64));
            }
            Phase::Instant => {
                row.set("s", Json::Str("t".to_string())); // thread scope
            }
            _ => {}
        }
        if !ev.args.is_empty() {
            let mut args = Json::obj();
            for (k, v) in &ev.args {
                match v {
                    ArgValue::Num(n) => args.set(k, Json::Num(*n)),
                    ArgValue::Str(s) => args.set(k, Json::Str(s.clone())),
                }
            }
            row.set("args", args);
        }
        events.push(row);
    }
    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(events));
    doc.set("displayTimeUnit", Json::Str("ms".to_string()));
    doc.set("droppedEvents", Json::Num(dropped_events() as f64));
    doc
}

/// Write [`export_chrome_trace`] to `path` (parent dirs created).
pub fn write_chrome_trace(path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, export_chrome_trace().to_string() + "\n")?;
    Ok(())
}

// ---------------------------------------------------------------------
// Measured FLOPs
// ---------------------------------------------------------------------

/// Per-layer measured-FLOP tallies for one kernel section (relaxed
/// atomics — backends increment from their hot paths with actual
/// dimensions; multiply-accumulates count ×2, matching
/// [`model::flops`](crate::model::flops)).
#[derive(Debug, Default)]
pub struct LayerFlops {
    /// Router MLP (DTR layers only).
    pub router: AtomicU64,
    /// Q/K/V/O projections for attended tokens (Q/K for routed only;
    /// dense layers pay all four for every token).
    pub qkvo_proj: AtomicU64,
    /// Attention score + weighted-sum cost at the *actual* per-row
    /// cache lengths (the quadratic term the router shrinks).
    pub attn_mix: AtomicU64,
    /// Linear bypass `x·Wv·Wo` for non-routed tokens.
    pub bypass: AtomicU64,
    /// SwiGLU MLP (every token, both paths).
    pub mlp: AtomicU64,
    /// What a dense layer would have spent on the same tokens at the
    /// same positions (qkvo + full-context attention + MLP) — the
    /// denominator of the measured FLOPs-vs-dense ratio.
    pub dense_equiv: AtomicU64,
}

impl LayerFlops {
    /// Sum of the measured sections (dense-equivalent excluded).
    pub fn total(&self) -> u64 {
        self.router.load(Ordering::Relaxed)
            + self.qkvo_proj.load(Ordering::Relaxed)
            + self.attn_mix.load(Ordering::Relaxed)
            + self.bypass.load(Ordering::Relaxed)
            + self.mlp.load(Ordering::Relaxed)
    }
}

/// Measured-FLOP accounting for one backend instance: one
/// [`LayerFlops`] per layer plus the unembed matmul. Owned by
/// [`CpuBackend`](crate::runtime::CpuBackend) and
/// [`QuantizedCpuBackend`](crate::runtime::QuantizedCpuBackend),
/// exposed through
/// [`Backend::flop_counters`](crate::runtime::Backend::flop_counters),
/// folded into [`ServeReport`](crate::coordinator::ServeReport).
#[derive(Debug)]
pub struct FlopCounters {
    /// Per-layer section tallies.
    pub layers: Vec<LayerFlops>,
    /// Final-norm + `[·, V]` unembed matmul FLOPs.
    pub unembed: AtomicU64,
}

impl FlopCounters {
    /// Zeroed counters for a model with `n_layers` layers.
    pub fn new(n_layers: usize) -> FlopCounters {
        FlopCounters {
            layers: (0..n_layers).map(|_| LayerFlops::default()).collect(),
            unembed: AtomicU64::new(0),
        }
    }

    /// Add router FLOPs at `layer`.
    #[inline]
    pub fn add_router(&self, layer: usize, flops: u64) {
        self.layers[layer].router.fetch_add(flops, Ordering::Relaxed);
    }

    /// Add Q/K/V/O projection FLOPs at `layer`.
    #[inline]
    pub fn add_qkvo(&self, layer: usize, flops: u64) {
        self.layers[layer].qkvo_proj.fetch_add(flops, Ordering::Relaxed);
    }

    /// Add attention-mix FLOPs at `layer`.
    #[inline]
    pub fn add_attn_mix(&self, layer: usize, flops: u64) {
        self.layers[layer].attn_mix.fetch_add(flops, Ordering::Relaxed);
    }

    /// Add linear-bypass FLOPs at `layer`.
    #[inline]
    pub fn add_bypass(&self, layer: usize, flops: u64) {
        self.layers[layer].bypass.fetch_add(flops, Ordering::Relaxed);
    }

    /// Add SwiGLU MLP FLOPs at `layer`.
    #[inline]
    pub fn add_mlp(&self, layer: usize, flops: u64) {
        self.layers[layer].mlp.fetch_add(flops, Ordering::Relaxed);
    }

    /// Add the dense-equivalent cost for the same tokens at `layer`.
    #[inline]
    pub fn add_dense_equiv(&self, layer: usize, flops: u64) {
        self.layers[layer].dense_equiv.fetch_add(flops, Ordering::Relaxed);
    }

    /// Add unembed FLOPs.
    #[inline]
    pub fn add_unembed(&self, flops: u64) {
        self.unembed.fetch_add(flops, Ordering::Relaxed);
    }

    /// Measured total across layers plus unembed.
    pub fn total(&self) -> u64 {
        self.layers.iter().map(|l| l.total()).sum::<u64>() + self.unembed.load(Ordering::Relaxed)
    }

    /// Per-layer measured / dense-equivalent ratio (1.0 where no
    /// dense-equivalent has been recorded).
    pub fn ratios_vs_dense(&self) -> Vec<f64> {
        self.layers
            .iter()
            .map(|l| {
                let de = l.dense_equiv.load(Ordering::Relaxed);
                if de == 0 {
                    1.0
                } else {
                    l.total() as f64 / de as f64
                }
            })
            .collect()
    }

    /// Zero every counter (between bench scenarios).
    pub fn reset(&self) {
        for l in &self.layers {
            l.router.store(0, Ordering::Relaxed);
            l.qkvo_proj.store(0, Ordering::Relaxed);
            l.attn_mix.store(0, Ordering::Relaxed);
            l.bypass.store(0, Ordering::Relaxed);
            l.mlp.store(0, Ordering::Relaxed);
            l.dense_equiv.store(0, Ordering::Relaxed);
        }
        self.unembed.store(0, Ordering::Relaxed);
    }

    /// JSON snapshot: per-layer section totals + ratio-vs-dense, plus
    /// the aggregate (`total`, `dense_equiv_total`, `ratio_vs_dense`,
    /// `unembed`).
    pub fn to_json(&self) -> Json {
        let mut layers = Vec::with_capacity(self.layers.len());
        let mut dense_total = 0u64;
        for l in &self.layers {
            let de = l.dense_equiv.load(Ordering::Relaxed);
            dense_total += de;
            layers.push(Json::from_pairs(vec![
                ("router", Json::Num(l.router.load(Ordering::Relaxed) as f64)),
                (
                    "qkvo_proj",
                    Json::Num(l.qkvo_proj.load(Ordering::Relaxed) as f64),
                ),
                (
                    "attn_mix",
                    Json::Num(l.attn_mix.load(Ordering::Relaxed) as f64),
                ),
                ("bypass", Json::Num(l.bypass.load(Ordering::Relaxed) as f64)),
                ("mlp", Json::Num(l.mlp.load(Ordering::Relaxed) as f64)),
                ("total", Json::Num(l.total() as f64)),
                ("dense_equiv", Json::Num(de as f64)),
                (
                    "ratio_vs_dense",
                    Json::Num(if de == 0 {
                        1.0
                    } else {
                        l.total() as f64 / de as f64
                    }),
                ),
            ]));
        }
        let layer_total: u64 = self.layers.iter().map(|l| l.total()).sum();
        Json::from_pairs(vec![
            ("layers", Json::Arr(layers)),
            (
                "unembed",
                Json::Num(self.unembed.load(Ordering::Relaxed) as f64),
            ),
            ("total", Json::Num(self.total() as f64)),
            ("dense_equiv_total", Json::Num(dense_total as f64)),
            (
                "ratio_vs_dense",
                Json::Num(if dense_total == 0 {
                    1.0
                } else {
                    layer_total as f64 / dense_total as f64
                }),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_counters_accumulate_and_ratio() {
        let fc = FlopCounters::new(2);
        fc.add_router(0, 10);
        fc.add_qkvo(0, 20);
        fc.add_attn_mix(0, 30);
        fc.add_bypass(0, 40);
        fc.add_mlp(0, 50);
        fc.add_dense_equiv(0, 300);
        fc.add_unembed(7);
        assert_eq!(fc.layers[0].total(), 150);
        assert_eq!(fc.total(), 157);
        let r = fc.ratios_vs_dense();
        assert!((r[0] - 0.5).abs() < 1e-12);
        assert_eq!(r[1], 1.0, "no dense-equiv recorded -> ratio 1.0");
        let j = fc.to_json();
        assert_eq!(j.path("total").and_then(Json::as_f64), Some(157.0));
        fc.reset();
        assert_eq!(fc.total(), 0);
    }

    #[test]
    fn disabled_recording_is_a_noop() {
        let _guard = state_guard();
        set_enabled(false);
        let before = snapshot_events().len();
        begin("noop");
        end("noop");
        instant("noop", vec![("x", ArgValue::Num(1.0))]);
        assert_eq!(snapshot_events().len(), before, "disabled events recorded");
    }
}
