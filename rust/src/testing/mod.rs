//! In-repo property-testing harness (proptest substitute — see DESIGN.md).
//!
//! Seeded random-input generation with failure shrinking: on a failing
//! case the harness retries with progressively "smaller" inputs produced
//! by the caller's shrink function and reports the minimal reproduction.
//!
//! ```no_run
//! use dtrnet::testing::{property, Gen};
//! property("sort is idempotent", 100, |g| {
//!     let mut v = g.vec_u32(0..64, 0..1000);
//!     v.sort();
//!     let w = { let mut w = v.clone(); w.sort(); w };
//!     assert_eq!(v, w);
//! });
//! ```

use crate::util::rng::Rng;

/// Random input generator handed to property bodies.
pub struct Gen {
    /// Seeded PRNG for raw draws.
    pub rng: Rng,
    /// Zero-based index of the current property case.
    pub case: usize,
}

impl Gen {
    /// Uniform usize in `range`.
    pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
        range.start + self.rng.usize_below(range.end - range.start)
    }

    /// Uniform u32 in `range`.
    pub fn u32(&mut self, range: std::ops::Range<u32>) -> u32 {
        range.start + self.rng.below((range.end - range.start) as u64) as u32
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Random-length f32 vector with elements in `[lo, hi)`.
    pub fn f32_vec(&mut self, len: std::ops::Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize(len);
        (0..n)
            .map(|_| self.rng.range_f64(lo as f64, hi as f64) as f32)
            .collect()
    }

    /// Random-length u32 vector with elements in `vals`.
    pub fn vec_u32(&mut self, len: std::ops::Range<usize>, vals: std::ops::Range<u32>) -> Vec<u32> {
        let n = self.usize(len);
        (0..n).map(|_| self.u32(vals.clone())).collect()
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }
}

/// Run `body` over `cases` generated inputs. Panics (with the failing seed)
/// if any case fails; rerun with `DTRNET_PROP_SEED` to reproduce exactly.
pub fn property<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, body: F) {
    let base_seed: u64 = std::env::var("DTRNET_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD7124E7);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Rng::new(seed),
                case,
            };
            body(&mut g);
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".to_string());
            panic!(
                "property {name:?} failed on case {case} (seed {seed}): {msg}\n\
                 reproduce with DTRNET_PROP_SEED={seed}"
            );
        }
    }
}

/// Assert two f32 slices are elementwise close (atol + rtol), with a
/// readable first-mismatch report — the Rust analogue of
/// `np.testing.assert_allclose`.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "mismatch at [{i}]: {x} vs {y} (tol {tol}); first of possibly many"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_passes() {
        property("add commutes", 50, |g| {
            let a = g.f64(-1e6, 1e6);
            let b = g.f64(-1e6, 1e6);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "reproduce with DTRNET_PROP_SEED")]
    fn property_reports_seed() {
        property("always fails", 3, |_g| panic!("boom"));
    }

    #[test]
    fn allclose_ok() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0 - 1e-7], 1e-5, 1e-6);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn allclose_detects() {
        assert_allclose(&[1.0], &[1.1], 1e-5, 1e-6);
    }
}
