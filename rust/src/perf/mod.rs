//! Reproducible performance harness — the `dtrnet bench` subcommand.
//!
//! Runs a fixed set of fixed-seed scenarios (training-shape forward,
//! autoregressive decode, native training steps, the continuous-batching
//! serving engine) across a sweep of kernel-thread counts, and emits one
//! machine-readable JSON document (`BENCH_pr4.json` at the repo root by
//! convention — the recorded perf trajectory every future PR diffs
//! against). See DESIGN.md §Benchmarking for the schema and methodology.
//!
//! Two properties make the numbers comparable across PRs:
//!
//! * **Fixed seeds everywhere** — model init, workload trace, and
//!   sampling RNGs are pinned, so two runs execute the same token
//!   streams and the same routing decisions; only the wall-clock moves.
//! * **Thread-count sweeps with a bitwise check** — every scenario runs
//!   at `--threads 1` (the determinism baseline) and at the host's
//!   parallelism, and the harness *verifies* that logits / generated
//!   token streams are bitwise identical across the sweep before
//!   reporting speedups. A bench run that breaks bit-identity fails
//!   loudly instead of recording tainted numbers.

use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::{ModelConfig, TrainConfig, Variant};
use crate::coordinator::{
    generate_workload, PrefillMode, Server, ServerConfig, WorkloadSpec,
};
use crate::runtime::{Backend, CpuBackend, CpuTrainer, Tensor, TrainBackend};
use crate::util::bench::bench;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool::available_threads;
use crate::coordinator::SamplingParams;

/// Schema tag stamped into every bench document.
pub const SCHEMA: &str = "dtrnet-bench-v1";

/// Fixed seed for model init in every scenario.
pub const MODEL_SEED: u64 = 0;
/// Fixed seed for the serving workload trace.
pub const WORKLOAD_SEED: u64 = 2;

/// Harness configuration (CLI flags map onto this).
#[derive(Debug, Clone)]
pub struct BenchOptions {
    /// Seconds-scale smoke configuration (`bench --test`, the CI mode):
    /// xs preset, fewer iterations/requests. Full mode uses tiny.
    pub quick: bool,
    /// Thread counts to sweep, ascending; must start at 1 (the
    /// determinism baseline every other count is diffed against).
    pub threads: Vec<usize>,
}

impl BenchOptions {
    /// Default sweep: `[1, available_parallelism]`.
    pub fn new(quick: bool) -> BenchOptions {
        let hw = available_threads();
        let mut threads = vec![1];
        if hw > 1 {
            threads.push(hw);
        }
        BenchOptions { quick, threads }
    }
}

/// Run every scenario and assemble the bench document.
pub fn run(opts: &BenchOptions) -> Result<Json> {
    ensure!(
        opts.threads.first() == Some(&1),
        "bench sweep must start at --threads 1 (the determinism baseline)"
    );
    let mut scenarios = Json::obj();
    for variant in [Variant::Dense, Variant::DtrBilayer] {
        let (fwd_key, fwd) = forward_scenario(opts, variant)?;
        scenarios.set(&fwd_key, fwd);
        let (dec_key, dec) = decode_scenario(opts, variant)?;
        scenarios.set(&dec_key, dec);
        let (tr_key, tr) = train_scenario(opts, variant)?;
        scenarios.set(&tr_key, tr);
        for &slots in serve_slot_fills(opts.quick) {
            let (key, s) = serve_scenario(opts, variant, slots)?;
            scenarios.set(&key, s);
        }
    }
    let mut out = Json::obj();
    out.set("schema", Json::Str(SCHEMA.to_string()));
    out.set("quick", Json::Bool(opts.quick));
    out.set(
        "host",
        Json::from_pairs(vec![
            ("hw_threads", Json::Num(available_threads() as f64)),
            (
                "threads_measured",
                Json::arr_f64(&opts.threads.iter().map(|&t| t as f64).collect::<Vec<_>>()),
            ),
        ]),
    );
    out.set(
        "seeds",
        Json::from_pairs(vec![
            ("model", Json::Num(MODEL_SEED as f64)),
            ("workload", Json::Num(WORKLOAD_SEED as f64)),
        ]),
    );
    out.set("scenarios", scenarios);
    Ok(out)
}

/// Write the document as pretty JSON (the committed `BENCH_*.json` form).
pub fn write(path: &Path, payload: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, payload.to_string_pretty() + "\n")?;
    println!("[bench] wrote {}", path.display());
    Ok(())
}

fn preset(quick: bool) -> &'static str {
    if quick {
        "xs"
    } else {
        "tiny"
    }
}

fn serve_slot_fills(quick: bool) -> &'static [usize] {
    if quick {
        &[2]
    } else {
        &[4, 8]
    }
}

fn backend_with_threads(variant: Variant, quick: bool, t: usize) -> Result<CpuBackend> {
    let cfg = ModelConfig::preset(preset(quick), variant);
    let mut be = CpuBackend::init(&cfg, MODEL_SEED)?;
    be.set_threads(t);
    Ok(be)
}

/// Training-shape forward throughput (tokens/s) per thread count, with a
/// bitwise logits check against the `--threads 1` baseline.
fn forward_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let (b, s) = if opts.quick { (2usize, 32usize) } else { (2, 64) };
    let (warmup, iters) = if opts.quick { (1, 3) } else { (2, 10) };
    let key = format!("forward_{}", variant.as_str());
    let mut sc = Json::obj();
    let mut baseline: Option<Vec<f32>> = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let be = backend_with_threads(variant, opts.quick, t)?;
        let tokens = Tensor::i32(
            vec![b, s],
            (0..(b * s) as i32).map(|i| i * 7 % 256).collect(),
        );
        let logits = be.forward(&tokens)?.logits;
        match &baseline {
            None => baseline = Some(logits.as_f32().to_vec()),
            Some(want) => ensure!(
                want.as_slice() == logits.as_f32(),
                "{key}: logits bits diverged between threads=1 and threads={t}"
            ),
        }
        let m = bench(&format!("{key}_t{t}"), warmup, iters, || {
            be.forward(&tokens).unwrap();
        });
        let tps = (b * s) as f64 / m.mean_s;
        tok_s.push(tps);
        sc.set(
            &format!("t{t}"),
            Json::from_pairs(vec![
                ("tokens_per_s", Json::Num(tps)),
                ("mean_ms", Json::Num(m.mean_s * 1e3)),
                ("p50_ms", Json::Num(m.p50_s * 1e3)),
                ("p95_ms", Json::Num(m.p95_s * 1e3)),
            ]),
        );
    }
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// Autoregressive decode (prefill + greedy generation) steps/s per
/// thread count, with a bitwise token-stream check.
fn decode_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let gen = if opts.quick { 8usize } else { 32 };
    let (warmup, iters) = if opts.quick { (1, 2) } else { (1, 5) };
    let key = format!("decode_{}", variant.as_str());
    let mut sc = Json::obj();
    let mut baseline: Option<Vec<i32>> = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let be = backend_with_threads(variant, opts.quick, t)?;
        let mut prompt_rng = Rng::new(MODEL_SEED.wrapping_add(1));
        let prompt: Vec<i32> = (0..16).map(|_| prompt_rng.below(256) as i32).collect();
        let mut rng = Rng::new(2);
        let out = be.generate(&prompt, gen, &SamplingParams::greedy(), &mut rng)?;
        match &baseline {
            None => baseline = Some(out.tokens.clone()),
            Some(want) => ensure!(
                *want == out.tokens,
                "{key}: token stream diverged between threads=1 and threads={t}"
            ),
        }
        let m = bench(&format!("{key}_t{t}"), warmup, iters, || {
            let mut r = Rng::new(2);
            be.generate(&prompt, gen, &SamplingParams::greedy(), &mut r)
                .unwrap();
        });
        let sps = gen as f64 / m.mean_s;
        tok_s.push(sps);
        sc.set(
            &format!("t{t}"),
            Json::from_pairs(vec![
                ("steps_per_s", Json::Num(sps)),
                ("mean_ms", Json::Num(m.mean_s * 1e3)),
            ]),
        );
    }
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// Native training throughput (optimizer steps/s over the fused
/// forward + backward + AdamW step) per thread count, with a bitwise
/// check of the final weights and loss across the sweep — the
/// `train_step` determinism contract, re-verified on every bench run.
/// Per-kernel timings include the backward sections (`bwd_*`,
/// `optimizer`).
fn train_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let steps = if opts.quick { 3usize } else { 8 };
    let hp = TrainConfig {
        steps,
        batch: 2,
        seq: if opts.quick { 32 } else { 64 },
        seed: MODEL_SEED,
        ..Default::default()
    };
    let key = format!("train_{}", variant.as_str());
    let mut sc = Json::obj();
    let mut baseline: Option<(u64, Vec<f32>)> = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let cfg = ModelConfig::preset(preset(opts.quick), variant);
        let mut tr = CpuTrainer::new(&cfg, &hp)?;
        tr.set_threads(t);
        let tokens: Vec<i32> = (0..(hp.batch * hp.seq) as i32).map(|i| i * 7 % 256).collect();
        let t0 = Instant::now();
        let mut last_loss = f64::NAN;
        for s in 1..=steps {
            last_loss = tr.train_step(&tokens, s, 3e-4, 0)?.loss;
        }
        let wall = t0.elapsed().as_secs_f64();
        let weights_cat: Vec<f32> = tr
            .weights()
            .tensors()
            .into_iter()
            .flat_map(|(w, _)| w.iter().copied())
            .collect();
        match &baseline {
            None => baseline = Some((last_loss.to_bits(), weights_cat)),
            Some((lb, wb)) => {
                ensure!(
                    *lb == last_loss.to_bits(),
                    "{key}: loss bits diverged between threads=1 and threads={t}"
                );
                ensure!(
                    *wb == weights_cat,
                    "{key}: trained weights diverged between threads=1 and threads={t}"
                );
            }
        }
        let tps = (steps * hp.batch * hp.seq) as f64 / wall;
        tok_s.push(tps);
        let mut row = Json::from_pairs(vec![
            ("steps_per_s", Json::Num(steps as f64 / wall)),
            ("tokens_per_s", Json::Num(tps)),
            ("mean_step_ms", Json::Num(wall * 1e3 / steps as f64)),
            ("final_loss", Json::Num(last_loss)),
        ]);
        if let Some(kt) = tr.kernel_timings() {
            row.set("kernel_timings", kt);
        }
        sc.set(&format!("t{t}"), row);
        println!(
            "[bench] {key} threads={t}: {:.2} steps/s ({:.1} tok/s)",
            steps as f64 / wall,
            tps
        );
    }
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// The serving engine end-to-end at a given batch width: tokens/s,
/// latency/TTFT percentiles, occupancy, per-kernel timings — plus the
/// bitwise token-stream check across the thread sweep.
fn serve_scenario(opts: &BenchOptions, variant: Variant, slots: usize) -> Result<(String, Json)> {
    let n_req = if opts.quick { 4usize } else { 16 };
    let key = format!("serve_{}_s{slots}", variant.as_str());
    let mut sc = Json::obj();
    let mut baseline: Option<Vec<Vec<i32>>> = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let be = backend_with_threads(variant, opts.quick, t)?;
        let cfg = be.config().clone();
        let spec = WorkloadSpec {
            n_requests: n_req,
            arrival_rate: 10_000.0,
            prompt_len_mean: 12,
            prompt_len_max: 32,
            gen_len_mean: if opts.quick { 8 } else { 24 },
            gen_len_max: if opts.quick { 16 } else { 48 },
            temperature: 0.0,
            vocab: cfg.vocab_size,
        };
        let trace = generate_workload(&spec, WORKLOAD_SEED);
        let scfg = ServerConfig {
            slots,
            prefill: PrefillMode::Chunked(32),
            ..Default::default()
        };
        be.timers().reset();
        let mut srv = Server::new(&be, scfg)?;
        let rep = srv.run_workload(&trace, 10_000_000)?;
        ensure!(
            rep.completed + rep.evicted == n_req,
            "{key}: requests lost at threads={t}"
        );
        let mut streams: Vec<(u64, Vec<i32>)> = rep
            .requests
            .iter()
            .map(|r| (r.id, r.tokens.clone()))
            .collect();
        streams.sort_by_key(|(id, _)| *id);
        let streams: Vec<Vec<i32>> = streams.into_iter().map(|(_, s)| s).collect();
        match &baseline {
            None => baseline = Some(streams),
            Some(want) => ensure!(
                *want == streams,
                "{key}: token streams diverged between threads=1 and threads={t}"
            ),
        }
        tok_s.push(rep.tokens_per_s);
        let mut row = Json::from_pairs(vec![
            ("tokens_per_s", Json::Num(rep.tokens_per_s)),
            ("latency_ms_p50", Json::Num(rep.latency_ms_p50)),
            ("latency_ms_p99", Json::Num(rep.latency_ms_p99)),
            ("ttft_ms_p50", Json::Num(rep.ttft_ms_p50)),
            ("ttft_ms_p99", Json::Num(rep.ttft_ms_p99)),
            ("step_ms_p50", Json::Num(rep.decode_step_ms_p50)),
            ("step_ms_p99", Json::Num(rep.decode_step_ms_p99)),
            ("batch_occupancy", Json::Num(rep.batch_occupancy)),
            ("steps", Json::Num(rep.steps as f64)),
        ]);
        if let Some(kt) = &rep.kernel_timings {
            row.set("kernel_timings", kt.clone());
        }
        sc.set(&format!("t{t}"), row);
        println!(
            "[bench] {key} threads={t}: {:.1} tok/s (p50 {:.2} ms, occupancy {:.2})",
            rep.tokens_per_s, rep.latency_ms_p50, rep.batch_occupancy
        );
    }
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// Stamp the cross-thread summary: speedup of the widest sweep point
/// over the `--threads 1` baseline, and the (already enforced) bitwise
/// identity marker.
fn finish_scenario(sc: &mut Json, tok_s: &[f64]) {
    if let (Some(&first), Some(&last)) = (tok_s.first(), tok_s.last()) {
        if first > 0.0 {
            sc.set("speedup_vs_t1", Json::Num(last / first));
        }
    }
    // run()/the scenario fns ensure! bitwise equality before we get here
    sc.set("bitwise_identical_across_threads", Json::Bool(true));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_bench_produces_schema_and_identity() {
        let opts = BenchOptions {
            quick: true,
            threads: vec![1, 2],
        };
        let doc = run(&opts).unwrap();
        assert_eq!(doc.path("schema").unwrap().as_str(), Some(SCHEMA));
        let sc = doc.path("scenarios").unwrap();
        for key in [
            "forward_dense",
            "forward_dtr_bilayer",
            "decode_dense",
            "train_dense",
            "train_dtr_bilayer",
            "serve_dtr_bilayer_s2",
        ] {
            let s = sc
                .get(key)
                .unwrap_or_else(|| panic!("scenario {key} missing"));
            assert_eq!(
                s.path("bitwise_identical_across_threads").and_then(Json::as_bool),
                Some(true),
                "{key} lost bit-identity"
            );
            assert!(s.path("t1").is_some() && s.path("t2").is_some(), "{key} sweep");
        }
        let serve = sc.path("serve_dense_s2.t1").unwrap();
        assert!(serve.path("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(serve.path("kernel_timings.total_ms").is_some());
        // the train scenario must record the backward-kernel sections
        let train = sc.path("train_dtr_bilayer.t1").unwrap();
        assert!(train.path("steps_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(train.path("kernel_timings.bwd_attention.total_ms").is_some());
        assert!(train.path("kernel_timings.optimizer.total_ms").is_some());
    }
}
