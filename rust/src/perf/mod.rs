//! Reproducible performance harness — the `dtrnet bench` subcommand.
//!
//! Runs a fixed set of fixed-seed scenarios (training-shape forward,
//! autoregressive decode, native training steps, the continuous-batching
//! serving engine, the int8 `quant_*` accuracy/throughput family, the
//! `simd_*` kernel-tier family, and the `spec_decode_*` self-speculative
//! serving family) across a sweep of kernel-thread counts, and emits one
//! machine-readable JSON document (`BENCH_pr7.json`
//! at the repo root by convention — the recorded perf trajectory every
//! future PR diffs against; the CI `bench-regression` job regenerates and
//! uploads it on every push). [`print_baseline_deltas`] additionally
//! diffs a fresh run against the committed `BENCH_baseline.json` and
//! prints per-scenario speedup-vs-baseline readouts (including the
//! simd-vs-scalar column) plus per-kernel wall-clock deltas; armed with
//! a regression threshold it counts scenarios whose primary throughput
//! metric fell below baseline by more than the threshold, which
//! `bench --gate-pct` turns into a nonzero exit (the CI
//! `bench-regression` gate). See DESIGN.md §Benchmarking for the schema
//! and methodology.
//!
//! The `spec_decode_*` scenarios run the serving engine with
//! `--speculate k` against the plain engine on the same greedy trace:
//! token streams must be bitwise identical per request and across the
//! thread sweep, KV pages must drain to zero at shutdown (rejected
//! draft pages released), and the rows record acceptance rate, mean
//! accepted length, and the tokens/s delta speculation buys.
//!
//! The `simd_*` scenarios compare a scalar-pinned pool against the
//! detected SIMD tier side by side (per-pool [`KernelCtx`] — no
//! process-global mutation): per-kernel micro speedups with bitwise
//! cross-tier asserts (`simd_kernels`), end-to-end prefill/decode
//! deltas (`simd_forward_*` / `simd_decode_*`), and the fast-precision
//! accuracy gates (`simd_fast_eval_*`: perplexity within
//! [`QUANT_PPL_GATE`] of exact, routing equivalence via the same
//! margin-aware check the int8 gates use).
//!
//! The `quant_*` scenarios double as the int8 accuracy gates: bitwise
//! thread invariance of the quantized forward/decode paths, routing
//! decisions matching the f32 backend wherever its router is decisive
//! ([`crate::runtime::quant::check_routing_equivalence`]), eval
//! perplexity within [`QUANT_PPL_GATE`] of f32, and weight-bytes
//! compression of at least [`QUANT_MIN_COMPRESSION`]×.
//!
//! Two properties make the numbers comparable across PRs:
//!
//! * **Fixed seeds everywhere** — model init, workload trace, and
//!   sampling RNGs are pinned, so two runs execute the same token
//!   streams and the same routing decisions; only the wall-clock moves.
//! * **Thread-count sweeps with a bitwise check** — every scenario runs
//!   at `--threads 1` (the determinism baseline) and at the host's
//!   parallelism, and the harness *verifies* that logits / generated
//!   token streams are bitwise identical across the sweep before
//!   reporting speedups. A bench run that breaks bit-identity fails
//!   loudly instead of recording tainted numbers.

mod http_load;

use std::path::Path;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::{LayerKind, ModelConfig, TrainConfig, Variant};
use crate::coordinator::{
    generate_workload, PrefillMode, SamplingParams, ServeReport, Server, ServerConfig,
    WorkloadSpec,
};
use crate::data::{corpus, needle_task, Dataset};
use crate::runtime::backend::PREFILL_CHUNK;
use crate::runtime::cpu::kernels;
use crate::runtime::quant;
use crate::runtime::{
    Backend, CpuBackend, CpuTrainer, DecodeState, QuantizedCpuBackend, Tensor, TrainBackend,
};
use crate::telemetry;
use crate::util::bench::{bench, print_table};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::simd::{detect, KernelCtx, Precision, SimdTier};
use crate::util::threadpool::{available_threads, Pool};

/// Schema tag stamped into every bench document.
pub const SCHEMA: &str = "dtrnet-bench-v1";

/// Fixed seed for model init in every scenario.
pub const MODEL_SEED: u64 = 0;
/// Fixed seed for the serving workload trace.
pub const WORKLOAD_SEED: u64 = 2;

/// Relative perplexity drift the int8 backend is allowed vs f32 on the
/// markov eval corpus (`quant_eval_*` gate). Measured deltas are ~0.05%.
pub const QUANT_PPL_GATE: f64 = 0.005;

/// Weight-memory compression the int8 backend must reach vs f32
/// (`quant_forward_*` / serve-report gate; measured ~3.7×).
pub const QUANT_MIN_COMPRESSION: f64 = 3.5;

/// Harness configuration (CLI flags map onto this).
#[derive(Debug, Clone)]
pub struct BenchOptions {
    /// Seconds-scale smoke configuration (`bench --test`, the CI mode):
    /// xs preset, fewer iterations/requests. Full mode uses tiny.
    pub quick: bool,
    /// Thread counts to sweep, ascending; must start at 1 (the
    /// determinism baseline every other count is diffed against).
    pub threads: Vec<usize>,
    /// Run the `quant_*` int8 scenarios (default on; `bench --quant off`
    /// skips them). These carry the accuracy gates: routing equivalence
    /// vs f32, perplexity delta, and weight-bytes compression.
    pub include_quant: bool,
}

impl BenchOptions {
    /// Default sweep: `[1, available_parallelism]`, quant scenarios on.
    pub fn new(quick: bool) -> BenchOptions {
        let hw = available_threads();
        let mut threads = vec![1];
        if hw > 1 {
            threads.push(hw);
        }
        BenchOptions {
            quick,
            threads,
            include_quant: true,
        }
    }
}

/// Run every scenario and assemble the bench document.
pub fn run(opts: &BenchOptions) -> Result<Json> {
    ensure!(
        opts.threads.first() == Some(&1),
        "bench sweep must start at --threads 1 (the determinism baseline)"
    );
    let mut scenarios = Json::obj();
    for variant in [Variant::Dense, Variant::DtrBilayer] {
        let (fwd_key, fwd) = forward_scenario(opts, variant)?;
        scenarios.set(&fwd_key, fwd);
        let (dec_key, dec) = decode_scenario(opts, variant)?;
        scenarios.set(&dec_key, dec);
        let (tr_key, tr) = train_scenario(opts, variant)?;
        scenarios.set(&tr_key, tr);
        for &slots in serve_slot_fills(opts.quick) {
            let (key, s) = serve_scenario_impl(opts, variant, slots, false)?;
            scenarios.set(&key, s);
        }
    }
    if opts.include_quant {
        let variant = Variant::DtrBilayer;
        let (key, s) = quant_forward_scenario(opts, variant)?;
        scenarios.set(&key, s);
        let (key, s) = quant_decode_scenario(opts, variant)?;
        scenarios.set(&key, s);
        let (key, s) = quant_eval_scenario(opts, variant)?;
        scenarios.set(&key, s);
        for &slots in serve_slot_fills(opts.quick) {
            let (key, s) = serve_scenario_impl(opts, variant, slots, true)?;
            scenarios.set(&key, s);
        }
        let (key, s) = spec_decode_scenario_impl(opts, variant, true)?;
        scenarios.set(&key, s);
    }
    {
        // Self-speculative decoding family: the serving engine drafting
        // on the linear bypass and verifying with the full router, vs
        // the plain engine on the same greedy trace (bitwise-identical
        // streams enforced; acceptance + speedup recorded).
        let (key, s) = spec_decode_scenario_impl(opts, Variant::DtrBilayer, false)?;
        scenarios.set(&key, s);
    }
    {
        // SIMD tier family: scalar-pinned vs detected-tier pools run
        // side by side via per-pool KernelCtx overrides, so the sweep
        // never mutates the process-wide selector.
        let (key, s) = simd_kernels_scenario(opts)?;
        scenarios.set(&key, s);
        let variant = Variant::DtrBilayer;
        let (key, s) = simd_forward_scenario(opts, variant)?;
        scenarios.set(&key, s);
        let (key, s) = simd_decode_scenario(opts, variant)?;
        scenarios.set(&key, s);
        let (key, s) = simd_fast_eval_scenario(opts, variant)?;
        scenarios.set(&key, s);
    }
    {
        let (key, s) = telemetry_overhead_scenario(opts, Variant::DtrBilayer)?;
        scenarios.set(&key, s);
    }
    {
        // HTTP front-end family: real TCP load test + the overload/429
        // backpressure gate (ISSUE 8's bounded-latency acceptance bar).
        let (key, s) = http_load::http_serve_scenario(opts)?;
        scenarios.set(&key, s);
        let (key, s) = http_load::http_overload_scenario(opts)?;
        scenarios.set(&key, s);
    }
    {
        // Long-context family: native streaming chunked prefill through
        // the page-view KV cache, bounded-vs-resident bitwise + page
        // budget gates, cost-vs-length and routing-vs-position curves.
        let (key, s) = longctx_scenario(opts)?;
        scenarios.set(&key, s);
    }
    let mut out = Json::obj();
    out.set("schema", Json::Str(SCHEMA.to_string()));
    out.set("quick", Json::Bool(opts.quick));
    out.set("quant_included", Json::Bool(opts.include_quant));
    out.set(
        "host",
        Json::from_pairs(vec![
            ("hw_threads", Json::Num(available_threads() as f64)),
            (
                "threads_measured",
                Json::arr_f64(&opts.threads.iter().map(|&t| t as f64).collect::<Vec<_>>()),
            ),
            (
                "simd_tier",
                Json::Str(crate::util::simd::tier().name().to_string()),
            ),
            ("simd_detected", Json::Str(detect().name().to_string())),
            (
                "precision",
                Json::Str(crate::util::simd::precision().name().to_string()),
            ),
        ]),
    );
    out.set(
        "seeds",
        Json::from_pairs(vec![
            ("model", Json::Num(MODEL_SEED as f64)),
            ("workload", Json::Num(WORKLOAD_SEED as f64)),
        ]),
    );
    out.set("scenarios", scenarios);
    Ok(out)
}

/// Write the document as pretty JSON (the committed `BENCH_*.json` form).
pub fn write(path: &Path, payload: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, payload.to_string_pretty() + "\n")?;
    println!("[bench] wrote {}", path.display());
    Ok(())
}

fn preset(quick: bool) -> &'static str {
    if quick {
        "xs"
    } else {
        "tiny"
    }
}

fn serve_slot_fills(quick: bool) -> &'static [usize] {
    if quick {
        &[2]
    } else {
        &[4, 8]
    }
}

fn backend_with_threads(variant: Variant, quick: bool, t: usize) -> Result<CpuBackend> {
    let cfg = ModelConfig::preset(preset(quick), variant);
    let mut be = CpuBackend::init(&cfg, MODEL_SEED)?;
    be.set_threads(t);
    Ok(be)
}

fn quant_backend_with_threads(
    variant: Variant,
    quick: bool,
    t: usize,
) -> Result<QuantizedCpuBackend> {
    let cfg = ModelConfig::preset(preset(quick), variant);
    let mut be = QuantizedCpuBackend::init(&cfg, MODEL_SEED)?;
    be.set_threads(t);
    Ok(be)
}

/// The markov eval corpus every accuracy scenario scores against —
/// the same generator and data-seed as the CLI's `make_dataset`.
fn markov_dataset(vocab: usize, seq: usize) -> Dataset {
    let mut rng = Rng::new(7);
    Dataset::new(corpus::markov_corpus(&mut rng, vocab, 600 * seq, 12), seq)
}

/// Training-shape forward throughput (tokens/s) per thread count, with a
/// bitwise logits check against the `--threads 1` baseline.
fn forward_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let (b, s) = if opts.quick { (2usize, 32usize) } else { (2, 64) };
    let (warmup, iters) = if opts.quick { (1, 3) } else { (2, 10) };
    let key = format!("forward_{}", variant.as_str());
    let mut sc = Json::obj();
    let mut baseline: Option<Vec<f32>> = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let be = backend_with_threads(variant, opts.quick, t)?;
        let tokens = Tensor::i32(
            vec![b, s],
            (0..(b * s) as i32).map(|i| i * 7 % 256).collect(),
        );
        let logits = be.forward(&tokens)?.logits;
        match &baseline {
            None => baseline = Some(logits.as_f32().to_vec()),
            Some(want) => ensure!(
                want.as_slice() == logits.as_f32(),
                "{key}: logits bits diverged between threads=1 and threads={t}"
            ),
        }
        let m = bench(&format!("{key}_t{t}"), warmup, iters, || {
            be.forward(&tokens).unwrap();
        });
        let tps = (b * s) as f64 / m.mean_s;
        tok_s.push(tps);
        sc.set(
            &format!("t{t}"),
            Json::from_pairs(vec![
                ("tokens_per_s", Json::Num(tps)),
                ("mean_ms", Json::Num(m.mean_s * 1e3)),
                ("p50_ms", Json::Num(m.p50_s * 1e3)),
                ("p95_ms", Json::Num(m.p95_s * 1e3)),
            ]),
        );
    }
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// Autoregressive decode (prefill + greedy generation) steps/s per
/// thread count, with a bitwise token-stream check.
fn decode_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let gen = if opts.quick { 8usize } else { 32 };
    let (warmup, iters) = if opts.quick { (1, 2) } else { (1, 5) };
    let key = format!("decode_{}", variant.as_str());
    let mut sc = Json::obj();
    let mut baseline: Option<Vec<i32>> = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let be = backend_with_threads(variant, opts.quick, t)?;
        let mut prompt_rng = Rng::new(MODEL_SEED.wrapping_add(1));
        let prompt: Vec<i32> = (0..16).map(|_| prompt_rng.below(256) as i32).collect();
        let mut rng = Rng::new(2);
        let out = be.generate(&prompt, gen, &SamplingParams::greedy(), &mut rng)?;
        match &baseline {
            None => baseline = Some(out.tokens.clone()),
            Some(want) => ensure!(
                *want == out.tokens,
                "{key}: token stream diverged between threads=1 and threads={t}"
            ),
        }
        let m = bench(&format!("{key}_t{t}"), warmup, iters, || {
            let mut r = Rng::new(2);
            be.generate(&prompt, gen, &SamplingParams::greedy(), &mut r)
                .unwrap();
        });
        let sps = gen as f64 / m.mean_s;
        tok_s.push(sps);
        sc.set(
            &format!("t{t}"),
            Json::from_pairs(vec![
                ("steps_per_s", Json::Num(sps)),
                ("mean_ms", Json::Num(m.mean_s * 1e3)),
            ]),
        );
    }
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// Native training throughput (optimizer steps/s over the fused
/// forward + backward + AdamW step) per thread count, with a bitwise
/// check of the final weights and loss across the sweep — the
/// `train_step` determinism contract, re-verified on every bench run.
/// Per-kernel timings include the backward sections (`bwd_*`,
/// `optimizer`).
fn train_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let steps = if opts.quick { 3usize } else { 8 };
    let hp = TrainConfig {
        steps,
        batch: 2,
        seq: if opts.quick { 32 } else { 64 },
        seed: MODEL_SEED,
        ..Default::default()
    };
    let key = format!("train_{}", variant.as_str());
    let mut sc = Json::obj();
    let mut baseline: Option<(u64, Vec<f32>)> = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let cfg = ModelConfig::preset(preset(opts.quick), variant);
        let mut tr = CpuTrainer::new(&cfg, &hp)?;
        tr.set_threads(t);
        let tokens: Vec<i32> = (0..(hp.batch * hp.seq) as i32).map(|i| i * 7 % 256).collect();
        let t0 = Instant::now();
        let mut last_loss = f64::NAN;
        for s in 1..=steps {
            last_loss = tr.train_step(&tokens, s, 3e-4, 0)?.loss;
        }
        let wall = t0.elapsed().as_secs_f64();
        let weights_cat: Vec<f32> = tr
            .weights()
            .tensors()
            .into_iter()
            .flat_map(|(w, _)| w.iter().copied())
            .collect();
        match &baseline {
            None => baseline = Some((last_loss.to_bits(), weights_cat)),
            Some((lb, wb)) => {
                ensure!(
                    *lb == last_loss.to_bits(),
                    "{key}: loss bits diverged between threads=1 and threads={t}"
                );
                ensure!(
                    *wb == weights_cat,
                    "{key}: trained weights diverged between threads=1 and threads={t}"
                );
            }
        }
        let tps = (steps * hp.batch * hp.seq) as f64 / wall;
        tok_s.push(tps);
        let mut row = Json::from_pairs(vec![
            ("steps_per_s", Json::Num(steps as f64 / wall)),
            ("tokens_per_s", Json::Num(tps)),
            ("mean_step_ms", Json::Num(wall * 1e3 / steps as f64)),
            ("final_loss", Json::Num(last_loss)),
        ]);
        if let Some(kt) = tr.kernel_timings() {
            row.set("kernel_timings", kt);
        }
        sc.set(&format!("t{t}"), row);
        println!(
            "[bench] {key} threads={t}: {:.2} steps/s ({:.1} tok/s)",
            steps as f64 / wall,
            tps
        );
    }
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// The serving engine end-to-end at a given batch width: tokens/s,
/// latency/TTFT percentiles, occupancy, per-kernel timings — plus the
/// bitwise token-stream check across the thread sweep. `quantized`
/// selects the int8 backend (the `quant_serve_*` keys, which also
/// record and gate the weight-bytes compression).
fn serve_scenario_impl(
    opts: &BenchOptions,
    variant: Variant,
    slots: usize,
    quantized: bool,
) -> Result<(String, Json)> {
    let n_req = if opts.quick { 4usize } else { 16 };
    let prefix = if quantized { "quant_serve" } else { "serve" };
    let key = format!("{prefix}_{}_s{slots}", variant.as_str());
    let mut sc = Json::obj();
    let mut baseline: Option<Vec<Vec<i32>>> = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let be_f32;
        let be_q;
        let be: &dyn Backend = if quantized {
            be_q = quant_backend_with_threads(variant, opts.quick, t)?;
            be_q.timers().reset();
            &be_q
        } else {
            be_f32 = backend_with_threads(variant, opts.quick, t)?;
            be_f32.timers().reset();
            &be_f32
        };
        let cfg = be.config().clone();
        let spec = WorkloadSpec {
            n_requests: n_req,
            arrival_rate: 10_000.0,
            prompt_len_mean: 12,
            prompt_len_max: 32,
            gen_len_mean: if opts.quick { 8 } else { 24 },
            gen_len_max: if opts.quick { 16 } else { 48 },
            temperature: 0.0,
            vocab: cfg.vocab_size,
        };
        let trace = generate_workload(&spec, WORKLOAD_SEED);
        let scfg = ServerConfig {
            slots,
            prefill: PrefillMode::Chunked(32),
            ..Default::default()
        };
        let mut srv = Server::new(be, scfg)?;
        let rep = srv.run_workload(&trace, 10_000_000)?;
        ensure!(
            rep.completed + rep.evicted == n_req,
            "{key}: requests lost at threads={t}"
        );
        let mut streams: Vec<(u64, Vec<i32>)> = rep
            .requests
            .iter()
            .map(|r| (r.id, r.tokens.clone()))
            .collect();
        streams.sort_by_key(|(id, _)| *id);
        let streams: Vec<Vec<i32>> = streams.into_iter().map(|(_, s)| s).collect();
        match &baseline {
            None => baseline = Some(streams),
            Some(want) => ensure!(
                *want == streams,
                "{key}: token streams diverged between threads=1 and threads={t}"
            ),
        }
        tok_s.push(rep.tokens_per_s);
        let mut row = Json::from_pairs(vec![
            ("tokens_per_s", Json::Num(rep.tokens_per_s)),
            ("latency_ms_p50", Json::Num(rep.latency_ms_p50)),
            ("latency_ms_p99", Json::Num(rep.latency_ms_p99)),
            ("ttft_ms_p50", Json::Num(rep.ttft_ms_p50)),
            ("ttft_ms_p99", Json::Num(rep.ttft_ms_p99)),
            ("step_ms_p50", Json::Num(rep.decode_step_ms_p50)),
            ("step_ms_p99", Json::Num(rep.decode_step_ms_p99)),
            ("batch_occupancy", Json::Num(rep.batch_occupancy)),
            ("steps", Json::Num(rep.steps as f64)),
        ]);
        if quantized {
            ensure!(
                rep.weight_bytes.compression() >= QUANT_MIN_COMPRESSION,
                "{key}: weight compression {:.3} below the {QUANT_MIN_COMPRESSION}x gate",
                rep.weight_bytes.compression()
            );
            row.set(
                "weight_bytes_resident",
                Json::Num(rep.weight_bytes.resident as f64),
            );
            row.set(
                "weight_compression",
                Json::Num(rep.weight_bytes.compression()),
            );
        }
        if let Some(kt) = &rep.kernel_timings {
            row.set("kernel_timings", kt.clone());
        }
        sc.set(&format!("t{t}"), row);
        println!(
            "[bench] {key} threads={t}: {:.1} tok/s (p50 {:.2} ms, occupancy {:.2})",
            rep.tokens_per_s, rep.latency_ms_p50, rep.batch_occupancy
        );
    }
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// The self-speculative serving engine (`--speculate k`) against the
/// plain engine on the same fixed-seed greedy trace. Gates enforced per
/// sweep point: per-request token streams bitwise identical to the
/// plain run (the determinism contract of bypass-draft / full-router
/// verify) and across the thread sweep, KV pages drained to zero at
/// shutdown (rejected draft pages released), and draft accounting
/// closed (`accepted <= drafted`, drafting actually engaged). Rows
/// record acceptance rate, mean accepted length, and the tokens/s
/// speedup accepted drafts buy. `quantized` selects the int8 backend
/// (the `quant_spec_decode_*` keys).
fn spec_decode_scenario_impl(
    opts: &BenchOptions,
    variant: Variant,
    quantized: bool,
) -> Result<(String, Json)> {
    let k = 4usize;
    let n_req = if opts.quick { 4usize } else { 12 };
    let prefix = if quantized { "quant_spec_decode" } else { "spec_decode" };
    let key = format!("{prefix}_{}", variant.as_str());
    let mut sc = Json::obj();
    sc.set("k", Json::Num(k as f64));
    let mut baseline: Option<Vec<Vec<i32>>> = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let be_f32;
        let be_q;
        let be: &dyn Backend = if quantized {
            be_q = quant_backend_with_threads(variant, opts.quick, t)?;
            &be_q
        } else {
            be_f32 = backend_with_threads(variant, opts.quick, t)?;
            &be_f32
        };
        let spec = WorkloadSpec {
            n_requests: n_req,
            arrival_rate: 10_000.0,
            prompt_len_mean: 12,
            prompt_len_max: 32,
            gen_len_mean: if opts.quick { 8 } else { 24 },
            gen_len_max: if opts.quick { 16 } else { 48 },
            temperature: 0.0,
            vocab: be.config().vocab_size,
        };
        let trace = generate_workload(&spec, WORKLOAD_SEED);
        let run = |speculate: usize| -> Result<ServeReport> {
            let scfg = ServerConfig {
                slots: 2,
                prefill: PrefillMode::Chunked(32),
                speculate,
                ..Default::default()
            };
            let mut srv = Server::new(be, scfg)?;
            srv.run_workload(&trace, 10_000_000)
        };
        let base_rep = run(0)?;
        let spec_rep = run(k)?;
        for rep in [&base_rep, &spec_rep] {
            ensure!(
                rep.completed + rep.evicted == n_req,
                "{key}: requests lost at threads={t}"
            );
        }
        ensure!(
            spec_rep.pool.pages_allocated == 0,
            "{key}: {} KV pages leaked after the speculative run at threads={t}",
            spec_rep.pool.pages_allocated
        );
        ensure!(
            spec_rep.spec.drafted > 0 && spec_rep.spec.accepted <= spec_rep.spec.drafted,
            "{key}: speculative draft accounting broken at threads={t} \
             (drafted {}, accepted {})",
            spec_rep.spec.drafted,
            spec_rep.spec.accepted
        );
        let streams = |rep: &ServeReport| -> Vec<Vec<i32>> {
            let mut s: Vec<(u64, Vec<i32>)> =
                rep.requests.iter().map(|r| (r.id, r.tokens.clone())).collect();
            s.sort_by_key(|(id, _)| *id);
            s.into_iter().map(|(_, toks)| toks).collect()
        };
        ensure!(
            streams(&base_rep) == streams(&spec_rep),
            "{key}: speculative token streams diverged from plain decode at threads={t}"
        );
        let spec_streams = streams(&spec_rep);
        match &baseline {
            None => baseline = Some(spec_streams),
            Some(want) => ensure!(
                *want == spec_streams,
                "{key}: token streams diverged between threads=1 and threads={t}"
            ),
        }
        tok_s.push(spec_rep.tokens_per_s);
        let speedup = if base_rep.tokens_per_s > 0.0 {
            spec_rep.tokens_per_s / base_rep.tokens_per_s
        } else {
            1.0
        };
        sc.set(
            &format!("t{t}"),
            Json::from_pairs(vec![
                ("tokens_per_s", Json::Num(spec_rep.tokens_per_s)),
                ("baseline_tokens_per_s", Json::Num(base_rep.tokens_per_s)),
                ("speedup_vs_plain", Json::Num(speedup)),
                ("acceptance_rate", Json::Num(spec_rep.spec.acceptance_rate())),
                ("mean_accepted_len", Json::Num(spec_rep.spec.mean_accepted_len())),
                ("drafted", Json::Num(spec_rep.spec.drafted as f64)),
                ("accepted", Json::Num(spec_rep.spec.accepted as f64)),
                ("steps", Json::Num(spec_rep.steps as f64)),
                ("baseline_steps", Json::Num(base_rep.steps as f64)),
                ("kv_pages_after", Json::Num(spec_rep.pool.pages_allocated as f64)),
            ]),
        );
        println!(
            "[bench] {key} threads={t}: {:.1} tok/s vs plain {:.1} ({:.2}x; accept {:.2}, mean len {:.2})",
            spec_rep.tokens_per_s,
            base_rep.tokens_per_s,
            speedup,
            spec_rep.spec.acceptance_rate(),
            spec_rep.spec.mean_accepted_len()
        );
    }
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// Int8 forward: throughput + bitwise thread sweep, the
/// routing-equivalence gate vs the f32 backend (same seed, same tokens),
/// the weight-bytes compression gate, and an f32-vs-int8 throughput
/// readout at the widest thread count.
fn quant_forward_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let (b, s) = if opts.quick { (2usize, 32usize) } else { (2, 64) };
    let (warmup, iters) = if opts.quick { (1, 3) } else { (2, 10) };
    let key = format!("quant_forward_{}", variant.as_str());
    let mut sc = Json::obj();
    let tokens = Tensor::i32(
        vec![b, s],
        (0..(b * s) as i32).map(|i| i * 7 % 256).collect(),
    );
    let tmax = *opts.threads.last().unwrap();
    let f32_be = backend_with_threads(variant, opts.quick, tmax)?;
    let f32_out = f32_be.forward(&tokens)?;

    let mut baseline: Option<Vec<f32>> = None;
    let mut q_out = None;
    let mut wb = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let be = quant_backend_with_threads(variant, opts.quick, t)?;
        wb = Some(be.weight_bytes());
        let out = be.forward(&tokens)?;
        match &baseline {
            None => {
                baseline = Some(out.logits.as_f32().to_vec());
                q_out = Some(out);
            }
            Some(want) => ensure!(
                want.as_slice() == out.logits.as_f32(),
                "{key}: int8 logits bits diverged between threads=1 and threads={t}"
            ),
        }
        let m = bench(&format!("{key}_t{t}"), warmup, iters, || {
            be.forward(&tokens).unwrap();
        });
        let tps = (b * s) as f64 / m.mean_s;
        tok_s.push(tps);
        sc.set(
            &format!("t{t}"),
            Json::from_pairs(vec![
                ("tokens_per_s", Json::Num(tps)),
                ("mean_ms", Json::Num(m.mean_s * 1e3)),
            ]),
        );
    }

    // Routing-equivalence gate: decisive f32 decisions must survive
    // quantization exactly; near-tie flips stay under the budget.
    let eq = quant::check_routing_equivalence(&f32_out, &q_out.unwrap())
        .map_err(|e| e.context(format!("{key}: routing-equivalence gate")))?;
    sc.set(
        "routing_equivalence",
        Json::from_pairs(vec![
            ("decisions", Json::Num(eq.decisions as f64)),
            ("dtr_decisions", Json::Num(eq.dtr_decisions as f64)),
            ("flips", Json::Num(eq.flips as f64)),
            ("decisive_flips", Json::Num(eq.decisive_flips as f64)),
            ("min_f32_margin", Json::Num(eq.min_f32_margin as f64)),
        ]),
    );

    // Weight-bytes compression gate + f32 throughput readout.
    let wb = wb.expect("thread sweep is non-empty");
    ensure!(
        wb.compression() >= QUANT_MIN_COMPRESSION,
        "{key}: weight compression {:.3} below the {QUANT_MIN_COMPRESSION}x gate",
        wb.compression()
    );
    sc.set("weight_bytes_resident", Json::Num(wb.resident as f64));
    sc.set("weight_bytes_f32", Json::Num(wb.f32_equiv as f64));
    sc.set("weight_compression", Json::Num(wb.compression()));
    let mf = bench(&format!("{key}_f32_t{tmax}"), warmup, iters, || {
        f32_be.forward(&tokens).unwrap();
    });
    let f32_tps = (b * s) as f64 / mf.mean_s;
    sc.set("f32_tokens_per_s", Json::Num(f32_tps));
    if f32_tps > 0.0 {
        sc.set(
            "speedup_vs_f32",
            Json::Num(tok_s.last().copied().unwrap_or(0.0) / f32_tps),
        );
    }
    println!(
        "[bench] {key}: {} routing decisions, {} near-tie flips, compression {:.2}x",
        eq.decisions,
        eq.flips,
        wb.compression()
    );
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// Int8 autoregressive decode: steps/s with the bitwise token-stream
/// thread sweep, plus the f32-vs-int8 decode speedup readout — the
/// weight-bandwidth-bound hot path quantization targets.
fn quant_decode_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let gen = if opts.quick { 8usize } else { 32 };
    let (warmup, iters) = if opts.quick { (1, 2) } else { (1, 5) };
    let key = format!("quant_decode_{}", variant.as_str());
    let mut sc = Json::obj();
    let mut prompt_rng = Rng::new(MODEL_SEED.wrapping_add(1));
    let prompt: Vec<i32> = (0..16).map(|_| prompt_rng.below(256) as i32).collect();
    let mut baseline: Option<Vec<i32>> = None;
    let mut tok_s = Vec::new();
    for &t in &opts.threads {
        let be = quant_backend_with_threads(variant, opts.quick, t)?;
        let mut rng = Rng::new(2);
        let out = be.generate(&prompt, gen, &SamplingParams::greedy(), &mut rng)?;
        match &baseline {
            None => baseline = Some(out.tokens.clone()),
            Some(want) => ensure!(
                *want == out.tokens,
                "{key}: int8 token stream diverged between threads=1 and threads={t}"
            ),
        }
        let m = bench(&format!("{key}_t{t}"), warmup, iters, || {
            let mut r = Rng::new(2);
            be.generate(&prompt, gen, &SamplingParams::greedy(), &mut r)
                .unwrap();
        });
        let sps = gen as f64 / m.mean_s;
        tok_s.push(sps);
        sc.set(
            &format!("t{t}"),
            Json::from_pairs(vec![
                ("steps_per_s", Json::Num(sps)),
                ("mean_ms", Json::Num(m.mean_s * 1e3)),
            ]),
        );
    }
    // f32 decode at the widest thread count: the speedup denominator.
    let tmax = *opts.threads.last().unwrap();
    let f32_be = backend_with_threads(variant, opts.quick, tmax)?;
    let mf = bench(&format!("{key}_f32_t{tmax}"), warmup, iters, || {
        let mut r = Rng::new(2);
        f32_be
            .generate(&prompt, gen, &SamplingParams::greedy(), &mut r)
            .unwrap();
    });
    let f32_sps = gen as f64 / mf.mean_s;
    sc.set("f32_steps_per_s", Json::Num(f32_sps));
    if f32_sps > 0.0 {
        let speed = tok_s.last().copied().unwrap_or(0.0) / f32_sps;
        sc.set("speedup_vs_f32", Json::Num(speed));
        println!("[bench] {key}: int8 decode {speed:.2}x vs f32 at threads={tmax}");
    }
    finish_scenario(&mut sc, &tok_s);
    Ok((key, sc))
}

/// Int8 eval accuracy: perplexity of the f32 and int8 backends on the
/// markov corpus must agree within [`QUANT_PPL_GATE`], and routing on a
/// realistic eval batch must pass the equivalence gate.
fn quant_eval_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let seq = if opts.quick { 32usize } else { 64 };
    let (batch, batches) = if opts.quick { (2usize, 2usize) } else { (2, 4) };
    let key = format!("quant_eval_{}", variant.as_str());
    let mut sc = Json::obj();
    let tmax = *opts.threads.last().unwrap();
    let f32_be = backend_with_threads(variant, opts.quick, tmax)?;
    let q_be = quant_backend_with_threads(variant, opts.quick, tmax)?;
    let data = markov_dataset(f32_be.config().vocab_size, seq);

    let rf = crate::eval::perplexity_backend(&f32_be, &data, batch, batches)?;
    let rq = crate::eval::perplexity_backend(&q_be, &data, batch, batches)?;
    let delta = (rq.ppl - rf.ppl).abs() / rf.ppl;
    ensure!(
        delta <= QUANT_PPL_GATE,
        "{key}: int8 perplexity drifted {:.4}% from f32 ({:.4} vs {:.4}; gate {:.2}%)",
        delta * 100.0,
        rq.ppl,
        rf.ppl,
        QUANT_PPL_GATE * 100.0
    );
    // Routing equivalence on a realistic corpus batch (near-tie flips
    // tolerated, decisive flips not — see DESIGN.md §Quantization).
    let first = data
        .eval_batches(batch)
        .next()
        .expect("markov corpus yields at least one eval batch");
    let tokens = Tensor::i32(vec![batch, seq], first);
    let eq = quant::check_routing_equivalence(&f32_be.forward(&tokens)?, &q_be.forward(&tokens)?)
        .map_err(|e| e.context(format!("{key}: routing-equivalence gate")))?;
    sc.set("f32_ppl", Json::Num(rf.ppl));
    sc.set("int8_ppl", Json::Num(rq.ppl));
    sc.set("ppl_delta_pct", Json::Num(delta * 100.0));
    sc.set("ppl_gate_pct", Json::Num(QUANT_PPL_GATE * 100.0));
    sc.set("eval_tokens", Json::Num(rf.n_tokens as f64));
    sc.set(
        "routing_equivalence",
        Json::from_pairs(vec![
            ("decisions", Json::Num(eq.decisions as f64)),
            ("dtr_decisions", Json::Num(eq.dtr_decisions as f64)),
            ("flips", Json::Num(eq.flips as f64)),
            ("decisive_flips", Json::Num(eq.decisive_flips as f64)),
        ]),
    );
    println!(
        "[bench] {key}: ppl f32 {:.4} vs int8 {:.4} (delta {:.4}%), {} flips/{}",
        rf.ppl,
        rq.ppl,
        delta * 100.0,
        eq.flips,
        eq.decisions
    );
    Ok((key, sc))
}

/// A serial [`Pool`] pinned to `tier` at `precision` — the building
/// block of every `simd_*` scenario comparison.
fn pinned_pool(tier: SimdTier, precision: Precision) -> Pool {
    Pool::serial().with_ctx(KernelCtx { tier, precision })
}

/// A [`CpuBackend`] whose pool is pinned to `tier` at `precision`
/// (widest sweep thread count), without touching the process selector.
fn backend_with_tier(
    variant: Variant,
    quick: bool,
    t: usize,
    tier: SimdTier,
    precision: Precision,
) -> Result<CpuBackend> {
    let cfg = ModelConfig::preset(preset(quick), variant);
    let mut be = CpuBackend::init(&cfg, MODEL_SEED)?;
    be.set_pool(Pool::with_threads(t).with_ctx(KernelCtx { tier, precision }));
    Ok(be)
}

/// Per-kernel SIMD micro-bench: the same fixed-seed problem through a
/// scalar-pinned pool and the detected-tier pool, on serial pools so the
/// readout isolates vectorization from threading. Asserts the
/// determinism contract before timing anything: exact-precision kernels
/// (`matmul` via axpy, `matmul_q8` via the striped `dot_q8`) and the
/// fast-precision striped reductions (`rmsnorm` here) are all
/// bit-identical across tiers at fixed precision. Records
/// `speedup_vs_scalar` per kernel.
fn simd_kernels_scenario(opts: &BenchOptions) -> Result<(String, Json)> {
    let key = "simd_kernels".to_string();
    let tier = detect();
    let (n, k, m) = if opts.quick {
        (8usize, 96usize, 96usize)
    } else {
        (32, 256, 256)
    };
    let (warmup, iters) = if opts.quick { (1, 5) } else { (2, 20) };
    let mut rng = Rng::new(11);
    let a: Vec<f32> = (0..n * k).map(|_| (rng.f32() - 0.5) * 2.0).collect();
    let b: Vec<f32> = (0..k * m).map(|_| (rng.f32() - 0.5) * 2.0).collect();
    let (q, scales) = kernels::quantize_rows(&b, k, m);
    let norm_w: Vec<f32> = (0..m).map(|_| 0.5 + rng.f32()).collect();

    let pool_s = pinned_pool(SimdTier::Scalar, Precision::Exact);
    let pool_v = pinned_pool(tier, Precision::Exact);
    // rmsnorm's reduction only vectorizes under fast precision; the
    // striped scalar twin pins the summation order, so cross-tier
    // bit-identity holds at fast precision too.
    let fpool_s = pinned_pool(SimdTier::Scalar, Precision::Fast);
    let fpool_v = pinned_pool(tier, Precision::Fast);

    let mut sc = Json::obj();
    sc.set("tier", Json::Str(tier.name().to_string()));
    let mut record = |name: &str,
                      out_s: Vec<f32>,
                      out_v: Vec<f32>,
                      ms_s: f64,
                      ms_v: f64|
     -> Result<()> {
        ensure!(
            out_s == out_v,
            "{key}/{name}: bits diverged between scalar and {} tiers",
            tier.name()
        );
        sc.set(
            name,
            Json::from_pairs(vec![
                ("scalar_ms", Json::Num(ms_s)),
                ("simd_ms", Json::Num(ms_v)),
                (
                    "speedup_vs_scalar",
                    Json::Num(if ms_v > 0.0 { ms_s / ms_v } else { 1.0 }),
                ),
                ("bitwise_identical", Json::Bool(true)),
            ]),
        );
        println!(
            "[bench] {key}/{name}: {:.2}x vs scalar ({} tier)",
            if ms_v > 0.0 { ms_s / ms_v } else { 1.0 },
            tier.name()
        );
        Ok(())
    };

    let out_s = kernels::matmul_par(&pool_s, &a, &b, n, k, m);
    let out_v = kernels::matmul_par(&pool_v, &a, &b, n, k, m);
    let ms = bench(&format!("{key}_matmul_scalar"), warmup, iters, || {
        kernels::matmul_par(&pool_s, &a, &b, n, k, m);
    });
    let mv = bench(&format!("{key}_matmul_simd"), warmup, iters, || {
        kernels::matmul_par(&pool_v, &a, &b, n, k, m);
    });
    record("matmul", out_s, out_v, ms.mean_s * 1e3, mv.mean_s * 1e3)?;

    let out_s = kernels::matmul_q8_par(&pool_s, &a, &q, &scales, n, k, m);
    let out_v = kernels::matmul_q8_par(&pool_v, &a, &q, &scales, n, k, m);
    let ms = bench(&format!("{key}_matmul_q8_scalar"), warmup, iters, || {
        kernels::matmul_q8_par(&pool_s, &a, &q, &scales, n, k, m);
    });
    let mv = bench(&format!("{key}_matmul_q8_simd"), warmup, iters, || {
        kernels::matmul_q8_par(&pool_v, &a, &q, &scales, n, k, m);
    });
    record("matmul_q8", out_s, out_v, ms.mean_s * 1e3, mv.mean_s * 1e3)?;

    let x: Vec<f32> = (0..n * m).map(|_| (rng.f32() - 0.5) * 4.0).collect();
    let out_s = kernels::rmsnorm_par(&fpool_s, &x, &norm_w, 1e-5);
    let out_v = kernels::rmsnorm_par(&fpool_v, &x, &norm_w, 1e-5);
    let ms = bench(&format!("{key}_rmsnorm_fast_scalar"), warmup, iters, || {
        kernels::rmsnorm_par(&fpool_s, &x, &norm_w, 1e-5);
    });
    let mv = bench(&format!("{key}_rmsnorm_fast_simd"), warmup, iters, || {
        kernels::rmsnorm_par(&fpool_v, &x, &norm_w, 1e-5);
    });
    record("rmsnorm_fast", out_s, out_v, ms.mean_s * 1e3, mv.mean_s * 1e3)?;

    drop(record);
    Ok((key, sc))
}

/// End-to-end training-shape forward (the prefill-shaped path): scalar
/// tier vs detected tier at the widest thread count, bitwise logits
/// assert under exact precision, `speedup_vs_scalar` readout.
fn simd_forward_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let (b, s) = if opts.quick { (2usize, 32usize) } else { (2, 64) };
    let (warmup, iters) = if opts.quick { (1, 3) } else { (2, 10) };
    let key = format!("simd_forward_{}", variant.as_str());
    let tier = detect();
    let t = *opts.threads.last().unwrap();
    let be_s = backend_with_tier(variant, opts.quick, t, SimdTier::Scalar, Precision::Exact)?;
    let be_v = backend_with_tier(variant, opts.quick, t, tier, Precision::Exact)?;
    let tokens = Tensor::i32(
        vec![b, s],
        (0..(b * s) as i32).map(|i| i * 7 % 256).collect(),
    );
    let ls = be_s.forward(&tokens)?.logits;
    let lv = be_v.forward(&tokens)?.logits;
    ensure!(
        ls.as_f32() == lv.as_f32(),
        "{key}: exact-precision logits bits diverged between scalar and {} tiers",
        tier.name()
    );
    let ms = bench(&format!("{key}_scalar"), warmup, iters, || {
        be_s.forward(&tokens).unwrap();
    });
    let mv = bench(&format!("{key}_{}", tier.name()), warmup, iters, || {
        be_v.forward(&tokens).unwrap();
    });
    let scalar_tps = (b * s) as f64 / ms.mean_s;
    let simd_tps = (b * s) as f64 / mv.mean_s;
    let mut sc = Json::obj();
    sc.set("tier", Json::Str(tier.name().to_string()));
    sc.set("scalar_tokens_per_s", Json::Num(scalar_tps));
    sc.set("simd_tokens_per_s", Json::Num(simd_tps));
    sc.set(
        "speedup_vs_scalar",
        Json::Num(if scalar_tps > 0.0 { simd_tps / scalar_tps } else { 1.0 }),
    );
    sc.set("bitwise_identical_across_tiers", Json::Bool(true));
    println!(
        "[bench] {key}: {:.2}x vs scalar ({} tier, threads={t})",
        if scalar_tps > 0.0 { simd_tps / scalar_tps } else { 1.0 },
        tier.name()
    );
    Ok((key, sc))
}

/// End-to-end autoregressive decode: scalar tier vs detected tier,
/// bitwise token-stream assert under exact precision,
/// `speedup_vs_scalar` readout for the decode hot path.
fn simd_decode_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let gen = if opts.quick { 8usize } else { 32 };
    let (warmup, iters) = if opts.quick { (1, 2) } else { (1, 5) };
    let key = format!("simd_decode_{}", variant.as_str());
    let tier = detect();
    let t = *opts.threads.last().unwrap();
    let be_s = backend_with_tier(variant, opts.quick, t, SimdTier::Scalar, Precision::Exact)?;
    let be_v = backend_with_tier(variant, opts.quick, t, tier, Precision::Exact)?;
    let mut prompt_rng = Rng::new(MODEL_SEED.wrapping_add(1));
    let prompt: Vec<i32> = (0..16).map(|_| prompt_rng.below(256) as i32).collect();
    let mut rng = Rng::new(2);
    let toks_s = be_s.generate(&prompt, gen, &SamplingParams::greedy(), &mut rng)?.tokens;
    let mut rng = Rng::new(2);
    let toks_v = be_v.generate(&prompt, gen, &SamplingParams::greedy(), &mut rng)?.tokens;
    ensure!(
        toks_s == toks_v,
        "{key}: token stream diverged between scalar and {} tiers",
        tier.name()
    );
    let ms = bench(&format!("{key}_scalar"), warmup, iters, || {
        let mut r = Rng::new(2);
        be_s.generate(&prompt, gen, &SamplingParams::greedy(), &mut r)
            .unwrap();
    });
    let mv = bench(&format!("{key}_{}", tier.name()), warmup, iters, || {
        let mut r = Rng::new(2);
        be_v.generate(&prompt, gen, &SamplingParams::greedy(), &mut r)
            .unwrap();
    });
    let scalar_sps = gen as f64 / ms.mean_s;
    let simd_sps = gen as f64 / mv.mean_s;
    let mut sc = Json::obj();
    sc.set("tier", Json::Str(tier.name().to_string()));
    sc.set("scalar_steps_per_s", Json::Num(scalar_sps));
    sc.set("simd_steps_per_s", Json::Num(simd_sps));
    sc.set(
        "speedup_vs_scalar",
        Json::Num(if scalar_sps > 0.0 { simd_sps / scalar_sps } else { 1.0 }),
    );
    sc.set("bitwise_identical_across_tiers", Json::Bool(true));
    println!(
        "[bench] {key}: {:.2}x vs scalar ({} tier, threads={t})",
        if scalar_sps > 0.0 { simd_sps / scalar_sps } else { 1.0 },
        tier.name()
    );
    Ok((key, sc))
}

/// The `--precision fast` accuracy gate: exact vs fast backends at the
/// detected tier must agree within [`QUANT_PPL_GATE`] on markov-corpus
/// perplexity, and routing decisions must pass the same margin-aware
/// equivalence check the int8 gates use (decisive flips forbidden,
/// near-tie flips budgeted). Also records the fast-vs-exact forward
/// speedup (the payoff the tolerance buys).
fn simd_fast_eval_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let seq = if opts.quick { 32usize } else { 64 };
    let (batch, batches) = if opts.quick { (2usize, 2usize) } else { (2, 4) };
    let (warmup, iters) = if opts.quick { (1, 3) } else { (2, 10) };
    let key = format!("simd_fast_eval_{}", variant.as_str());
    let tier = detect();
    let t = *opts.threads.last().unwrap();
    let be_e = backend_with_tier(variant, opts.quick, t, tier, Precision::Exact)?;
    let be_f = backend_with_tier(variant, opts.quick, t, tier, Precision::Fast)?;
    let data = markov_dataset(be_e.config().vocab_size, seq);

    let re = crate::eval::perplexity_backend(&be_e, &data, batch, batches)?;
    let rf = crate::eval::perplexity_backend(&be_f, &data, batch, batches)?;
    let delta = (rf.ppl - re.ppl).abs() / re.ppl;
    ensure!(
        delta <= QUANT_PPL_GATE,
        "{key}: fast-precision perplexity drifted {:.4}% from exact ({:.4} vs {:.4}; gate {:.2}%)",
        delta * 100.0,
        rf.ppl,
        re.ppl,
        QUANT_PPL_GATE * 100.0
    );
    let first = data
        .eval_batches(batch)
        .next()
        .expect("markov corpus yields at least one eval batch");
    let tokens = Tensor::i32(vec![batch, seq], first);
    let eq = quant::check_routing_equivalence(&be_e.forward(&tokens)?, &be_f.forward(&tokens)?)
        .map_err(|e| e.context(format!("{key}: routing-equivalence gate")))?;
    let me = bench(&format!("{key}_exact"), warmup, iters, || {
        be_e.forward(&tokens).unwrap();
    });
    let mf = bench(&format!("{key}_fast"), warmup, iters, || {
        be_f.forward(&tokens).unwrap();
    });
    let exact_tps = (batch * seq) as f64 / me.mean_s;
    let fast_tps = (batch * seq) as f64 / mf.mean_s;
    let mut sc = Json::obj();
    sc.set("tier", Json::Str(tier.name().to_string()));
    sc.set("exact_ppl", Json::Num(re.ppl));
    sc.set("fast_ppl", Json::Num(rf.ppl));
    sc.set("ppl_delta_pct", Json::Num(delta * 100.0));
    sc.set("ppl_gate_pct", Json::Num(QUANT_PPL_GATE * 100.0));
    sc.set("eval_tokens", Json::Num(re.n_tokens as f64));
    sc.set(
        "routing_equivalence",
        Json::from_pairs(vec![
            ("decisions", Json::Num(eq.decisions as f64)),
            ("dtr_decisions", Json::Num(eq.dtr_decisions as f64)),
            ("flips", Json::Num(eq.flips as f64)),
            ("decisive_flips", Json::Num(eq.decisive_flips as f64)),
        ]),
    );
    sc.set("exact_tokens_per_s", Json::Num(exact_tps));
    sc.set("fast_tokens_per_s", Json::Num(fast_tps));
    sc.set(
        "speedup_fast_vs_exact",
        Json::Num(if exact_tps > 0.0 { fast_tps / exact_tps } else { 1.0 }),
    );
    println!(
        "[bench] {key}: ppl exact {:.4} vs fast {:.4} (delta {:.4}%), {} flips/{}, fast {:.2}x",
        re.ppl,
        rf.ppl,
        delta * 100.0,
        eq.flips,
        eq.decisions,
        if exact_tps > 0.0 { fast_tps / exact_tps } else { 1.0 },
    );
    Ok((key, sc))
}

/// Telemetry overhead gate: the same fixed-seed serving workload (the
/// most heavily instrumented path — request async spans, prefill and
/// engine-step spans, eviction instants) with tracing disabled vs
/// enabled. Asserts the determinism contract first — token streams are
/// bitwise identical on vs off — then gates the tracing-on overhead via
/// alternating min-of-N wall-clock measurement (alternation keeps both
/// modes in the same thermal/cache environment; min filters scheduler
/// noise). Full mode carries the ≤3% acceptance gate; quick mode (the
/// seconds-scale CI/test configuration, where runs sit near timer
/// resolution and execute under parallel-test contention) uses a loose
/// sanity bound that still catches catastrophic regressions. Always
/// restores the process-global telemetry state (disabled, rings
/// cleared) before returning.
fn telemetry_overhead_scenario(opts: &BenchOptions, variant: Variant) -> Result<(String, Json)> {
    let key = "telemetry_overhead".to_string();
    let _state = telemetry::state_guard();
    let n_req = if opts.quick { 6usize } else { 16 };
    let rounds = if opts.quick { 4usize } else { 7 };
    let gate = if opts.quick { 0.50 } else { 0.03 };
    let t = *opts.threads.last().unwrap();
    let be = backend_with_threads(variant, opts.quick, t)?;
    let spec = WorkloadSpec {
        n_requests: n_req,
        arrival_rate: 10_000.0,
        prompt_len_mean: 12,
        prompt_len_max: 32,
        gen_len_mean: if opts.quick { 12 } else { 24 },
        gen_len_max: if opts.quick { 24 } else { 48 },
        temperature: 0.0,
        vocab: be.config().vocab_size,
    };
    let trace = generate_workload(&spec, WORKLOAD_SEED);
    let run = |be: &CpuBackend| -> Result<Vec<Vec<i32>>> {
        let scfg = ServerConfig {
            slots: 4,
            prefill: PrefillMode::Chunked(32),
            ..Default::default()
        };
        let mut srv = Server::new(be, scfg)?;
        let rep = srv.run_workload(&trace, 10_000_000)?;
        let mut streams: Vec<(u64, Vec<i32>)> =
            rep.requests.iter().map(|r| (r.id, r.tokens.clone())).collect();
        streams.sort_by_key(|(id, _)| *id);
        Ok(streams.into_iter().map(|(_, s)| s).collect())
    };
    // Determinism contract: tracing is read-only observation.
    telemetry::set_enabled(false);
    let off_streams = run(&be)?;
    telemetry::set_enabled(true);
    telemetry::clear();
    let on_streams = run(&be)?;
    let events = telemetry::snapshot_events().len();
    telemetry::set_enabled(false);
    ensure!(
        off_streams == on_streams,
        "{key}: token streams diverged between tracing off and on"
    );
    ensure!(events > 0, "{key}: tracing-on serve run recorded no events");
    let mut min_off = f64::INFINITY;
    let mut min_on = f64::INFINITY;
    for _ in 0..rounds {
        telemetry::set_enabled(false);
        let t0 = Instant::now();
        run(&be)?;
        min_off = min_off.min(t0.elapsed().as_secs_f64());
        telemetry::set_enabled(true);
        telemetry::clear();
        let t0 = Instant::now();
        run(&be)?;
        min_on = min_on.min(t0.elapsed().as_secs_f64());
    }
    telemetry::set_enabled(false);
    telemetry::clear();
    let overhead = (min_on / min_off - 1.0).max(0.0);
    ensure!(
        overhead <= gate,
        "{key}: tracing-on overhead {:.2}% above the {:.0}% gate (off {:.2} ms vs on {:.2} ms)",
        overhead * 100.0,
        gate * 100.0,
        min_off * 1e3,
        min_on * 1e3
    );
    let mut sc = Json::obj();
    sc.set("off_ms", Json::Num(min_off * 1e3));
    sc.set("on_ms", Json::Num(min_on * 1e3));
    sc.set("overhead_pct", Json::Num(overhead * 100.0));
    sc.set("overhead_gate_pct", Json::Num(gate * 100.0));
    sc.set("events_per_run", Json::Num(events as f64));
    sc.set("bitwise_identical_on_vs_off", Json::Bool(true));
    println!(
        "[bench] {key}: {:.2}% overhead ({events} events/run; gate {:.0}%)",
        overhead * 100.0,
        gate * 100.0
    );
    Ok((key, sc))
}

/// The long-context family: streaming chunked prefill at native 32k
/// lengths through the page-view KV cache ([`crate::runtime::KvCache`]),
/// run twice per length — once on the unbounded resident slab, once on
/// the bounded paged cache with LRU spill-to-disk eviction — with the
/// determinism and memory gates asserted before anything is recorded:
///
/// * generated token streams and per-row routing telemetry bitwise
///   identical between the bounded and resident runs (the page budget
///   bounds *memory*, never what attention sees);
/// * the bounded run's resident-page high-water mark within the budget
///   while the total cached page count exceeds it (eviction genuinely
///   engaged, not just configured);
/// * the resident slab never pages (`resident_pages_peak == 0`).
///
/// Rows record the cost-vs-length curve (prefill wall clock/throughput
/// and measured FLOPs vs the dense-equivalent — the native Fig. 3
/// reproduction) plus the routing-fraction-vs-position curve from the
/// prompt's per-row routing telemetry (DTR layers, eight equal-width
/// position buckets). Quick mode sweeps seconds-scale lengths; full
/// mode runs the native 32k tier.
fn longctx_scenario(opts: &BenchOptions) -> Result<(String, Json)> {
    let variant = Variant::DtrBilayer;
    let key = format!("longctx_{}", variant.as_str());
    let lengths: &[usize] = if opts.quick {
        &[128, 256, 512]
    } else {
        &[1024, 8192, 32768]
    };
    let gen = if opts.quick { 8usize } else { 16 };
    let page_rows = if opts.quick { 16usize } else { 64 };
    let t = *opts.threads.last().unwrap();
    // Context length is the variable under test, not model size: both
    // modes run the xs preset with max_seq raised to the sweep maximum
    // (RoPE is computed from absolute positions, so raising the cap is
    // purely a config change).
    let mut cfg = ModelConfig::preset("xs", variant);
    cfg.max_seq = lengths.last().unwrap() + gen;
    let mut be = CpuBackend::init(&cfg, MODEL_SEED)?;
    be.set_threads(t);
    let d = cfg.d_model;
    let dtr_layers: Vec<usize> = cfg
        .layer_kinds()
        .iter()
        .enumerate()
        .filter(|(_, k)| matches!(k, LayerKind::Dtr))
        .map(|(i, _)| i)
        .collect();

    struct LongCtxRun {
        tokens: Vec<i32>,
        routed: Vec<Vec<bool>>,
        prefill_s: f64,
        decode_s: f64,
        flops_measured: f64,
        flops_dense: f64,
        flops_ratio: f64,
    }

    // Greedy argmax over logits (both runs share it, so the bitwise
    // stream comparison is a pure cache-path comparison).
    fn argmax(logits: &[f32]) -> i32 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as i32
    }

    // Streaming chunked prefill + greedy decode on the caller's state.
    let run = |state: &mut DecodeState, prompt: &[i32]| -> Result<LongCtxRun> {
        if let Some(c) = be.flop_counters() {
            c.reset();
        }
        let t0 = Instant::now();
        let pr = be.prefill_rows(state, prompt, PREFILL_CHUNK)?;
        let prefill_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let mut logits = pr.last.logits;
        let mut tokens = Vec::with_capacity(gen);
        for _ in 0..gen {
            let next = argmax(logits.as_f32());
            tokens.push(next);
            logits = be.decode_step(state, next)?.logits;
        }
        let decode_s = t0.elapsed().as_secs_f64();
        let (flops_measured, flops_dense, flops_ratio) = match be.flop_counters() {
            Some(c) => {
                let fj = c.to_json();
                (
                    fj.path("total").and_then(Json::as_f64).unwrap_or(0.0),
                    fj.path("dense_equiv_total").and_then(Json::as_f64).unwrap_or(0.0),
                    fj.path("ratio_vs_dense").and_then(Json::as_f64).unwrap_or(1.0),
                )
            }
            None => (0.0, 0.0, 1.0),
        };
        Ok(LongCtxRun {
            tokens,
            routed: pr.routed,
            prefill_s,
            decode_s,
            flops_measured,
            flops_dense,
            flops_ratio,
        })
    };

    let mut sc = Json::obj();
    sc.set("model", Json::Str(cfg.name.clone()));
    sc.set("layout", Json::Str(cfg.layout_string()));
    sc.set("threads", Json::Num(t as f64));
    sc.set("page_rows", Json::Num(page_rows as f64));
    sc.set("gen_tokens", Json::Num(gen as f64));
    sc.set("max_len", Json::Num(*lengths.last().unwrap() as f64));
    let mut rows = Vec::new();
    for &len in lengths {
        let mut rng = Rng::new(WORKLOAD_SEED.wrapping_add(len as u64));
        let item = needle_task(&mut rng, cfg.vocab_size, len, 16);
        let prompt: Vec<i32> = item.tokens.iter().map(|&u| u as i32).collect();
        // The budget must cover one layer's full working set (pinning a
        // layer faults it fully resident) but sit well under the
        // all-layers total, so eviction genuinely engages: dense layers
        // alone cache ≥ 3× one layer's pages on this layout.
        let per_layer_pages = (len + gen).div_ceil(page_rows);
        let budget = per_layer_pages + 2;

        let mut st_res = be.begin_decode();
        let res = run(&mut st_res, &prompt)?;
        ensure!(
            st_res.kv.resident_pages_peak() == 0,
            "{key}/{len}: the unbounded resident slab reported paged residency"
        );
        let mut st_b = DecodeState::bounded(cfg.n_layers, d, page_rows, budget, None);
        let bnd = run(&mut st_b, &prompt)?;
        ensure!(
            res.tokens == bnd.tokens,
            "{key}/{len}: bounded-cache token stream diverged from the resident slab"
        );
        ensure!(
            res.routed == bnd.routed,
            "{key}/{len}: bounded-cache routing telemetry diverged from the resident slab"
        );
        let peak = st_b.kv.resident_pages_peak();
        ensure!(
            peak > 0 && peak <= budget,
            "{key}/{len}: resident high-water mark {peak} outside (0, {budget}]"
        );
        let total_pages: usize = st_b.lens(d).iter().map(|&l| l.div_ceil(page_rows)).sum();
        ensure!(
            total_pages > budget,
            "{key}/{len}: {total_pages} cached pages fit the {budget}-page budget — \
             eviction never engaged"
        );
        // Routing fraction vs absolute position: DTR layers only, eight
        // equal-width buckets across the prompt.
        let mut curve = Vec::new();
        let n_buckets = 8usize.min(len);
        for bkt in 0..n_buckets {
            let start = len * bkt / n_buckets;
            let end = len * (bkt + 1) / n_buckets;
            let mut num = 0u64;
            let mut den = 0u64;
            for row in start..end {
                for &li in &dtr_layers {
                    num += u64::from(res.routed[row][li]);
                    den += 1;
                }
            }
            curve.push(Json::from_pairs(vec![
                ("pos_start", Json::Num(start as f64)),
                ("pos_end", Json::Num(end as f64)),
                (
                    "attn_frac",
                    Json::Num(if den == 0 { 1.0 } else { num as f64 / den as f64 }),
                ),
            ]));
        }
        rows.push(Json::from_pairs(vec![
            ("len", Json::Num(len as f64)),
            ("budget_pages", Json::Num(budget as f64)),
            ("resident_pages_peak", Json::Num(peak as f64)),
            ("total_pages", Json::Num(total_pages as f64)),
            ("prefill_ms", Json::Num(res.prefill_s * 1e3)),
            (
                "prefill_tokens_per_s",
                Json::Num(len as f64 / res.prefill_s.max(1e-12)),
            ),
            ("decode_ms", Json::Num(res.decode_s * 1e3)),
            ("bounded_prefill_ms", Json::Num(bnd.prefill_s * 1e3)),
            ("bounded_decode_ms", Json::Num(bnd.decode_s * 1e3)),
            ("flops_measured", Json::Num(res.flops_measured)),
            ("flops_dense_equiv", Json::Num(res.flops_dense)),
            ("flops_ratio_vs_dense", Json::Num(res.flops_ratio)),
            ("routing_vs_position", Json::Arr(curve)),
            ("bitwise_identical_bounded_vs_resident", Json::Bool(true)),
        ]));
        println!(
            "[bench] {key} len={len}: prefill {:.1} ms ({:.0} tok/s), \
             flops {:.3}x dense, resident peak {peak}/{budget} pages (total {total_pages})",
            res.prefill_s * 1e3,
            len as f64 / res.prefill_s.max(1e-12),
            res.flops_ratio
        );
    }
    sc.set("lengths", Json::Arr(rows));
    Ok((key, sc))
}

/// The primary throughput metric of a scenario row for baseline diffs:
/// the widest-thread `tokens_per_s`/`steps_per_s` when the scenario has
/// a thread sweep, otherwise a scenario-level readout (`simd_*` family).
/// Returns `(json_path_within_scenario, value)`.
fn primary_metric(sc: &Json) -> Option<(String, f64)> {
    if let Json::Obj(m) = sc {
        let mut best: Option<(usize, String, f64)> = None;
        for (k, v) in m {
            if let Some(n) = k.strip_prefix('t').and_then(|r| r.parse::<usize>().ok()) {
                for metric in ["tokens_per_s", "steps_per_s"] {
                    if let Some(val) = v.get(metric).and_then(Json::as_f64) {
                        if best.as_ref().map(|(bn, _, _)| n > *bn).unwrap_or(true) {
                            best = Some((n, format!("{k}.{metric}"), val));
                        }
                        break;
                    }
                }
            }
        }
        if let Some((_, path, val)) = best {
            return Some((path, val));
        }
        for metric in [
            "simd_tokens_per_s",
            "simd_steps_per_s",
            "fast_tokens_per_s",
            "matmul.speedup_vs_scalar",
        ] {
            if let Some(val) = sc.path(metric).and_then(Json::as_f64) {
                return Some((metric.to_string(), val));
            }
        }
    }
    None
}

/// Collect `(json_path_within_scenario, kernel_label)` pairs for the
/// per-kernel delta table: the widest thread row's
/// `kernel_timings.<kernel>.total_ms` sections (serve/train scenarios),
/// plus the `simd_kernels` micro-bench `<kernel>.simd_ms` rows.
fn kernel_metric_paths(sc: &Json) -> Vec<(String, String)> {
    let mut out = Vec::new();
    if let Json::Obj(m) = sc {
        let mut best: Option<(usize, &Json)> = None;
        for (k, v) in m {
            if let Some(n) = k.strip_prefix('t').and_then(|r| r.parse::<usize>().ok()) {
                if v.get("kernel_timings").is_some()
                    && best.as_ref().map(|(bn, _)| n > *bn).unwrap_or(true)
                {
                    best = Some((n, v));
                }
            }
        }
        if let Some((n, row)) = best {
            if let Some(Json::Obj(kt)) = row.get("kernel_timings") {
                for (kernel, v) in kt {
                    if v.path("total_ms").and_then(Json::as_f64).is_some() {
                        out.push((
                            format!("t{n}.kernel_timings.{kernel}.total_ms"),
                            kernel.clone(),
                        ));
                    }
                }
            }
        }
        for (kernel, v) in m {
            if v.path("simd_ms").and_then(Json::as_f64).is_some() {
                out.push((format!("{kernel}.simd_ms"), kernel.clone()));
            }
        }
    }
    out
}

/// Diff a fresh bench document against the committed baseline
/// (`BENCH_baseline.json`) and print the delta readout: a per-scenario
/// table (the primary throughput metric now vs then, plus the
/// simd-vs-scalar speedup column where the scenario records one),
/// followed by a per-kernel wall-clock table from every scenario that
/// embeds `kernel_timings` (and the `simd_kernels` micro-bench rows).
///
/// `regression_gate_pct` arms the regression gate: a scenario whose
/// primary throughput metric fell more than that many percent below
/// baseline is flagged `REGRESSED` in the table and counted in the
/// return value — `bench --gate-pct` maps a nonzero count onto a
/// nonzero process exit (the CI `bench-regression` job's gate).
/// Per-kernel rows are informational only; per-kernel wall-clock is too
/// noisy to gate. With `None` the readout never fails anything (the
/// historical behavior). A missing baseline file, a
/// `"status": "pending-measurement"` stub (committed before the first
/// measured run lands — promote one with
/// `cp results/bench_ci.json BENCH_baseline.json`), or rows the
/// baseline lacks are reported and skipped, and count zero regressions.
pub fn print_baseline_deltas(
    doc: &Json,
    baseline_path: &Path,
    regression_gate_pct: Option<f64>,
) -> usize {
    let base = match Json::parse_file(baseline_path) {
        Ok(b) => b,
        Err(_) => {
            println!(
                "[bench] no baseline at {} — skipping delta readout",
                baseline_path.display()
            );
            return 0;
        }
    };
    // A pending-measurement stub (committed before the first measured CI
    // artifact is promoted) has no real numbers — diffing against it
    // would print meaningless ratios, so say so and stop instead.
    let status = base.path("status").and_then(Json::as_str).unwrap_or("measured");
    if status == "pending-measurement" {
        println!(
            "[bench] baseline at {} is unmeasured (status: pending-measurement) — deltas skipped",
            baseline_path.display()
        );
        println!(
            "[bench] promote a measured CI artifact with: cp results/bench_ci.json {}",
            baseline_path.display()
        );
        return 0;
    }
    let cur = match doc.get("scenarios") {
        Some(Json::Obj(m)) => m,
        _ => return 0,
    };
    let mut regressions = 0usize;
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (name, sc) in cur {
        let Some((metric, val)) = primary_metric(sc) else {
            continue;
        };
        let base_val = base
            .path(&format!("scenarios.{name}.{metric}"))
            .and_then(Json::as_f64)
            .filter(|v| *v > 0.0);
        let delta_pct = base_val.map(|bv| (val / bv - 1.0) * 100.0);
        let (base_cell, delta_cell) = match (base_val, delta_pct) {
            (Some(bv), Some(d)) => (format!("{bv:.1}"), format!("{d:+.1}%")),
            _ => ("-".to_string(), "-".to_string()),
        };
        let status_cell = match (regression_gate_pct, delta_pct) {
            (Some(gate), Some(d)) if d < -gate => {
                regressions += 1;
                "REGRESSED".to_string()
            }
            (Some(_), Some(_)) => "ok".to_string(),
            _ => "-".to_string(),
        };
        let simd_cell = sc
            .path("speedup_vs_scalar")
            .or_else(|| sc.path("matmul.speedup_vs_scalar"))
            .or_else(|| sc.path("speedup_fast_vs_exact"))
            .and_then(Json::as_f64)
            .map(|v| format!("{v:.2}x"))
            .unwrap_or_else(|| "-".to_string());
        rows.push(vec![
            name.clone(),
            metric,
            format!("{val:.1}"),
            base_cell,
            delta_cell,
            simd_cell,
            status_cell,
        ]);
    }
    print_table(
        &format!("speedup vs baseline ({})", baseline_path.display()),
        &[
            "scenario",
            "metric",
            "current",
            "baseline",
            "delta",
            "simd-vs-scalar",
            "status",
        ],
        &rows,
    );
    let mut krows: Vec<Vec<String>> = Vec::new();
    for (name, sc) in cur {
        for (path, kernel) in kernel_metric_paths(sc) {
            let Some(val) = sc.path(&path).and_then(Json::as_f64) else {
                continue;
            };
            let base_val = base
                .path(&format!("scenarios.{name}.{path}"))
                .and_then(Json::as_f64)
                .filter(|v| *v > 0.0);
            let (base_cell, delta_cell) = match base_val {
                Some(bv) => (format!("{bv:.2}"), format!("{:+.1}%", (val / bv - 1.0) * 100.0)),
                None => ("-".to_string(), "-".to_string()),
            };
            krows.push(vec![
                name.clone(),
                kernel,
                format!("{val:.2}"),
                base_cell,
                delta_cell,
            ]);
        }
    }
    if !krows.is_empty() {
        print_table(
            "per-kernel wall-clock vs baseline (informational)",
            &["scenario", "kernel", "current_ms", "baseline_ms", "delta"],
            &krows,
        );
    }
    if let Some(gate) = regression_gate_pct {
        println!(
            "[bench] regression gate: {regressions} scenario(s) more than {gate:.1}% below baseline"
        );
    }
    regressions
}

/// Stamp the cross-thread summary: speedup of the widest sweep point
/// over the `--threads 1` baseline, and the (already enforced) bitwise
/// identity marker.
fn finish_scenario(sc: &mut Json, tok_s: &[f64]) {
    if let (Some(&first), Some(&last)) = (tok_s.first(), tok_s.last()) {
        if first > 0.0 {
            sc.set("speedup_vs_t1", Json::Num(last / first));
        }
    }
    // run()/the scenario fns ensure! bitwise equality before we get here
    sc.set("bitwise_identical_across_threads", Json::Bool(true));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_bench_produces_schema_and_identity() {
        let opts = BenchOptions {
            quick: true,
            threads: vec![1, 2],
            include_quant: true,
        };
        let doc = run(&opts).unwrap();
        assert_eq!(doc.path("schema").unwrap().as_str(), Some(SCHEMA));
        let sc = doc.path("scenarios").unwrap();
        for key in [
            "forward_dense",
            "forward_dtr_bilayer",
            "decode_dense",
            "train_dense",
            "train_dtr_bilayer",
            "serve_dtr_bilayer_s2",
            "quant_forward_dtr_bilayer",
            "quant_decode_dtr_bilayer",
            "quant_serve_dtr_bilayer_s2",
            "spec_decode_dtr_bilayer",
            "quant_spec_decode_dtr_bilayer",
        ] {
            let s = sc
                .get(key)
                .unwrap_or_else(|| panic!("scenario {key} missing"));
            assert_eq!(
                s.path("bitwise_identical_across_threads").and_then(Json::as_bool),
                Some(true),
                "{key} lost bit-identity"
            );
            assert!(s.path("t1").is_some() && s.path("t2").is_some(), "{key} sweep");
        }
        let serve = sc.path("serve_dense_s2.t1").unwrap();
        assert!(serve.path("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(serve.path("kernel_timings.total_ms").is_some());
        // the train scenario must record the backward-kernel sections
        let train = sc.path("train_dtr_bilayer.t1").unwrap();
        assert!(train.path("steps_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(train.path("kernel_timings.bwd_attention.total_ms").is_some());
        assert!(train.path("kernel_timings.optimizer.total_ms").is_some());
        // the quant scenarios must carry their accuracy-gate readouts
        let qf = sc.path("quant_forward_dtr_bilayer").unwrap();
        assert_eq!(
            qf.path("routing_equivalence.decisive_flips").and_then(Json::as_f64),
            Some(0.0),
            "decisive routing flips must be zero (the gate would have failed)"
        );
        assert!(
            qf.path("weight_compression").unwrap().as_f64().unwrap()
                >= QUANT_MIN_COMPRESSION
        );
        let qe = sc.path("quant_eval_dtr_bilayer").unwrap();
        let delta = qe.path("ppl_delta_pct").unwrap().as_f64().unwrap();
        assert!(delta <= QUANT_PPL_GATE * 100.0, "ppl delta {delta}%");
        assert!(doc.path("quant_included").and_then(Json::as_bool) == Some(true));
        // the spec_decode family must carry its acceptance readouts and
        // the pages-to-zero marker (the bitwise gates already ran inside
        // the scenario — a run that broke them would have errored)
        for key in ["spec_decode_dtr_bilayer", "quant_spec_decode_dtr_bilayer"] {
            let sd = sc.path(key).unwrap();
            let rate = sd.path("t2.acceptance_rate").unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&rate), "{key} acceptance rate {rate}");
            assert!(
                sd.path("t2.mean_accepted_len").unwrap().as_f64().unwrap() >= 1.0,
                "{key}: every iteration emits at least one token"
            );
            assert!(sd.path("t2.speedup_vs_plain").unwrap().as_f64().unwrap() > 0.0);
            assert!(sd.path("t2.drafted").unwrap().as_f64().unwrap() > 0.0);
            assert_eq!(
                sd.path("t2.kv_pages_after").and_then(Json::as_f64),
                Some(0.0),
                "{key} leaked KV pages"
            );
        }
        // the simd family must record its determinism + accuracy gates
        let sk = sc.path("simd_kernels").unwrap();
        for kernel in ["matmul", "matmul_q8", "rmsnorm_fast"] {
            assert_eq!(
                sk.path(&format!("{kernel}.bitwise_identical")).and_then(Json::as_bool),
                Some(true),
                "simd_kernels/{kernel} lost cross-tier bit-identity"
            );
            assert!(
                sk.path(&format!("{kernel}.speedup_vs_scalar")).and_then(Json::as_f64)
                    .unwrap_or(0.0)
                    > 0.0,
                "simd_kernels/{kernel} missing speedup readout"
            );
        }
        for key in ["simd_forward_dtr_bilayer", "simd_decode_dtr_bilayer"] {
            let s = sc.path(key).unwrap();
            assert_eq!(
                s.path("bitwise_identical_across_tiers").and_then(Json::as_bool),
                Some(true),
                "{key} lost cross-tier bit-identity"
            );
            assert!(s.path("speedup_vs_scalar").is_some(), "{key} missing speedup");
        }
        let fe = sc.path("simd_fast_eval_dtr_bilayer").unwrap();
        let d = fe.path("ppl_delta_pct").unwrap().as_f64().unwrap();
        assert!(d <= QUANT_PPL_GATE * 100.0, "fast-precision ppl delta {d}%");
        assert_eq!(
            fe.path("routing_equivalence.decisive_flips").and_then(Json::as_f64),
            Some(0.0),
            "fast precision flipped a decisive routing decision"
        );
        assert!(doc.path("host.simd_tier").is_some());
        assert!(doc.path("host.simd_detected").is_some());
        // the telemetry overhead scenario must record its determinism
        // marker and gate readout, and must leave tracing disabled
        let to = sc.path("telemetry_overhead").unwrap();
        assert_eq!(
            to.path("bitwise_identical_on_vs_off").and_then(Json::as_bool),
            Some(true),
            "tracing on/off lost bit-identity"
        );
        assert!(to.path("events_per_run").unwrap().as_f64().unwrap() > 0.0);
        assert!(to.path("overhead_pct").unwrap().as_f64().unwrap() >= 0.0);
        assert!(!crate::telemetry::enabled(), "bench left telemetry enabled");
        // the http family must record its latency readouts and gates
        let hs = sc.path("http_serve").unwrap();
        assert!(hs.path("client_ttft_ms_p50").unwrap().as_f64().unwrap() > 0.0);
        assert!(hs.path("client_ttlt_ms_p99").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            hs.path("all_streams_finished").and_then(Json::as_bool),
            Some(true)
        );
        let ho = sc.path("http_overload").unwrap();
        assert!(ho.path("rejected_429").unwrap().as_f64().unwrap() >= 1.0);
        assert_eq!(ho.path("kv_pages_after").and_then(Json::as_f64), Some(0.0));
        assert_eq!(ho.path("accounting_closed").and_then(Json::as_bool), Some(true));
        // the longctx family must record its budget + determinism gates
        // and both curves for every sweep length
        let lc = sc.path("longctx_dtr_bilayer").unwrap();
        let rows = match lc.get("lengths") {
            Some(Json::Arr(rows)) => rows,
            _ => panic!("longctx lengths missing"),
        };
        assert!(!rows.is_empty(), "longctx sweep is empty");
        for row in rows {
            assert_eq!(
                row.path("bitwise_identical_bounded_vs_resident").and_then(Json::as_bool),
                Some(true),
                "longctx bounded run lost bit-identity"
            );
            let peak = row.path("resident_pages_peak").unwrap().as_f64().unwrap();
            let budget = row.path("budget_pages").unwrap().as_f64().unwrap();
            let total = row.path("total_pages").unwrap().as_f64().unwrap();
            assert!(peak > 0.0 && peak <= budget, "peak {peak} vs budget {budget}");
            assert!(total > budget, "eviction never engaged ({total} <= {budget})");
            assert!(row.path("flops_measured").unwrap().as_f64().unwrap() > 0.0);
            let ratio = row.path("flops_ratio_vs_dense").unwrap().as_f64().unwrap();
            assert!(ratio > 0.0 && ratio < 1.5, "flops ratio {ratio}");
            let curve = match row.get("routing_vs_position") {
                Some(Json::Arr(c)) => c,
                _ => panic!("routing_vs_position missing"),
            };
            assert!(!curve.is_empty());
            for b in curve {
                let f = b.path("attn_frac").unwrap().as_f64().unwrap();
                assert!((0.0..=1.0).contains(&f), "bucket attn_frac {f}");
            }
        }
    }

    #[test]
    fn primary_metric_prefers_widest_thread_sweep_point() {
        let sc = Json::from_pairs(vec![
            ("t1", Json::from_pairs(vec![("tokens_per_s", Json::Num(10.0))])),
            ("t2", Json::from_pairs(vec![("tokens_per_s", Json::Num(18.0))])),
            ("speedup_vs_t1", Json::Num(1.8)),
        ]);
        assert_eq!(primary_metric(&sc), Some(("t2.tokens_per_s".to_string(), 18.0)));
        // simd-family rows have no thread sweep: scenario-level readout
        let sd = Json::from_pairs(vec![
            ("simd_tokens_per_s", Json::Num(40.0)),
            ("speedup_vs_scalar", Json::Num(2.0)),
        ]);
        assert_eq!(primary_metric(&sd), Some(("simd_tokens_per_s".to_string(), 40.0)));
    }

    #[test]
    fn baseline_delta_readout_tolerates_stub_and_missing_files() {
        let mut doc = Json::obj();
        let mut scenarios = Json::obj();
        scenarios.set(
            "forward_dense",
            Json::from_pairs(vec![(
                "t1",
                Json::from_pairs(vec![("tokens_per_s", Json::Num(100.0))]),
            )]),
        );
        doc.set("scenarios", scenarios);
        // missing file: must not panic, and counts zero regressions even
        // with the gate armed
        assert_eq!(
            print_baseline_deltas(&doc, Path::new("/nonexistent/BENCH_baseline.json"), Some(5.0)),
            0
        );
        // pending stub with no numeric metrics: must not panic either
        let dir = std::env::temp_dir().join("dtrnet_baseline_stub_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("BENCH_baseline.json");
        std::fs::write(
            &path,
            "{\"schema\": \"dtrnet-bench-v1\", \"status\": \"pending-measurement\", \
             \"scenarios\": {}}",
        )
        .unwrap();
        assert_eq!(print_baseline_deltas(&doc, &path, Some(5.0)), 0);
        // a measured baseline yields a real delta row; 100 vs 80 is an
        // improvement, so the gate stays quiet
        std::fs::write(
            &path,
            "{\"schema\": \"dtrnet-bench-v1\", \"scenarios\": {\"forward_dense\": \
             {\"t1\": {\"tokens_per_s\": 80.0}}}}",
        )
        .unwrap();
        assert_eq!(print_baseline_deltas(&doc, &path, None), 0);
        assert_eq!(print_baseline_deltas(&doc, &path, Some(5.0)), 0);
    }

    #[test]
    fn baseline_delta_gate_counts_regressions_beyond_threshold() {
        let dir = std::env::temp_dir().join("dtrnet_baseline_gate_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("BENCH_baseline.json");
        std::fs::write(
            &path,
            "{\"schema\": \"dtrnet-bench-v1\", \"scenarios\": {\"forward_dense\": \
             {\"t1\": {\"tokens_per_s\": 80.0}}}}",
        )
        .unwrap();
        // current 60 vs baseline 80 is -25%: regressed past a 5% gate,
        // tolerated by a 50% gate, and never counted without a gate
        let mut doc = Json::obj();
        let mut scenarios = Json::obj();
        scenarios.set(
            "forward_dense",
            Json::from_pairs(vec![(
                "t1",
                Json::from_pairs(vec![("tokens_per_s", Json::Num(60.0))]),
            )]),
        );
        doc.set("scenarios", scenarios);
        assert_eq!(print_baseline_deltas(&doc, &path, Some(5.0)), 1);
        assert_eq!(print_baseline_deltas(&doc, &path, Some(50.0)), 0);
        assert_eq!(print_baseline_deltas(&doc, &path, None), 0);
    }

    #[test]
    fn kernel_metric_paths_find_timing_sections_and_simd_rows() {
        // serve/train-shaped scenario: widest thread row wins
        let sc = Json::from_pairs(vec![
            (
                "t1",
                Json::from_pairs(vec![(
                    "kernel_timings",
                    Json::from_pairs(vec![
                        ("total_ms", Json::Num(9.0)),
                        ("attention", Json::from_pairs(vec![("total_ms", Json::Num(5.0))])),
                    ]),
                )]),
            ),
            (
                "t4",
                Json::from_pairs(vec![(
                    "kernel_timings",
                    Json::from_pairs(vec![
                        ("total_ms", Json::Num(4.0)),
                        ("attention", Json::from_pairs(vec![("total_ms", Json::Num(2.0))])),
                    ]),
                )]),
            ),
        ]);
        assert_eq!(
            kernel_metric_paths(&sc),
            vec![(
                "t4.kernel_timings.attention.total_ms".to_string(),
                "attention".to_string()
            )]
        );
        // simd_kernels-shaped scenario: per-kernel simd_ms rows
        let sk = Json::from_pairs(vec![
            ("tier", Json::Str("avx2".to_string())),
            (
                "matmul",
                Json::from_pairs(vec![("scalar_ms", Json::Num(3.0)), ("simd_ms", Json::Num(1.0))]),
            ),
        ]);
        assert_eq!(
            kernel_metric_paths(&sk),
            vec![("matmul.simd_ms".to_string(), "matmul".to_string())]
        );
    }

    #[test]
    fn quant_scenarios_can_be_skipped() {
        let opts = BenchOptions {
            quick: true,
            threads: vec![1],
            include_quant: false,
        };
        let doc = run(&opts).unwrap();
        let sc = doc.path("scenarios").unwrap();
        assert!(sc.get("quant_forward_dtr_bilayer").is_none());
        assert!(sc.get("quant_spec_decode_dtr_bilayer").is_none());
        // the f32 spec_decode scenario is not part of the quant family
        assert!(sc.get("spec_decode_dtr_bilayer").is_some());
        assert!(doc.path("quant_included").and_then(Json::as_bool) == Some(false));
    }

    #[test]
    fn write_creates_missing_parent_dirs() {
        // `bench --out results/nested/bench.json` must not require the
        // directory to exist (the CI jobs write into fresh results/).
        let dir = std::env::temp_dir()
            .join("dtrnet_bench_out_test")
            .join("nested");
        let _ = std::fs::remove_dir_all(std::env::temp_dir().join("dtrnet_bench_out_test"));
        let path = dir.join("bench.json");
        let mut doc = Json::obj();
        doc.set("schema", Json::Str(SCHEMA.to_string()));
        write(&path, &doc).unwrap();
        let re = Json::parse_file(&path).unwrap();
        assert_eq!(re.path("schema").unwrap().as_str(), Some(SCHEMA));
    }
}
