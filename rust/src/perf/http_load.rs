//! HTTP front-end load scenarios: real TCP connections against
//! `serve --listen`, measuring client-observed streaming latency and
//! gating the overload behaviour the ISSUE demands — prompt bounded-
//! latency 429s under backpressure, accepted streams finishing, and KV
//! pool accounting back to idle afterward.
//!
//! The server runs in a plain spawned thread that builds its own
//! backend ([`crate::runtime::Backend`] never crosses threads), binds
//! an ephemeral loopback port, and reports the address back over a
//! channel. Clients are the crate's own blocking
//! [`HttpClient`], whose per-chunk arrival stamps give client-side TTFT
//! and time-to-last-token without any server cooperation.

use std::net::SocketAddr;
use std::sync::{mpsc, Arc, Barrier};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use super::{preset, BenchOptions, MODEL_SEED};
use crate::config::{ModelConfig, Variant};
use crate::coordinator::http::{
    generate_request, HttpClient, HttpReport, ListenConfig, NetFrontend, StopHandle,
};
use crate::coordinator::{PrefillMode, ServerConfig};
use crate::runtime::CpuBackend;
use crate::util::json::Json;

struct TestServer {
    addr: SocketAddr,
    stop: StopHandle,
    handle: thread::JoinHandle<Result<HttpReport>>,
}

impl TestServer {
    /// Stop the front end and collect its final report.
    fn shutdown(self) -> Result<HttpReport> {
        self.stop.stop();
        self.handle
            .join()
            .map_err(|_| anyhow!("http server thread panicked"))?
    }
}

/// Spawn a `serve --listen`-equivalent server on an ephemeral loopback
/// port; the backend is constructed inside the server thread.
fn spawn_server(
    variant: Variant,
    quick: bool,
    threads: usize,
    scfg: ServerConfig,
    lcfg: ListenConfig,
) -> Result<TestServer> {
    let (tx, rx) = mpsc::channel();
    let handle = thread::spawn(move || -> Result<HttpReport> {
        let cfg = ModelConfig::preset(preset(quick), variant);
        let mut be = CpuBackend::init(&cfg, MODEL_SEED)?;
        be.set_threads(threads);
        let fe = NetFrontend::bind("127.0.0.1:0", lcfg)?;
        let _ = tx.send((fe.local_addr()?, fe.stop_handle()));
        fe.run(&be, scfg, None)
    });
    match rx.recv() {
        Ok((addr, stop)) => Ok(TestServer { addr, stop, handle }),
        Err(_) => {
            // The server thread died before binding; surface its error.
            let err = handle
                .join()
                .map_err(|_| anyhow!("http server thread panicked during startup"))?;
            Err(err.err().unwrap_or_else(|| anyhow!("server exited before binding")))
        }
    }
}

fn pctl(xs: &mut [f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    xs[((xs.len() - 1) as f64 * q).round() as usize]
}

/// Streaming load test: N concurrent keep-alive connections, each
/// running several chunked generate requests back to back. Records
/// client-observed TTFT / time-to-last-token percentiles and gates that
/// every stream finished (`done` row seen) with pool accounting idle.
pub(super) fn http_serve_scenario(opts: &BenchOptions) -> Result<(String, Json)> {
    let key = "http_serve".to_string();
    let variant = Variant::DtrBilayer;
    let (clients, per_client, gen) = if opts.quick {
        (3usize, 2usize, 6usize)
    } else {
        (8, 4, 24)
    };
    let t = *opts.threads.last().unwrap();
    let scfg = ServerConfig {
        slots: 4,
        prefill: PrefillMode::Chunked(32),
        ..Default::default()
    };
    let srv = spawn_server(variant, opts.quick, t, scfg, ListenConfig::default())?;
    let t0 = Instant::now();
    let mut workers = Vec::new();
    for c in 0..clients {
        let addr = srv.addr;
        workers.push(thread::spawn(move || -> Result<Vec<(f64, f64, usize)>> {
            let mut cl = HttpClient::connect(addr, Duration::from_secs(60))?;
            let mut out = Vec::new();
            for r in 0..per_client {
                let prompt: Vec<String> = (0..8)
                    .map(|i| ((c * 31 + r * 7 + i) % 256).to_string())
                    .collect();
                let body = format!(
                    "{{\"prompt\":[{}],\"max_new_tokens\":{gen},\"stream\":true}}",
                    prompt.join(",")
                );
                let resp = cl.roundtrip(&generate_request(&body, false))?;
                ensure!(resp.status == 200, "client {c} req {r}: status {}", resp.status);
                ensure!(
                    resp.chunked && !resp.chunk_ms.is_empty(),
                    "client {c} req {r}: expected a chunked token stream"
                );
                let text = String::from_utf8_lossy(&resp.body).into_owned();
                ensure!(
                    text.contains("\"done\":true"),
                    "client {c} req {r}: stream ended without a done row"
                );
                let n_tokens = text.lines().filter(|l| l.contains("\"token\":")).count();
                let last = *resp.chunk_ms.last().unwrap();
                out.push((resp.chunk_ms[0], last, n_tokens));
            }
            Ok(out)
        }));
    }
    let mut ttft = Vec::new();
    let mut ttlt = Vec::new();
    let mut tokens = 0usize;
    for w in workers {
        let rows = w.join().map_err(|_| anyhow!("{key}: client thread panicked"))??;
        for (first, last, n) in rows {
            ttft.push(first);
            ttlt.push(last);
            tokens += n;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = srv.shutdown()?;

    let n_req = (clients * per_client) as u64;
    ensure!(
        report.net.status(200) == n_req,
        "{key}: {} of {n_req} requests returned 200",
        report.net.status(200)
    );
    ensure!(
        report.engine.completed + report.engine.evicted == n_req as usize,
        "{key}: engine retired {} of {n_req} accepted requests",
        report.engine.completed + report.engine.evicted
    );
    ensure!(
        report.engine.pool.pages_allocated == 0,
        "{key}: {} KV pages still allocated after shutdown",
        report.engine.pool.pages_allocated
    );
    let mut sc = Json::obj();
    sc.set("clients", Json::Num(clients as f64));
    sc.set("requests", Json::Num(n_req as f64));
    sc.set("client_ttft_ms_p50", Json::Num(pctl(&mut ttft, 0.5)));
    sc.set("client_ttft_ms_p99", Json::Num(pctl(&mut ttft, 0.99)));
    sc.set("client_ttlt_ms_p50", Json::Num(pctl(&mut ttlt, 0.5)));
    sc.set("client_ttlt_ms_p99", Json::Num(pctl(&mut ttlt, 0.99)));
    sc.set(
        "client_tokens_per_s",
        Json::Num(if wall > 0.0 { tokens as f64 / wall } else { 0.0 }),
    );
    sc.set("server_tokens_per_s", Json::Num(report.engine.tokens_per_s));
    sc.set("bytes_out", Json::Num(report.net.bytes_out as f64));
    sc.set("all_streams_finished", Json::Bool(true));
    println!(
        "[bench] {key}: {n_req} streamed requests over {clients} conns, ttft p50 {:.1} ms \
         p99 {:.1} ms, ttlt p99 {:.1} ms, {:.1} client tok/s",
        pctl(&mut ttft, 0.5),
        pctl(&mut ttft, 0.99),
        pctl(&mut ttlt, 0.99),
        if wall > 0.0 { tokens as f64 / wall } else { 0.0 },
    );
    Ok((key, sc))
}

/// Overload gate: a tiny engine (1 slot, queue depth 1) hit with a
/// simultaneous burst. Backpressure must surface as prompt 429s — not
/// hangs — while every accepted request still finishes, and the KV pool
/// must be idle afterward with `completed + rejected` covering the
/// whole burst.
pub(super) fn http_overload_scenario(opts: &BenchOptions) -> Result<(String, Json)> {
    let key = "http_overload".to_string();
    let variant = Variant::DtrBilayer;
    let (burst, gen) = if opts.quick { (6usize, 8usize) } else { (12, 32) };
    // 429s must arrive well before a full generation could complete;
    // generous enough for a loaded CI box, tight enough to catch a
    // "rejection waits for the queue" bug.
    let deadline_ms = 2_500.0;
    let t = *opts.threads.last().unwrap();
    let scfg = ServerConfig {
        slots: 1,
        max_queue: 1,
        prefill: PrefillMode::Chunked(32),
        ..Default::default()
    };
    let srv = spawn_server(variant, opts.quick, t, scfg, ListenConfig::default())?;
    let barrier = Arc::new(Barrier::new(burst));
    let mut workers = Vec::new();
    for c in 0..burst {
        let addr = srv.addr;
        let barrier = Arc::clone(&barrier);
        workers.push(thread::spawn(move || -> Result<(u16, f64, bool)> {
            let mut cl = HttpClient::connect(addr, Duration::from_secs(60))?;
            let prompt: Vec<String> = (0..8).map(|i| ((c * 13 + i) % 256).to_string()).collect();
            let body = format!(
                "{{\"prompt\":[{}],\"max_new_tokens\":{gen}}}",
                prompt.join(",")
            );
            let req = generate_request(&body, true);
            barrier.wait();
            let t0 = Instant::now();
            let resp = cl.roundtrip(&req)?;
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let finished = resp.status == 200
                && String::from_utf8_lossy(&resp.body).contains("\"finish\":");
            Ok((resp.status, ms, finished))
        }));
    }
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    let mut reject_ms = Vec::new();
    let mut accept_ms = Vec::new();
    for w in workers {
        let (status, ms, finished) =
            w.join().map_err(|_| anyhow!("{key}: client thread panicked"))??;
        match status {
            200 => {
                ensure!(finished, "{key}: an accepted request never finished");
                accepted += 1;
                accept_ms.push(ms);
            }
            429 => {
                rejected += 1;
                reject_ms.push(ms);
            }
            other => anyhow::bail!("{key}: unexpected status {other} under overload"),
        }
    }
    let report = srv.shutdown()?;

    ensure!(accepted >= 1, "{key}: overload burst starved every request");
    ensure!(
        rejected >= 1,
        "{key}: a 1-slot/1-queue engine absorbed a burst of {burst} without a 429"
    );
    let worst_reject = reject_ms.iter().cloned().fold(0.0f64, f64::max);
    ensure!(
        worst_reject <= deadline_ms,
        "{key}: slowest 429 took {worst_reject:.0} ms (deadline {deadline_ms:.0} ms) — \
         backpressure is not prompt"
    );
    ensure!(
        report.engine.rejected as u64 == rejected,
        "{key}: engine counted {} rejections, clients saw {rejected}",
        report.engine.rejected
    );
    ensure!(
        (report.engine.completed + report.engine.evicted) as u64 == accepted,
        "{key}: engine retired {}, clients saw {accepted} accepted",
        report.engine.completed + report.engine.evicted
    );
    ensure!(
        report.engine.pool.pages_allocated == 0,
        "{key}: {} KV pages leaked across the overload burst",
        report.engine.pool.pages_allocated
    );
    let mut sc = Json::obj();
    sc.set("burst", Json::Num(burst as f64));
    sc.set("accepted", Json::Num(accepted as f64));
    sc.set("rejected_429", Json::Num(rejected as f64));
    sc.set("reject_ms_worst", Json::Num(worst_reject));
    sc.set("reject_deadline_ms", Json::Num(deadline_ms));
    sc.set("accept_ms_worst", Json::Num(accept_ms.iter().cloned().fold(0.0, f64::max)));
    sc.set("kv_pages_after", Json::Num(report.engine.pool.pages_allocated as f64));
    sc.set("accounting_closed", Json::Bool(true));
    println!(
        "[bench] {key}: burst {burst} -> {accepted} accepted / {rejected} x 429 \
         (worst 429 {worst_reject:.0} ms, deadline {deadline_ms:.0} ms)"
    );
    Ok((key, sc))
}
