//! Evaluation harnesses: perplexity, routing fractions, long-context spans,
//! cosine-similarity probe, synthetic zero-shot tasks — everything the
//! paper's tables/figures report.
//!
//! The metric code ([`cross_entropy`], [`EvalResult`]) and the
//! backend-driven harness ([`perplexity_backend`]) are feature-free; the
//! artifact-driven harnesses need the `pjrt` feature.

pub mod tasks;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::coordinator::RoutingStats;
#[cfg(feature = "pjrt")]
use crate::data::longctx::LongCtxItem;
use crate::data::Dataset;
use crate::runtime::Backend;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
use crate::runtime::Tensor;

/// Cross-entropy (nats/token) of logits over next-token targets.
///
/// `logits`: [B, S, V] row-major; `tokens`: [B, S]. Positions 0..S-1
/// predict tokens 1..S. `span`: optional (start, end) restriction on the
/// *target* index range (long-context answer spans).
pub fn cross_entropy(
    logits: &[f32],
    tokens: &[i32],
    batch: usize,
    seq: usize,
    vocab: usize,
    span: Option<(usize, usize)>,
) -> f64 {
    let (lo, hi) = span.unwrap_or((1, seq));
    let lo = lo.max(1);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for b in 0..batch {
        for t in lo..hi {
            let target = tokens[b * seq + t];
            let row = &logits[(b * seq + t - 1) * vocab..(b * seq + t) * vocab];
            // log-softmax
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let logz: f64 = row.iter().map(|&x| ((x - m) as f64).exp()).sum::<f64>().ln()
                + m as f64;
            total += logz - row[target as usize] as f64;
            count += 1;
        }
    }
    total / count.max(1) as f64
}

/// Result of a forward-eval pass.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// Mean next-token cross-entropy in nats.
    pub ce_nats: f64,
    /// Perplexity, `exp(ce_nats)`.
    pub ppl: f64,
    /// Per-layer routing statistics accumulated over the eval.
    pub routing: RoutingStats,
    /// Number of scored (next-token) positions.
    pub n_tokens: usize,
}

/// Perplexity of a [`Backend`] on `data` — the feature-free mirror of
/// [`perplexity`], used by the offline test suite and the CPU demo path.
pub fn perplexity_backend(
    backend: &dyn Backend,
    data: &Dataset,
    batch: usize,
    max_batches: usize,
) -> Result<EvalResult> {
    let cfg = backend.config();
    let (vocab, n_layers) = (cfg.vocab_size, cfg.n_layers);
    let seq = data.seq;

    let mut total_ce = 0.0;
    let mut n_batches = 0usize;
    let mut routing = RoutingStats::new(n_layers);
    for tokens in data.eval_batches(batch).take(max_batches) {
        let out = backend.forward(&Tensor::i32(vec![batch, seq], tokens.clone()))?;
        total_ce += cross_entropy(out.logits.as_f32(), &tokens, batch, seq, vocab, None);
        routing.record_route_tensor(out.route.as_f32(), batch, n_layers, seq);
        n_batches += 1;
    }
    anyhow::ensure!(n_batches > 0, "no eval batches");
    let ce = total_ce / n_batches as f64;
    Ok(EvalResult {
        ce_nats: ce,
        ppl: ce.exp(),
        routing,
        n_tokens: n_batches * batch * (seq - 1),
    })
}

/// Perplexity of `params` (flat literals) on `data` via a fwd artifact.
#[cfg(feature = "pjrt")]
pub fn perplexity(
    engine: &Engine,
    artifact: &str,
    params: &[xla::Literal],
    data: &Dataset,
    max_batches: usize,
) -> Result<EvalResult> {
    let exe = engine.load(artifact)?;
    let spec = &exe.spec;
    let batch = spec.batch.context("fwd missing batch")?;
    let seq = spec.seq.context("fwd missing seq")?;
    let vocab = spec.config.vocab_size;
    let n_layers = spec.config.n_layers;

    let mut total_ce = 0.0;
    let mut n_batches = 0usize;
    let mut routing = RoutingStats::new(n_layers);
    for tokens in data.eval_batches(batch).take(max_batches) {
        let tok_lit = Tensor::i32(vec![batch, seq], tokens.clone()).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&tok_lit);
        let outs = exe.call_literals_ref(&inputs)?;
        // outputs: logits, route [B,L,S], g_attn, attn_frac
        let logits = Tensor::from_literal(&outs[0])?;
        let route = Tensor::from_literal(&outs[1])?;
        total_ce += cross_entropy(logits.as_f32(), &tokens, batch, seq, vocab, None);
        routing.record_route_tensor(route.as_f32(), batch, n_layers, seq);
        n_batches += 1;
    }
    anyhow::ensure!(n_batches > 0, "no eval batches");
    let ce = total_ce / n_batches as f64;
    Ok(EvalResult {
        ce_nats: ce,
        ppl: ce.exp(),
        routing,
        n_tokens: n_batches * batch * (seq - 1),
    })
}

/// Span-restricted perplexity for long-context items (Fig. 3 metric).
/// The artifact must be a fwd with batch=1 and seq == item length.
#[cfg(feature = "pjrt")]
pub fn span_perplexity(
    engine: &Engine,
    artifact: &str,
    params: &[xla::Literal],
    items: &[LongCtxItem],
) -> Result<f64> {
    let exe = engine.load(artifact)?;
    let spec = &exe.spec;
    let seq = spec.seq.context("fwd missing seq")?;
    let vocab = spec.config.vocab_size;
    let mut total = 0.0;
    for item in items {
        anyhow::ensure!(item.tokens.len() == seq, "item length != artifact seq");
        let tokens: Vec<i32> = item.tokens.iter().map(|&t| t as i32).collect();
        let tok_lit = Tensor::i32(vec![1, seq], tokens.clone()).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&tok_lit);
        let outs = exe.call_literals_ref(&inputs)?;
        let logits = Tensor::from_literal(&outs[0])?;
        total += cross_entropy(
            logits.as_f32(),
            &tokens,
            1,
            seq,
            vocab,
            Some((item.answer_start, item.answer_end)),
        );
    }
    Ok((total / items.len() as f64).exp())
}

/// Fig. 1 cosine-similarity matrix from a probe artifact: returns the
/// [L+1, L+1] row-major similarity matrix.
#[cfg(feature = "pjrt")]
pub fn cosine_probe(
    engine: &Engine,
    artifact: &str,
    params: &[xla::Literal],
    tokens: &[i32],
) -> Result<Tensor> {
    let exe = engine.load(artifact)?;
    let spec = &exe.spec;
    let batch = spec.batch.context("probe missing batch")?;
    let seq = spec.seq.context("probe missing seq")?;
    anyhow::ensure!(tokens.len() == batch * seq);
    let tok_lit = Tensor::i32(vec![batch, seq], tokens.to_vec()).to_literal()?;
    let mut inputs: Vec<&xla::Literal> = params.iter().collect();
    inputs.push(&tok_lit);
    let outs = exe.call_literals_ref(&inputs)?;
    Tensor::from_literal(&outs[0])
}

/// Adjacent-layer similarity summary of a probe matrix (the paper's
/// "S_{i,i+1} ≈ 0.98 for inner layers" observation).
pub fn adjacent_similarity(sim: &Tensor) -> Vec<f64> {
    let l = sim.shape[0];
    (0..l - 1).map(|i| sim.at(&[i, i + 1]) as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ce_of_uniform_logits_is_log_v() {
        let (b, s, v) = (1, 4, 8);
        let logits = vec![0.0f32; b * s * v];
        let tokens = vec![3i32; b * s];
        let ce = cross_entropy(&logits, &tokens, b, s, v, None);
        assert!((ce - (v as f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn ce_rewards_correct_logits() {
        let (b, s, v) = (1, 3, 4);
        let mut logits = vec![0.0f32; b * s * v];
        let tokens = vec![0, 1, 2];
        // position 0 predicts token 1, position 1 predicts token 2
        logits[1] = 10.0;
        logits[v + 2] = 10.0;
        let ce = cross_entropy(&logits, &tokens, b, s, v, None);
        assert!(ce < 0.01, "ce={ce}");
    }

    #[test]
    fn span_restricts_targets() {
        let (b, s, v) = (1, 6, 4);
        let mut logits = vec![0.0f32; b * s * v];
        let tokens = vec![0, 1, 2, 3, 0, 1];
        // make only the span targets (positions 4..6) predictable
        logits[3 * v] = 10.0;
        logits[4 * v + 1] = 10.0;
        let full = cross_entropy(&logits, &tokens, b, s, v, None);
        let span = cross_entropy(&logits, &tokens, b, s, v, Some((4, 6)));
        assert!(span < 0.01 && full > span);
    }
}
