//! Synthetic zero-shot task suite — the offline stand-in for the paper's
//! accuracy benchmarks (ARC / BoolQ / HellaSwag / PIQA / Winogrande …).
//!
//! Real multiple-choice suites are meaningless at ~1M parameters, so we
//! generate *learnable* multiple-choice items from the same Markov process
//! the model was trained on and score them the standard zero-shot way:
//! the answer option with the lowest length-normalized perplexity wins.
//! This yields an accuracy metric whose ORDERING across architectures is
//! informative (trained-on-structure models beat chance; better LMs score
//! higher) — the quantity Table 1 compares.
//!
//! Task types:
//!  * `Continuation` — HellaSwag-style: pick the true continuation of a
//!    Markov-process prefix vs corrupted distractors.
//!  * `Recall` — Winogrande/cloze-style: the prompt establishes a
//!    key→value binding; options differ in the recalled value.

#[cfg(feature = "pjrt")]
use anyhow::{Context, Result};

#[cfg(feature = "pjrt")]
use crate::runtime::{Engine, Tensor};
use crate::util::rng::Rng;

/// One multiple-choice item. Every candidate sequence is prompt+option,
/// padded to the artifact's sequence length.
#[derive(Debug, Clone)]
pub struct McItem {
    /// Candidate completions as full token sequences.
    pub options: Vec<Vec<u32>>, // full token sequences per option
    /// Index where the options start diverging (shared prefix length).
    pub answer_start: usize,    // option span start (shared)
    /// Index of the correct option.
    pub correct: usize,
}

/// Continuation task items from a Markov sampler: the true continuation
/// is the actual process rollout; distractors are rollouts from a
/// *different* (resampled) state trajectory.
pub fn continuation_items(
    rng: &mut Rng,
    corpus: &[u32],
    n_items: usize,
    seq: usize,
    opt_len: usize,
    n_options: usize,
) -> Vec<McItem> {
    assert!(seq > opt_len * 2);
    let prompt_len = seq - opt_len;
    let mut items = Vec::with_capacity(n_items);
    let max_start = corpus.len() - seq - 1;
    for _ in 0..n_items {
        let start = rng.usize_below(max_start);
        let prompt = &corpus[start..start + prompt_len];
        let truth = &corpus[start + prompt_len..start + seq];
        let correct = rng.usize_below(n_options);
        let mut options = Vec::with_capacity(n_options);
        for o in 0..n_options {
            let mut full = prompt.to_vec();
            if o == correct {
                full.extend_from_slice(truth);
            } else {
                // distractor: a continuation sampled from elsewhere
                let ds = rng.usize_below(max_start);
                full.extend_from_slice(&corpus[ds..ds + opt_len]);
            }
            options.push(full);
        }
        items.push(McItem {
            options,
            answer_start: prompt_len,
            correct,
        });
    }
    items
}

/// Recall task: prompt contains `[key, value]` pairs; the question repeats
/// a key and options differ in the value. Correct option = bound value.
pub fn recall_items(
    rng: &mut Rng,
    vocab: usize,
    n_items: usize,
    seq: usize,
    n_pairs: usize,
    n_options: usize,
) -> Vec<McItem> {
    let mut items = Vec::with_capacity(n_items);
    for _ in 0..n_items {
        let keys: Vec<u32> = (0..n_pairs).map(|_| rng.below(vocab as u64) as u32).collect();
        let vals: Vec<u32> = (0..n_pairs).map(|_| rng.below(vocab as u64) as u32).collect();
        let probe = rng.usize_below(n_pairs);
        let mut prompt = Vec::new();
        for (k, v) in keys.iter().zip(&vals) {
            prompt.push(*k);
            prompt.push(*v);
        }
        // repeat pairs until close to seq-2, then ask
        while prompt.len() < seq - 2 {
            let i = rng.usize_below(n_pairs);
            prompt.push(keys[i]);
            prompt.push(vals[i]);
        }
        prompt.truncate(seq - 2);
        prompt.push(keys[probe]);
        let answer_start = prompt.len();
        let correct = rng.usize_below(n_options);
        let mut options = Vec::with_capacity(n_options);
        for o in 0..n_options {
            let mut full = prompt.clone();
            if o == correct {
                full.push(vals[probe]);
            } else {
                full.push(rng.below(vocab as u64) as u32);
            }
            options.push(full);
        }
        items.push(McItem {
            options,
            answer_start,
            correct,
        });
    }
    items
}

/// Zero-shot accuracy: lowest length-normalized answer-span CE wins.
/// Items are packed into the fwd artifact's [B, S] batches (padded with
/// token 0; CE measured only on the answer span).
#[cfg(feature = "pjrt")]
pub fn mc_accuracy(
    engine: &Engine,
    artifact: &str,
    params: &[xla::Literal],
    items: &[McItem],
) -> Result<f64> {
    let exe = engine.load(artifact)?;
    let spec = &exe.spec;
    let batch = spec.batch.context("fwd missing batch")?;
    let seq = spec.seq.context("fwd missing seq")?;
    let vocab = spec.config.vocab_size;

    // flatten all candidate sequences, then batch them through the artifact
    let mut flat: Vec<(usize, usize, Vec<i32>, usize, usize)> = Vec::new();
    for (ii, item) in items.iter().enumerate() {
        for (oi, opt) in item.options.iter().enumerate() {
            assert!(opt.len() <= seq, "option longer than artifact seq");
            let mut padded: Vec<i32> = opt.iter().map(|&t| t as i32).collect();
            let end = padded.len();
            padded.resize(seq, 0);
            flat.push((ii, oi, padded, item.answer_start, end));
        }
    }
    let mut scores: Vec<Vec<f64>> = items
        .iter()
        .map(|it| vec![f64::INFINITY; it.options.len()])
        .collect();
    for chunk in flat.chunks(batch) {
        let mut tokens = Vec::with_capacity(batch * seq);
        for (_, _, padded, _, _) in chunk {
            tokens.extend_from_slice(padded);
        }
        tokens.resize(batch * seq, 0); // ragged final chunk
        let tok = Tensor::i32(vec![batch, seq], tokens.clone()).to_literal()?;
        let mut inputs: Vec<&xla::Literal> = params.iter().collect();
        inputs.push(&tok);
        let outs = exe.call_literals_ref(&inputs)?;
        let logits = Tensor::from_literal(&outs[0])?;
        for (bi, (ii, oi, _, a_start, a_end)) in chunk.iter().enumerate() {
            let lf = logits.as_f32();
            let row = &lf[bi * seq * vocab..(bi + 1) * seq * vocab];
            let ce = super::cross_entropy(row, &tokens[bi * seq..(bi + 1) * seq],
                                          1, seq, vocab, Some((*a_start, *a_end)));
            scores[*ii][*oi] = ce;
        }
    }
    let mut correct = 0usize;
    for (ii, item) in items.iter().enumerate() {
        let best = (0..item.options.len())
            .min_by(|&a, &b| scores[ii][a].partial_cmp(&scores[ii][b]).unwrap())
            .unwrap();
        if best == item.correct {
            correct += 1;
        }
    }
    Ok(correct as f64 / items.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn continuation_items_wellformed() {
        let mut rng = Rng::new(1);
        let corpus: Vec<u32> = (0..5000u32).map(|i| i % 256).collect();
        let items = continuation_items(&mut rng, &corpus, 10, 64, 8, 4);
        assert_eq!(items.len(), 10);
        for it in &items {
            assert_eq!(it.options.len(), 4);
            assert!(it.correct < 4);
            for o in &it.options {
                assert_eq!(o.len(), 64);
                // prompts identical across options
                assert_eq!(o[..it.answer_start], it.options[0][..it.answer_start]);
            }
        }
    }

    #[test]
    fn recall_items_bind_correctly() {
        let mut rng = Rng::new(2);
        let items = recall_items(&mut rng, 256, 5, 32, 3, 4);
        for it in &items {
            let probe_key = it.options[0][it.answer_start - 1];
            // the correct option's answer equals the value bound to probe_key
            // earlier in the prompt
            let prompt = &it.options[it.correct][..it.answer_start];
            let ans = it.options[it.correct][it.answer_start];
            let mut found = false;
            for w in prompt.windows(2) {
                if w[0] == probe_key && w[1] == ans {
                    found = true;
                }
            }
            assert!(found, "correct answer must appear as the bound value");
        }
    }
}
