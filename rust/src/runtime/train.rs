//! Backend-generic training: the [`TrainBackend`] trait and the native
//! CPU implementation ([`CpuTrainer`]).
//!
//! The L3 orchestrator ([`crate::coordinator::Trainer`]) owns the cosine
//! LR schedule, data batching and logging; a `TrainBackend` owns one
//! optimizer step: forward, backward, AdamW. Two implementations:
//!
//! * [`CpuTrainer`] — pure Rust, always available. Forward mirrors
//!   `python/compile/model.py` train semantics (identical to the
//!   inference path — soft-score weighting, hard token-choice routing);
//!   backward is the hand-derived kernels in
//!   [`crate::runtime::cpu::grads`]; the loss, penalty and AdamW
//!   constants mirror `python/compile/train.py` (CE + Eq. 7 routing
//!   penalty, global-norm clip, decoupled weight decay on matrices).
//! * The PJRT artifact path (`pjrt` feature) — retrofitted behind the
//!   same trait in `coordinator::trainer` (`ArtifactTrainer`), driving
//!   the fused `{tag}_train_step` HLO executable.
//!
//! # Loss (mirrors `model.loss_fn` / `train.train_step`)
//!
//! `loss = CE + λ·Σ_l α_l·mean_i(g_attn)_{l,i}` over DTR layers, where
//! `α_l = stopgrad(f_l / Σ f)` is the per-layer routed-load weight
//! (`f_l` = mean hard routing decision). The hard decision `δ` is a
//! straight-through estimator: it selects the path but receives no
//! gradient — gradients reach the router only through the soft scale
//! (`g_attn` on the attention path, `g_bypass` on the bypass) and the
//! penalty.
//!
//! # Determinism
//!
//! `train_step` is **bit-identical for every thread count**: all kernels
//! follow the disjoint-chunk/fixed-accumulation-order discipline
//! (DESIGN.md §Parallel CPU execution), cross-sequence gradient
//! accumulation is serial in batch order, and scalar reductions (loss,
//! global norm) are serial f64. `rust/tests/properties_backend.rs` pins
//! this bitwise; `rust/tests/grad_check.rs` holds every gradient to
//! finite differences.

use anyhow::{ensure, Result};

use crate::config::{LayerKind, ModelConfig, TrainConfig, Variant};
use crate::metrics::KernelTimers;
use crate::util::json::Json;
use crate::util::threadpool::{self, Pool};

use super::checkpoint::Checkpoint;
use super::cpu::{
    grads, init_weights, kernels, weights_to_checkpoint, CpuBackend, ModelWeights, RouterMode,
    RMSNORM_EPS, ROPE_THETA,
};

/// Scalar outcomes of one optimizer step (the `train_step` artifact's
/// metric tuple).
#[derive(Debug, Clone)]
pub struct TrainMetrics {
    /// Total loss (`ce + penalty`).
    pub loss: f64,
    /// Cross-entropy component (nats/token).
    pub ce: f64,
    /// Routing-penalty component (Eq. 7, already λ-scaled).
    pub penalty: f64,
    /// Pre-clip global gradient norm.
    pub grad_norm: f64,
    /// `[L]` mean fraction of tokens routed through attention this step.
    pub attn_frac: Vec<f64>,
}

/// An execution backend for training: owns parameters and optimizer
/// state between steps; the coordinator drives it step by step.
pub trait TrainBackend {
    /// Human-readable backend name (for logs/reports).
    fn name(&self) -> &'static str;

    /// The model configuration being trained.
    fn config(&self) -> &ModelConfig;

    /// Sequences per step this backend was built for.
    fn batch(&self) -> usize;

    /// Tokens per sequence this backend was built for.
    fn seq(&self) -> usize;

    /// One optimizer step on `tokens` (`[batch*seq]` i32 row-major).
    /// `step` is 1-based (Adam bias correction), `lr` comes from the
    /// coordinator's schedule, `seed` feeds any stochastic layer (unused
    /// by the deterministic CPU path; the D-LLM artifact samples with it).
    fn train_step(&mut self, tokens: &[i32], step: usize, lr: f64, seed: u64)
        -> Result<TrainMetrics>;

    /// Export the current parameters as a DTCK checkpoint (the
    /// `flatten_params` naming contract — loadable by every serving
    /// path).
    fn to_checkpoint(&self) -> Result<Checkpoint>;

    /// Per-kernel wall-clock snapshot, if this backend records one
    /// (the [`KernelTimers`] JSON schema). Default: `None`.
    fn kernel_timings(&self) -> Option<Json> {
        None
    }
}

/// Saved per-layer forward activations for one sequence (what the
/// backward pass consumes).
struct LayerActs {
    x_in: Vec<f32>,     // [n, d] residual stream entering the layer
    u: Vec<f32>,        // [n, d] norm1 output
    g: Vec<f32>,        // [n, 2] router scores (empty on dense layers)
    delta: Vec<f32>,    // [n] hard routing decision (ones on dense)
    qr: Vec<f32>,       // [n, d] RoPE'd queries
    kr: Vec<f32>,       // [n, d] RoPE'd keys
    v: Vec<f32>,        // [n, d] values (also the bypass input)
    probs: Vec<f32>,    // [n, h, n] attention softmax probabilities
    ctx: Vec<f32>,      // [n, d] attention context (pre-Wo)
    attn_out: Vec<f32>, // [n, d] attention output (post-Wo)
    byp: Vec<f32>,      // [n, d] linear bypass v·Wo (empty on dense)
    x_mid: Vec<f32>,    // [n, d] stream after the token-mixing residual
    h2: Vec<f32>,       // [n, d] norm2 output
    gate_pre: Vec<f32>, // [n, ff]
    up: Vec<f32>,       // [n, ff]
    hmid: Vec<f32>,     // [n, ff] SiLU(gate)·up
}

/// Saved forward state for one sequence.
struct SeqActs {
    layers: Vec<LayerActs>,
    x_final: Vec<f32>, // [n, d]
    xn: Vec<f32>,      // [n, d] out_norm output
    logits: Vec<f32>,  // [n, V]
}

/// The native CPU training backend: parameters, Adam moments, and a
/// fused forward/backward/AdamW step over the threadpool kernels.
pub struct CpuTrainer {
    cfg: ModelConfig,
    hp: TrainConfig,
    weights: ModelWeights,
    opt_m: ModelWeights,
    opt_v: ModelWeights,
    pool: Pool,
    timers: KernelTimers,
}

impl CpuTrainer {
    /// Build a trainer from a model config and training hyperparameters.
    /// Parameters are seeded from `hp.seed` with the same init as
    /// [`CpuBackend::init`], so training continues exactly the model
    /// `demo`/`serve` would have started from at that seed.
    pub fn new(cfg: &ModelConfig, hp: &TrainConfig) -> Result<CpuTrainer> {
        cfg.validate()?;
        ensure!(
            cfg.variant == Variant::Dense || cfg.variant.is_dtr(),
            "CPU trainer supports dense/dtr_* variants, not {:?} (MoD/D-LLM are PJRT-only)",
            cfg.variant
        );
        ensure!(hp.batch >= 1, "train batch must be >= 1");
        ensure!(hp.seq >= 2, "train seq must be >= 2 (position t predicts t+1)");
        Ok(CpuTrainer {
            cfg: cfg.clone(),
            hp: hp.clone(),
            weights: init_weights(cfg, hp.seed),
            opt_m: ModelWeights::zeros_like(cfg),
            opt_v: ModelWeights::zeros_like(cfg),
            pool: threadpool::global().clone(),
            timers: KernelTimers::default(),
        })
    }

    /// Run kernels on an explicit pool (thread count is a throughput
    /// knob only — `train_step` is bit-identical for every pool size).
    pub fn set_pool(&mut self, pool: Pool) {
        self.pool = pool;
    }

    /// Convenience for [`CpuTrainer::set_pool`]: a fresh pool of `n`
    /// threads (`1` = the serial determinism baseline).
    pub fn set_threads(&mut self, n: usize) {
        self.pool = Pool::with_threads(n);
    }

    /// Kernel-thread concurrency this trainer currently runs with.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Per-kernel wall-clock accounting (forward, backward and optimizer
    /// sections).
    pub fn timers(&self) -> &KernelTimers {
        &self.timers
    }

    /// The current parameters (gradient-check and test access).
    pub fn weights(&self) -> &ModelWeights {
        &self.weights
    }

    /// Mutable parameter access — used by the finite-difference gradient
    /// checks to perturb single weights; not part of the training loop.
    pub fn weights_mut(&mut self) -> &mut ModelWeights {
        &mut self.weights
    }

    /// Snapshot the current parameters into a serving backend (the
    /// in-process version of the checkpoint round-trip).
    pub fn to_backend(&self) -> Result<CpuBackend> {
        CpuBackend::new(self.cfg.clone(), self.weights.clone(), RouterMode::TokenChoice)
    }

    /// Composite loss and parameter gradients on one `[batch*seq]` token
    /// block, without touching optimizer state. Public for the
    /// finite-difference gradient checks; [`TrainBackend::train_step`]
    /// is the training entry point.
    pub fn loss_grads(&self, tokens: &[i32]) -> Result<(f64, ModelWeights)> {
        let (loss, _, _, grads, _) = self.loss_grads_full(tokens)?;
        Ok((loss, grads))
    }

    /// Forward + backward over the whole batch: returns
    /// `(loss, ce, penalty, grads, attn_frac)`.
    fn loss_grads_full(
        &self,
        tokens: &[i32],
    ) -> Result<(f64, f64, f64, ModelWeights, Vec<f64>)> {
        let cfg = &self.cfg;
        let (b, n) = (self.hp.batch, self.hp.seq);
        let vocab = cfg.vocab_size;
        let n_layers = cfg.n_layers;
        ensure!(
            tokens.len() == b * n,
            "train_step expects {}x{} = {} tokens, got {}",
            b,
            n,
            b * n,
            tokens.len()
        );
        for &t in tokens {
            ensure!(
                t >= 0 && (t as usize) < vocab,
                "token id {t} out of range for vocab {vocab}"
            );
        }

        // ---- phase 1: forward every sequence, saving activations ----
        let mut acts_all = Vec::with_capacity(b);
        let mut route_sum = vec![0.0f64; n_layers];
        let mut g_sum = vec![0.0f64; n_layers];
        let mut ce_total = 0.0f64;
        let count = b * (n - 1);
        for bi in 0..b {
            let toks = &tokens[bi * n..(bi + 1) * n];
            let acts = self.forward_acts(toks);
            // Loss evaluation is forward-head work — keep it out of the
            // bwd_* buckets so the fwd/bwd timing split stays honest.
            ce_total += self
                .timers
                .unembed
                .time(|| grads::xent_loss_sum(&acts.logits, toks, n, vocab));
            for (li, la) in acts.layers.iter().enumerate() {
                route_sum[li] += la.delta.iter().map(|&r| r as f64).sum::<f64>();
                g_sum[li] += if la.g.is_empty() {
                    n as f64 // dense layers: g_attn ≡ 1
                } else {
                    (0..n).map(|i| la.g[i * 2] as f64).sum::<f64>()
                };
            }
            acts_all.push(acts);
        }
        let ce = ce_total / count as f64;

        // Eq. 7 penalty: alpha = stopgrad(f / sum f) over DTR layers.
        let kinds = cfg.layer_kinds();
        let route_mean: Vec<f64> = route_sum.iter().map(|&s| s / (b * n) as f64).collect();
        let g_mean: Vec<f64> = g_sum.iter().map(|&s| s / (b * n) as f64).collect();
        let f_sum: f64 = (0..n_layers)
            .filter(|&l| kinds[l] == LayerKind::Dtr)
            .map(|l| route_mean[l])
            .sum();
        let alpha: Vec<f64> = (0..n_layers)
            .map(|l| {
                if kinds[l] == LayerKind::Dtr {
                    route_mean[l] / (f_sum + 1e-9)
                } else {
                    0.0
                }
            })
            .collect();
        let pen: f64 = self.hp.lambda_reg
            * (0..n_layers)
                .filter(|&l| kinds[l] == LayerKind::Dtr)
                .map(|l| alpha[l] * g_mean[l])
                .sum::<f64>();
        let loss = ce + pen;

        // ---- phase 2: backward per sequence, serial batch order ----
        let mut gacc = ModelWeights::zeros_like(cfg);
        for bi in 0..b {
            let toks = &tokens[bi * n..(bi + 1) * n];
            self.backward_acts(toks, &acts_all[bi], count, &alpha, &mut gacc);
        }
        Ok((loss, ce, pen, gacc, route_mean))
    }

    /// Forward one sequence, saving every activation the backward pass
    /// needs. Identical math to [`CpuBackend`]'s forward path (the
    /// attention kernel additionally materializes its softmax rows).
    fn forward_acts(&self, toks: &[i32]) -> SeqActs {
        let cfg = &self.cfg;
        let (d, ff, vocab) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
        let (heads, hd) = (cfg.n_heads, cfg.head_dim());
        let n = toks.len();
        let pool = &self.pool;
        let positions: Vec<f32> = (0..n).map(|i| i as f32).collect();

        let mut x = Vec::with_capacity(n * d);
        for &t in toks {
            let t = t as usize;
            x.extend_from_slice(&self.weights.tok_embed[t * d..(t + 1) * d]);
        }

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for lw in &self.weights.layers {
            let x_in = x.clone();
            let u = self
                .timers
                .norm
                .time(|| kernels::rmsnorm_par(pool, &x, &lw.norm1, RMSNORM_EPS));
            let (g, delta) = if lw.kind == LayerKind::Dtr {
                let g = self
                    .timers
                    .router
                    .time(|| kernels::router_par(pool, &u, &lw.r_w1, &lw.r_w2, n, d, d / 2));
                let delta = if cfg.variant == Variant::DtrSkip {
                    vec![0.0f32; n]
                } else {
                    kernels::route_decision(&g)
                };
                (g, delta)
            } else {
                (Vec::new(), vec![1.0f32; n])
            };
            let (qr, kr, v, probs, ctx, attn_out) = self.timers.attention.time(|| {
                let (qr, kr, v) = kernels::qkv_rope_par(
                    pool, &u, &lw.wq, &lw.wk, &lw.wv, &positions, n, d, heads, ROPE_THETA,
                );
                let (ctx, probs) =
                    grads::routed_attention_probs(pool, &qr, &kr, &v, &delta, n, heads, hd);
                let attn_out = kernels::matmul_par(pool, &ctx, &lw.wo, n, d, d);
                (qr, kr, v, probs, ctx, attn_out)
            });
            let byp = if lw.kind == LayerKind::Dtr {
                self.timers
                    .bypass
                    .time(|| kernels::matmul_par(pool, &v, &lw.wo, n, d, d))
            } else {
                Vec::new()
            };
            // Soft-score path select + residual (straight-through δ).
            if lw.kind == LayerKind::Dtr {
                for i in 0..n {
                    let (w, src) = if delta[i] > 0.5 {
                        (g[i * 2], &attn_out)
                    } else {
                        (g[i * 2 + 1], &byp)
                    };
                    for j in 0..d {
                        x[i * d + j] += w * src[i * d + j];
                    }
                }
            } else {
                for (xv, av) in x.iter_mut().zip(&attn_out) {
                    *xv += av;
                }
            }
            let x_mid = x.clone();
            let h2 = self
                .timers
                .norm
                .time(|| kernels::rmsnorm_par(pool, &x, &lw.norm2, RMSNORM_EPS));
            let (gate_pre, up, hmid, mlp) = self.timers.mlp.time(|| {
                let gate_pre = kernels::matmul_par(pool, &h2, &lw.w_gate, n, d, ff);
                let up = kernels::matmul_par(pool, &h2, &lw.w_up, n, d, ff);
                let mut hmid = gate_pre.clone();
                let grain = (kernels::PAR_CHUNK_FLOPS / 8).max(16);
                pool.run_rows(&mut hmid, 1, grain, |i0, rows| {
                    for (t, o) in rows.iter_mut().enumerate() {
                        *o = kernels::silu(*o) * up[i0 + t];
                    }
                });
                let mlp = kernels::matmul_par(pool, &hmid, &lw.w_down, n, ff, d);
                (gate_pre, up, hmid, mlp)
            });
            for (xv, mv) in x.iter_mut().zip(&mlp) {
                *xv += mv;
            }
            layers.push(LayerActs {
                x_in,
                u,
                g,
                delta,
                qr,
                kr,
                v,
                probs,
                ctx,
                attn_out,
                byp,
                x_mid,
                h2,
                gate_pre,
                up,
                hmid,
            });
        }
        let x_final = x.clone();
        let (xn, logits) = self.timers.unembed.time(|| {
            let xn = kernels::rmsnorm_par(pool, &x, &self.weights.out_norm, RMSNORM_EPS);
            let logits = kernels::matmul_par(pool, &xn, &self.weights.unembed, n, d, vocab);
            (xn, logits)
        });
        SeqActs {
            layers,
            x_final,
            xn,
            logits,
        }
    }

    /// Reverse-mode pass for one sequence, accumulating into `gacc`.
    /// `count` is the batch-wide CE target count; `alpha` the stop-grad
    /// penalty load weights.
    fn backward_acts(
        &self,
        toks: &[i32],
        acts: &SeqActs,
        count: usize,
        alpha: &[f64],
        gacc: &mut ModelWeights,
    ) {
        let cfg = &self.cfg;
        let (d, ff, vocab) = (cfg.d_model, cfg.d_ff, cfg.vocab_size);
        let (heads, hd) = (cfg.n_heads, cfg.head_dim());
        let n = toks.len();
        let b = self.hp.batch;
        let pool = &self.pool;
        let positions: Vec<f32> = (0..n).map(|i| i as f32).collect();

        // CE head + unembed + out_norm.
        let mut dx = self.timers.bwd_unembed.time(|| {
            let dlogits = grads::xent_bwd(pool, &acts.logits, toks, count, n, vocab);
            let dun = grads::matmul_bwd_b(pool, &acts.xn, &dlogits, n, d, vocab);
            grads::axpy(pool, &mut gacc.unembed, &dun);
            grads::matmul_bwd_a(pool, &dlogits, &self.weights.unembed, n, d, vocab)
        });
        {
            let (dx2, dwn) = self.timers.bwd_norm.time(|| {
                grads::rmsnorm_bwd(pool, &acts.x_final, &self.weights.out_norm, &dx, RMSNORM_EPS)
            });
            grads::axpy(pool, &mut gacc.out_norm, &dwn);
            dx = dx2;
        }

        for li in (0..cfg.n_layers).rev() {
            let lw = &self.weights.layers[li];
            let a = &acts.layers[li];
            let is_dtr = lw.kind == LayerKind::Dtr;

            // MLP sublayer: x_out = x_mid + SwiGLU(norm2(x_mid)).
            let (dh2, dwg, dwu, dwd) = self.timers.bwd_mlp.time(|| {
                grads::swiglu_bwd(
                    pool, &a.h2, &lw.w_gate, &lw.w_up, &lw.w_down, &a.gate_pre, &a.up, &a.hmid,
                    &dx, n, d, ff,
                )
            });
            {
                let gl = &mut gacc.layers[li];
                grads::axpy(pool, &mut gl.w_gate, &dwg);
                grads::axpy(pool, &mut gl.w_up, &dwu);
                grads::axpy(pool, &mut gl.w_down, &dwd);
            }
            let (dxm_norm, dn2) = self
                .timers
                .bwd_norm
                .time(|| grads::rmsnorm_bwd(pool, &a.x_mid, &lw.norm2, &dh2, RMSNORM_EPS));
            grads::axpy(pool, &mut gacc.layers[li].norm2, &dn2);
            let mut dx_mid = dx;
            grads::axpy(pool, &mut dx_mid, &dxm_norm);

            // Token-mixing sublayer: x_mid = x_in + mixed.
            // Straight-through select: δ is constant; gradients reach g
            // only through the soft scale of the taken path (+ penalty).
            let mut dg = vec![0.0f32; if is_dtr { n * 2 } else { 0 }];
            let (dctx, dv_byp) = self.timers.bwd_attention.time(|| {
                if is_dtr {
                    let mut dattn = vec![0.0f32; n * d];
                    let mut dbyp = vec![0.0f32; n * d];
                    for i in 0..n {
                        let dm = &dx_mid[i * d..(i + 1) * d];
                        if a.delta[i] > 0.5 {
                            dg[i * 2] = kernels::dot(dm, &a.attn_out[i * d..(i + 1) * d]);
                            let w = a.g[i * 2];
                            for (o, &v) in dattn[i * d..(i + 1) * d].iter_mut().zip(dm) {
                                *o = w * v;
                            }
                        } else {
                            dg[i * 2 + 1] = kernels::dot(dm, &a.byp[i * d..(i + 1) * d]);
                            let w = a.g[i * 2 + 1];
                            for (o, &v) in dbyp[i * d..(i + 1) * d].iter_mut().zip(dm) {
                                *o = w * v;
                            }
                        }
                    }
                    // Eq. 7 penalty: d pen / d g_attn_i = λ·α_l / (B·n).
                    let pgrad = (self.hp.lambda_reg * alpha[li] / (b * n) as f64) as f32;
                    for i in 0..n {
                        dg[i * 2] += pgrad;
                    }
                    let dctx = grads::matmul_bwd_a(pool, &dattn, &lw.wo, n, d, d);
                    let dwo = grads::matmul_bwd_b(pool, &a.ctx, &dattn, n, d, d);
                    grads::axpy(pool, &mut gacc.layers[li].wo, &dwo);
                    let dv_byp = grads::matmul_bwd_a(pool, &dbyp, &lw.wo, n, d, d);
                    let dwo2 = grads::matmul_bwd_b(pool, &a.v, &dbyp, n, d, d);
                    grads::axpy(pool, &mut gacc.layers[li].wo, &dwo2);
                    (dctx, Some(dv_byp))
                } else {
                    let dctx = grads::matmul_bwd_a(pool, &dx_mid, &lw.wo, n, d, d);
                    let dwo = grads::matmul_bwd_b(pool, &a.ctx, &dx_mid, n, d, d);
                    grads::axpy(pool, &mut gacc.layers[li].wo, &dwo);
                    (dctx, None)
                }
            });

            // Attention → RoPE → projections.
            let du = self.timers.bwd_attention.time(|| {
                let (dqr, dkr, mut dv) = grads::routed_attention_bwd(
                    pool, &a.qr, &a.kr, &a.v, &a.probs, &dctx, n, heads, hd,
                );
                if let Some(dvb) = &dv_byp {
                    grads::axpy(pool, &mut dv, dvb);
                }
                let dq = grads::rope_bwd(pool, &dqr, &positions, n, heads, hd, ROPE_THETA);
                let dk = grads::rope_bwd(pool, &dkr, &positions, n, heads, hd, ROPE_THETA);
                let gl = &mut gacc.layers[li];
                let dwq = grads::matmul_bwd_b(pool, &a.u, &dq, n, d, d);
                grads::axpy(pool, &mut gl.wq, &dwq);
                let dwk = grads::matmul_bwd_b(pool, &a.u, &dk, n, d, d);
                grads::axpy(pool, &mut gl.wk, &dwk);
                let dwv = grads::matmul_bwd_b(pool, &a.u, &dv, n, d, d);
                grads::axpy(pool, &mut gl.wv, &dwv);
                let mut du = grads::matmul_bwd_a(pool, &dq, &lw.wq, n, d, d);
                let du_k = grads::matmul_bwd_a(pool, &dk, &lw.wk, n, d, d);
                grads::axpy(pool, &mut du, &du_k);
                let du_v = grads::matmul_bwd_a(pool, &dv, &lw.wv, n, d, d);
                grads::axpy(pool, &mut du, &du_v);
                du
            });
            let mut du = du;
            if is_dtr {
                let (du_r, dr1, dr2) = self.timers.bwd_router.time(|| {
                    grads::router_bwd(pool, &a.u, &lw.r_w1, &lw.r_w2, &a.g, &dg, n, d, d / 2)
                });
                grads::axpy(pool, &mut du, &du_r);
                let gl = &mut gacc.layers[li];
                grads::axpy(pool, &mut gl.r_w1, &dr1);
                grads::axpy(pool, &mut gl.r_w2, &dr2);
            }
            let (dx_norm, dn1) = self
                .timers
                .bwd_norm
                .time(|| grads::rmsnorm_bwd(pool, &a.x_in, &lw.norm1, &du, RMSNORM_EPS));
            grads::axpy(pool, &mut gacc.layers[li].norm1, &dn1);
            dx = dx_mid;
            grads::axpy(pool, &mut dx, &dx_norm);
        }

        self.timers
            .bwd_unembed
            .time(|| grads::embedding_bwd(&mut gacc.tok_embed, toks, &dx, d));
    }
}

impl TrainBackend for CpuTrainer {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    fn batch(&self) -> usize {
        self.hp.batch
    }

    fn seq(&self) -> usize {
        self.hp.seq
    }

    fn train_step(
        &mut self,
        tokens: &[i32],
        step: usize,
        lr: f64,
        _seed: u64,
    ) -> Result<TrainMetrics> {
        ensure!(step >= 1, "step is 1-based (Adam bias correction)");
        let (loss, ce, pen, gacc, attn_frac) = self.loss_grads_full(tokens)?;

        self.timers.optimizer.time(|| {
            // Pre-clip global norm (serial f64 — part of the determinism
            // contract), then the train.py clip-and-AdamW update.
            let gn = {
                let mut ss = 0.0f64;
                for (t, _) in gacc.tensors() {
                    for &x in t.iter() {
                        ss += x as f64 * x as f64;
                    }
                }
                ss.sqrt()
            };
            let scale = (self.hp.grad_clip / (gn + 1e-12)).min(1.0) as f32;
            let b1 = self.hp.beta1 as f32;
            let b2 = self.hp.beta2 as f32;
            let eps = self.hp.adam_eps as f32;
            let wd = self.hp.weight_decay as f32;
            let lrf = lr as f32;
            let b1c = 1.0 - b1.powi(step as i32);
            let b2c = 1.0 - b2.powi(step as i32);
            let pool = self.pool.clone();
            let grain = (kernels::PAR_CHUNK_FLOPS / 8).max(64);
            let pts = self.weights.tensors_mut();
            let mts = self.opt_m.tensors_mut();
            let vts = self.opt_v.tensors_mut();
            let gts = gacc.tensors();
            for ((pw, mw), (vw, gw)) in
                pts.into_iter().zip(mts).zip(vts.into_iter().zip(gts))
            {
                let (p, is_mat) = pw;
                let (m, _) = mw;
                let (v, _) = vw;
                let (g, _) = gw;
                // m ← β1·m + (1−β1)·g̃ ;  v ← β2·v + (1−β2)·g̃²
                pool.run_rows(m, 1, grain, |i0, rows| {
                    for (t, mv) in rows.iter_mut().enumerate() {
                        *mv = b1 * *mv + (1.0 - b1) * (g[i0 + t] * scale);
                    }
                });
                pool.run_rows(v, 1, grain, |i0, rows| {
                    for (t, vv) in rows.iter_mut().enumerate() {
                        let gs = g[i0 + t] * scale;
                        *vv = b2 * *vv + (1.0 - b2) * gs * gs;
                    }
                });
                let wdp = if is_mat { wd } else { 0.0 };
                let m_ro: &[f32] = m;
                let v_ro: &[f32] = v;
                pool.run_rows(p, 1, grain, |i0, rows| {
                    for (t, pv) in rows.iter_mut().enumerate() {
                        let mhat = m_ro[i0 + t] / b1c;
                        let vhat = v_ro[i0 + t] / b2c;
                        let p0 = *pv;
                        *pv = p0 - lrf * (mhat / (vhat.sqrt() + eps) + wdp * p0);
                    }
                });
            }
            Ok(TrainMetrics {
                loss,
                ce,
                penalty: pen,
                grad_norm: gn,
                attn_frac,
            })
        })
    }

    fn to_checkpoint(&self) -> Result<Checkpoint> {
        Ok(weights_to_checkpoint(&self.cfg, &self.weights))
    }

    fn kernel_timings(&self) -> Option<Json> {
        Some(self.timers.snapshot_with_ctx(self.pool.kernel_ctx()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Backend;
    use crate::runtime::Tensor;

    fn tiny_cfg() -> (ModelConfig, TrainConfig) {
        let cfg = ModelConfig::preset("xs", Variant::DtrBilayer);
        let hp = TrainConfig {
            steps: 4,
            batch: 2,
            seq: 12,
            ..Default::default()
        };
        (cfg, hp)
    }

    fn toks(hp: &TrainConfig, vocab: usize, seed: u64) -> Vec<i32> {
        let mut rng = crate::util::rng::Rng::new(seed);
        (0..hp.batch * hp.seq).map(|_| rng.below(vocab as u64) as i32).collect()
    }

    #[test]
    fn train_step_runs_and_reports_finite_metrics() {
        let (cfg, hp) = tiny_cfg();
        let mut tr = CpuTrainer::new(&cfg, &hp).unwrap();
        let tokens = toks(&hp, cfg.vocab_size, 1);
        let m = tr.train_step(&tokens, 1, 1e-3, 0).unwrap();
        assert!(m.loss.is_finite() && m.ce.is_finite() && m.penalty.is_finite());
        assert!(m.grad_norm > 0.0);
        assert_eq!(m.attn_frac.len(), cfg.n_layers);
        // dense layers (first/last) always route everything
        assert_eq!(m.attn_frac[0], 1.0);
        assert_eq!(m.attn_frac[cfg.n_layers - 1], 1.0);
    }

    #[test]
    fn rejects_wrong_token_count_and_bad_tokens() {
        let (cfg, hp) = tiny_cfg();
        let mut tr = CpuTrainer::new(&cfg, &hp).unwrap();
        assert!(tr.train_step(&[1, 2, 3], 1, 1e-3, 0).is_err());
        let mut tokens = toks(&hp, cfg.vocab_size, 1);
        tokens[0] = cfg.vocab_size as i32;
        assert!(tr.train_step(&tokens, 1, 1e-3, 0).is_err());
    }

    #[test]
    fn trainer_init_matches_backend_init_bits() {
        // Training continues exactly what demo/serve would start from.
        let (cfg, hp) = tiny_cfg();
        let tr = CpuTrainer::new(&cfg, &hp).unwrap();
        let be = CpuBackend::init(&cfg, hp.seed).unwrap();
        let tokens = Tensor::i32(vec![1, 8], (0..8).collect());
        let a = tr.to_backend().unwrap().forward(&tokens).unwrap();
        let b = be.forward(&tokens).unwrap();
        assert_eq!(a.logits, b.logits);
    }

    #[test]
    fn short_training_reduces_loss() {
        let (cfg, mut hp) = tiny_cfg();
        hp.seq = 24;
        hp.steps = 12;
        let mut tr = CpuTrainer::new(&cfg, &hp).unwrap();
        let tokens = toks(&hp, cfg.vocab_size, 3);
        // repeated steps on one batch must drive its loss down
        let first = tr.train_step(&tokens, 1, 3e-3, 0).unwrap().loss;
        let mut last = first;
        for s in 2..=hp.steps {
            last = tr.train_step(&tokens, s, 3e-3, 0).unwrap().loss;
        }
        assert!(
            last < first,
            "loss did not decrease: first {first:.4} last {last:.4}"
        );
    }

    #[test]
    fn checkpoint_roundtrips_into_serving_backend() {
        let (cfg, hp) = tiny_cfg();
        let mut tr = CpuTrainer::new(&cfg, &hp).unwrap();
        let tokens = toks(&hp, cfg.vocab_size, 5);
        tr.train_step(&tokens, 1, 1e-3, 0).unwrap();
        let ck = TrainBackend::to_checkpoint(&tr).unwrap();
        let re = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        let be = CpuBackend::from_checkpoint(&cfg, &re).unwrap();
        let probe = Tensor::i32(vec![1, 6], vec![1, 2, 3, 4, 5, 6]);
        let direct = tr.to_backend().unwrap().forward(&probe).unwrap();
        let loaded = be.forward(&probe).unwrap();
        assert_eq!(direct.logits, loaded.logits, "checkpoint changed the weights");
    }
}
