//! Checkpointing: persist/restore parameter sets (and Adam state) to disk.
//!
//! Format ("DTCK" v1, little-endian): a self-describing binary container —
//!   magic "DTCK" · u32 version · u32 tensor count ·
//!   per tensor: u32 name_len · name bytes · u8 dtype (0=f32, 1=i32) ·
//!               u32 rank · u64 dims[rank] · raw data
//! plus a trailing u64 FNV-1a checksum over everything before it.
//!
//! This gives the coordinator real train → serve handoff across processes
//! (`dtrnet train --save ckpt.dtck`, `dtrnet serve --load ckpt.dtck`)
//! without any external serialization crates.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::{Data, Tensor};

const MAGIC: &[u8; 4] = b"DTCK";
const VERSION: u32 = 1;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A named tensor collection (parameters, optimizer state, …).
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    /// Named tensors in insertion order (the flatten_params order).
    pub entries: Vec<(String, Tensor)>,
}

impl Checkpoint {
    /// An empty checkpoint.
    pub fn new() -> Checkpoint {
        Checkpoint::default()
    }

    /// Append a named tensor.
    pub fn push(&mut self, name: impl Into<String>, t: Tensor) {
        self.entries.push((name.into(), t));
    }

    /// Look up a tensor by name.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    /// Serialize to bytes (see module docs for the layout).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, t) in &self.entries {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            match &t.data {
                Data::F32(_) => out.push(0u8),
                Data::I32(_) => out.push(1u8),
            }
            out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
            for &d in &t.shape {
                out.extend_from_slice(&(d as u64).to_le_bytes());
            }
            match &t.data {
                Data::F32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                Data::I32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse the DTCK container format.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < 20 {
            bail!("checkpoint too short");
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(tail.try_into().unwrap());
        let got = fnv1a(body);
        if want != got {
            bail!("checkpoint checksum mismatch (corrupt file?)");
        }
        let mut p = body;
        let mut take = |n: usize| -> Result<&[u8]> {
            if p.len() < n {
                bail!("truncated checkpoint");
            }
            let (a, b) = p.split_at(n);
            p = b;
            Ok(a)
        };
        if take(4)? != MAGIC {
            bail!("bad magic (not a DTCK checkpoint)");
        }
        let version = u32::from_le_bytes(take(4)?.try_into().unwrap());
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let mut entries = Vec::with_capacity(count);
        for _ in 0..count {
            let nlen = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
            let name = std::str::from_utf8(take(nlen)?)
                .context("bad tensor name")?
                .to_string();
            let dtype = take(1)?[0];
            let rank = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize);
            }
            let n: usize = shape.iter().product();
            let t = match dtype {
                0 => {
                    let raw = take(n * 4)?;
                    let v: Vec<f32> = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Tensor::f32(shape, v)
                }
                1 => {
                    let raw = take(n * 4)?;
                    let v: Vec<i32> = raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Tensor::i32(shape, v)
                }
                other => bail!("unknown dtype tag {other}"),
            };
            entries.push((name, t));
        }
        Ok(Checkpoint { entries })
    }

    /// Write the DTCK container to `path` (parent dirs created).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    /// Read a DTCK container from `path`.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?
            .read_to_end(&mut bytes)?;
        Checkpoint::from_bytes(&bytes)
    }

    /// Build from parameter literals + the manifest layout (names/shapes
    /// validated against the manifest contract).
    #[cfg(feature = "pjrt")]
    pub fn from_literals(
        names: &[super::manifest::ParamSpec],
        literals: &[xla::Literal],
    ) -> Result<Checkpoint> {
        anyhow::ensure!(names.len() == literals.len(), "layout/literal arity mismatch");
        let mut ck = Checkpoint::new();
        for (spec, lit) in names.iter().zip(literals) {
            let t = Tensor::from_literal(lit)?;
            anyhow::ensure!(
                t.shape == spec.shape,
                "{}: shape {:?} != manifest {:?}",
                spec.path,
                t.shape,
                spec.shape
            );
            ck.push(spec.path.clone(), t);
        }
        Ok(ck)
    }

    /// Convert back to literals in manifest order (errors on missing/extra).
    #[cfg(feature = "pjrt")]
    pub fn to_literals(&self, names: &[super::manifest::ParamSpec]) -> Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            self.entries.len() == names.len(),
            "checkpoint has {} tensors, manifest wants {}",
            self.entries.len(),
            names.len()
        );
        names
            .iter()
            .map(|spec| {
                let t = self
                    .get(&spec.path)
                    .with_context(|| format!("checkpoint missing {}", spec.path))?;
                anyhow::ensure!(t.shape == spec.shape, "{}: shape mismatch", spec.path);
                t.to_literal()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.push("a", Tensor::f32(vec![2, 3], vec![1., -2., 3.5, 0., 1e-9, 7.]));
        ck.push("b.c", Tensor::i32(vec![4], vec![1, -2, 3, 4]));
        ck.push("scalar", Tensor::scalar_f32(42.0));
        ck
    }

    #[test]
    fn roundtrip_bytes() {
        let ck = sample();
        let re = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(ck.entries.len(), re.entries.len());
        for ((n1, t1), (n2, t2)) in ck.entries.iter().zip(&re.entries) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
    }

    #[test]
    fn roundtrip_file() {
        let dir = std::env::temp_dir().join("dtrnet_ck_test");
        let path = dir.join("x.dtck");
        let ck = sample();
        ck.save(&path).unwrap();
        let re = Checkpoint::load(&path).unwrap();
        assert_eq!(re.get("a").unwrap(), ck.get("a").unwrap());
    }

    #[test]
    fn detects_corruption() {
        let mut bytes = sample().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }
}
